"""The peer-to-peer data plane (ISSUE 10): 8-OS-process collectives checked
bit-exact against a ``lax.psum`` oracle, lazy-dial connection caching,
scatter-gather frame roundtrips for every wire-type code, and SIGKILL
death detection with no router in the path (the victim *is* the
rendezvous rank)."""
from __future__ import annotations

import time

import numpy as np
import pytest

from repro.core import SocketTransport, SpSerializer, register_wire_type
from repro.core.comm import (
    _SEGMENT_MIN_BYTES,
    SpDeserializer,
    decode_message,
    encode_segments,
)
from repro.launch.rendezvous import (
    _det_grad,
    run_collective,
    run_elastic_ring,
)

# 8 rank processes timeshare the CI container's core; raise the per-test cap.
pytestmark = pytest.mark.timeout(300)


def _psum_oracle(size: int, n: int) -> np.ndarray:
    """The all-reduce ground truth from jax itself: vmap over a stacked
    per-rank axis, ``lax.psum`` across it.  Inputs are integer-valued
    float32 (< 2**24), so any honest sum matches bit-for-bit regardless
    of accumulation order."""
    import jax

    stacked = np.stack([_det_grad(r, 0, n) for r in range(size)])
    out = jax.vmap(lambda x: jax.lax.psum(x, "i"), axis_name="i")(stacked)
    return np.asarray(out[0])


# ---------------------------------------------------------------------------
# 8 OS processes, direct peer links, vs the lax.psum oracle
# ---------------------------------------------------------------------------

def test_eight_process_ring_all_reduce_chunked_vs_psum_oracle():
    """8 spawned ranks ring-all-reduce float32[4099] with chunk pipelining
    (2 KiB pieces: many in-flight piece frames per ring step) over direct
    TCP links; every rank must match the psum oracle bit-for-bit, and the
    transport stats must show a full mesh of direct links with rank 0
    carrying no relay traffic."""
    size, n = 8, 4099
    expected = _psum_oracle(size, n)
    results = run_collective(size, n, kind="ring", chunk_bytes=2048)
    assert set(results) == set(range(size))
    for rank, rep in results.items():
        got = np.asarray(rep["value"])
        assert got.dtype == np.float32
        np.testing.assert_array_equal(got, expected)
        st = rep["stats"]
        assert st["boxes"] == 0 and st["queued"] == 0
        assert st["received"] == st["delivered"] > 0
    # ring traffic dials at most the two neighbours per rank — a star would
    # concentrate 2*(size-1) relayed links on rank 0
    assert results[0]["stats"]["links"] <= 4


def test_eight_process_hierarchical_all_reduce_vs_psum_oracle():
    """8 spawned ranks, two pods of 4: intra-pod reduce-scatter, inter-pod
    ring over pod leaders-per-chunk, intra-pod all-gather.  Same oracle,
    same bit-exactness bar as the flat ring."""
    size, n = 8, 4099
    expected = _psum_oracle(size, n)
    results = run_collective(size, n, kind="hier", pod_size=4)
    assert set(results) == set(range(size))
    for rank, rep in results.items():
        got = np.asarray(rep["value"])
        assert got.dtype == np.float32
        np.testing.assert_array_equal(got, expected)
        assert rep["stats"]["boxes"] == 0


# ---------------------------------------------------------------------------
# lazy dial + connection cache
# ---------------------------------------------------------------------------

def _drain(tr, key, timeout=10.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        ok, msg = tr.poll(key)
        if ok:
            return msg
        time.sleep(0.002)
    raise TimeoutError(f"nothing arrived for {key}")


def test_lazy_dial_and_connection_cache():
    """Links are dialed on first use and cached: repeat posts to the same
    peer reuse the link, a reply reuses the *accepted* link (no dial on
    the receiver's side), and self-posts never touch the wire."""
    t0 = SocketTransport(0, 3)
    t1 = SocketTransport(1, 3, port=t0.port)
    t2 = SocketTransport(2, 3, port=t0.port)
    try:
        # rendezvous done, no data sent: nobody has dialed a peer link yet
        assert t0.stats()["dials"] == 0
        assert t1.stats()["dials"] == 0

        t0.post((0, 0, "self"), 42)  # self-delivery: local mailbox, no link
        assert _drain(t0, (0, 0, "self")) == 42
        assert t0.stats()["dials"] == 0 and t0.stats()["links"] == 0

        t0.post((0, 1, "a"), 1)  # first frame to rank 1: dial once
        assert _drain(t1, (0, 1, "a")) == 1
        assert t0.stats()["dials"] == 1

        for i in range(5):  # cached link: no further dials
            t0.post((0, 1, ("a", i)), i)
        for i in range(5):
            assert _drain(t1, (0, 1, ("a", i))) == i
        assert t0.stats()["dials"] == 1

        # the reply direction reuses the accepted link: rank 1 never dials
        t1.post((1, 0, "b"), 2)
        assert _drain(t0, (1, 0, "b")) == 2
        assert t1.stats()["dials"] == 0 and t1.stats()["links"] >= 1

        t0.post((0, 2, "c"), 3)  # a second peer: exactly one more dial
        assert _drain(t2, (0, 2, "c")) == 3
        assert t0.stats()["dials"] == 2 and t0.stats()["links"] >= 2
    finally:
        t2.close()
        t1.close()
        t0.close()


# ---------------------------------------------------------------------------
# scatter-gather frames: every wire-type code roundtrips
# ---------------------------------------------------------------------------

class _Grid:
    """``sp_serialize`` path (code ``O``)."""

    def __init__(self, values):
        self.values = values

    def sp_serialize(self, s: SpSerializer) -> None:
        s.append_array(self.values)

    @classmethod
    def sp_deserialize(cls, d: SpDeserializer) -> "_Grid":
        return cls(d.next_array())


class _Blob:
    """``comm_buffer`` path (code ``C``)."""

    def __init__(self, raw: bytes):
        self.raw = raw

    def comm_buffer(self) -> bytes:
        return self.raw

    @classmethod
    def from_comm_buffer(cls, buf: bytes) -> "_Blob":
        return cls(bytes(buf))


register_wire_type(_Grid)
register_wire_type(_Blob)


def _wire_values():
    """One value per type code: N b I J F S B T L D A O C."""
    big = np.arange(1024, dtype=np.float32)  # 4 KiB: a memoryview segment
    return {
        "N": None,
        "b": True,
        "I": -(1 << 62),
        "J": 1 << 80,  # beyond int64: the decimal-string encoding
        "F": 2.5,
        "S": "naïve ∑",  # non-ascii: utf-8 length, not char count
        "B": b"\x00\xff raw",
        "T": (1, "two", None),
        "L": [False, 3.0, b"x"],
        "D": {"k": (1, 2), 7: "v"},
        "A": big,
        "O": _Grid(np.full((3, 5), 9.0)),
        "C": _Blob(b"opaque-bytes"),
    }


def _assert_same(got, want):
    if isinstance(want, np.ndarray):
        assert got.dtype == want.dtype and np.array_equal(got, want)
    elif isinstance(want, _Grid):
        assert isinstance(got, _Grid)
        np.testing.assert_array_equal(got.values, want.values)
    elif isinstance(want, _Blob):
        assert isinstance(got, _Blob) and got.raw == want.raw
    elif isinstance(want, tuple):
        assert isinstance(got, tuple) and len(got) == len(want)
        for g, w in zip(got, want):
            _assert_same(g, w)
    elif isinstance(want, list):
        assert isinstance(got, list) and len(got) == len(want)
        for g, w in zip(got, want):
            _assert_same(g, w)
    elif isinstance(want, dict):
        assert isinstance(got, dict) and set(got) == set(want)
        for k in want:
            _assert_same(got[k], want[k])
    else:
        assert type(got) is type(want) and got == want


@pytest.mark.parametrize("code", sorted(_wire_values()))
def test_scatter_gather_roundtrip_per_wire_type(code):
    """encode_segments → join → decode_message is the identity for every
    type code the codec speaks, and the segment total matches the joined
    length (what ``sendmsg`` is told equals what hits the wire)."""
    value = _wire_values()[code]
    segs, nbytes = encode_segments(value)
    joined = b"".join(bytes(s) for s in segs)
    assert len(joined) == nbytes
    _assert_same(decode_message(joined), value)


def test_large_arrays_travel_as_zero_copy_segments():
    """An array at/above the segment threshold must appear in the segment
    list as a memoryview of the *source* buffer — the sendmsg path never
    copies the payload — while a sub-threshold array is a plain bytes
    chunk (one iovec beats a tiny zero-copy view)."""
    big = np.arange(_SEGMENT_MIN_BYTES // 4, dtype=np.float32)
    segs, _ = encode_segments({"g": big})
    views = [s for s in segs if isinstance(s, memoryview)]
    assert len(views) == 1
    assert np.shares_memory(np.frombuffer(views[0], dtype=np.float32), big)

    small = np.arange(4, dtype=np.float32)
    segs_small, _ = encode_segments({"g": small})
    assert all(isinstance(s, bytes) for s in segs_small)


def test_decode_from_writable_buffer_is_zero_copy():
    """Decoding from a writable buffer (the p2p receive path hands each
    frame a private bytearray/np buffer) must alias the frame for large
    arrays — no copy — and the result must be writable in place.
    Immutable ``bytes`` input still gets a private copy."""
    big = np.arange(2048, dtype=np.float32)
    frame = bytearray(b"".join(bytes(s) for s in encode_segments(big)[0]))
    out = decode_message(frame)
    assert out.flags.writeable
    assert np.shares_memory(out, np.frombuffer(frame, dtype=np.uint8))

    out_copy = decode_message(bytes(frame))
    assert out_copy.flags.writeable  # consumers may mutate either way
    assert not np.shares_memory(
        out_copy, np.frombuffer(bytes(frame), dtype=np.uint8)
    )
    np.testing.assert_array_equal(out, big)
    np.testing.assert_array_equal(out_copy, big)


# ---------------------------------------------------------------------------
# SIGKILL the rendezvous rank: detection over direct links only
# ---------------------------------------------------------------------------

def test_sigkill_rank0_detected_without_router():
    """SIGKILL rank 0 — the rendezvous rank itself — mid-collective.  On
    the star this was fatal (the router died with it); on the p2p plane
    the address book is already distributed and the survivors detect the
    death over their *direct* links, re-mesh, and finish bit-exact."""
    n, steps = 257, 4
    results, info = run_elastic_ring(
        size=3, n=n, steps=steps, fail_at=2, victim=0
    )
    assert info["victim"] == 0
    assert set(results) == {1, 2}

    bases = [
        np.random.default_rng(r).standard_normal(n).astype(np.float32)
        for r in range(3)
    ]
    full = bases[0] + bases[1] + bases[2]
    surviving = bases[1] + bases[2]

    resumes = {rep["resume_step"] for rep in results.values()}
    assert len(resumes) == 1, f"survivors disagree on the resume step: {resumes}"
    resume = resumes.pop()
    assert resume is not None and 0 <= resume < steps

    for rank, rep in results.items():
        assert rep["dead"] == [0]
        assert rep["members"] == [1, 2]
        # detection is peer-observed (heartbeat staleness / link EOF on a
        # direct link), not a router relaying a death notice
        latency = rep["detect_at"] - info["t_kill"]
        assert -0.05 < latency < 5.0, f"rank {rank}: detection took {latency}s"
        assert sorted(rep["steps"]) == list(range(steps))
        for step, arr in rep["steps"].items():
            if step < resume:  # full-mesh steps: 3-way sums, order-dependent
                np.testing.assert_allclose(arr, full, rtol=1e-5, atol=1e-6)
            else:  # shrunken mesh: 2-way float32 sums are bit-exact
                np.testing.assert_array_equal(arr, surviving)
    for step in results[1]["steps"]:
        np.testing.assert_array_equal(
            results[1]["steps"][step], results[2]["steps"][step]
        )

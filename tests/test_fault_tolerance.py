"""Fault tolerance end to end (ISSUE 6): typed comm-error hierarchy,
dead-rank failure detection (EOF + heartbeat), deterministic fault
injection, bounded retry with escalation, shrunken-ring collectives,
rendezvous re-roll, and the SIGKILL acceptance run — a real OS process
killed mid-``ring_all_reduce`` while the survivors finish."""
from __future__ import annotations

import socket
import time
import warnings

import numpy as np
import pytest

from repro.core import (
    ChannelHub,
    SocketTransport,
    SpCommAbortedError,
    SpCommError,
    SpCommGroup,
    SpCommTimeoutError,
    SpCommTransientError,
    SpComputeEngine,
    SpData,
    SpRankDeadError,
    SpRead,
    SpTaskGraph,
    SpWorkerTeamBuilder,
    SpWrite,
    mpi_broadcast,
    mpi_recv,
    mpi_send,
)
from repro.dist.collectives import ring_all_reduce
from repro.dist.fault import (
    FailureSimulator,
    FaultyTransport,
    RetryingTransport,
    remesh_plan,
)
from repro.launch.rendezvous import reroll_ranks, run_elastic_ring

# The SIGKILL acceptance test spawns real OS ranks; raise the CI per-test cap.
pytestmark = pytest.mark.timeout(180)


@pytest.fixture()
def engine():
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(4))
    yield eng
    eng.stop()


# ---------------------------------------------------------------------------
# the consolidated error hierarchy: one base to catch them all
# ---------------------------------------------------------------------------

def test_every_comm_error_derives_from_sp_comm_error():
    for exc_type in (
        SpCommTimeoutError,
        SpCommAbortedError,
        SpRankDeadError,
        SpCommTransientError,
    ):
        assert issubclass(exc_type, SpCommError), exc_type
        assert isinstance(exc_type("x"), SpCommError)
    # and the failure paths raise from it: dead-rank post...
    hub = ChannelHub()
    hub.mark_dead(1)
    with pytest.raises(SpCommError):
        hub.post((0, 1, "t"), 1)
    # ...dead-rank poll...
    with pytest.raises(SpCommError):
        hub.poll((1, 0, "t"))
    # ...and injected transients
    ft = FaultyTransport(ChannelHub(), seed=0, flaky={1: 1})
    with pytest.raises(SpCommError):
        ft.post((0, 1, "t"), 1)


# ---------------------------------------------------------------------------
# dead-rank semantics on the mailbox layer
# ---------------------------------------------------------------------------

def test_dead_rank_post_and_poll_raise():
    hub = ChannelHub()
    hub.mark_dead(2)
    assert hub.is_dead(2) and 2 in hub.dead_ranks
    assert hub.death_detected_at(2) is not None
    with pytest.raises(SpRankDeadError, match="rank 2"):
        hub.post((0, 2, "x"), 1)
    with pytest.raises(SpRankDeadError, match="rank 2"):
        hub.poll((2, 0, "x"))


def test_dead_rank_queued_messages_still_drain():
    """Messages a rank posted before dying stay deliverable; only an empty
    mailbox fails fast."""
    hub = ChannelHub()
    hub.post((2, 0, "x"), "last words")
    hub.mark_dead(2)
    ok, msg = hub.poll((2, 0, "x"))
    assert ok and msg == "last words"
    with pytest.raises(SpRankDeadError):
        hub.poll((2, 0, "x"))


def test_mark_dead_is_idempotent_and_reset_clears():
    hub = ChannelHub()
    hub.mark_dead(1)
    stamp = hub.death_detected_at(1)
    time.sleep(0.01)
    hub.mark_dead(1)
    assert hub.death_detected_at(1) == stamp  # first stamp sticks
    hub.reset()
    assert hub.dead_ranks == frozenset()


def test_pending_recv_fails_fast_and_cancels_dependents(engine):
    """A recv already in flight when the source dies must fail with
    SpRankDeadError on the next comm tick — not wait out its timeout —
    and its dependents must cancel transitively."""
    hub = ChannelHub()
    g1 = SpCommGroup(1, 2, hub)
    tg = SpTaskGraph().compute_on(engine)
    r, out = SpData(None, "r"), SpData("untouched", "out")
    # generous timeout: if death were NOT detected, this test would hang
    # far past its deadline — failing fast is the point
    view = mpi_recv(tg, g1, r, src=0, tag="dead", timeout=60.0)
    dep = tg.task(SpRead(r), SpWrite(out),
                  lambda v, ref: setattr(ref, "value", v))
    deadline = time.monotonic() + 5.0
    while engine._comm is None and time.monotonic() < deadline:
        time.sleep(0.005)
    hub.mark_dead(0)
    exc = view.exception(timeout=5.0)
    assert isinstance(exc, SpRankDeadError)
    assert "src=0" in str(exc)
    tg.wait_all_tasks(timeout=5.0)
    assert dep.state == "cancelled"
    assert out.value == "untouched"


def test_future_requests_to_dead_rank_fail_immediately(engine):
    hub = ChannelHub()
    hub.mark_dead(0)
    g1 = SpCommGroup(1, 2, hub)
    tg = SpTaskGraph().compute_on(engine)
    r = SpData(None, "r")
    view = mpi_recv(tg, g1, r, src=0, tag="late", timeout=60.0)
    assert isinstance(view.exception(timeout=5.0), SpRankDeadError)
    tg.wait_all_tasks(timeout=5.0)
    # sends too
    tg2 = SpTaskGraph().compute_on(engine)
    m = SpData(1, "m")
    view2 = mpi_send(tg2, SpCommGroup(1, 2, hub), m, dest=0, tag="s")
    assert isinstance(view2.exception(timeout=5.0), SpRankDeadError)
    tg2.wait_all_tasks(timeout=5.0)


# ---------------------------------------------------------------------------
# fault injection: deterministic schedules, dedup, retry, escalation
# ---------------------------------------------------------------------------

def _fault_schedule(seed: int, n: int = 60):
    ft = FaultyTransport(
        ChannelHub(), seed=seed,
        drop=0.3, duplicate=0.2, delay=0.1, truncate=0.1, delay_s=0.001,
    )
    outcomes = []
    for i in range(n):
        try:
            ft.post((0, 1, i), i)
            outcomes.append("ok")
        except SpCommTransientError:
            outcomes.append("transient")
    return outcomes, dict(ft.injected)


def test_faulty_transport_schedule_is_deterministic():
    o1, c1 = _fault_schedule(42)
    o2, c2 = _fault_schedule(42)
    o3, _ = _fault_schedule(43)
    assert o1 == o2 and c1 == c2
    assert o3 != o1  # a different seed injects a different schedule
    assert c1["dropped"] > 0 and c1["truncated"] > 0  # faults actually fired


def test_faulty_transport_dedups_duplicates_and_discards_corrupt():
    hub = ChannelHub()
    ft = FaultyTransport(hub, seed=7, duplicate=1.0)  # every post doubled
    for i in range(10):
        ft.post((0, 1, i), i)
    for i in range(10):
        ok, msg = ft.poll((0, 1, i))
        assert ok and msg == i
        ok, _ = ft.poll((0, 1, i))  # the duplicate is filtered, not delivered
        assert not ok
    assert ft.injected["duplicated"] == 10
    assert ft.injected["deduped"] == 10


def test_retrying_transport_absorbs_transients():
    hub = ChannelHub()
    ft = FaultyTransport(hub, seed=1, drop=0.4, delay_s=0.001)
    rt = RetryingTransport(ft, max_retries=25, backoff=0.0002)
    for i in range(30):
        rt.post((0, 1, i), {"v": i})
    for i in range(30):
        ok, msg = ft.poll((0, 1, i))
        assert ok and msg["v"] == i
    assert rt.retries > 0  # drops actually happened and were retried
    assert rt.escalations == 0


def test_retrying_transport_flaky_rank_recovers():
    ft = FaultyTransport(ChannelHub(), seed=0, flaky={1: 3})
    rt = RetryingTransport(ft, max_retries=5, backoff=0.0001)
    rt.post((0, 1, "a"), 1)  # 3 injected failures, then the rank recovers
    assert rt.retries == 3
    ok, msg = ft.poll((0, 1, "a"))
    assert ok and msg == 1


def test_retry_budget_exhaustion_escalates_to_rank_dead():
    hub = ChannelHub()
    ft = FaultyTransport(hub, seed=0, flaky={2: 100})
    rt = RetryingTransport(ft, max_retries=3, backoff=0.0001)
    with pytest.raises(SpRankDeadError, match="rank 2"):
        rt.post((0, 2, "x"), 1)
    assert rt.escalations == 1
    assert hub.is_dead(2)  # escalation is recorded on the inner transport
    with pytest.raises(SpRankDeadError):  # and sticks for future posts
        rt.post((0, 2, "y"), 1)


def test_faulty_kill_plan_marks_rank_dead():
    ft = FaultyTransport(ChannelHub(), seed=0, kill_plan={2: 5})
    ft.post((0, 1, 0), 0)
    ft.post((0, 1, 1), 1)
    with pytest.raises(SpRankDeadError):
        ft.post((0, 5, 2), 2)  # post ordinal 2 kills rank 5 first
    assert ft.is_dead(5)


def test_ring_all_reduce_survives_injected_faults(engine):
    """The full stack: ring all-reduce over Retrying(Faulty(hub)) with
    drops and duplicates — the numerics must come out exact."""
    size = 3
    hub = ChannelHub()
    rng = np.random.default_rng(3)
    arrays = [rng.standard_normal(17).astype(np.float32) for _ in range(size)]
    transports = [
        RetryingTransport(
            FaultyTransport(hub, seed=r, drop=0.15, duplicate=0.15,
                            delay=0.1, delay_s=0.001),
            max_retries=30, backoff=0.0002,
        )
        for r in range(size)
    ]
    groups = [
        SpCommGroup(r, size, transports[r], default_timeout=60.0)
        for r in range(size)
    ]
    graphs = [SpTaskGraph().compute_on(engine) for _ in range(size)]
    cells = [SpData(arrays[r].copy(), f"f{r}") for r in range(size)]
    for r in range(size):
        ring_all_reduce(graphs[r], groups[r], cells[r], op="sum")
    for g in graphs:
        g.wait_all_tasks(timeout=120.0)
    expected = np.sum(np.stack(arrays).astype(np.float64), axis=0)
    for r in range(size):
        np.testing.assert_allclose(cells[r].value, expected, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# socket-transport failure detection: EOF and heartbeat
# ---------------------------------------------------------------------------

def test_socket_eof_death_detection_and_survivor_traffic():
    """An abrupt hangup without the goodbye frame (what a SIGKILL looks
    like on the wire) is declared dead by the router and broadcast to every
    survivor — in milliseconds, not after any timeout — while surviving
    pairs keep exchanging frames."""
    t0 = SocketTransport(0, 3)
    t1 = SocketTransport(1, 3, port=t0.port)
    t2 = SocketTransport(2, 3, port=t0.port)
    try:
        t2._hb_stop.set()
        with pytest.warns(RuntimeWarning, match="dead"):
            t2._sock.shutdown(socket.SHUT_RDWR)  # FIN without a bye
            gone_t = time.monotonic()
            deadline = gone_t + 5.0
            while not (t0.is_dead(2) and t1.is_dead(2)):
                assert time.monotonic() < deadline, "death never detected"
                time.sleep(0.002)
        assert t0.death_detected_at(2) is not None
        with pytest.raises(SpRankDeadError):
            t0.poll((2, 0, "never"))
        with pytest.raises(SpRankDeadError):
            t1.post((1, 2, "x"), 1)
        # the surviving pair still talks through the router
        t0.post((0, 1, "z"), 7)
        deadline = time.monotonic() + 5.0
        while True:
            ok, msg = t1.poll((0, 1, "z"))
            if ok:
                break
            assert time.monotonic() < deadline
            time.sleep(0.002)
        assert msg == 7
    finally:
        t0.close()
        t1.close()
        t2.close()


def test_socket_heartbeat_staleness_death_detection():
    """A rank whose socket stays open but whose heartbeats stop (wedged
    process) is declared dead by the router's monitor within
    O(heartbeat_timeout)."""
    ta = SocketTransport(0, 2, heartbeat_interval=0.05, heartbeat_timeout=0.4)
    tb = SocketTransport(
        1, 2, port=ta.port, heartbeat_interval=0.05, heartbeat_timeout=0.4
    )
    try:
        time.sleep(0.15)  # let a few heartbeats land
        with pytest.warns(RuntimeWarning, match="no heartbeat"):
            tb._hb_stop.set()  # wedge: TCP alive, heartbeats gone
            stale_t = time.monotonic()
            deadline = stale_t + 5.0
            while not ta.is_dead(1):
                assert time.monotonic() < deadline, "staleness never detected"
                time.sleep(0.005)
        latency = ta.death_detected_at(1) - stale_t
        assert latency < 2.0  # O(heartbeat_timeout), far below any comm timeout
    finally:
        ta.close()
        tb.close()


def test_socket_dial_failure_is_bounded_and_names_the_address():
    """The dial loop must give up after its bounded retry budget with an
    SpCommError naming the rendezvous address — not spin forever."""
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        free_port = s.getsockname()[1]
    # nobody listens on free_port: rank 1 dials a dead rendezvous
    t0 = time.monotonic()
    with pytest.raises(SpCommError, match=rf"127\.0\.0\.1:{free_port}"):
        SocketTransport(
            1, 2, port=free_port, connect_timeout=0.5, max_dial_retries=5
        )
    assert time.monotonic() - t0 < 5.0


# ---------------------------------------------------------------------------
# shrunken groups + re-roll agreement
# ---------------------------------------------------------------------------

def test_group_members_and_shrunk():
    hub = ChannelHub()
    g = SpCommGroup(2, 4, hub)
    assert g.members == (0, 1, 2, 3)
    assert (g.logical_rank, g.logical_size) == (2, 4)
    s = g.shrunk([1, 3])
    assert s.members == (0, 2)
    assert (s.logical_rank, s.logical_size) == (1, 2)
    assert s.to_physical(s.logical_rank + 1) == 0  # ring wraps over members
    with pytest.raises(SpCommError):
        g.shrunk([2])  # cannot shrink away yourself
    with pytest.raises(ValueError):
        SpCommGroup(5, 4, hub, members=(0, 1))  # rank must be a member


def test_ring_all_reduce_on_shrunken_group(engine):
    """After 'losing' rank 1 of 3, the survivors' shrunken groups still form
    a closed logical ring and the reduce is bit-exact (2-rank float32 sums
    are order-independent)."""
    hub = ChannelHub()
    arrays = {
        r: np.random.default_rng(r).standard_normal(13).astype(np.float32)
        for r in (0, 2)
    }
    groups = {
        r: SpCommGroup(r, 3, hub, default_timeout=30.0).shrunk([1])
        for r in (0, 2)
    }
    graphs = {r: SpTaskGraph().compute_on(engine) for r in (0, 2)}
    cells = {r: SpData(arrays[r].copy(), f"s{r}") for r in (0, 2)}
    for r in (0, 2):
        ring_all_reduce(graphs[r], groups[r], cells[r], op="sum")
    for g in graphs.values():
        g.wait_all_tasks(timeout=60.0)
    expected = arrays[0] + arrays[2]
    for r in (0, 2):
        np.testing.assert_array_equal(cells[r].value, expected)


def test_broadcast_on_shrunken_group(engine):
    hub = ChannelHub()
    groups = {
        r: SpCommGroup(r, 3, hub, default_timeout=30.0).shrunk([1])
        for r in (0, 2)
    }
    graphs = {r: SpTaskGraph().compute_on(engine) for r in (0, 2)}
    cells = {
        r: SpData(np.arange(4.0) if r == 0 else None, f"b{r}") for r in (0, 2)
    }
    for r in (0, 2):
        mpi_broadcast(graphs[r], groups[r], cells[r], root=0)
    for g in graphs.values():
        g.wait_all_tasks(timeout=60.0)
    np.testing.assert_array_equal(cells[2].value, np.arange(4.0))
    # the dead rank got nothing: no mailbox keyed to it lingers
    assert not any(key[1] == 1 for key in hub._boxes)


def test_reroll_ranks_agreement():
    """Survivors with the same dead-set view agree in two rounds and come
    out with the shrunken group plus each other's payloads."""
    import threading

    hub = ChannelHub()
    hub.mark_dead(2)
    groups = {r: SpCommGroup(r, 3, hub, default_timeout=30.0) for r in (0, 1)}
    out: dict = {}

    def roll(r, payload):
        out[r] = reroll_ranks(
            groups[r], epoch=1, payload=payload, timeout=10.0
        )

    threads = [
        threading.Thread(target=roll, args=(0, {"next_step": 5})),
        threading.Thread(target=roll, args=(1, {"next_step": 4})),
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=15.0)
    assert set(out) == {0, 1}
    for r in (0, 1):
        new_group, dead, payloads = out[r]
        assert dead == frozenset({2})
        assert new_group.members == (0, 1)
        assert {p["next_step"] for p in payloads.values()} == {4, 5}
        assert min(p["next_step"] for p in payloads.values()) == 4


# ---------------------------------------------------------------------------
# the acceptance run: SIGKILL a real OS rank mid-collective
# ---------------------------------------------------------------------------

def test_sigkill_rank_mid_all_reduce_survivors_finish():
    """Three real OS processes ring-all-reduce over TCP; the parent SIGKILLs
    rank 2 mid-collective.  The survivors must detect the death via the
    failure detector (milliseconds — far below the 30s comm timeout),
    agree on the dead set, re-mesh to a 2-rank ring, redo the interrupted
    step, and finish all steps with bit-exact results."""
    n, steps = 257, 4
    results, info = run_elastic_ring(size=3, n=n, steps=steps, fail_at=2)
    assert set(results) == {0, 1}

    bases = [
        np.random.default_rng(r).standard_normal(n).astype(np.float32)
        for r in range(3)
    ]
    full = bases[0] + bases[1] + bases[2]
    surviving = bases[0] + bases[1]

    resumes = {rep["resume_step"] for rep in results.values()}
    assert len(resumes) == 1, f"survivors disagree on the resume step: {resumes}"
    resume = resumes.pop()
    assert resume is not None and 0 <= resume < steps

    for rank, rep in results.items():
        assert rep["dead"] == [2]
        assert rep["members"] == [0, 1]
        # detection came from the failure detector, not the 30s recv timeout
        latency = rep["detect_at"] - info["t_kill"]
        assert -0.05 < latency < 5.0, f"rank {rank}: detection took {latency}s"
        assert rep["reroll_s"] < 30.0
        assert sorted(rep["steps"]) == list(range(steps))
        for step, arr in rep["steps"].items():
            if step < resume:  # full-mesh steps: 3-way sums, order-dependent
                np.testing.assert_allclose(arr, full, rtol=1e-5, atol=1e-6)
            else:  # shrunken mesh: 2-way float32 sums are bit-exact
                np.testing.assert_array_equal(arr, surviving)
    # both survivors computed identical bits everywhere
    for step in results[0]["steps"]:
        np.testing.assert_array_equal(
            results[0]["steps"][step], results[1]["steps"][step]
        )


# ---------------------------------------------------------------------------
# FailureSimulator + remesh_plan edge cases
# ---------------------------------------------------------------------------

def test_failure_simulator_fires_once_and_counts():
    sim = FailureSimulator({0: 2, 3: 1})
    assert sim.check(0) == 2  # failure at step 0 is legal
    assert sim.check(0) == 0  # and fires exactly once
    assert sim.check(1) == 0
    assert sim.check(3) == 1
    assert sim.total_lost == 3
    assert sim.events == [(0, 2), (3, 1)]


def test_failure_simulator_flaky_recovers():
    sim = FailureSimulator({}, flaky={2: 3})
    assert not sim.flaky_down(0)
    assert not sim.flaky_down(1)
    assert sim.flaky_down(2)  # outage starts
    assert sim.flaky_down(3)
    assert sim.flaky_down(4)
    assert not sim.flaky_down(5)  # recovered
    assert not sim.flaky_down(6)
    assert sim.flaky_events == [(2, 5)]
    assert sim.total_lost == 0  # transient outages are not deaths


def test_remesh_plan_all_ranks_lost_raises():
    with pytest.raises(RuntimeError, match="reschedule"):
        remesh_plan(8, 8, model_parallel=2)


def test_remesh_plan_below_model_parallel_raises():
    # 3 survivors cannot host a model axis of 4 — must raise, not emit a
    # degenerate mesh
    with pytest.raises(RuntimeError, match="model_parallel=4"):
        remesh_plan(8, 5, model_parallel=4)


def test_remesh_plan_idles_remainder_chips():
    plan = remesh_plan(8, 3, model_parallel=2)  # 5 alive -> 2x2 mesh, 1 idle
    assert plan.shape == (2, 2)
    assert plan.n_chips == 4
    assert plan.dropped_chips == 4  # 3 failed + 1 idled


# ---------------------------------------------------------------------------
# engine.stop() idempotence (recovery path + atexit may both call it)
# ---------------------------------------------------------------------------

def test_engine_stop_is_idempotent():
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(2))
    tg = SpTaskGraph().compute_on(eng)
    out = SpData(None, "out")
    tg.task(SpWrite(out), lambda ref: setattr(ref, "value", 1))
    tg.wait_all_tasks(timeout=10.0)
    first = eng.stop()
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # a second stop must not re-warn
        second = eng.stop()
        third = eng.stop()
    assert first == second == third == []


def test_engine_stop_idempotent_with_aborted_requests():
    """The first stop's abort report is cached: a second stop returns the
    same names instead of re-cancelling (or losing) them."""
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(1))
    hub = ChannelHub()
    g1 = SpCommGroup(1, 2, hub)
    tg = SpTaskGraph().compute_on(eng)
    r = SpData(None, "r")
    mpi_recv(tg, g1, r, src=0, tag=13)  # never satisfied
    deadline = time.monotonic() + 5.0
    while eng._comm is None and time.monotonic() < deadline:
        time.sleep(0.005)
    with pytest.warns(RuntimeWarning, match="in-flight"):
        first = eng.stop()
    assert first == ["recv(from=0,tag=13)"]
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert eng.stop() == first
    tg.wait_all_tasks(timeout=5.0, raise_errors=False)

"""The staged train step (runtime/train.py): learning, microbatch
equivalence, schedule structure, nonfinite rollback (C6), compression."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.timeout(120)

from repro.configs import reduced_config
from repro.data import SyntheticLMDataset
from repro.models.config import ShapeSpec
from repro.runtime.train import build_train_step, init_train_state

CFG = reduced_config("deepseek-7b")
SHAPE = ShapeSpec("t", "train", 32, 8)


def _batch(step=0):
    ds = SyntheticLMDataset(CFG, SHAPE, seed=0)
    return {k: jnp.asarray(v) for k, v in ds.batch_for_step(step).items()}


def test_loss_decreases():
    state = init_train_state(jax.random.PRNGKey(0), CFG)
    art = build_train_step(CFG, n_microbatches=2, lr_schedule=lambda s: jnp.float32(1e-3))
    ds = SyntheticLMDataset(CFG, SHAPE, seed=0)
    losses = []
    for step in range(30):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_for_step(step).items()}
        state, m = art(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5
    assert int(state.step) == 30


def test_schedule_structure():
    art = build_train_step(CFG, n_microbatches=4, schedule_policy="overlap", jit=False)
    state = init_train_state(jax.random.PRNGKey(0), CFG)
    art(state, _batch())
    names = art.schedule_names
    assert sum(n.startswith("mb") for n in names) == 4
    assert "grad_allreduce" in names and "optimizer" in names
    assert names.index("grad_allreduce") < names.index("optimizer")


def test_microbatch_equivalence():
    state = init_train_state(jax.random.PRNGKey(1), CFG)
    batch = _batch()
    a1 = build_train_step(CFG, n_microbatches=1, donate=False)
    a2 = build_train_step(CFG, n_microbatches=2, donate=False)
    s1, m1 = a1(state, batch)
    s2, m2 = a2(state, batch)
    # same data, same params → same accumulated grads up to fp error
    np.testing.assert_allclose(
        float(m1["grad_norm"]), float(m2["grad_norm"]), rtol=2e-2
    )
    w1 = jax.tree.leaves(s1.params)[0].astype(jnp.float32)
    w2 = jax.tree.leaves(s2.params)[0].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=2e-2)


def test_nonfinite_rollback():
    """Branchless C6 speculation: a NaN batch must leave params unchanged."""
    state = init_train_state(jax.random.PRNGKey(2), CFG)
    art = build_train_step(CFG, n_microbatches=1, donate=False)
    batch = _batch()
    bad = dict(batch)
    # poison the loss through labels is hard (int); instead poison params'
    # gradient via an inf in the embed input path: use an out-of-range label
    # clamped... simplest: drive a NaN through a float param
    params = state.params
    poisoned = jax.tree_util.tree_map(lambda x: x, params)
    poisoned["layers"]["ln1"] = poisoned["layers"]["ln1"].at[0, 0].set(jnp.nan)
    bad_state = state._replace(params=poisoned)
    new_state, m = art(bad_state, batch)
    assert not bool(jnp.isfinite(m["grad_norm"]))
    # rollback: params (including the NaN cell) unchanged by the optimizer
    before = poisoned["layers"]["mlp"]["wo"].astype(jnp.float32)
    after = new_state.params["layers"]["mlp"]["wo"].astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(before), np.asarray(after))
    assert int(new_state.step) == 1  # step still advances


def test_grad_compression_path_runs():
    state = init_train_state(jax.random.PRNGKey(3), CFG)
    art = build_train_step(CFG, n_microbatches=1, grad_compression=True, donate=False)
    state, m = art(state, _batch())
    assert bool(jnp.isfinite(m["loss"]))


def test_donation_buffer_reuse():
    state = init_train_state(jax.random.PRNGKey(4), CFG)
    art = build_train_step(CFG, n_microbatches=1, donate=True)
    s2, _ = art(state, _batch())
    with pytest.raises(RuntimeError):
        _ = jax.tree.leaves(state.params)[0] + 0  # donated buffer is dead

"""Multi-device integration (subprocess with 8 virtual host devices):
sharded staged train step, checkpoint→elastic re-mesh→restore→resume —
the fault-tolerance story end to end (DESIGN.md §5)."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

# Each test spawns a fresh interpreter that compiles sharded train steps on
# 8 virtual devices; raise the CI per-test cap.
pytestmark = pytest.mark.timeout(300)

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, tempfile
    from repro.configs import reduced_config
    from repro.data import SyntheticLMDataset
    from repro.dist.sharding import use_mesh
    from repro.dist.fault import remesh_plan, FailureSimulator
    from repro.checkpoint import CheckpointManager
    from repro.models.config import ShapeSpec
    from repro.runtime.train import (abstract_train_state, build_train_step,
                                     init_train_state, train_state_shardings)

    cfg = reduced_config("deepseek-7b")
    shape = ShapeSpec("t", "train", 32, 8)
    ds = SyntheticLMDataset(cfg, shape, seed=0)
    ckdir = tempfile.mkdtemp()
    mgr = CheckpointManager(ckdir, keep=2, async_commit=False)
    sim = FailureSimulator({4: 4})  # lose half the chips at step 4

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    with use_mesh(mesh):
        sh = train_state_shardings(cfg)
        state = init_train_state(jax.random.PRNGKey(0), cfg)
        state = jax.device_put(state, sh)
        art = build_train_step(cfg, n_microbatches=2, donate=False)
        losses = []
        step = 0
        while step < 4:
            batch = {k: jnp.asarray(v) for k, v in ds.batch_for_step(step).items()}
            state, m = art(state, batch)
            losses.append(float(m["loss"]))
            step += 1
            mgr.save(step, state, block=True)
    assert sim.check(4) == 4, "failure injected"

    # elastic re-mesh: 8 chips → 4 alive, model_parallel preserved at 2
    plan = remesh_plan(8, 4, model_parallel=2)
    assert plan.shape == (2, 2), plan
    devices = np.array(jax.devices()[: plan.n_chips]).reshape(plan.shape)
    mesh2 = jax.sharding.Mesh(devices, plan.axes)
    with use_mesh(mesh2):
        template = abstract_train_state(cfg)
        restored_step, state2 = mgr.restore(template)
        assert restored_step == 4
        art2 = build_train_step(cfg, n_microbatches=2, donate=False)
        # the data pipeline cursor IS the step counter: resume deterministically
        while restored_step < 8:
            batch = {k: jnp.asarray(v) for k, v in ds.batch_for_step(restored_step).items()}
            state2, m = art2(state2, batch)
            restored_step += 1
            losses.append(float(m["loss"]))
    assert int(state2.step) == 8
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0] + 0.5  # still training sanely after re-mesh
    print("ELASTIC_OK", losses[0], "->", losses[-1])
    """
)


def test_elastic_remesh_end_to_end():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True,
        timeout=900, cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout[-3000:]}\nstderr:\n{r.stderr[-3000:]}"
    assert "ELASTIC_OK" in r.stdout


HIER_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.dist.collectives import hierarchical_psum

    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    x = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)

    @partial(shard_map, mesh=mesh, in_specs=P(("pod", "data"), None),
             out_specs=P(("pod", "data"), None))
    def hier(v):
        return hierarchical_psum(v, pod_axis="pod", inner_axis="data")

    @partial(shard_map, mesh=mesh, in_specs=P(("pod", "data"), None),
             out_specs=P(("pod", "data"), None))
    def flat(v):
        return jax.lax.psum(v, ("pod", "data"))

    a, b = hier(x), flat(x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    # HLO of the hierarchical version must contain the 3-stage pattern
    lowered = jax.jit(hier).lower(x).compile().as_text()
    assert "reduce-scatter" in lowered and "all-gather" in lowered, "3-stage pattern"
    print("HIER_OK")
    """
)


def test_hierarchical_psum_matches_flat():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", HIER_SCRIPT], env=env, capture_output=True, text=True,
        timeout=600, cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout[-2000:]}\nstderr:\n{r.stderr[-2000:]}"
    assert "HIER_OK" in r.stdout

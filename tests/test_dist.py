"""repro.dist unit tests: ring collectives over a multi-rank ChannelHub,
gradient compression bounds, duplicated-task cancellation, mesh context,
and failure-simulation → re-mesh planning."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ChannelHub,
    SpCommGroup,
    SpComputeEngine,
    SpData,
    SpTaskGraph,
    SpWorkerTeamBuilder,
)
from repro.dist.collectives import (
    compress_int8,
    decompress_int8,
    ring_all_gather,
    ring_all_reduce,
)
from repro.dist.fault import CancelToken, FailureSimulator, remesh_plan, run_duplicated
from repro.dist.sharding import current_mesh, safe_spec, use_mesh


@pytest.fixture()
def engine():
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(4))
    yield eng
    eng.stop()


# ---------------------------------------------------------------------------
# ring collectives over the hub
# ---------------------------------------------------------------------------

def _ranks(engine, size, hub):
    groups = [SpCommGroup(r, size, hub) for r in range(size)]
    graphs = [SpTaskGraph().compute_on(engine) for _ in range(size)]
    return groups, graphs


def test_ring_all_reduce_matches_psum(engine):
    size = 4
    rng = np.random.default_rng(0)
    # 18 elements: not divisible by 4, exercises uneven chunk splits
    arrays = [rng.standard_normal(18).astype(np.float32) for _ in range(size)]
    groups, graphs = _ranks(engine, size, ChannelHub())
    cells = [SpData(arrays[r].copy(), f"g{r}") for r in range(size)]
    views = [
        ring_all_reduce(graphs[r], groups[r], cells[r]) for r in range(size)
    ]
    for g in graphs:
        g.wait_all_tasks()

    # reference: jax.lax.psum over a named axis (vmap substrate)
    expected = np.asarray(
        jax.vmap(lambda v: jax.lax.psum(v, "i"), axis_name="i")(jnp.stack(arrays))
    )[0]
    for r in range(size):
        np.testing.assert_allclose(cells[r].value, expected, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(views[r].get_value(), expected, rtol=1e-5, atol=1e-6)


def test_ring_all_reduce_mean_and_2d(engine):
    size = 3
    arrays = [np.full((2, 5), float(r + 1), np.float32) for r in range(size)]
    groups, graphs = _ranks(engine, size, ChannelHub())
    cells = [SpData(arrays[r], f"m{r}") for r in range(size)]
    for r in range(size):
        ring_all_reduce(graphs[r], groups[r], cells[r], op="mean")
    for g in graphs:
        g.wait_all_tasks()
    for r in range(size):
        assert cells[r].value.shape == (2, 5)
        np.testing.assert_allclose(cells[r].value, 2.0, rtol=1e-6)


def test_ring_all_gather_orders_by_rank(engine):
    size = 4
    groups, graphs = _ranks(engine, size, ChannelHub())
    cells = [SpData(np.arange(3) + 10 * r, f"x{r}") for r in range(size)]
    views = [
        ring_all_gather(graphs[r], groups[r], cells[r]) for r in range(size)
    ]
    for g in graphs:
        g.wait_all_tasks()
    for r in range(size):
        got = views[r].get_value()
        assert len(got) == size
        for src in range(size):
            np.testing.assert_array_equal(got[src], np.arange(3) + 10 * src)


def test_hub_stays_bounded_over_100_step_ring_loop(engine):
    """Regression: per-step tags used to leak one deque per (src, dst, tag)
    key forever; 100 reduce steps must leave the hub's mailbox dict empty."""
    size, steps = 2, 100
    hub = ChannelHub()
    groups, graphs = _ranks(engine, size, hub)
    base = [np.full(6, float(r + 1), np.float32) for r in range(size)]
    cells = [SpData(base[r].copy(), f"h{r}") for r in range(size)]
    for step in range(steps):
        for r in range(size):
            cells[r].value = base[r].copy()
            ring_all_reduce(graphs[r], groups[r], cells[r], tag=step)
        for g in graphs:
            g.wait_all_tasks()
        for r in range(size):
            np.testing.assert_array_equal(cells[r].value, np.full(6, 3.0, np.float32))
    st = hub.stats()
    assert st["boxes"] == 0 and st["queued"] == 0
    assert len(hub._boxes) == 0  # the dict itself is pruned, not just empty
    assert st["posted"] == st["delivered"] > 0


def test_ring_single_rank_identity(engine):
    hub = ChannelHub()
    g = SpTaskGraph().compute_on(engine)
    grp = SpCommGroup(0, 1, hub)
    x = SpData(np.ones(4, np.float32), "solo")
    v = ring_all_reduce(g, grp, x)
    g.wait_all_tasks()
    np.testing.assert_array_equal(v.get_value(), np.ones(4, np.float32))


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compress_int8_roundtrip_bound_explicit():
    g = jnp.asarray([-100.0, -0.3, 0.0, 0.7, 99.9], jnp.float32)
    q, scale = compress_int8(g)
    assert q.dtype == jnp.int8
    err = jnp.abs(decompress_int8(q, scale) - g)
    assert float(err.max()) <= float(scale) / 2 + 1e-6


def test_compress_int8_zero_tensor():
    q, scale = compress_int8(jnp.zeros((7,), jnp.float32))
    np.testing.assert_array_equal(np.asarray(q), 0)
    np.testing.assert_allclose(np.asarray(decompress_int8(q, scale)), 0.0)


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_run_duplicated_cancels_losers():
    # one worker ⇒ copies run sequentially ⇒ the winner is copy0 and every
    # other copy is cancelled at its pre-execution token check
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(1))
    try:
        tg = SpTaskGraph().compute_on(eng)
        x = SpData(7, "x")
        out = SpData(None, "out")
        view = run_duplicated(tg, lambda v: v * 3, [x], out, n=3, name="dup")
        tg.wait_all_tasks()
        assert view.get_value() == 21 and out.value == 21
        states = sorted(t.state for t in tg.tasks if t.name.startswith("dup.copy"))
        assert states == ["cancelled", "cancelled", "finished"]
    finally:
        eng.stop()


def test_run_duplicated_masks_a_crashing_copy():
    # a replica that raises must not claim the token or fail the graph;
    # a healthy replica still produces the value (the point of replication)
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(1))
    try:
        tg = SpTaskGraph().compute_on(eng)
        x = SpData(5, "x")
        out = SpData(None, "out")
        calls = []

        def flaky(v):
            calls.append(1)
            if len(calls) == 1:
                raise RuntimeError("injected replica crash")
            return v + 1

        view = run_duplicated(tg, flaky, [x], out, n=3, name="flaky")
        tg.wait_all_tasks()  # must NOT raise: the crash was masked
        assert view.get_value() == 6 and out.value == 6
    finally:
        eng.stop()


def test_run_duplicated_raises_when_all_copies_fail():
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(1))
    try:
        tg = SpTaskGraph().compute_on(eng)
        out = SpData(None, "out")

        def always_fails():
            raise RuntimeError("boom")

        run_duplicated(tg, always_fails, [], out, n=2, name="doomed")
        with pytest.raises(RuntimeError, match="all 2 duplicated copies failed"):
            tg.wait_all_tasks()
    finally:
        eng.stop()


def test_cancel_token_claims_once():
    tok = CancelToken()
    assert not tok.is_set()
    assert tok.set("a") and tok.winner == "a"
    assert not tok.set("b") and tok.winner == "a"
    assert tok.is_set() and tok.wait(0.01)


def test_failure_then_remesh_plan():
    sim = FailureSimulator({3: 2})
    assert sim.check(0) == 0
    lost = sim.check(3)
    assert lost == 2 and sim.total_lost == 2
    plan = remesh_plan(8, lost, model_parallel=2)
    assert plan.shape == (3, 2) and plan.axes == ("data", "model")
    assert plan.n_chips == 6 and plan.dropped_chips == 2
    with pytest.raises(RuntimeError):
        remesh_plan(8, 7, model_parallel=2)


def test_remesh_plan_validates():
    with pytest.raises(ValueError):
        remesh_plan(16, 0, model_parallel=0)
    with pytest.raises(ValueError):
        remesh_plan(512, 0, model_parallel=16, pod_size=40)


# ---------------------------------------------------------------------------
# mesh context
# ---------------------------------------------------------------------------

def test_use_mesh_nests_and_restores():
    assert current_mesh() is None
    m1 = jax.make_mesh((1, 1), ("data", "model"))
    m2 = jax.make_mesh((1,), ("data",))
    with use_mesh(m1):
        assert current_mesh() is m1
        with use_mesh(m2):
            assert current_mesh() is m2
        assert current_mesh() is m1
    assert current_mesh() is None


def test_safe_spec_uses_each_mesh_axis_once():
    class FakeMesh:
        shape = {"data": 4, "model": 8}

    # both "experts" and "expert_ff" want "model"; only the first gets it
    spec = safe_spec((8, 16, 32), ("experts", "embed", "expert_ff"), mesh=FakeMesh())
    assert spec[0] == "model" and spec[1] is None and spec[2] is None

"""Speculative execution (paper §4.6): commit, rollback, chains, stats,
and the property that speculation never changes observable results."""
from __future__ import annotations

import time

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    SpComputeEngine,
    SpData,
    SpMaybeWrite,
    SpRead,
    SpSpeculativeModel,
    SpTaskGraph,
    SpWorkerTeamBuilder,
    SpWrite,
)


@pytest.fixture(scope="module")
def engine():
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(4))
    yield eng
    eng.stop()


def _run_chain(engine, writes: list[bool], spec: bool):
    """maybe-write(x) → read(x)+write(y) pairs; returns (x, y, stats)."""
    model = SpSpeculativeModel.SP_MODEL_1 if spec else SpSpeculativeModel.SP_NO_SPEC
    tg = SpTaskGraph(model).compute_on(engine)
    x = SpData(1.0, "x")
    y = SpData(0.0, "y")
    for i, do_write in enumerate(writes):
        def update(ref, _w=do_write, _i=i):
            if _w:
                ref.value = ref.value + 10.0

        def consume(xv, yref):
            yref.value = yref.value + xv

        tg.task(SpMaybeWrite(x), update, name=f"u{i}")
        tg.task(SpRead(x), SpWrite(y), consume, name=f"r{i}")
    tg.wait_all_tasks()
    return x.value, y.value, dict(tg.spec_stats)


def test_commit_path(engine):
    x, y, stats = _run_chain(engine, [False], spec=True)
    assert (x, y) == (1.0, 1.0)
    assert stats["commits"] == 1 and stats["rollbacks"] == 0


def test_rollback_path(engine):
    x, y, stats = _run_chain(engine, [True], spec=True)
    assert (x, y) == (11.0, 11.0)
    assert stats["rollbacks"] == 1 and stats["commits"] == 0


@settings(max_examples=25, deadline=None)
@given(writes=st.lists(st.booleans(), min_size=1, max_size=6))
def test_property_spec_equals_nospec(writes):
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(4))
    try:
        base = _run_chain(eng, writes, spec=False)[:2]
        spec = _run_chain(eng, writes, spec=True)[:2]
        assert base == spec
    finally:
        eng.stop()


def test_speculation_overlaps_wallclock(engine):
    def timed(spec):
        model = SpSpeculativeModel.SP_MODEL_1 if spec else SpSpeculativeModel.SP_NO_SPEC
        tg = SpTaskGraph(model).compute_on(engine)
        x = SpData(1.0, "x")
        y = SpData(0.0, "y")
        t0 = time.perf_counter()
        tg.task(SpMaybeWrite(x), lambda r: time.sleep(0.05), name="U")
        tg.task(SpRead(x), SpWrite(y), lambda v, r: (time.sleep(0.05), setattr(r, "value", v))[-1], name="R")
        tg.wait_all_tasks()
        return time.perf_counter() - t0

    assert timed(True) < timed(False) * 0.8


def test_certain_write_clears_uncertainty(engine):
    tg = SpTaskGraph(SpSpeculativeModel.SP_MODEL_1).compute_on(engine)
    x = SpData(1.0, "x")
    y = SpData(0.0, "y")
    tg.task(SpMaybeWrite(x), lambda r: setattr(r, "value", 5.0), name="maybe")
    tg.task(SpWrite(x), lambda r: setattr(r, "value", 100.0), name="certain")
    tg.task(SpRead(x), SpWrite(y), lambda v, r: setattr(r, "value", v), name="read")
    tg.wait_all_tasks()
    assert y.value == 100.0
    assert tg.spec_stats["speculated"] == 0  # reader after certain write


def test_multiple_readers_share_snapshot(engine):
    tg = SpTaskGraph(SpSpeculativeModel.SP_MODEL_1).compute_on(engine)
    x = SpData(2.0, "x")
    outs = [SpData(0.0, f"o{i}") for i in range(3)]
    tg.task(SpMaybeWrite(x), lambda r: None, name="U")  # never writes
    for i in range(3):
        tg.task(SpRead(x), SpWrite(outs[i]), lambda v, r: setattr(r, "value", v * (1)), name=f"r{i}")
    tg.wait_all_tasks()
    assert [o.value for o in outs] == [2.0, 2.0, 2.0]
    assert tg.spec_stats["commits"] == 3


def test_comm_refuses_speculative_graph(engine):
    from repro.core import SpCommGroup, mpi_send

    tg = SpTaskGraph(SpSpeculativeModel.SP_MODEL_1)
    g = SpCommGroup(0, 2)
    x = SpData(1.0, "x")
    with pytest.raises(ValueError, match="incompatible"):
        mpi_send(tg, g, x, dest=1, tag=0)


@settings(max_examples=20, deadline=None)
@given(writes=st.lists(st.booleans(), min_size=2, max_size=5))
def test_property_model2_equals_nospec(writes):
    """SP_MODEL_2 (writer chains, paper's second model) is also result-exact."""
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(4))
    try:
        base = _run_chain(eng, writes, spec=False)[:2]
        tg_model2 = SpSpeculativeModel.SP_MODEL_2
        # inline chain with interleaved reader at the end of each prefix
        def run(model):
            tg = SpTaskGraph(model).compute_on(eng)
            x = SpData(1.0, "x")
            y = SpData(0.0, "y")
            for i, do_write in enumerate(writes):
                def update(ref, _w=do_write):
                    if _w:
                        ref.value = ref.value + 10.0
                tg.task(SpMaybeWrite(x), update, name=f"u{i}")
            tg.task(SpRead(x), SpWrite(y),
                    lambda xv, yref: setattr(yref, "value", xv * 2), name="r")
            tg.wait_all_tasks()
            return x.value, y.value
        assert run(SpSpeculativeModel.SP_NO_SPEC) == run(tg_model2)
    finally:
        eng.stop()


def test_model2_overlaps_whole_chain(engine):
    """With an all-reject chain, MODEL_2's reader overlaps every writer:
    wall ≈ max(ΣU, R); MODEL_1 waits for all but the last writer."""
    import time as _t

    def run(model, d_u=0.03, d_r=0.12):
        tg = SpTaskGraph(model).compute_on(engine)
        x = SpData(1.0, "x")
        y = SpData(0.0, "y")
        t0 = _t.perf_counter()
        for i in range(2):
            tg.task(SpMaybeWrite(x), lambda ref: _t.sleep(d_u), name=f"u{i}")
        tg.task(
            SpRead(x), SpWrite(y),
            lambda xv, yref: (_t.sleep(d_r), setattr(yref, "value", xv))[-1],
            name="r",
        )
        tg.wait_all_tasks()
        return _t.perf_counter() - t0

    t_none = run(SpSpeculativeModel.SP_NO_SPEC)
    t_m1 = run(SpSpeculativeModel.SP_MODEL_1)
    t_m2 = run(SpSpeculativeModel.SP_MODEL_2)
    assert t_m2 < t_m1 < t_none, (t_none, t_m1, t_m2)


# ---------------------------------------------------------------------------
# chained speculation through the @sp_task codelet frontend (ISSUE 9):
# the draft/verify/commit shape speculative decoding uses
# ---------------------------------------------------------------------------

def _codelet_round(engine, poison: bool, k: int = 3):
    """k maybe-write drafters → one speculated verifier → certain-write
    commit, all declared as codelets.  Returns (state, log, stats)."""
    from repro.core.api import graph_scope, sp_task

    log = []

    @sp_task(maybe=("state",), write=("prop",), name="draft")
    def draft(state, prop, *, j, poison):
        log.append(("draft", j))
        if poison and j == 1:
            state.value = state.value  # self-assignment still counts as a write
        prop.value = j

    @sp_task(read=("state", "prop"), write=("vout",), name="verify")
    def verify(state, prop, vout):
        log.append(("verify", state, prop))
        vout.value = state * 10 + prop

    @sp_task(write=("state",), read=("vout",), name="commit")
    def commit(state, vout):
        log.append(("commit", vout))
        state.value = vout

    tg = SpTaskGraph(SpSpeculativeModel.SP_MODEL_2).compute_on(engine)
    state = SpData(7, "state")
    prop = SpData(None, "prop")
    vout = SpData(None, "vout")
    with graph_scope(tg):
        for j in range(k):
            draft(state, prop, j=j, poison=poison)
        verify(state, prop, vout)
        commit(state, vout)
    tg.wait_all_tasks()
    return state.value, log, dict(tg.spec_stats)


def test_codelet_chain_commit(engine):
    """Clean chain: the verifier runs once (speculatively), its output is
    committed, graph records one commit and no rollback."""
    final, log, stats = _codelet_round(engine, poison=False)
    assert final == 7 * 10 + 2  # last drafter's proposal, verified once
    assert [e for e in log if e[0] == "verify"] == [("verify", 7, 2)]
    assert stats["speculated"] == 1
    assert stats["commits"] == 1 and stats["rollbacks"] == 0
    assert [e for e in log if e[0] == "commit"] == [("commit", 72)]


def test_codelet_chain_rollback_reexecutes_verifier(engine):
    """A drafter that writes (even its own value back) invalidates the
    chain's shared snapshot: the verifier's body runs twice — speculative
    pass plus rollback re-execution on the real state — and commit sees
    the re-executed output."""
    final, log, stats = _codelet_round(engine, poison=True)
    verifies = [e for e in log if e[0] == "verify"]
    assert len(verifies) == 2
    assert all(v == ("verify", 7, 2) for v in verifies)
    assert stats["speculated"] == 1
    assert stats["rollbacks"] == 1
    assert final == 72
    assert [e for e in log if e[0] == "commit"] == [("commit", 72)]


def test_codelet_chain_equals_nospec(engine):
    """SP_MODEL_2 through codelets is observably identical to SP_NO_SPEC
    regardless of which drafters write."""
    from repro.core.api import graph_scope, sp_task

    def run(model, writes):
        @sp_task(maybe=("x",), name="u")
        def update(x, *, w, inc):
            if w:
                x.value = x.value + inc

        @sp_task(read=("x",), write=("y",), name="r")
        def reader(x, y):
            y.value = y.value + x

        tg = SpTaskGraph(model).compute_on(engine)
        x = SpData(1.0, "x")
        y = SpData(0.0, "y")
        with graph_scope(tg):
            for i, w in enumerate(writes):
                update(x, w=w, inc=10.0 * (i + 1))
                reader(x, y)
        tg.wait_all_tasks()
        return x.value, y.value

    for writes in ([], [True], [False, True], [True, False, True], [False] * 3):
        assert run(SpSpeculativeModel.SP_MODEL_2, writes) == run(
            SpSpeculativeModel.SP_NO_SPEC, writes
        )

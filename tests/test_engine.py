"""Compute engines, worker teams, dynamic moves, straggler duplication."""
from __future__ import annotations

import time

import pytest

from repro.core import (
    SpComputeEngine,
    SpData,
    SpRead,
    SpTaskGraph,
    SpWorkerTeamBuilder,
    SpWrite,
    WorkStealingScheduler,
    trace_metrics,
)
from repro.dist.fault import CancelToken, run_duplicated


def test_team_builders():
    t = SpWorkerTeamBuilder.team_of_cpu_workers(3)
    assert len(t) == 3
    t2 = SpWorkerTeamBuilder.team_of_cpu_cuda_workers(2, 1)
    assert t2.kinds.count("ref") == 2 and t2.kinds.count("pallas") == 1


def test_move_workers_between_engines():
    a = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(4), name="a")
    b = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(1), name="b")
    try:
        moved = a.send_workers_to(b, 2)
        assert moved == 2
        deadline = time.time() + 2.0
        while time.time() < deadline and (a.n_workers, b.n_workers) != (2, 3):
            time.sleep(0.01)
        assert (a.n_workers, b.n_workers) == (2, 3)
        # engine b still executes fine after the move
        tg = SpTaskGraph().compute_on(b)
        x = SpData(5, "x")
        assert tg.task(SpRead(x), lambda v: v + 1).get_value() == 6
    finally:
        a.stop()
        b.stop()


def test_multiple_graphs_one_engine():
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(2))
    try:
        tgs = [SpTaskGraph().compute_on(eng) for _ in range(3)]
        outs = []
        for i, tg in enumerate(tgs):
            x = SpData(i, f"x{i}")
            outs.append(tg.task(SpRead(x), lambda v: v * 2))
        assert [o.get_value() for o in outs] == [0, 2, 4]
    finally:
        eng.stop()


def test_straggler_duplicates_first_wins():
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(4))
    try:
        tg = SpTaskGraph().compute_on(eng)
        x = SpData(21, "x")
        out = SpData(None, "out")
        view = run_duplicated(tg, lambda v: v * 2, [x], out, n=3, name="dup")
        tg.wait_all_tasks()
        assert out.value == 42
        assert view.get_value() == 42
        # at least one copy should have been cancelled or all finished with
        # identical results — either way the select picked a winner
        states = [t.state for t in tg.tasks if t.name.startswith("dup.copy")]
        assert all(s in ("finished", "cancelled") for s in states)
    finally:
        eng.stop()


def test_cancel_token_single_winner():
    tok = CancelToken()

    class T:  # minimal stand-in
        pass

    a, b = T(), T()
    tok.set(a)
    tok.set(b)
    assert tok.winner is a


def test_engine_keeps_explicit_empty_scheduler():
    # regression: schedulers define __len__, so an empty one is falsy —
    # `scheduler or FifoScheduler()` used to silently swap it for FIFO
    ws = WorkStealingScheduler()
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(1), scheduler=ws)
    try:
        assert eng.scheduler is ws
    finally:
        eng.stop()


def test_locality_routing_end_to_end():
    """Write-chains: after warmup, successors are pushed to the deque of the
    worker that produced their input, and get popped locally."""
    ws = WorkStealingScheduler(locality=True)
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(2), scheduler=ws)
    try:
        tg = SpTaskGraph().compute_on(eng)
        cells = [SpData(0, f"c{i}") for i in range(4)]
        for step in range(20):
            for c in cells:
                tg.task(SpWrite(c), lambda r: None)
        tg.wait_all_tasks()
        s = ws.stats()
        # counters are deliberately lock-free (a lost increment is harmless
        # for monitoring), so assert a tolerant range, not exact equality
        assert 70 <= s["pushes"] <= 80, s
        assert s["locality_hits"] > 0, s
        assert s["pops_local"] > 0, s
        # every cell's last writer is one of this engine's workers
        names = {w.name for w in eng._workers}
        assert all(c.last_writer in names for c in cells)
    finally:
        eng.stop()


def test_send_workers_mid_run_keeps_deque_invariants():
    """Moving workers while a work-stealing graph is executing must not
    lose tasks: detached workers' deques drain to overflow and everything
    still completes."""
    ws = WorkStealingScheduler(locality=True)
    a = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(3), scheduler=ws, name="a")
    b = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(1), name="b")
    try:
        tg = SpTaskGraph().compute_on(a)
        cells = [SpData(0, f"c{i}") for i in range(6)]
        for step in range(30):
            for c in cells:
                tg.task(SpWrite(c), lambda r: time.sleep(0.0005))
            if step == 5:
                assert a.send_workers_to(b, 2) == 2
        tg.wait_all_tasks(timeout=30.0)
        assert len(ws) == 0  # no task left behind in any deque
        assert all(c.version > 0 for c in cells)
        deadline = time.time() + 2.0
        while time.time() < deadline and (a.n_workers, b.n_workers) != (1, 3):
            time.sleep(0.01)
        assert (a.n_workers, b.n_workers) == (1, 3)
    finally:
        a.stop()
        b.stop()


def test_trace_opt_out_records_nothing():
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(2))
    try:
        tg = SpTaskGraph(trace=False).compute_on(eng)
        x = SpData(0, "x")
        for _ in range(10):
            tg.task(SpWrite(x), lambda r: None)
        tg.wait_all_tasks()
        assert tg.trace_events == []
        assert trace_metrics(tg) == {"n_tasks": 0}

        # default stays opt-out-able: trace=True records and metrics work
        tg2 = SpTaskGraph(trace=True).compute_on(eng)
        for _ in range(10):
            tg2.task(SpWrite(x), lambda r: None)
        tg2.wait_all_tasks()
        assert len(tg2.trace_events) == 10
        m = trace_metrics(tg2)
        assert m["n_tasks"] == 10 and m["utilization"] > 0
    finally:
        eng.stop()


def test_commutative_handles_precomputed_at_insert():
    from repro.core import SpCommutativeWrite

    tg = SpTaskGraph()
    a, b = SpData(0, "a"), SpData(0, "b")
    v = tg.task(SpCommutativeWrite(b), SpCommutativeWrite(a), lambda rb, ra: None)
    uids = [h.data.uid for h in v.task.commutative_handles]
    assert uids == sorted(uids) and len(uids) == 2
    v2 = tg.task(SpRead(a), lambda x: None)
    assert v2.task.commutative_handles == ()

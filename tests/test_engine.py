"""Compute engines, worker teams, dynamic moves, straggler duplication."""
from __future__ import annotations

import time

import pytest

from repro.core import (
    SpComputeEngine,
    SpData,
    SpRead,
    SpTaskGraph,
    SpWorkerTeamBuilder,
    SpWrite,
)
from repro.dist.fault import CancelToken, run_duplicated


def test_team_builders():
    t = SpWorkerTeamBuilder.team_of_cpu_workers(3)
    assert len(t) == 3
    t2 = SpWorkerTeamBuilder.team_of_cpu_cuda_workers(2, 1)
    assert t2.kinds.count("ref") == 2 and t2.kinds.count("pallas") == 1


def test_move_workers_between_engines():
    a = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(4), name="a")
    b = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(1), name="b")
    try:
        moved = a.send_workers_to(b, 2)
        assert moved == 2
        deadline = time.time() + 2.0
        while time.time() < deadline and (a.n_workers, b.n_workers) != (2, 3):
            time.sleep(0.01)
        assert (a.n_workers, b.n_workers) == (2, 3)
        # engine b still executes fine after the move
        tg = SpTaskGraph().compute_on(b)
        x = SpData(5, "x")
        assert tg.task(SpRead(x), lambda v: v + 1).get_value() == 6
    finally:
        a.stop()
        b.stop()


def test_multiple_graphs_one_engine():
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(2))
    try:
        tgs = [SpTaskGraph().compute_on(eng) for _ in range(3)]
        outs = []
        for i, tg in enumerate(tgs):
            x = SpData(i, f"x{i}")
            outs.append(tg.task(SpRead(x), lambda v: v * 2))
        assert [o.get_value() for o in outs] == [0, 2, 4]
    finally:
        eng.stop()


def test_straggler_duplicates_first_wins():
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(4))
    try:
        tg = SpTaskGraph().compute_on(eng)
        x = SpData(21, "x")
        out = SpData(None, "out")
        view = run_duplicated(tg, lambda v: v * 2, [x], out, n=3, name="dup")
        tg.wait_all_tasks()
        assert out.value == 42
        assert view.get_value() == 42
        # at least one copy should have been cancelled or all finished with
        # identical results — either way the select picked a winner
        states = [t.state for t in tg.tasks if t.name.startswith("dup.copy")]
        assert all(s in ("finished", "cancelled") for s in states)
    finally:
        eng.stop()


def test_cancel_token_single_winner():
    tok = CancelToken()

    class T:  # minimal stand-in
        pass

    a, b = T(), T()
    tok.set(a)
    tok.set(b)
    assert tok.winner is a

"""Schedulers (paper §4.5) + staged linearization — incl. the property that
every policy emits a valid topological order of random STF streams."""
from __future__ import annotations

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    AccessMode,
    CriticalPathScheduler,
    FifoScheduler,
    LifoScheduler,
    PriorityScheduler,
    SpCommutativeWrite,
    SpData,
    SpPriority,
    SpRead,
    SpTaskGraph,
    SpWrite,
    WorkStealingScheduler,
    compute_upward_ranks,
    execute_staged,
    linearize,
    make_scheduler,
    schedule_summary,
)
from repro.core.task import Task
from repro.core.access import SpAccess


def _mk_task(name, prio=0, cost=1.0):
    x = SpData(0, name + ".x")
    acc = SpAccess(x, AccessMode.READ)
    t = Task({"ref": lambda v: None}, [acc], [("single", acc)], priority=prio, name=name, cost=cost)
    t.state = "ready"
    return t


def test_fifo_lifo_priority_order():
    f, l, p = FifoScheduler(), LifoScheduler(), PriorityScheduler()
    tasks = [_mk_task(f"t{i}", prio=i) for i in range(3)]
    for s in (f, l, p):
        for t in tasks:
            s.push(t)
    assert [f.pop().name for _ in range(3)] == ["t0", "t1", "t2"]
    assert [l.pop().name for _ in range(3)] == ["t2", "t1", "t0"]
    assert [p.pop().name for _ in range(3)] == ["t2", "t1", "t0"]
    assert f.pop() is None


def test_work_stealing():
    ws = WorkStealingScheduler()
    for i in range(4):
        ws.push(_mk_task(f"t{i}"))
    got = []
    for _ in range(4):
        t = ws.pop(worker_name="w0")
        assert t is not None
        got.append(t.name)
    assert sorted(got) == ["t0", "t1", "t2", "t3"]


def _hinted_task(name, last_writer):
    t = _mk_task(name)
    t.accesses[0].data.last_writer = last_writer
    return t


def test_locality_push_lands_on_last_writer_deque():
    ws = WorkStealingScheduler(locality=True)
    ws.register_worker("wa")
    ws.register_worker("wb")
    owner = ws.push(_hinted_task("t0", "wb"))
    assert owner == "wb"
    assert ws.stats()["locality_hits"] == 1
    # wb pops its own deque — a local hit, not a steal
    t = ws.pop(worker_name="wb")
    assert t is not None and t.name == "t0"
    s = ws.stats()
    assert s["pops_local"] == 1 and s["steals"] == 0
    # a hint naming an unregistered worker falls back (no crash, no hit)
    owner = ws.push(_hinted_task("t1", "nonexistent-worker"))
    assert owner in ("wa", "wb")
    assert ws.stats()["locality_hits"] == 1


def test_dominant_input_wins_locality_vote():
    ws = WorkStealingScheduler(locality=True)
    ws.register_worker("wa")
    ws.register_worker("wb")
    xs = [SpData(0, f"x{i}") for i in range(3)]
    xs[0].last_writer = "wa"
    xs[1].last_writer = "wb"
    xs[2].last_writer = "wb"
    accs = [SpAccess(x, AccessMode.READ) for x in xs]
    t = Task({"ref": lambda *a: None}, accs, [("single", a) for a in accs], name="multi")
    assert ws.push(t) == "wb"


def test_steal_counters_increment():
    ws = WorkStealingScheduler(locality=False)
    ws.register_worker("wa")
    ws.register_worker("wb")
    for i in range(4):
        ws.push(_mk_task(f"t{i}"))
    # wc is not an owner of any deque → every pop is a steal
    ws.register_worker("wc")
    got = 0
    while ws.pop(worker_name="wc") is not None:
        got += 1
    assert got == 4
    s = ws.stats()
    assert s["steals"] == 4 and s["pops_local"] == 0
    assert s["failed_pops"] >= 1  # the final empty pop
    assert s["steal_rate"] == 1.0


def test_overflow_preferred_over_stealing():
    ws = WorkStealingScheduler(locality=False)
    ws.push(_mk_task("orphan"))  # no workers registered yet → overflow deque
    ws.register_worker("wa")
    ws.register_worker("wb")
    ws.push(_mk_task("r0"))
    ws.push(_mk_task("r1"))
    # wa/wb own deques hold r0/r1; a popper whose own deque is empty must
    # return the overflow task before stealing from a random victim
    popped = []
    for _ in range(3):
        t = ws.pop(worker_name="wc-idle")
        assert t is not None
        popped.append(t.name)
    assert popped[0] == "orphan"
    assert ws.stats()["pops_overflow"] == 1


def test_unregister_drains_to_overflow_and_gets_popped():
    ws = WorkStealingScheduler(locality=False)
    ws.register_worker("wa")
    ws.register_worker("wb")
    for i in range(4):
        ws.push(_mk_task(f"t{i}"))
    n_wa = len(ws._deques["wa"].q)
    ws.unregister_worker("wa")
    assert "wa" not in ws._deques
    # nothing lost: all 4 still poppable by the surviving worker
    names = []
    while True:
        t = ws.pop(worker_name="wb")
        if t is None:
            break
        names.append(t.name)
    assert sorted(names) == ["t0", "t1", "t2", "t3"]
    if n_wa:
        assert ws.stats()["pops_overflow"] == n_wa


def test_priority_len_is_thread_safe_under_lock():
    p = PriorityScheduler()
    assert len(p) == 0
    p.push(_mk_task("t", prio=3))
    assert len(p) == 1


def test_make_scheduler_registry():
    for name in ("fifo", "lifo", "priority", "critical_path", "work_stealing"):
        assert make_scheduler(name) is not None
    with pytest.raises(ValueError):
        make_scheduler("nope")


def _random_graph(seed_modes):
    tg = SpTaskGraph()
    cells = [SpData(0, f"c{i}") for i in range(3)]
    for i, (ci, mode_w) in enumerate(seed_modes):
        acc = SpWrite(cells[ci]) if mode_w else SpRead(cells[ci])
        tg.task(acc, lambda *_: None, name=f"t{i}", priority=i % 3)
    return tg


@settings(max_examples=30, deadline=None)
@given(
    seed_modes=st.lists(
        st.tuples(st.integers(0, 2), st.booleans()), min_size=1, max_size=12
    ),
    policy=st.sampled_from(["fifo", "priority", "critical_path", "overlap"]),
)
def test_property_linearize_is_topological(seed_modes, policy):
    tg = _random_graph(seed_modes)
    order = linearize(tg, policy)
    assert len(order) == len(tg.tasks)
    pos = {t.uid: i for i, t in enumerate(order)}
    for src, dst in tg.edges():
        assert pos[src.uid] < pos[dst.uid], f"{src.name} !< {dst.name} under {policy}"


def test_overlap_hoists_comm():
    tg = SpTaskGraph()
    xs = [SpData(0, f"x{i}") for i in range(3)]
    for i in range(3):
        tg.task(SpWrite(xs[i]), lambda r: None, name=f"compute{i}")
    tg.task(SpRead(xs[0]), lambda v: None, name="allreduce", comm=True)
    fifo = [t.name for t in linearize(tg, "fifo")]
    ovl = [t.name for t in linearize(tg, "overlap")]
    assert ovl.index("allreduce") < fifo.index("allreduce")
    s = schedule_summary(linearize(tg, "overlap"))
    assert s["n_comm"] == 1


def test_critical_path_ranks():
    tg = SpTaskGraph()
    a, b = SpData(0, "a"), SpData(0, "b")
    t1 = tg.task(SpWrite(a), lambda r: None, name="head", cost=1.0)
    tg.task(SpRead(a), lambda v: None, name="long", cost=10.0)
    tg.task(SpWrite(b), lambda r: None, name="solo", cost=1.0)
    compute_upward_ranks(tg.tasks, tg.successor_map())
    ranks = {t.name: t._rank for t in tg.tasks}
    assert ranks["head"] > ranks["solo"]  # head unlocks the expensive task


def test_execute_staged_respects_values():
    tg = SpTaskGraph()
    x = SpData(2.0, "x")
    y = SpData(0.0, "y")
    tg.task(SpRead(x), SpWrite(y), lambda v, r: setattr(r, "value", v + 1))
    tg.task(SpWrite(y), lambda r: setattr(r, "value", r.value * 10))
    order = execute_staged(tg, "fifo")
    assert y.value == 30.0
    assert len(order) == 2

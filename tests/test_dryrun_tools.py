"""Dry-run tooling: the collective-bytes HLO parser, config overrides,
input/cache specs, DOT + SVG exports."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.launch.dryrun import collective_stats, config_for_dryrun
from repro.launch.mesh import make_host_mesh


SAMPLE_HLO = """
HloModule test
ENTRY %main {
  %ag = bf16[256,1024]{1,0} all-gather(%p0), channel_id=1, replica_groups=[16,16]<=[256]T(1,0), dimensions={0}
  %ar = f32[128,128]{1,0} all-reduce(%x), channel_id=2, replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = bf16[16,1024]{1,0} reduce-scatter(%y), channel_id=3, replica_groups=[16,16]<=[256]T(1,0), dimensions={0}
  %agst = (bf16[8,4]{1,0}, bf16[128,4]{1,0}) all-gather-start(%z), channel_id=4, replica_groups=[16,16]<=[256]
  %cp = u32[64]{0} collective-permute(%w), channel_id=5, source_target_pairs={{0,1}}
  %fusion.1 = f32[4]{0} fusion(%a), kind=kLoop
}
"""


def test_collective_parser_kinds_and_bytes():
    s = collective_stats(SAMPLE_HLO)
    assert s["all-gather"]["count"] == 2  # plain + -start
    assert s["all-gather"]["bytes"] == 256 * 1024 * 2 + 128 * 4 * 2
    assert s["all-reduce"]["count"] == 1
    assert s["all-reduce"]["bytes"] == 128 * 128 * 4
    # reduce-scatter operand = result × group_size (16)
    assert s["reduce-scatter"]["bytes"] == 16 * 1024 * 2 * 16
    assert s["collective-permute"]["count"] == 1
    assert s["total_count"] == 5
    # wire estimates: AR counts 2×(g−1)/g
    assert s["all-reduce"]["wire_bytes"] == 2 * 128 * 128 * 4 * 3 // 4


def test_config_overrides_flat_and_nested():
    cfg = config_for_dryrun("qwen3-moe-235b-a22b", {"n_layers": 4, "moe.dispatch": "scatter"})
    assert cfg.n_layers == 4
    assert cfg.moe.dispatch == "scatter"
    assert cfg.opt_state_dtype == "bfloat16"  # arch-specific dry-run default


def test_input_and_cache_specs_cover_all_cells():
    from repro.configs import ARCH_NAMES, get_config
    from repro.models import abstract_cache, abstract_inputs, applicable_shapes

    n = 0
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            specs = abstract_inputs(cfg, shape)
            assert all(
                isinstance(leaf, jax.ShapeDtypeStruct) for leaf in jax.tree.leaves(specs)
            )
            if shape.kind == "decode":
                cache = abstract_cache(cfg, shape.global_batch, shape.seq_len)
                assert jax.tree.leaves(cache)
            n += 1
    assert n == 31  # the assigned-cell count after skip rules


def test_applicable_shape_rules():
    from repro.configs import get_config
    from repro.models import applicable_shapes

    names = lambda a: [s.name for s in applicable_shapes(get_config(a))]
    assert names("hubert-xlarge") == ["train_4k", "prefill_32k"]  # encoder-only
    assert "long_500k" not in names("gemma-7b")  # full attention
    assert "long_500k" in names("mamba2-130m")
    assert "long_500k" in names("recurrentgemma-9b")


def test_dot_and_trace_export(tmp_path):
    from repro.core import SpComputeEngine, SpData, SpRead, SpTaskGraph, SpWorkerTeamBuilder, SpWrite

    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(2))
    try:
        tg = SpTaskGraph().compute_on(eng)
        a, b = SpData(1, "a"), SpData(0, "b")
        tg.task(SpRead(a), SpWrite(b), lambda v, r: setattr(r, "value", v + 1), name="t1")
        tg.task(SpRead(b), lambda v: v, name="t2")
        tg.wait_all_tasks()
        dot = tg.generate_dot(str(tmp_path / "g.dot"), show_accesses=True)
        assert "t1" in dot and "->" in dot and "read:b" in dot
        svg = tg.generate_trace(str(tmp_path / "g.svg"))
        assert svg.startswith("<svg") and "t1" in svg
    finally:
        eng.stop()


def test_trace_metrics():
    import time

    from repro.core import (
        SpComputeEngine,
        SpData,
        SpRead,
        SpTaskGraph,
        SpWorkerTeamBuilder,
        trace_metrics,
    )

    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(2))
    try:
        tg = SpTaskGraph().compute_on(eng)
        x = SpData(1, "x")
        for _ in range(4):
            tg.task(SpRead(x), lambda v: time.sleep(0.01))
        tg.wait_all_tasks()
        m = trace_metrics(tg)
        assert m["n_tasks"] == 4
        assert 0 < m["utilization"] <= 1.0
        assert m["mean_task_us"] >= 9000
    finally:
        eng.stop()

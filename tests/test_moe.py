"""MoE layer: einsum (GShard) vs scatter dispatch equivalence, capacity
drops, aux losses, and router determinism."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models.moe import moe_apply, moe_defs, _capacity
from repro.models.param import init_tree


def _setup(dispatch: str, capacity_factor: float = 8.0, dtype="float32"):
    cfg = reduced_config("qwen3-moe-235b-a22b").replace(dtype=dtype)
    cfg = cfg.replace(
        moe=dataclasses.replace(cfg.moe, dispatch=dispatch, capacity_factor=capacity_factor)
    )
    params = init_tree(moe_defs(cfg), jax.random.PRNGKey(0), cfg.dtype)
    return cfg, params


def test_dispatch_strategies_agree_when_no_drops():
    """With generous capacity both dispatches route identically, so outputs
    must match (the §Perf lever changes FLOPs, not semantics)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64), jnp.float32)
    cfg_e, params = _setup("einsum")
    cfg_s, _ = _setup("scatter")
    y_e, aux_e = moe_apply(params, x, cfg_e)
    y_s, aux_s = moe_apply(params, x, cfg_s)
    np.testing.assert_allclose(np.asarray(y_e), np.asarray(y_s), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        float(aux_e["moe_balance"]), float(aux_s["moe_balance"]), rtol=1e-5
    )


def test_capacity_drops_reduce_output_norm():
    """Starving capacity must drop tokens (zero contribution), not crash."""
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 64), jnp.float32)
    cfg_full, params = _setup("einsum", capacity_factor=8.0)
    cfg_tight, _ = _setup("einsum", capacity_factor=0.25)
    y_full, _ = moe_apply(params, x, cfg_full)
    y_tight, _ = moe_apply(params, x, cfg_tight)
    assert float(jnp.linalg.norm(y_tight)) < float(jnp.linalg.norm(y_full))


def test_capacity_rounding():
    cfg, _ = _setup("einsum", capacity_factor=1.0)
    c = _capacity(4096, cfg)
    assert c % 8 == 0 and c >= 8


def test_moe_grads_flow_both_dispatches():
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 64), jnp.float32)
    for dispatch in ("einsum", "scatter"):
        cfg, params = _setup(dispatch)

        def loss(p):
            y, aux = moe_apply(p, x, cfg)
            return jnp.sum(y**2) + 0.01 * aux["moe_balance"]

        g = jax.grad(loss)(params)
        gnorm = sum(float(jnp.sum(jnp.square(t))) for t in jax.tree.leaves(g))
        assert np.isfinite(gnorm) and gnorm > 0, dispatch
        # router must receive gradient through the combine weights
        assert float(jnp.sum(jnp.abs(g["router"]))) > 0, dispatch

"""The cross-process wire: SocketTransport framing/rendezvous, collectives
over both transports, non-blocking poll contract, and the two-OS-process
ring-all-reduce acceptance path (spawned via multiprocessing)."""
from __future__ import annotations

import time

import numpy as np
import pytest

from repro.core import (
    ChannelHub,
    SocketTransport,
    SpCommGroup,
    SpComputeEngine,
    SpData,
    SpSerializer,
    SpTaskGraph,
    SpWorkerTeamBuilder,
    mpi_broadcast,
    mpi_recv,
    mpi_send,
)
from repro.core.comm import _RecvRequest
from repro.dist.collectives import ring_all_gather, ring_all_reduce
from repro.launch.rendezvous import run_ring_reduce


@pytest.fixture()
def engine():
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(4))
    yield eng
    eng.stop()


@pytest.fixture()
def socket_pair():
    """Two socket transports (ranks 0, 1) in one process over localhost."""
    t0 = SocketTransport(0, 2)
    t1 = SocketTransport(1, 2, port=t0.port)
    yield t0, t1
    t0.close()
    t1.close()


def _socket_ring(size: int):
    t0 = SocketTransport(0, size)
    rest = [SocketTransport(r, size, port=t0.port) for r in range(1, size)]
    return [t0, *rest]


# ---------------------------------------------------------------------------
# transport basics
# ---------------------------------------------------------------------------

def test_socket_transport_frames_keys_and_payloads(socket_pair):
    t0, t1 = socket_pair
    tag = ("rar", 3, "rs", 0)  # the collectives' structured-tuple tags
    t0.post((0, 1, tag), {"chunk": np.arange(5, dtype=np.float32), "step": 0})
    deadline = time.monotonic() + 5.0
    ok, msg = False, None
    while not ok and time.monotonic() < deadline:
        ok, msg = t1.poll((0, 1, tag))
        if not ok:
            time.sleep(0.002)
    assert ok
    np.testing.assert_array_equal(msg["chunk"], np.arange(5, dtype=np.float32))
    assert msg["step"] == 0
    # wrong tag / wrong direction never match
    assert t1.poll((0, 1, ("rar", 3, "rs", 1)))[0] is False
    assert t0.poll((0, 1, tag))[0] is False


def test_socket_transport_prunes_and_counts(socket_pair):
    t0, t1 = socket_pair
    for step in range(20):
        t0.post((0, 1, step), step)
    got = 0
    deadline = time.monotonic() + 5.0
    while got < 20 and time.monotonic() < deadline:
        ok, msg = t1.poll((0, 1, got))
        if ok:
            assert msg == got
            got += 1
        else:
            time.sleep(0.002)
    assert got == 20
    st = t1.stats()
    assert st["boxes"] == 0 and st["queued"] == 0
    assert st["received"] == 20 and st["delivered"] == 20
    assert t0.stats()["posted"] == 20


def test_socket_poll_is_nonblocking(socket_pair):
    t0, t1 = socket_pair
    t0_ = time.perf_counter()
    for _ in range(500):
        ok, _msg = t1.poll((0, 1, "never-posted"))
        assert not ok
    assert time.perf_counter() - t0_ < 1.0  # pure dict lookups, no recv()


def test_recv_request_test_only_polls():
    """CommRequest.test() must stay non-blocking: its only transport call is
    poll() — never a blocking receive — so the comm thread's test-any loop
    keeps progressing other requests."""

    class RecordingTransport:
        def __init__(self):
            self.calls = []

        def poll(self, key):
            self.calls.append(("poll", key))
            return False, None

        def __getattr__(self, name):  # any other method => contract breach
            raise AssertionError(f"request touched transport.{name}")

    tr = RecordingTransport()
    req = _RecvRequest(tr, (0, 1, "t"), ref=None)
    for _ in range(3):
        assert req.test() is False
    assert tr.calls == [("poll", (0, 1, "t"))] * 3


def test_sp_serialize_object_roundtrips_both_transports(engine, socket_pair):
    class Grid:
        def __init__(self, values):
            self.values = values

        def sp_serialize(self, s: SpSerializer) -> None:
            s.append_array(self.values)

        @classmethod
        def sp_deserialize(cls, d) -> "Grid":
            return cls(d.next_array())

    from repro.core import register_wire_type

    register_wire_type(Grid)  # local class: not importable, register by hand

    t_sock0, t_sock1 = socket_pair
    for hub0, hub1 in ((ChannelHub(),) * 2, (t_sock0, t_sock1)):
        g0, g1 = SpCommGroup(0, 2, hub0), SpCommGroup(1, 2, hub1)
        tg0 = SpTaskGraph().compute_on(engine)
        tg1 = SpTaskGraph().compute_on(engine)
        m = SpData(Grid(np.full((2, 3), 7.0)), "m")
        r = SpData(None, "r")
        mpi_recv(tg1, g1, r, src=0, tag="grid", timeout=30.0)
        mpi_send(tg0, g0, m, dest=1, tag="grid")
        tg0.wait_all_tasks()
        tg1.wait_all_tasks()
        assert isinstance(r.value, Grid)
        r.value.values += 1.0  # received arrays must be writable in place
        np.testing.assert_array_equal(r.value.values, np.full((2, 3), 8.0))


# ---------------------------------------------------------------------------
# collective numerics over both transports (threads in one process)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op", ["sum", "mean"])
def test_ring_all_reduce_socket_threads(engine, op):
    size = 3
    transports = _socket_ring(size)
    try:
        rng = np.random.default_rng(7)
        # 17 elements: not divisible by 3 — uneven chunk splits on the wire
        arrays = [rng.standard_normal(17).astype(np.float32) for _ in range(size)]
        groups = [
            SpCommGroup(r, size, transports[r], default_timeout=60.0)
            for r in range(size)
        ]
        graphs = [SpTaskGraph().compute_on(engine) for _ in range(size)]
        cells = [SpData(arrays[r].copy(), f"s{r}") for r in range(size)]
        for r in range(size):
            ring_all_reduce(graphs[r], groups[r], cells[r], op=op)
        for g in graphs:
            g.wait_all_tasks()
        expected = np.sum(np.stack(arrays).astype(np.float64), axis=0)
        if op == "mean":
            expected = expected / size
        for r in range(size):
            np.testing.assert_allclose(cells[r].value, expected, rtol=1e-5, atol=1e-6)
        for t in transports:
            assert t.stats()["boxes"] == 0  # all mailboxes drained + pruned
    finally:
        for t in transports:
            t.close()


def test_ring_all_gather_and_broadcast_socket_threads(engine):
    size = 2
    transports = _socket_ring(size)
    try:
        groups = [
            SpCommGroup(r, size, transports[r], default_timeout=60.0)
            for r in range(size)
        ]
        graphs = [SpTaskGraph().compute_on(engine) for _ in range(size)]

        cells = [SpData(np.arange(4) + 10 * r, f"x{r}") for r in range(size)]
        views = [
            ring_all_gather(graphs[r], groups[r], cells[r]) for r in range(size)
        ]
        bcells = [
            SpData(np.linspace(0, 1, 5) if r == 0 else None, f"b{r}")
            for r in range(size)
        ]
        for r in range(size):
            mpi_broadcast(graphs[r], groups[r], bcells[r], root=0)
        for g in graphs:
            g.wait_all_tasks()

        for r in range(size):
            got = views[r].get_value()
            assert len(got) == size
            for src in range(size):
                np.testing.assert_array_equal(got[src], np.arange(4) + 10 * src)
            np.testing.assert_array_equal(bcells[r].value, np.linspace(0, 1, 5))
    finally:
        for t in transports:
            t.close()


# ---------------------------------------------------------------------------
# the acceptance path: two OS processes over real TCP
# ---------------------------------------------------------------------------

def test_two_process_ring_all_reduce_over_tcp():
    """Two spawned processes reduce float32[4099] (odd: non-divisible
    chunks) over the socket transport; the sum must match the NumPy
    reference bit-for-bit (each element is one float32 addition at size 2),
    the mean must match allclose, and both ranks must agree."""
    size, n = 2, 4099
    results = run_ring_reduce(size, n, steps=2, timeout=300.0)
    arrays = [
        np.random.default_rng(r).standard_normal(n).astype(np.float32)
        for r in range(size)
    ]
    expected_sum = arrays[0] + arrays[1]
    for rank in range(size):
        got = results[rank]["sum"]
        assert got.dtype == np.float32
        np.testing.assert_array_equal(got, expected_sum)  # bit-for-bit
        np.testing.assert_allclose(
            results[rank]["mean"], expected_sum / size, rtol=1e-6
        )
        # every per-step mailbox was drained and pruned on both ranks
        st = results[rank]["stats"]
        assert st["boxes"] == 0 and st["queued"] == 0
        assert st["received"] == st["delivered"] > 0
    np.testing.assert_array_equal(results[0]["sum"], results[1]["sum"])

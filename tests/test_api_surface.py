"""Public-API snapshot: ``repro.core.__all__`` is a compatibility contract.

Old names must keep resolving (the positional spelling is the documented
compatibility form) and the codelet-frontend surface must stay exported.
Update the snapshot deliberately when the API grows — never by accident.
"""
import subprocess
import sys
from pathlib import Path

import pytest

import repro.core as core

# The quickstart subprocess compiles both backends and allows itself 300s;
# the pytest-timeout cap must sit above that.
pytestmark = pytest.mark.timeout(360)

# frozen snapshot — PR 4 (codelet frontend) state
EXPECTED = sorted([
    # access modes / data
    "AccessMode", "SpAccess", "SpArrayAccess", "SpAtomicWrite",
    "SpAtomicWriteArray", "SpCommutativeWrite", "SpCommutativeWriteArray",
    "SpData", "SpMaybeWrite", "SpMaybeWriteArray", "SpPriority", "SpRead",
    "SpReadArray", "SpWrite", "SpWriteArray", "SpWriteRef",
    # impl variants
    "SpCpu", "SpCuda", "SpHip", "SpHost", "SpImpl", "SpPallas", "SpRef",
    # comm (PR 5: transport split + wire codec; PR 6: failure detection)
    "ChannelHub", "SocketTransport", "SpTransport", "SpCommGroup",
    "SpCommError", "SpCommTimeoutError", "SpCommAbortedError",
    "SpCommTransientError", "SpRankDeadError",
    "SpDeserializer", "SpSerializer", "decode_message", "default_hub",
    "encode_message", "register_wire_type", "reset_default_hub",
    "mpi_broadcast", "mpi_recv", "mpi_send",
    # engine / graph / runtime
    "SpComputeEngine", "SpWorker", "SpWorkerTeam", "SpWorkerTeamBuilder",
    "SpRuntime", "SpSpeculativeModel", "SpTaskGraph",
    # codelet frontend (PR 4)
    "SpCodelet", "SpSlot", "sp_task", "graph_scope", "current_graph",
    # schedulers
    "CriticalPathScheduler", "FifoScheduler", "LifoScheduler",
    "PriorityScheduler", "SpAbstractScheduler", "WorkStealingScheduler",
    "compute_upward_ranks", "make_scheduler",
    # staged backend + introspection
    "execute_staged", "linearize", "schedule_summary", "trace_metrics",
    # task internals
    "Task", "TaskState", "TaskView",
    # robustness (ISSUE 8): policies, watchdog timeout, elastic runtime
    "ElasticEvent", "SpTaskPolicy", "SpTaskTimeoutError",
])


def test_public_api_snapshot():
    assert sorted(core.__all__) == EXPECTED


def test_all_names_resolve():
    missing = [n for n in core.__all__ if not hasattr(core, n)]
    assert not missing, f"__all__ names that do not resolve: {missing}"


def test_quickstart_example_runs():
    """The quickstart is the documented tour of the frontend; it must run
    (also exercised as a CI smoke step)."""
    repo = Path(__file__).resolve().parent.parent
    proc = subprocess.run(
        [sys.executable, str(repo / "examples" / "quickstart.py")],
        capture_output=True,
        text=True,
        timeout=300,
        env={
            **__import__("os").environ,
            "PYTHONPATH": str(repo / "src"),
        },
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "staged b =" in proc.stdout  # both backends actually ran

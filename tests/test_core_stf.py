"""STF semantics of the core runtime (paper §4.1, §4.7) — unit + property.

The central invariant (the STF contract): *any* parallel execution produces
exactly the state a sequential execution of the insertion stream would —
verified by a hypothesis property over random task streams with random
access modes, executed on 1 and 4 workers and compared against a sequential
interpreter.
"""
from __future__ import annotations

import threading
import time

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    AccessMode,
    FifoScheduler,
    PriorityScheduler,
    SpAtomicWrite,
    SpCommutativeWrite,
    SpComputeEngine,
    SpData,
    SpPriority,
    SpRead,
    SpReadArray,
    SpTaskGraph,
    SpWorkerTeamBuilder,
    SpWrite,
    SpWriteArray,
)


@pytest.fixture(scope="module")
def engine():
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(4))
    yield eng
    eng.stop()


def test_raw_war_waw_ordering(engine):
    tg = SpTaskGraph().compute_on(engine)
    x = SpData(1.0, "x")
    log = []

    def writer(tag):
        def body(ref):
            time.sleep(0.005)
            log.append(tag)
            ref.value = ref.value * 2

        return body

    def reader(tag):
        def body(v):
            log.append((tag, v))
            return v

        return body

    tg.task(SpWrite(x), writer("w1"))
    tg.task(SpRead(x), reader("r1"))
    tg.task(SpWrite(x), writer("w2"))
    tg.task(SpRead(x), reader("r2"))
    tg.wait_all_tasks()
    assert x.value == 4.0
    assert log == ["w1", ("r1", 2.0), "w2", ("r2", 4.0)]


def test_parallel_reads_overlap(engine):
    tg = SpTaskGraph().compute_on(engine)
    x = SpData(0, "x")
    t0 = time.perf_counter()
    for _ in range(4):
        tg.task(SpRead(x), lambda v: time.sleep(0.05))
    tg.wait_all_tasks()
    assert time.perf_counter() - t0 < 0.15  # 4×50ms would be 0.2s serial


def test_commutative_mutual_exclusion_and_completeness(engine):
    tg = SpTaskGraph().compute_on(engine)
    acc = SpData(0, "acc")
    inside = {"n": 0, "max": 0}
    lock = threading.Lock()

    def bump(ref):
        with lock:
            inside["n"] += 1
            inside["max"] = max(inside["max"], inside["n"])
        time.sleep(0.002)
        ref.value = ref.value + 1
        with lock:
            inside["n"] -= 1

    for _ in range(16):
        tg.task(SpCommutativeWrite(acc), bump)
    tg.wait_all_tasks()
    assert acc.value == 16  # no lost updates
    assert inside["max"] == 1  # runtime mutual exclusion (paper §4.7)


def test_atomic_writes_concurrent(engine):
    tg = SpTaskGraph().compute_on(engine)
    cell = SpData([], "cell")
    lock = threading.Lock()

    def atomic_append(ref):
        time.sleep(0.02)
        with lock:  # user-provided protection (the SpAtomicWrite contract)
            ref.value.append(1)  # IN-PLACE: atomic writers share the object

    t0 = time.perf_counter()
    for _ in range(4):
        tg.task(SpAtomicWrite(cell), atomic_append)
    tg.wait_all_tasks()
    assert len(cell.value) == 4
    assert time.perf_counter() - t0 < 0.06  # ran concurrently


def test_array_views(engine):
    tg = SpTaskGraph().compute_on(engine)
    cells = [SpData(i, f"c{i}") for i in range(8)]

    def scale(refs):
        for r in refs:
            r.value = r.value * 10

    tg.task(SpWriteArray(cells, range(0, 8, 2)), scale)
    v = tg.task(SpReadArray(cells, [0, 2, 4, 6]), lambda vals: sum(vals))
    assert v.get_value() == (0 + 20 + 40 + 60)
    assert cells[1].value == 1  # untouched


def test_task_viewer_and_priority(engine):
    tg = SpTaskGraph().compute_on(engine)
    x = SpData(3, "x")
    view = tg.task(SpPriority(7), SpRead(x), lambda v: v * v)
    view.set_task_name("square")
    assert view.get_value() == 9
    assert view.get_task_name() == "square"
    assert view.task.priority == 7


def test_exceptions_propagate(engine):
    tg = SpTaskGraph().compute_on(engine)
    x = SpData(1, "x")

    def boom(v):
        raise RuntimeError("task failed")

    tg.task(SpRead(x), boom)
    with pytest.raises(RuntimeError, match="task failed"):
        tg.wait_all_tasks()


def test_duplicate_handle_rejected(engine):
    tg = SpTaskGraph()
    x = SpData(1, "x")
    with pytest.raises(ValueError, match="twice"):
        tg.task(SpRead(x), SpWrite(x), lambda a, b: None)


def test_recursive_subgraph(engine):
    tg = SpTaskGraph().compute_on(engine)
    out = SpData(0, "out")

    def parent(ref):
        sub = SpTaskGraph().compute_on(engine)
        inner = SpData(0, "inner")
        for _ in range(3):
            sub.task(SpCommutativeWrite(inner), lambda r: setattr(r, "value", r.value + 1))
        sub.wait_all_tasks()
        ref.value = inner.value

    tg.task(SpWrite(out), parent)
    tg.wait_all_tasks()
    assert out.value == 3


# ---------------------------------------------------------------------------
# Property: parallel == sequential for random access streams
# ---------------------------------------------------------------------------

# ATOMIC_WRITE is excluded: its contract is in-place mutation (see
# test_atomic_writes_concurrent); the oracle below models copy-out/copy-in
MODES = [AccessMode.READ, AccessMode.WRITE, AccessMode.COMMUTATIVE_WRITE]
WRAP = {
    AccessMode.READ: SpRead,
    AccessMode.WRITE: SpWrite,
    AccessMode.COMMUTATIVE_WRITE: SpCommutativeWrite,
    AccessMode.ATOMIC_WRITE: SpAtomicWrite,
}

task_strategy = st.lists(
    st.tuples(
        st.lists(  # (cell_idx, mode) accesses, unique cells per task
            st.tuples(st.integers(0, 3), st.sampled_from(MODES)),
            min_size=1,
            max_size=3,
            unique_by=lambda t: t[0],
        ),
        st.integers(1, 5),  # multiplier used by the task body
    ),
    min_size=1,
    max_size=14,
)


def _sequential_oracle(stream):
    cells = [0, 10, 20, 30]
    for accesses, mult in stream:
        read_sum = sum(cells[i] for i, m in accesses if m is AccessMode.READ)
        for i, m in accesses:
            if m is not AccessMode.READ:
                cells[i] = cells[i] + mult + read_sum
    return cells


@settings(max_examples=40, deadline=None)
@given(stream=task_strategy, n_workers=st.sampled_from([1, 4]))
def test_property_parallel_equals_sequential(stream, n_workers):
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(n_workers))
    try:
        tg = SpTaskGraph()
        cells = [SpData(v, f"c{i}") for i, v in enumerate([0, 10, 20, 30])]
        lock = threading.Lock()

        def make_body(accesses, mult):
            modes = [m for _, m in accesses]

            def body(*args):
                read_sum = sum(
                    a for a, m in zip(args, modes) if m is AccessMode.READ
                )
                for a, m in zip(args, modes):
                    if m is not AccessMode.READ:
                        a.value = a.value + mult + read_sum

            return body

        for accesses, mult in stream:
            tg.task(
                *[WRAP[m](cells[i]) for i, m in accesses],
                make_body(accesses, mult),
            )
        tg.compute_on(eng)
        tg.wait_all_tasks()
        got = [c.value for c in cells]
        want = _sequential_oracle(stream)
        # commutative/atomic groups are order-free, but all ops here are
        # commutative additions, so the final state must match exactly
        assert got == want
    finally:
        eng.stop()

"""Speculative decoding subsystem (ISSUE 9): draft/verify/commit rounds on
the serve engine's SP_MODEL_2 commit/rollback machinery.

The load-bearing property everywhere: committed output is **bit-exact**
with the non-speculative engine (and the sequential oracle) — for greedy
and seeded sampling, across mixed spec/plain batches, mid-flight
join/leave, forced rollback, and preemption — because commits only ever
publish the target model's own sampled tokens.
"""
from __future__ import annotations

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.timeout(300)

from repro.configs import reduced_config
from repro.models import decode_step, init_params, prefill
from repro.runtime.serve import prime_cache
from repro.serving import KVPagePool, PageError, ServeEngine, ServeScheduler, shrunken_draft


@pytest.fixture(scope="module")
def served():
    cfg = reduced_config("deepseek-7b").replace(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def garbage_draft(served):
    """Same architecture, unrelated weights: proposals are mostly wrong."""
    cfg, _ = served
    return cfg, init_params(jax.random.PRNGKey(99), cfg)


def _oracle(cfg, params, prompt, n, max_seq=48, temperature=0.0, seed=0):
    """Prefill + sequential decode with the engine's sampling rule
    (absolute-position-folded keys)."""
    logits, caches = prefill(params, {"tokens": jnp.asarray(prompt[None, :])}, cfg)
    caches = prime_cache(cfg, caches, len(prompt), max_seq)
    out = []
    lg = logits[0, -1]
    pos = len(prompt)
    while True:
        if temperature == 0.0:
            out.append(int(jnp.argmax(lg)))
        else:
            key = jax.random.fold_in(
                jax.random.PRNGKey(seed), len(prompt) + len(out)
            )
            out.append(int(jax.random.categorical(key, lg / temperature)))
        if len(out) >= n:
            return out
        lg_all, caches = decode_step(
            params, jnp.asarray([[out[-1]]], jnp.int32), caches, jnp.int32(pos), cfg
        )
        lg = lg_all[0, 0]
        pos += 1


def _prompts(cfg, lens, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, size=L).astype(np.int32) for L in lens]


# ---------------------------------------------------------------------------
# bit-exactness across draft qualities and batch mixes
# ---------------------------------------------------------------------------

def test_self_draft_bit_exact_mixed_batch(served):
    """Draft == target: every proposal accepted, mixed spec/plain batch
    matches the sequential oracle token for token."""
    cfg, params = served
    prompts = _prompts(cfg, (6, 9, 5, 7))
    with ServeEngine(cfg, params, n_slots=3, max_seq=48, block_size=4,
                     draft_cfg=cfg, draft_params=params, draft_k=3) as eng:
        reqs = [eng.submit(p, 10, speculative=(i % 2 == 0))
                for i, p in enumerate(prompts)]
        eng.run_until_drained()
        for p, r in zip(prompts, reqs):
            assert r.out_tokens == _oracle(cfg, params, p, 10)
        sp = eng.stats()["spec"]
        assert sp["accept_rate"] == 1.0
        assert sp["graph"]["commits"] > 0 and sp["graph"]["rollbacks"] == 0
        # spec requests carry per-request round accounting
        assert reqs[0].spec_rounds > 0
        assert reqs[0].spec_accepted > 0
        assert reqs[1].spec_rounds == 0  # plain rider


def test_garbage_draft_still_bit_exact(served, garbage_draft):
    """A draft that proposes junk costs speed, never correctness."""
    cfg, params = served
    _, gparams = garbage_draft
    prompts = _prompts(cfg, (6, 9, 5), seed=7)
    with ServeEngine(cfg, params, n_slots=3, max_seq=48, block_size=4,
                     draft_cfg=cfg, draft_params=gparams, draft_k=3) as eng:
        reqs = [eng.submit(p, 8, speculative=True) for p in prompts]
        eng.run_until_drained()
        for p, r in zip(prompts, reqs):
            assert r.out_tokens == _oracle(cfg, params, p, 8)
        sp = eng.stats()["spec"]
        assert sp["accept_rate"] < 0.5  # junk proposals mostly rejected
        # rejection never rolls the graph back — it is decided inside verify
        assert sp["graph"]["rollbacks"] == 0


def test_shrunken_draft_bit_exact(served):
    cfg, params = served
    dcfg, dparams = shrunken_draft(cfg, params, n_layers=1)
    assert dcfg.n_layers == 1
    prompts = _prompts(cfg, (6, 7), seed=11)
    with ServeEngine(cfg, params, n_slots=2, max_seq=48, block_size=4,
                     draft_cfg=dcfg, draft_params=dparams, draft_k=3) as eng:
        reqs = [eng.submit(p, 8, speculative=True) for p in prompts]
        eng.run_until_drained()
        for p, r in zip(prompts, reqs):
            assert r.out_tokens == _oracle(cfg, params, p, 8)


def test_shrunken_draft_rejects_non_pageable():
    cfg = reduced_config("mamba2-130m")
    with pytest.raises(ValueError):
        shrunken_draft(cfg, None, n_layers=1)


def test_mid_flight_join_and_leave(served):
    """Requests joining/finishing mid-round: spec slots keep decoding
    bit-exact while the batch composition churns."""
    cfg, params = served
    prompts = _prompts(cfg, (6, 9, 5), seed=13)
    with ServeEngine(cfg, params, n_slots=3, max_seq=64, block_size=4,
                     draft_cfg=cfg, draft_params=params, draft_k=3) as eng:
        a = eng.submit(prompts[0], 14, speculative=True)
        b = eng.submit(prompts[1], 3, speculative=False)  # leaves early
        for _ in range(2):
            eng.step(wait=True)
        c = eng.submit(prompts[2], 9, speculative=True)  # joins mid-flight
        eng.run_until_drained()
        assert a.out_tokens == _oracle(cfg, params, prompts[0], 14, max_seq=64)
        assert b.out_tokens == _oracle(cfg, params, prompts[1], 3, max_seq=64)
        assert c.out_tokens == _oracle(cfg, params, prompts[2], 9, max_seq=64)


def test_forced_rollback_recovers_bit_exact(served):
    """A poisoned round re-runs verify on the real state (SP_MODEL_2
    rollback) and commits nothing speculative — output stays exact."""
    cfg, params = served
    prompts = _prompts(cfg, (6, 9), seed=17)
    with ServeEngine(cfg, params, n_slots=2, max_seq=48, block_size=4,
                     draft_cfg=cfg, draft_params=params, draft_k=3) as eng:
        reqs = [eng.submit(p, 10, speculative=True) for p in prompts]
        eng.step(wait=True)
        eng.force_rollback(2)
        eng.run_until_drained()
        for p, r in zip(prompts, reqs):
            assert r.out_tokens == _oracle(cfg, params, p, 10)
        sp = eng.stats()["spec"]
        assert sp["rollback_rounds"] == 2
        assert sp["graph"]["rollbacks"] == 2
        assert sp["graph"]["commits"] > 0


def test_force_rollback_requires_draft(served):
    cfg, params = served
    with ServeEngine(cfg, params, n_slots=2, max_seq=32, block_size=4) as eng:
        with pytest.raises(RuntimeError):
            eng.force_rollback()


def test_preemption_and_shed_under_pool_pressure(served):
    """A pool too small for the batch forces preemptions and speculation
    sheds mid-run; committed output still matches the oracle."""
    cfg, params = served
    prompts = _prompts(cfg, (6, 9, 5, 7, 8, 6), seed=19)
    with ServeEngine(cfg, params, n_slots=4, max_seq=64, block_size=4,
                     n_blocks=12, draft_cfg=cfg, draft_params=params,
                     draft_k=4) as eng:
        reqs = [eng.submit(p, 12, speculative=(i % 2 == 0))
                for i, p in enumerate(prompts)]
        eng.run_until_drained()
        st = eng.stats()
        assert st["preemptions"] > 0
        assert st["spec"]["sheds"] > 0
        for p, r in zip(prompts, reqs):
            assert r.out_tokens == _oracle(cfg, params, p, 12, max_seq=64)


def test_sampled_spec_matches_plain_and_oracle(served):
    """Seeded sampling is bit-exact too: keys fold the absolute token
    position, so verify sub-steps and plain decode draw identical keys."""
    cfg, params = served
    prompts = _prompts(cfg, (6, 9, 5), seed=23)
    kw = dict(temperature=0.8, top_k=0)
    with ServeEngine(cfg, params, n_slots=3, max_seq=48, block_size=4,
                     draft_cfg=cfg, draft_params=params, draft_k=3) as eng:
        reqs = [eng.submit(p, 8, seed=5 + i, speculative=True, **kw)
                for i, p in enumerate(prompts)]
        eng.run_until_drained()
        spec_out = [r.out_tokens for r in reqs]
    with ServeEngine(cfg, params, n_slots=3, max_seq=48, block_size=4) as eng:
        reqs = [eng.submit(p, 8, seed=5 + i, **kw) for i, p in enumerate(prompts)]
        eng.run_until_drained()
        plain_out = [r.out_tokens for r in reqs]
    assert spec_out == plain_out
    for i, p in enumerate(prompts):
        assert spec_out[i] == _oracle(cfg, params, p, 8,
                                      temperature=0.8, seed=5 + i)


def test_sampling_key_folds_position_not_step(served):
    """Regression (satellite 3): a preempted-and-resumed sampled request
    must reproduce the uninterrupted run.  Engine-step-folded keys would
    resample resumed positions with different keys."""
    cfg, params = served
    prompts = _prompts(cfg, (6, 9, 5, 7, 8, 6), seed=29)
    def run(n_blocks):
        with ServeEngine(cfg, params, n_slots=3, max_seq=64, block_size=4,
                         n_blocks=n_blocks) as eng:
            reqs = [eng.submit(p, 10, temperature=0.7, seed=i)
                    for i, p in enumerate(prompts)]
            eng.run_until_drained()
            return [r.out_tokens for r in reqs], eng.stats()["preemptions"]
    roomy, _ = run(n_blocks=64)
    tight, preempts = run(n_blocks=12)
    assert preempts > 0, "pool must be tight enough to force preemption"
    assert tight == roomy


# ---------------------------------------------------------------------------
# streaming (satellite 1)
# ---------------------------------------------------------------------------

def test_on_token_sees_only_committed_tokens(served):
    cfg, params = served
    [p] = _prompts(cfg, (6,), seed=31)
    got = []
    with ServeEngine(cfg, params, n_slots=2, max_seq=48, block_size=4,
                     draft_cfg=cfg, draft_params=params, draft_k=3) as eng:
        r = eng.submit(p, 10, speculative=True, on_token=got.append)
        eng.run_until_drained()
    assert got == r.out_tokens == _oracle(cfg, params, p, 10)


def test_stream_iterator_from_consumer_thread(served):
    cfg, params = served
    [p] = _prompts(cfg, (7,), seed=37)
    with ServeEngine(cfg, params, n_slots=2, max_seq=48, block_size=4,
                     draft_cfg=cfg, draft_params=params, draft_k=3) as eng:
        r = eng.submit(p, 10, speculative=True)
        got = []
        t = threading.Thread(target=lambda: got.extend(r.stream(timeout=120)))
        t.start()
        eng.run_until_drained()
        t.join(timeout=120)
        assert not t.is_alive()
    assert got == r.out_tokens == _oracle(cfg, params, p, 10)


def test_on_token_exception_counted_not_fatal(served):
    cfg, params = served
    [p] = _prompts(cfg, (6,), seed=41)
    def boom(tok):
        raise RuntimeError("consumer bug")
    with ServeEngine(cfg, params, n_slots=1, max_seq=32, block_size=4) as eng:
        r = eng.submit(p, 5, on_token=boom)
        eng.run_until_drained()
        assert r.done and len(r.out_tokens) == 5
        assert eng.stats()["stream_errors"] == 5


# ---------------------------------------------------------------------------
# opt-in and configuration errors
# ---------------------------------------------------------------------------

def test_speculative_submit_requires_draft(served):
    cfg, params = served
    with ServeEngine(cfg, params, n_slots=1, max_seq=32, block_size=4) as eng:
        with pytest.raises(ValueError):
            eng.submit(np.arange(4, dtype=np.int32), 4, speculative=True)


def test_draft_vocab_must_match(served):
    cfg, params = served
    bad = cfg.replace(vocab=cfg.vocab // 2)
    bad_params = init_params(jax.random.PRNGKey(0), bad)
    with pytest.raises(ValueError):
        ServeEngine(cfg, params, n_slots=1, max_seq=32, block_size=4,
                    draft_cfg=bad, draft_params=bad_params, draft_k=2)


# ---------------------------------------------------------------------------
# scheduler knobs (satellite 2) and draft-depth policy
# ---------------------------------------------------------------------------

def test_max_batch_caps_running(served):
    cfg, params = served
    prompts = _prompts(cfg, (5, 6, 7, 8), seed=43)
    with ServeEngine(cfg, params, n_slots=4, max_seq=32, block_size=4,
                     max_batch=2) as eng:
        reqs = [eng.submit(p, 4) for p in prompts]
        peak = 0
        while not all(r.done for r in reqs):
            eng.step(wait=True)
            peak = max(peak, eng.n_running)
        assert peak <= 2
        for p, r in zip(prompts, reqs):
            assert r.out_tokens == _oracle(cfg, params, p, 4, max_seq=32)


def test_max_batch_validation():
    pool = KVPagePool(8, block_size=4)
    with pytest.raises(ValueError):
        ServeScheduler(pool, 4, max_batch=0)
    with pytest.raises(ValueError):
        ServeScheduler(pool, 4, max_batch=5)


def test_admit_max_wait_batches_arrivals():
    """Within the batching window a lone waiter is held back; it is
    admitted once the window expires or the batch can fill."""
    import time

    pool = KVPagePool(32, block_size=4)
    sched = ServeScheduler(pool, 4, admit_max_wait=10.0)

    class R:
        def __init__(self, rid):
            self.req_id = rid
            self.prompt = [1, 2, 3]
            self.out_tokens = []
            self.t_arrival = time.perf_counter()
    r1 = R(1)
    sched.submit(r1)
    assert sched.plan(pageable=False) == []  # held: window open, batch not full
    for i in range(2, 6):
        sched.submit(R(i))
    adm = sched.plan(pageable=False)  # queue ≥ capacity → admit now
    assert len(adm) == 4
    # expired window admits even a lone waiter
    sched2 = ServeScheduler(pool, 2, admit_max_wait=0.01)
    late = R(9)
    late.t_arrival = time.perf_counter() - 1.0
    sched2.submit(late)
    assert len(sched2.plan(pageable=False)) == 1


def test_draft_depth_sheds_under_pool_pressure():
    pool = KVPagePool(4, block_size=4)
    sched = ServeScheduler(pool, 2, draft_k=4)
    assert sched.draft_depth(1) == 4  # headroom: full depth
    pool.allocate(1, list(range(14)))  # pin nearly everything
    assert sched.draft_depth(2) == 0  # no room for 2 slots' draft blocks
    assert sched.draft_depth(0) == 0
    assert ServeScheduler(pool, 2).draft_depth(1) == 0  # draft_k unset


# ---------------------------------------------------------------------------
# kvcache staging (uncommitted draft rows)
# ---------------------------------------------------------------------------

def test_pool_staged_rows_lifecycle():
    pool = KVPagePool(8, block_size=4)
    pool.allocate(1, [1, 2, 3])
    pool.stage_rows(1, 3, {"k": np.ones(4)})
    assert pool.staged(1) is not None
    start, rows = pool.take_staged(1)
    assert start == 3 and rows["k"].shape == (4,)
    assert pool.staged(1) is None
    # re-stage then release: rollback/teardown must not leak staged rows
    pool.stage_rows(1, 3, {"k": np.zeros(4)})
    pool.stage_rows(1, 5, {"k": np.ones(2)})  # overwrite is idempotent
    assert pool.take_staged(1)[0] == 5
    pool.stage_rows(1, 6, {"k": np.ones(1)})
    pool.release(1, keep_resident=False)
    assert pool.staged(1) is None
    assert pool.stats()["staged_drops"] >= 1


def test_pool_stage_rows_requires_active_seq():
    pool = KVPagePool(8, block_size=4)
    with pytest.raises(PageError):
        pool.stage_rows(42, 0, {"k": np.ones(1)})


def test_staged_rows_promoted_to_block_payloads(served):
    """Blocks filled by committed speculative tokens get their KV payloads
    from the staged verify rows — a later prefix-cache hit can restore
    from them (pageable family)."""
    cfg, params = served
    [p] = _prompts(cfg, (5,), seed=47)
    with ServeEngine(cfg, params, n_slots=2, max_seq=48, block_size=4,
                     draft_cfg=cfg, draft_params=params, draft_k=3) as eng:
        r = eng.submit(p, 11, speculative=True)
        eng.run_until_drained()
        assert eng.stats()["spec"]["staged_promotions"] > 0
        # a repeat of the same prompt restores instead of re-prefilling
        prefills_before = eng.stats()["prefills"]
        r2 = eng.submit(p, 6, speculative=True)
        eng.run_until_drained()
        assert r2.out_tokens == r.out_tokens[:6]
        assert eng.stats()["restores"] >= 1
        assert eng.stats()["prefills"] == prefills_before


# ---------------------------------------------------------------------------
# loadgen integration (bench plumbing)
# ---------------------------------------------------------------------------

def test_run_load_speculative_checksum_matches_plain(served):
    from repro.serving import LoadSpec, build_workload
    from repro.serving.loadgen import run_load

    cfg, params = served
    spec = LoadSpec(seed=3, n_requests=4, rate_rps=500.0,
                    prompt_lens=(5, 9), out_lens=(6,), vocab=32,
                    dup_frac=0.0, speculative=True)
    wl = build_workload(spec)
    import dataclasses
    with ServeEngine(cfg, params, n_slots=3, max_seq=48, block_size=4,
                     draft_cfg=cfg, draft_params=params, draft_k=3) as eng:
        res_spec = run_load(eng, wl, mode="continuous", spec=spec)
    with ServeEngine(cfg, params, n_slots=3, max_seq=48, block_size=4) as eng:
        res_plain = run_load(
            eng, wl, mode="continuous",
            spec=dataclasses.replace(spec, speculative=False),
        )
    assert res_spec["output_checksum"] == res_plain["output_checksum"]
    assert res_spec["engine"]["spec"]["graph"]["commits"] > 0

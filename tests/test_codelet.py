"""Codelet frontend (core/api.py): declaration, capability dispatch,
backend parity, speculation through the decorator, future-like TaskView,
and the pick_impl regression (ISSUE 4)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    SpData,
    SpRead,
    SpRuntime,
    SpSpeculativeModel,
    SpTaskGraph,
    SpWorkerTeam,
    SpWrite,
    Task,
    graph_scope,
    sp_task,
)
from repro.kernels.dispatch import pallas_available


# ---------------------------------------------------------------------------
# Declaration spellings.
# ---------------------------------------------------------------------------

def test_kwarg_spelling_slots_in_signature_order():
    @sp_task(write=("out",), read=("a", "b"))
    def f(a, b, out):
        out.value = a + b

    assert [s.name for s in f.slots] == ["a", "b", "out"]
    assert [s.mode.name for s in f.slots] == ["READ", "READ", "WRITE"]


def test_annotation_spelling():
    @sp_task
    def f(a: SpRead, out: SpWrite, *, k=1.0):
        out.value = a * k

    assert [s.name for s in f.slots] == ["a", "out"]
    a, out = SpData(3.0), SpData(None)
    with SpRuntime(backend="eager", workers=1):
        f(a, out, k=2.0)
    assert out.value == 6.0


def test_bad_declarations_rejected():
    with pytest.raises(ValueError, match="two access modes"):
        @sp_task(read=("a",), write=("a",))
        def f(a):
            pass

    with pytest.raises(ValueError, match="not positional parameters"):
        @sp_task(read=("nope",))
        def g(a):
            pass

    with pytest.raises(ValueError, match="no data slots"):
        @sp_task
        def h(a, b):
            pass


def test_call_errors():
    @sp_task(read=("a",))
    def f(a, *, k=1):
        return a * k

    a = SpData(1.0)
    with pytest.raises(RuntimeError, match="outside a graph scope"):
        f(a)
    tg = SpTaskGraph()
    with graph_scope(tg):
        with pytest.raises(TypeError, match="missing data slots"):
            f()
        with pytest.raises(TypeError, match="unknown static parameters"):
            f(a, zzz=1)
        with pytest.raises(TypeError, match="takes an SpData cell"):
            f(42)


# ---------------------------------------------------------------------------
# One definition, two backends — identical numerics.
# ---------------------------------------------------------------------------

@sp_task(read=("x",), write=("y",))
def _scale(x, y, *, alpha=2.0):
    y.value = alpha * x + jnp.sin(x)


@sp_task(commutative=("acc",))
def _bump(acc, *, inc):
    acc.value = acc.value + inc


def _run_chain(backend):
    x = SpData(jnp.arange(8.0), "x")
    y = SpData(None, "y")
    acc = SpData(jnp.zeros(()), "acc")
    kw = {"workers": 2} if backend == "eager" else {"policy": "overlap"}
    with SpRuntime(backend=backend, **kw) as rt:
        _scale(x, y, alpha=3.0)
        for i in range(5):
            _bump(acc, inc=float(i), name=f"bump{i}")
        rt.wait_all_tasks()
    return np.asarray(y.value), float(acc.value)


def test_same_codelet_eager_and_staged_identical():
    y_e, acc_e = _run_chain("eager")
    y_s, acc_s = _run_chain("staged")
    np.testing.assert_allclose(y_e, y_s)
    assert acc_e == acc_s == 10.0


# ---------------------------------------------------------------------------
# Capability dispatch (SpCpu/SpCuda selection, paper §4.3).
# ---------------------------------------------------------------------------

def _dispatch_codelet(ran):
    @sp_task(read=("x",), write=("y",))
    def work(x, y):
        ran.append("ref")
        y.value = x * 2

    @work.impl("pallas", available=pallas_available)
    def _(x, y):
        ran.append("pallas")
        y.value = x * 2

    return work


def test_staged_dispatch_prefers_pallas_under_forced_interpret(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_PALLAS_INTERPRET", "1")
    ran = []
    x, y = SpData(21.0), SpData(None)
    with SpRuntime(backend="staged") as rt:
        _dispatch_codelet(ran)(x, y)
    assert y.value == 42.0
    assert ran == ["pallas"]


def test_staged_dispatch_falls_back_to_ref_without_capability(monkeypatch):
    monkeypatch.delenv("REPRO_FORCE_PALLAS_INTERPRET", raising=False)
    ran = []
    x, y = SpData(21.0), SpData(None)
    with SpRuntime(backend="staged") as rt:
        _dispatch_codelet(ran)(x, y)
    assert y.value == 42.0
    assert ran == ["ref"]  # pallas filtered out at call time off-TPU


def test_eager_dispatch_by_worker_kind(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_PALLAS_INTERPRET", "1")
    ran = []
    x, y = SpData(21.0), SpData(None)
    team = SpWorkerTeam(["pallas"])  # one device-kind worker
    with SpRuntime(backend="eager", workers=team) as rt:
        _dispatch_codelet(ran)(x, y)
        rt.wait_all_tasks()
    assert y.value == 42.0 and ran == ["pallas"]


def test_kernel_codelet_capability_dispatch(monkeypatch):
    """The registered rmsnorm codelet picks the (interpret-mode) Pallas
    kernel under forced interpret and matches the reference numerics."""
    from repro.kernels.rmsnorm.ops import rmsnorm_codelet, rmsnorm_ref

    assert rmsnorm_codelet.impl_kinds == ["pallas", "ref"]
    x = np.random.default_rng(0).normal(size=(4, 128)).astype(np.float32)
    scale = np.ones(128, np.float32)
    monkeypatch.delenv("REPRO_FORCE_PALLAS_INTERPRET", raising=False)
    assert rmsnorm_codelet.available_kinds() == ["ref"]
    monkeypatch.setenv("REPRO_FORCE_PALLAS_INTERPRET", "1")
    assert rmsnorm_codelet.available_kinds() == ["pallas", "ref"]

    xc, sc, out = SpData(jnp.asarray(x)), SpData(jnp.asarray(scale)), SpData(None)
    with SpRuntime(backend="staged") as rt:
        v = rmsnorm_codelet(xc, sc, out)
        v.result()
    np.testing.assert_allclose(
        np.asarray(out.value), np.asarray(rmsnorm_ref(x, scale, 1e-6)),
        rtol=1e-5, atol=1e-5,
    )


def test_force_interpret_honored_by_all_four_kernels(monkeypatch):
    """Regression: REPRO_FORCE_PALLAS_INTERPRET used to be honored only by
    flash_attention/ops.py."""
    import repro.kernels.decode_attention.ops as da
    import repro.kernels.flash_attention.ops as fa
    import repro.kernels.rmsnorm.ops as rn
    import repro.kernels.ssd.ops as ssd

    monkeypatch.setenv("REPRO_FORCE_PALLAS_INTERPRET", "1")
    assert all(m.available() for m in (fa, da, rn, ssd))


# ---------------------------------------------------------------------------
# pick_impl regression: no silent any-impl fallback.
# ---------------------------------------------------------------------------

def test_pick_impl_raises_keyerror_without_ref_fallback():
    t = Task({"pallas": lambda: None, "host": lambda: None}, [], [])
    with pytest.raises(KeyError, match=r"no 'cuda' implementation.*'host', 'pallas'"):
        t.pick_impl("cuda")
    # the documented fallback chain still works
    t2 = Task({"ref": (lambda: 1)}, [], [])
    assert t2.pick_impl("pallas")() == 1


# ---------------------------------------------------------------------------
# Future-like TaskView.
# ---------------------------------------------------------------------------

@sp_task(read=("x",))
def _boom(x):
    raise ValueError("kaboom")


@pytest.mark.parametrize("backend", ["eager", "staged"])
def test_exception_propagates_through_result(backend):
    x = SpData(1.0)
    kw = {"workers": 1} if backend == "eager" else {}
    with SpRuntime(backend=backend, **kw) as rt:
        v = _boom(x)
        with pytest.raises(ValueError, match="kaboom"):
            v.result()
        assert isinstance(v.exception(), ValueError)
        assert v.done()
    # observed errors are not re-raised at scope exit (we got here)


def test_unobserved_error_raises_at_scope_exit():
    x = SpData(1.0)
    with pytest.raises(ValueError, match="kaboom"):
        with SpRuntime(backend="staged"):
            _boom(x)


def test_staged_failure_cancels_downstream_and_result_raises():
    """A downstream task cancelled by an upstream staged failure must not
    report success: result()/exception() raise CancelledError."""
    from concurrent.futures import CancelledError

    @sp_task(write=("x",))
    def fail_writer(x):
        raise ValueError("kaboom")

    @sp_task(read=("x",))
    def consumer(x):
        return x

    x = SpData(1.0)
    with SpRuntime(backend="staged") as rt:
        head = fail_writer(x)
        down = consumer(x)
        with pytest.raises(ValueError, match="kaboom"):
            head.result()
        assert down.done()
        with pytest.raises(CancelledError):
            down.result()
        with pytest.raises(CancelledError):
            down.exception()


@pytest.mark.parametrize("backend", ["eager", "staged"])
def test_then_chaining(backend):
    @sp_task(read=("a", "b"))
    def add(a, b):
        return a + b

    a, b = SpData(2.0), SpData(3.0)
    kw = {"workers": 2} if backend == "eager" else {}
    with SpRuntime(backend=backend, **kw) as rt:
        v = add(a, b).then(lambda s: s * 10).then(lambda s: s + 1)
        assert v.result() == 51.0


def test_staged_result_triggers_flush():
    """On the staged backend nothing runs until asked; result() is an ask."""
    @sp_task(read=("a",), write=("out",))
    def work(a, out):
        out.value = a + 1
        return out.value

    a, out = SpData(1.0), SpData(None)
    with SpRuntime(backend="staged") as rt:
        v = work(a, out)
        assert not v.done() and out.value is None  # pending
        assert v.result() == 2.0                   # flushes
        assert v.done() and out.value == 2.0


# ---------------------------------------------------------------------------
# Speculation through the decorator path (SpMaybeWrite slot).
# ---------------------------------------------------------------------------

@sp_task(maybe=("state",))
def _maybe_writer(state, *, do_write):
    if do_write:
        state.value = state.value + 100.0


@sp_task(read=("state",), write=("out",))
def _reader(state, out):
    out.value = state * 2


@pytest.mark.parametrize("do_write,expected,key", [
    (False, 2.0, "commits"),
    (True, 202.0, "rollbacks"),
])
def test_speculation_through_decorator(do_write, expected, key):
    state, out = SpData(1.0, "state"), SpData(None, "out")
    with SpRuntime(
        backend="eager", workers=2,
        speculative_model=SpSpeculativeModel.SP_MODEL_1,
    ) as rt:
        _maybe_writer(state, do_write=do_write)
        _reader(state, out)
        rt.wait_all_tasks()
    assert out.value == expected
    assert rt.graph.spec_stats["speculated"] == 1
    assert rt.graph.spec_stats[key] == 1


# ---------------------------------------------------------------------------
# The positional shim and the legacy runtime spelling.
# ---------------------------------------------------------------------------

def test_positional_shim_and_legacy_int_runtime():
    rt = SpRuntime(2)  # legacy SpRuntime(n_threads)
    try:
        assert rt.backend == "eager"
        a, b = SpData(1.0, "a"), SpData(0.0, "b")
        view = rt.task(SpRead(a), SpWrite(b),
                       lambda av, bref: setattr(bref, "value", av + 41))
        rt.wait_all_tasks()
        assert b.value == 42.0 and view.get_value() is None
    finally:
        rt.stop()


def test_array_slot_binding():
    @sp_task(read=("cells",), write=("out",))
    def total(cells, out):
        out.value = sum(cells)

    cells = [SpData(float(i)) for i in range(5)]
    out = SpData(None)
    with SpRuntime(backend="eager", workers=2):
        total([cells[i] for i in (1, 3)], out)
    assert out.value == 4.0

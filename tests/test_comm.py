"""Communication tasks + background progress thread (paper §4.4): the
in-process transport, the canonical wire codec, recv timeouts, and the
comm-thread shutdown contract."""
from __future__ import annotations

import time

import numpy as np
import pytest

from repro.core import (
    ChannelHub,
    SpCommAbortedError,
    SpCommGroup,
    SpCommTimeoutError,
    SpComputeEngine,
    SpData,
    SpDeserializer,
    SpRead,
    SpSerializer,
    SpTaskGraph,
    SpWorkerTeamBuilder,
    SpWrite,
    decode_message,
    default_hub,
    encode_message,
    mpi_broadcast,
    mpi_recv,
    mpi_send,
    reset_default_hub,
)


@pytest.fixture()
def engine():
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(2))
    yield eng
    eng.stop()


def test_send_recv_releases_dependencies(engine):
    hub = ChannelHub()
    g0, g1 = SpCommGroup(0, 2, hub), SpCommGroup(1, 2, hub)
    tg0 = SpTaskGraph().compute_on(engine)
    tg1 = SpTaskGraph().compute_on(engine)

    m = SpData(np.arange(6, dtype=np.float32), "m")
    r = SpData(None, "r")
    got = SpData(None, "got")

    mpi_recv(tg1, g1, r, src=0, tag=3)
    # downstream compute on the received value must wait for the recv
    tg1.task(SpRead(r), SpWrite(got), lambda v, ref: setattr(ref, "value", float(v.sum())))
    mpi_send(tg0, g0, m, dest=1, tag=3)
    tg0.wait_all_tasks()
    tg1.wait_all_tasks()
    assert got.value == 15.0


def test_broadcast_order(engine):
    hub = ChannelHub()
    groups = [SpCommGroup(r, 3, hub) for r in range(3)]
    graphs = [SpTaskGraph().compute_on(engine) for _ in range(3)]
    cells = [SpData(42 if r == 0 else None, f"c{r}") for r in range(3)]
    # two back-to-back broadcasts; sequence tags keep them matched
    cells2 = [SpData(7 if r == 0 else None, f"d{r}") for r in range(3)]
    for r in range(3):
        mpi_broadcast(graphs[r], groups[r], cells[r], root=0)
        mpi_broadcast(graphs[r], groups[r], cells2[r], root=0)
    for g in graphs:
        g.wait_all_tasks()
    assert [c.value for c in cells] == [42, 42, 42]
    assert [c.value for c in cells2] == [7, 7, 7]


def test_serializer_roundtrip():
    s = SpSerializer()
    a = np.arange(12, dtype=np.float32).reshape(3, 4)
    b = np.array(5, dtype=np.int64)
    s.append_array(a)
    s.append_scalar(b)
    d = SpDeserializer(s.buffer())
    a2 = d.next_array()
    b2 = d.next_array()
    np.testing.assert_array_equal(a, a2)
    assert b2 == 5


class Matrix:
    """Paper Code 7: an object using the serializer protocol."""

    def __init__(self, values: np.ndarray):
        self.values = values

    def sp_serialize(self, s: SpSerializer) -> None:
        s.append_array(self.values)

    @classmethod
    def sp_deserialize(cls, d: SpDeserializer) -> "Matrix":
        return cls(d.next_array().copy())


def test_matrix_object_send_recv(engine):
    hub = ChannelHub()
    g0, g1 = SpCommGroup(0, 2, hub), SpCommGroup(1, 2, hub)
    tg0 = SpTaskGraph().compute_on(engine)
    tg1 = SpTaskGraph().compute_on(engine)
    m = SpData(Matrix(np.eye(3, dtype=np.float64) * 2), "m")
    r = SpData(None, "r")
    mpi_recv(tg1, g1, r, src=0, tag=9)
    mpi_send(tg0, g0, m, dest=1, tag=9)
    tg0.wait_all_tasks()
    tg1.wait_all_tasks()
    assert isinstance(r.value, Matrix)
    np.testing.assert_array_equal(r.value.values, np.eye(3) * 2)


# ---------------------------------------------------------------------------
# canonical wire codec (the socket transport's encoding)
# ---------------------------------------------------------------------------

def test_wire_codec_roundtrips_pytrees():
    msg = {
        "arr": np.arange(12, dtype=np.float32).reshape(3, 4),
        "nested": (1, [2.5, "text", None, True, b"\x00\xff"], {"k": -7}),
        "big": 1 << 80,
        "scalar": np.float64(3.25),
    }
    out = decode_message(encode_message(msg))
    np.testing.assert_array_equal(out["arr"], msg["arr"])
    assert out["nested"] == (1, [2.5, "text", None, True, b"\x00\xff"], {"k": -7})
    assert out["big"] == 1 << 80
    assert out["scalar"] == 3.25
    # tuples stay tuples and lists stay lists (tags embed tuples as keys)
    assert isinstance(out["nested"], tuple) and isinstance(out["nested"][1], list)


def test_wire_codec_rejects_unencodable():
    with pytest.raises(TypeError, match="cannot serialize"):
        encode_message({"fn": lambda: None})


def test_deserialized_arrays_are_writable():
    # regression: np.frombuffer views over bytes are read-only; consumers
    # mutating a received array in place used to get ValueError
    s = SpSerializer()
    s.append_array(np.arange(6, dtype=np.float32))
    a = SpDeserializer(s.buffer()).next_array()
    a += 1.0  # must not raise
    np.testing.assert_array_equal(a, np.arange(6, dtype=np.float32) + 1.0)
    b = decode_message(encode_message(np.zeros((2, 2))))
    b[0, 0] = 5.0
    assert b[0, 0] == 5.0


# ---------------------------------------------------------------------------
# mailbox hygiene (leak regressions)
# ---------------------------------------------------------------------------

def test_hub_prunes_drained_mailboxes():
    hub = ChannelHub()
    for step in range(50):  # per-step tags: the unbounded-growth pattern
        hub.post((0, 1, ("step", step)), step)
        ok, msg = hub.poll((0, 1, ("step", step)))
        assert ok and msg == step
    st = hub.stats()
    assert st["boxes"] == 0 and st["queued"] == 0
    assert st["posted"] == 50 and st["delivered"] == 50
    assert len(hub._boxes) == 0


def test_hub_keeps_unread_messages():
    hub = ChannelHub()
    hub.post((0, 1, "a"), 1)
    hub.post((0, 1, "a"), 2)
    ok, msg = hub.poll((0, 1, "a"))
    assert ok and msg == 1
    assert hub.stats()["boxes"] == 1  # still one queued message
    ok, msg = hub.poll((0, 1, "a"))
    assert ok and msg == 2
    assert hub.stats()["boxes"] == 0


def test_default_hub_reset():
    hub = default_hub()
    assert SpCommGroup(0, 2).hub is hub  # no-transport groups share it
    hub.post((0, 1, "stale"), "leftover")
    assert hub.stats()["queued"] >= 1
    reset_default_hub()
    st = hub.stats()
    assert st == {"boxes": 0, "queued": 0, "posted": 0, "delivered": 0}


# ---------------------------------------------------------------------------
# timeout + shutdown semantics
# ---------------------------------------------------------------------------

def test_recv_timeout_surfaces_as_task_exception(engine):
    hub = ChannelHub()
    g1 = SpCommGroup(1, 2, hub)
    tg = SpTaskGraph().compute_on(engine)
    r, out = SpData(None, "r"), SpData("untouched", "out")
    view = mpi_recv(tg, g1, r, src=0, tag=99, timeout=0.1)  # peer never posts
    # a dependent of data that never arrives must be cancelled, not run
    # with garbage input
    dep = tg.task(SpRead(r), SpWrite(out),
                  lambda v, ref: setattr(ref, "value", v))
    exc = view.exception(timeout=10.0)
    assert isinstance(exc, SpCommTimeoutError)
    assert "tag=99" in str(exc)
    # the error was observed through the future API — the graph must not
    # re-raise it at wait time
    tg.wait_all_tasks(timeout=10.0)
    assert dep.state == "cancelled"
    assert out.value == "untouched"


def test_group_default_timeout(engine):
    hub = ChannelHub()
    g1 = SpCommGroup(1, 2, hub, default_timeout=0.1)
    tg = SpTaskGraph().compute_on(engine)
    r = SpData(None, "r")
    mpi_recv(tg, g1, r, src=0, tag=5)
    with pytest.raises(SpCommTimeoutError):
        tg.wait_all_tasks()


def test_broadcast_recv_timeout(engine):
    hub = ChannelHub()
    g1 = SpCommGroup(1, 2, hub)  # root never broadcasts
    tg = SpTaskGraph().compute_on(engine)
    c = SpData(None, "c")
    mpi_broadcast(tg, g1, c, root=0, timeout=0.1)
    with pytest.raises(SpCommTimeoutError):
        tg.wait_all_tasks()


def test_timely_recv_does_not_time_out(engine):
    hub = ChannelHub()
    g0, g1 = SpCommGroup(0, 2, hub), SpCommGroup(1, 2, hub)
    tg0 = SpTaskGraph().compute_on(engine)
    tg1 = SpTaskGraph().compute_on(engine)
    m, r = SpData(41, "m"), SpData(None, "r")
    mpi_recv(tg1, g1, r, src=0, tag=1, timeout=30.0)
    mpi_send(tg0, g0, m, dest=1, tag=1)
    tg1.wait_all_tasks()
    assert r.value == 41


def test_comm_stop_reports_in_flight_requests():
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(1))
    try:
        hub = ChannelHub()
        g1 = SpCommGroup(1, 2, hub)
        tg = SpTaskGraph().compute_on(eng)
        r = SpData(None, "r")
        view = mpi_recv(tg, g1, r, src=0, tag=7)  # no timeout, never satisfied
        deadline = time.monotonic() + 5.0
        while eng._comm is None and time.monotonic() < deadline:
            time.sleep(0.005)  # wait for the task to reach the comm thread
        assert eng._comm is not None
        with pytest.warns(RuntimeWarning, match="in-flight"):
            aborted = eng._comm.stop(grace=0.2)
        assert aborted == ["recv(from=0,tag=7)"]
        assert isinstance(view.exception(timeout=5.0), SpCommAbortedError)
        tg.wait_all_tasks()  # observed error is not re-raised
    finally:
        eng.stop()  # second stop: clean no-op, no duplicate warning


def test_comm_abort_cancels_dependent_chain():
    """An aborted recv must not strand its dependents in a stopped engine:
    successors are transitively cancelled, so wait_all_tasks returns
    instead of hanging on a chain that will never run."""
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(1))
    try:
        hub = ChannelHub()
        g1 = SpCommGroup(1, 2, hub)
        tg = SpTaskGraph().compute_on(eng)
        r, out = SpData(None, "r"), SpData(None, "out")
        view = mpi_recv(tg, g1, r, src=0, tag=11)  # never satisfied
        dep = tg.task(SpRead(r), SpWrite(out),
                      lambda v, ref: setattr(ref, "value", v))
        deadline = time.monotonic() + 5.0
        while eng._comm is None and time.monotonic() < deadline:
            time.sleep(0.005)
        with pytest.warns(RuntimeWarning, match="in-flight"):
            eng.stop()  # workers die first, then the comm thread aborts
        assert isinstance(view.exception(timeout=5.0), SpCommAbortedError)
        assert dep.state == "cancelled"
        tg.wait_all_tasks(timeout=5.0)  # must not hang (or re-raise)
        assert out.value is None  # the dependent never ran
    finally:
        eng.stop()


def test_clean_comm_shutdown_reports_nothing(engine):
    hub = ChannelHub()
    g0, g1 = SpCommGroup(0, 2, hub), SpCommGroup(1, 2, hub)
    tg0 = SpTaskGraph().compute_on(engine)
    tg1 = SpTaskGraph().compute_on(engine)
    m, r = SpData(1, "m"), SpData(None, "r")
    mpi_recv(tg1, g1, r, src=0, tag=2)
    mpi_send(tg0, g0, m, dest=1, tag=2)
    tg1.wait_all_tasks()
    assert engine._comm.stop() == []

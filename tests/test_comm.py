"""Communication tasks + background progress thread (paper §4.4)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import (
    ChannelHub,
    SpCommGroup,
    SpComputeEngine,
    SpData,
    SpDeserializer,
    SpRead,
    SpSerializer,
    SpTaskGraph,
    SpWorkerTeamBuilder,
    SpWrite,
    mpi_broadcast,
    mpi_recv,
    mpi_send,
)


@pytest.fixture()
def engine():
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(2))
    yield eng
    eng.stop()


def test_send_recv_releases_dependencies(engine):
    hub = ChannelHub()
    g0, g1 = SpCommGroup(0, 2, hub), SpCommGroup(1, 2, hub)
    tg0 = SpTaskGraph().compute_on(engine)
    tg1 = SpTaskGraph().compute_on(engine)

    m = SpData(np.arange(6, dtype=np.float32), "m")
    r = SpData(None, "r")
    got = SpData(None, "got")

    mpi_recv(tg1, g1, r, src=0, tag=3)
    # downstream compute on the received value must wait for the recv
    tg1.task(SpRead(r), SpWrite(got), lambda v, ref: setattr(ref, "value", float(v.sum())))
    mpi_send(tg0, g0, m, dest=1, tag=3)
    tg0.wait_all_tasks()
    tg1.wait_all_tasks()
    assert got.value == 15.0


def test_broadcast_order(engine):
    hub = ChannelHub()
    groups = [SpCommGroup(r, 3, hub) for r in range(3)]
    graphs = [SpTaskGraph().compute_on(engine) for _ in range(3)]
    cells = [SpData(42 if r == 0 else None, f"c{r}") for r in range(3)]
    # two back-to-back broadcasts; sequence tags keep them matched
    cells2 = [SpData(7 if r == 0 else None, f"d{r}") for r in range(3)]
    for r in range(3):
        mpi_broadcast(graphs[r], groups[r], cells[r], root=0)
        mpi_broadcast(graphs[r], groups[r], cells2[r], root=0)
    for g in graphs:
        g.wait_all_tasks()
    assert [c.value for c in cells] == [42, 42, 42]
    assert [c.value for c in cells2] == [7, 7, 7]


def test_serializer_roundtrip():
    s = SpSerializer()
    a = np.arange(12, dtype=np.float32).reshape(3, 4)
    b = np.array(5, dtype=np.int64)
    s.append_array(a)
    s.append_scalar(b)
    d = SpDeserializer(s.buffer())
    a2 = d.next_array()
    b2 = d.next_array()
    np.testing.assert_array_equal(a, a2)
    assert b2 == 5


class Matrix:
    """Paper Code 7: an object using the serializer protocol."""

    def __init__(self, values: np.ndarray):
        self.values = values

    def sp_serialize(self, s: SpSerializer) -> None:
        s.append_array(self.values)

    @classmethod
    def sp_deserialize(cls, d: SpDeserializer) -> "Matrix":
        return cls(d.next_array().copy())


def test_matrix_object_send_recv(engine):
    hub = ChannelHub()
    g0, g1 = SpCommGroup(0, 2, hub), SpCommGroup(1, 2, hub)
    tg0 = SpTaskGraph().compute_on(engine)
    tg1 = SpTaskGraph().compute_on(engine)
    m = SpData(Matrix(np.eye(3, dtype=np.float64) * 2), "m")
    r = SpData(None, "r")
    mpi_recv(tg1, g1, r, src=0, tag=9)
    mpi_send(tg0, g0, m, dest=1, tag=9)
    tg0.wait_all_tasks()
    tg1.wait_all_tasks()
    assert isinstance(r.value, Matrix)
    np.testing.assert_array_equal(r.value.values, np.eye(3) * 2)

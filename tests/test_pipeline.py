"""Pipeline parallelism as a task graph: gradient correctness vs monolithic
jax.grad, and schedule quality (1F1B priorities vs FIFO fill-drain)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SpComputeEngine, SpWorkerTeamBuilder, trace_metrics
from repro.runtime.pipeline import pipeline_value_and_grad, split_stages


def _toy_problem(key, depth=4, width=16, M=4, B=8):
    ks = jax.random.split(key, depth + 2)
    stage_params = [
        {"w": jax.random.normal(ks[i], (width, width)) * 0.3} for i in range(depth)
    ]
    head_params = {"w": jax.random.normal(ks[-2], (width, 1)) * 0.3}
    xs = jax.random.normal(ks[-1], (M, B, width))
    ys = jnp.sin(xs.sum(-1, keepdims=True))

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"])

    def head_fn(p, x, mb):
        pred = x @ p["w"]
        return jnp.mean((pred - mb["y"]) ** 2)

    mbs = [{"x": xs[m], "y": ys[m]} for m in range(M)]
    return stage_params, head_params, [stage_fn] * depth, head_fn, mbs


def _reference_grads(stage_params, head_params, stage_fns, head_fn, mbs):
    def full_loss(all_p):
        stages, head = all_p
        tot = 0.0
        for mb in mbs:
            x = mb["x"]
            for p, fn in zip(stages, stage_fns):
                x = fn(p, x)
            tot = tot + head_fn(head, x, mb)
        return tot / len(mbs)

    return jax.value_and_grad(full_loss)((stage_params, head_params))


@pytest.mark.parametrize("schedule", ["1f1b", "fifo"])
def test_pipeline_grads_match_monolithic(schedule):
    stage_params, head_params, stage_fns, head_fn, mbs = _toy_problem(
        jax.random.PRNGKey(0)
    )
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(4))
    try:
        loss, g_stages, g_head, tg = pipeline_value_and_grad(
            stage_fns, head_fn, stage_params, head_params, mbs, eng, schedule=schedule
        )
        ref_loss, (ref_stages, ref_head) = _reference_grads(
            stage_params, head_params, stage_fns, head_fn, mbs
        )
        np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)
        for g, r in zip(g_stages, ref_stages):
            np.testing.assert_allclose(
                np.asarray(g["w"]), np.asarray(r["w"]), rtol=1e-4, atol=1e-5
            )
        np.testing.assert_allclose(
            np.asarray(g_head["w"]), np.asarray(ref_head["w"]), rtol=1e-4, atol=1e-5
        )
        m = trace_metrics(tg)
        S, M = 4, len(mbs)
        assert m["n_tasks"] == 2 * S * M + M  # F[s,m] + B[s,m] + L[m]
    finally:
        eng.stop()


def test_split_stages():
    layers = {"w": jnp.arange(8 * 3).reshape(8, 3)}
    stages = split_stages(layers, 4, 8)
    assert len(stages) == 4
    assert stages[0]["w"].shape == (2, 3)
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(s["w"]) for s in stages]), np.asarray(layers["w"])
    )

"""Substrate tests: optimizer, schedules, data pipeline, checkpointing,
sharding rules, gradient compression, elastic re-mesh planning."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.data import Prefetcher, SyntheticLMDataset
from repro.dist.collectives import compress_int8, compress_tree, decompress_int8, init_residuals
from repro.dist.fault import remesh_plan
from repro.dist.sharding import safe_spec, use_mesh
from repro.models.config import ShapeSpec
from repro.configs import reduced_config
from repro.optim import (
    adamw_init,
    adamw_update,
    adafactor_init,
    adafactor_update,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    linear_warmup_cosine,
)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_first_step_matches_analytic():
    params = {"w": jnp.array([1.0, 2.0])}
    grads = {"w": jnp.array([0.1, -0.2])}
    state = adamw_init(params)
    new_p, _ = adamw_update(
        grads, state, params, lr=jnp.float32(0.01), step=jnp.int32(0), weight_decay=0.0
    )
    # bias-corrected first step ⇒ update ≈ lr·sign(g)
    np.testing.assert_allclose(
        np.asarray(new_p["w"]), np.array([1.0 - 0.01, 2.0 + 0.01]), rtol=1e-4
    )


def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    for step in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = adamw_update(
            grads, state, params, lr=jnp.float32(0.05), step=jnp.int32(step), weight_decay=0.0
        )
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_adafactor_shapes_and_descent():
    params = {"w": jnp.ones((8, 4)), "b": jnp.ones((4,))}
    state = adafactor_init(params)
    assert state["w"]["vr"].shape == (8,) and state["w"]["vc"].shape == (4,)
    assert state["b"]["v"].shape == (4,)
    loss0 = float(jnp.sum(params["w"] ** 2))
    for step in range(50):
        grads = jax.tree.map(lambda p: 2 * p, params)
        params, state = adafactor_update(
            grads, state, params, lr=jnp.float32(0.05), step=jnp.int32(step)
        )
    assert float(jnp.sum(params["w"] ** 2)) < loss0


def test_clip_by_global_norm():
    tree = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


def test_schedules():
    s = cosine_schedule(1.0, 100)
    assert float(s(jnp.int32(0))) == pytest.approx(1.0)
    assert float(s(jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)
    w = linear_warmup_cosine(1.0, 10, 110)
    assert float(w(jnp.int32(5))) == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1, max_size=32))
def test_property_int8_roundtrip_bounded(vals):
    g = jnp.asarray(vals, jnp.float32)
    q, scale = compress_int8(g)
    err = jnp.abs(decompress_int8(q, scale) - g)
    assert float(err.max()) <= float(scale) / 2 + 1e-6


def test_error_feedback_accumulates():
    grads = {"w": jnp.full((16,), 0.001, jnp.float32)}
    res = init_residuals(grads)
    total = jnp.zeros((16,))
    for _ in range(50):
        deq, res = compress_tree(grads, res)
        total = total + deq["w"]
    # with error feedback the long-run mean approaches the true gradient
    np.testing.assert_allclose(np.asarray(total / 50), 0.001, rtol=0.2)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_determinism_and_rule():
    cfg = reduced_config("deepseek-7b")
    shape = ShapeSpec("t", "train", 16, 4)
    ds1 = SyntheticLMDataset(cfg, shape, seed=7)
    ds2 = SyntheticLMDataset(cfg, shape, seed=7)
    b1, b2 = ds1.batch_for_step(5), ds2.batch_for_step(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are the next-token shift of tokens
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    # different steps → different data
    assert not np.array_equal(b1["tokens"], ds1.batch_for_step(6)["tokens"])


def test_prefetcher_order_and_restart():
    cfg = reduced_config("deepseek-7b")
    shape = ShapeSpec("t", "train", 16, 4)
    ds = SyntheticLMDataset(cfg, shape, seed=1)
    pf = Prefetcher(ds, start_step=3, depth=2)
    try:
        s0, b0 = pf.get()
        s1, b1 = pf.get()
        assert (s0, s1) == (3, 4)
        np.testing.assert_array_equal(b0["tokens"], ds.batch_for_step(3)["tokens"])
    finally:
        pf.stop()


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_retention_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_commit=False)
    state = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3), "step": jnp.int32(4)}
    for s in (1, 2, 3):
        mgr.save(s, state, block=True)
    assert mgr.all_steps() == [2, 3]  # retention
    step, restored = mgr.restore(state)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
    # corruption detection
    d = os.path.join(str(tmp_path), "step_000000003")
    victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    with open(os.path.join(d, victim), "r+b") as f:
        f.seek(-4, 2)
        f.write(b"\xde\xad\xbe\xef")
    with pytest.raises(IOError, match="corruption"):
        mgr.restore(state)


def test_checkpoint_async_and_crash_tmp_cleanup(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = {"w": jnp.ones((4,))}
    mgr.save(10, state)
    mgr.wait()
    assert mgr.latest_step() == 10
    # simulate a crash leaving a tmp dir
    os.makedirs(os.path.join(str(tmp_path), "step_000000099.tmp"))
    mgr2 = CheckpointManager(str(tmp_path), keep=3)
    assert mgr2.latest_step() == 10
    assert not any(d.endswith(".tmp") for d in os.listdir(str(tmp_path)))


# ---------------------------------------------------------------------------
# sharding rules + re-mesh
# ---------------------------------------------------------------------------

def test_safe_spec_drops_indivisible_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with use_mesh(mesh):
        spec = safe_spec((8, 40), ("batch", "heads"))
        assert spec == jax.sharding.PartitionSpec(None, None) or all(
            e is None or isinstance(e, (str, tuple)) for e in spec
        )
    # synthetic 16-way mesh check via rules math (no devices needed):
    from repro.dist.sharding import default_rules

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    spec = safe_spec((40, 64), ("heads", "ff"), mesh=FakeMesh(), rules=default_rules())
    assert spec[0] is None  # 40 % 16 != 0 → replicated
    assert spec[1] == "model"


def test_remesh_plan_shrinks_data_axis():
    p = remesh_plan(256, 13, model_parallel=16)
    assert p.shape == (15, 16) and p.n_chips == 240 and p.dropped_chips == 16
    p2 = remesh_plan(512, 0, model_parallel=16, pod_size=256)
    assert p2.shape == (2, 16, 16)
    p3 = remesh_plan(512, 260, model_parallel=16, pod_size=256)  # one pod lost
    assert p3.shape == (15, 16)
    with pytest.raises(RuntimeError):
        remesh_plan(16, 8, model_parallel=16)

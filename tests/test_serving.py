"""Serving stack: paged KV cache (blocks, prefix sharing, COW, deterministic
LRU — paper §4.3 adapted), admission control/backpressure, and the
continuous-batching ServeEngine — token correctness vs a sequential generate
loop, mid-decode admission, restore-instead-of-prefill, preemption, and
per-request sampling controls."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.timeout(180)

from repro.configs import reduced_config
from repro.models import decode_step, init_params, prefill
from repro.runtime.serve import prime_cache
from repro.serving import (
    AdmissionError,
    KVPagePool,
    PageError,
    Request,
    ServeEngine,
    ServeScheduler,
)


# ---------------------------------------------------------------------------
# pool: blocks, refcounts, sharing, COW, LRU
# ---------------------------------------------------------------------------

def test_pool_allocate_free_refcount():
    pool = KVPagePool(4, block_size=4)
    t = pool.allocate(1, list(range(6)))  # one full + one partial block
    assert t.n_tokens == 6 and len(t.block_ids) == 2
    assert all(pool.refcount(b) == 1 for b in t.block_ids)
    assert pool.n_live == 2 and pool.n_free == 2
    pool.release(1, keep_resident=False)
    assert pool.n_live == 0 and pool.table_of(1) is None


def test_pool_prefix_share_full_and_partial():
    pool = KVPagePool(8, block_size=4)
    toks = list(range(6))
    t1 = pool.allocate(1, toks)
    t2 = pool.allocate(2, toks)  # exact match: shares full AND partial
    assert t1.block_ids == t2.block_ids
    assert all(pool.refcount(b) == 2 for b in t1.block_ids)
    assert pool.shared_hits == 2
    t3 = pool.allocate(3, toks[:4])  # prefix: shares only the full block
    assert t3.block_ids == t1.block_ids[:1]
    assert pool.refcount(t1.block_ids[0]) == 3


def test_pool_cow_on_shared_partial_append():
    pool = KVPagePool(8, block_size=4)
    toks = list(range(6))
    t1 = pool.allocate(1, toks)
    t2 = pool.allocate(2, toks)
    ev = pool.append_token(1, 99)  # divergent write into shared partial
    assert ev["cow"] is not None
    old, new = ev["cow"]
    assert t1.block_ids[-1] == new and t2.block_ids[-1] == old
    assert pool.refcount(old) == 1 and pool.refcount(new) == 1
    assert pool.block(new).tokens == [4, 5, 99]
    assert pool.block(old).tokens == [4, 5]
    assert pool.cow_copies == 1


def test_pool_deterministic_lru_eviction():
    pool = KVPagePool(2, block_size=4)
    t1 = pool.allocate(1, [1, 2, 3])
    pool.release(1, keep_resident=True)
    t2 = pool.allocate(2, [4, 5, 6])
    pool.release(2, keep_resident=True)
    # both evictable; seq 1's block has the older use stamp → evicted first
    pool.allocate(3, list(range(10, 15)))  # needs 2 blocks
    assert pool.evictions == 2
    assert not pool.resident(1) and not pool.resident(2)
    with pytest.raises(KeyError):
        pool.block(t1.block_ids[0])
    with pytest.raises(KeyError):
        pool.block(t2.block_ids[0])


def test_pool_resume_after_eviction_fails():
    pool = KVPagePool(2, block_size=4)
    pool.allocate(1, [1, 2, 3])
    pool.release(1, keep_resident=True)
    assert pool.resident(1)
    pool.allocate(2, list(range(10, 18)))  # evicts seq 1's block
    assert pool.resume(1) is None  # caller must re-prefill


def test_pool_resume_repins_blocks():
    pool = KVPagePool(4, block_size=4)
    t = pool.allocate(1, [1, 2, 3])
    pool.release(1, keep_resident=True)
    assert pool.refcount(t.block_ids[0]) == 0
    t2 = pool.resume(1)
    assert t2 is t and pool.refcount(t.block_ids[0]) == 1


def test_pool_allocate_rollback_is_atomic():
    pool = KVPagePool(2, block_size=4)
    t1 = pool.allocate(1, list(range(8)))  # pins both blocks
    with pytest.raises(PageError):
        pool.allocate(2, list(range(100, 108)))
    # failed allocation left nothing behind
    assert pool.table_of(2) is None
    assert pool.n_live == 2
    assert all(pool.refcount(b) == 1 for b in t1.block_ids)


def test_pool_page_error_when_all_pinned():
    pool = KVPagePool(1, block_size=4)
    pool.allocate(1, [1, 2, 3, 4])
    with pytest.raises(PageError):
        pool.append_token(1, 5)  # needs a second block; only one, pinned


# ---------------------------------------------------------------------------
# scheduler: bounded admission, overload policies, backpressure
# ---------------------------------------------------------------------------

def _req(prompt_len=5, seed=0):
    rng = np.random.default_rng(seed)
    return Request(rng.integers(0, 64, size=prompt_len).astype(np.int32))


def test_scheduler_reject_policy():
    sched = ServeScheduler(KVPagePool(8, 4), n_slots=2, max_queue=2)
    sched.submit(_req(seed=1))
    sched.submit(_req(seed=2))
    with pytest.raises(AdmissionError):
        sched.submit(_req(seed=3))
    assert sched.rejected == 1 and sched.queue_depth == 2


def test_scheduler_shed_oldest_policy():
    sched = ServeScheduler(
        KVPagePool(8, 4), n_slots=2, max_queue=2, overload="shed-oldest"
    )
    old = _req(seed=1)
    sched.submit(old)
    sched.submit(_req(seed=2))
    sched.submit(_req(seed=3))  # sheds `old`
    assert old.rejected and old.done and sched.shed == 1
    assert sched.queue_depth == 2


def test_scheduler_backpressure_when_pool_full():
    pool = KVPagePool(1, block_size=4)
    sched = ServeScheduler(pool, n_slots=2, max_queue=8)
    sched.submit(_req(prompt_len=8, seed=1))  # needs 2 blocks; pool has 1
    assert sched.plan(pageable=True) == []
    assert sched.queue_depth == 1  # stays queued, not dropped


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served():
    cfg = reduced_config("deepseek-7b").replace(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _sequential_generate(cfg, params, prompt: np.ndarray, n: int, max_seq: int):
    """Oracle: prefill + single-sequence greedy decode loop."""
    logits, caches = prefill(params, {"tokens": jnp.asarray(prompt[None, :])}, cfg)
    caches = prime_cache(cfg, caches, len(prompt), max_seq)
    toks = [int(jnp.argmax(logits[0, -1]))]
    for s in range(n - 1):
        t = jnp.asarray([[toks[-1]]], jnp.int32)
        logits, caches = decode_step(params, t, caches, jnp.int32(len(prompt) + s), cfg)
        toks.append(int(jnp.argmax(logits[0, 0])))
    return toks


def test_serve_engine_matches_sequential(served):
    cfg, params = served
    rng = np.random.default_rng(0)
    # staggered prompt lengths → per-slot positions differ
    prompts = [rng.integers(0, cfg.vocab, size=l).astype(np.int32) for l in (5, 9, 7)]
    N = 6
    with ServeEngine(cfg, params, n_slots=4, max_seq=32, block_size=4) as eng:
        reqs = [eng.submit(p, max_new_tokens=N) for p in prompts]
        eng.run_until_drained(max_iters=50)
        for p, r in zip(prompts, reqs):
            want = _sequential_generate(cfg, params, p, N, 32)
            assert r.done
            assert r.out_tokens == want, (r.out_tokens, want)


def test_serve_engine_admits_mid_decode(served):
    """Regression (continuous batching): a request arriving while another is
    mid-decode gets its prefill + first token immediately — it does not wait
    for in-flight sequences to drain."""
    cfg, params = served
    rng = np.random.default_rng(3)
    pa = rng.integers(0, cfg.vocab, size=9).astype(np.int32)
    pb = rng.integers(0, cfg.vocab, size=5).astype(np.int32)
    with ServeEngine(cfg, params, n_slots=4, max_seq=32, block_size=4) as eng:
        A = eng.submit(pa, 12)
        for _ in range(3):
            eng.step()
        assert not A.done and eng.n_running == 1
        B = eng.submit(pb, 2)
        eng.step()  # B's prefill rides this step, concurrent with A's decode
        assert B.t_first is not None and not A.done
        eng.run_until_drained()
        assert B.done and A.done
        # B (2 tokens) finished strictly before A's last token
        assert B.t_tokens[-1] < A.t_tokens[-1]


def test_serve_engine_shared_prefix_refcount_and_cow(served):
    """Two requests with the same prompt share KV blocks (refcount == 2)
    until the first divergent write, which copy-on-writes the shared tail."""
    cfg, params = served
    rng = np.random.default_rng(4)
    p = rng.integers(0, cfg.vocab, size=9).astype(np.int32)
    with ServeEngine(cfg, params, n_slots=4, max_seq=32, block_size=4) as eng:
        a = eng.submit(p, 4)
        b = eng.submit(p, 4)
        eng.step()  # admission only: both prefilled + installed
        ta, tb = eng.pool.table_of(a.req_id), eng.pool.table_of(b.req_id)
        assert ta.block_ids == tb.block_ids
        assert [eng.pool.refcount(i) for i in ta.block_ids] == [2, 2, 2]
        eng.step()  # first appended token diverges the shared partial block
        assert eng.pool.cow_copies == 1
        assert ta.block_ids[-1] != tb.block_ids[-1]
        eng.run_until_drained()
        assert a.out_tokens == b.out_tokens  # greedy: same prompt, same text


def test_serve_engine_restore_skips_prefill(served):
    """A repeat prompt whose prefix blocks carry saved KV rows is admitted
    through restore — no prefill — and decodes identical tokens."""
    cfg, params = served
    rng = np.random.default_rng(5)
    p = rng.integers(0, cfg.vocab, size=9).astype(np.int32)  # 9 ≡ 1 (mod 4)
    with ServeEngine(cfg, params, n_slots=2, max_seq=32, block_size=4) as eng:
        r1 = eng.submit(p, 5)
        eng.run_until_drained()
        prefills = eng.prefills
        r2 = eng.submit(p, 5)
        eng.run_until_drained()
        assert eng.prefills == prefills  # no new prefill
        assert eng.restores == 1
        assert r2.out_tokens == r1.out_tokens


def test_serve_engine_evict_then_resume_reprefills(served):
    """Once a finished sequence's blocks are evicted by later traffic, a
    repeat prompt goes back through prefill (payloads are gone) and still
    produces the same tokens."""
    cfg, params = served
    rng = np.random.default_rng(6)
    p = rng.integers(0, cfg.vocab, size=5).astype(np.int32)
    with ServeEngine(cfg, params, n_slots=2, max_seq=16, block_size=4, n_blocks=4) as eng:
        r1 = eng.submit(p, 3)
        eng.run_until_drained()
        for seed in (7, 8):  # distinct traffic evicts p's resident blocks
            eng.submit(rng.integers(0, cfg.vocab, size=5).astype(np.int32), 3)
            eng.run_until_drained()
        assert eng.pool.evictions >= 1
        prefills = eng.prefills
        r2 = eng.submit(p, 3)
        eng.run_until_drained()
        assert eng.prefills == prefills + 1 and eng.restores == 0
        assert r2.out_tokens == r1.out_tokens


def test_serve_engine_preemption_roundtrip(served):
    """Under a pool too small for both sequences, one is preempted mid-decode
    (written back + requeued) and both still finish with exactly the tokens
    an unpressured run produces."""
    cfg, params = served
    rng = np.random.default_rng(7)
    p1 = rng.integers(0, cfg.vocab, size=5).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab, size=5).astype(np.int32)
    with ServeEngine(cfg, params, n_slots=2, max_seq=16, block_size=4, n_blocks=4) as eng:
        r1, r2 = eng.submit(p1, 8), eng.submit(p2, 8)
        eng.run_until_drained(max_iters=200)
        assert r1.done and r2.done
        assert eng.scheduler.preemptions >= 1
    with ServeEngine(cfg, params, n_slots=2, max_seq=16, block_size=4) as eng:
        q1, q2 = eng.submit(p1, 8), eng.submit(p2, 8)
        eng.run_until_drained()
        assert q1.out_tokens == r1.out_tokens
        assert q2.out_tokens == r2.out_tokens


def test_serve_engine_admission_reject_and_occupancy(served):
    cfg, params = served
    rng = np.random.default_rng(8)
    mk = lambda: rng.integers(0, cfg.vocab, size=5).astype(np.int32)
    with ServeEngine(cfg, params, n_slots=2, max_seq=16, block_size=4,
                     max_queue=1) as eng:
        eng.submit(mk(), 2)
        with pytest.raises(AdmissionError):
            eng.submit(mk(), 2)  # bounded queue full before any step
        assert eng.stats()["rejected"] == 1
        eng.step()
        assert eng.scheduler.slot_occupancy == 0.5
        eng.run_until_drained()


def test_serve_engine_sampling_deterministic(served):
    cfg, params = served
    rng = np.random.default_rng(9)
    p = rng.integers(0, cfg.vocab, size=7).astype(np.int32)

    def run(temp, top_k, seed):
        with ServeEngine(cfg, params, n_slots=2, max_seq=32, block_size=4) as eng:
            r = eng.submit(p, 5, temperature=temp, top_k=top_k, seed=seed)
            eng.run_until_drained()
            return r.out_tokens

    assert run(0.8, 5, 42) == run(0.8, 5, 42)  # same seed → same tokens
    assert run(1.0, 1, 3) == run(0.0, 0, 0)  # top-1 sampling ≡ greedy


def test_serve_engine_context_manager_closes(served):
    cfg, params = served
    rng = np.random.default_rng(10)
    p = rng.integers(0, cfg.vocab, size=5).astype(np.int32)
    with ServeEngine(cfg, params, n_slots=2, max_seq=16, block_size=4) as eng:
        r = eng.submit(p, 2)
        eng.run_until_drained()
        assert r.done
    assert eng.closed
    with pytest.raises(RuntimeError):
        eng.submit(p, 2)

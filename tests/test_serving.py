"""Serving stack: KV slot pool with LRU eviction (paper §4.3 adapted) and
the continuous-batching ServeEngine — correctness of generated tokens vs a
sequential generate loop, with staggered request lengths."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.timeout(120)

from repro.configs import reduced_config
from repro.models import decode_step, init_cache, init_params, prefill
from repro.runtime.serve import prime_cache
from repro.serving import KVPagePool, PageError, Request, ServeEngine


# ---------------------------------------------------------------------------
# pool
# ---------------------------------------------------------------------------

def test_pool_acquire_release_lru():
    pool = KVPagePool(2)
    a = pool.acquire(100)
    b = pool.acquire(200)
    assert pool.n_free == 0
    with pytest.raises(PageError):
        pool.acquire(300)  # both active
    pool.release(100, keep_resident=True)  # inactive, evictable
    c = pool.acquire(300)
    assert c == a  # LRU victim was seq 100
    assert pool.evictions == 1
    assert not pool.resident(100)
    assert pool.resident(200) and pool.resident(300)


def test_pool_reacquire_resident():
    pool = KVPagePool(2)
    s = pool.acquire(7)
    pool.release(7, keep_resident=True)
    s2 = pool.acquire(7)  # cache hit: same slot, no eviction
    assert s2 == s and pool.evictions == 0


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

def _sequential_generate(cfg, params, prompt: np.ndarray, n: int, max_seq: int):
    """Oracle: prefill + single-sequence decode loop."""
    logits, caches = prefill(params, {"tokens": jnp.asarray(prompt[None, :])}, cfg)
    caches = prime_cache(cfg, caches, len(prompt), max_seq)
    toks = [int(jnp.argmax(logits[0, -1]))]
    for s in range(n - 1):
        t = jnp.asarray([[toks[-1]]], jnp.int32)
        logits, caches = decode_step(params, t, caches, jnp.int32(len(prompt) + s), cfg)
        toks.append(int(jnp.argmax(logits[0, 0])))
    return toks


def test_serve_engine_matches_sequential():
    cfg = reduced_config("deepseek-7b").replace(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    # staggered prompt lengths → per-slot positions differ
    prompts = [rng.integers(0, cfg.vocab, size=l).astype(np.int32) for l in (5, 9, 7)]
    N = 6
    eng = ServeEngine(cfg, params, n_slots=4, max_seq=32)
    try:
        reqs = [eng.submit(p, max_new_tokens=N) for p in prompts]
        eng.run_until_drained(max_iters=50)
        for p, r in zip(prompts, reqs):
            want = _sequential_generate(cfg, params, p, N, 32)
            assert r.done
            assert r.out_tokens == want, (r.out_tokens, want)
    finally:
        eng.close()


def test_serve_engine_oversubscribed_queue():
    cfg = reduced_config("deepseek-7b").replace(dtype="float32")
    params = init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    eng = ServeEngine(cfg, params, n_slots=2, max_seq=32)
    try:
        reqs = [
            eng.submit(rng.integers(0, cfg.vocab, size=6).astype(np.int32), 4)
            for _ in range(5)
        ]
        eng.run_until_drained(max_iters=200)
        assert all(r.done and len(r.out_tokens) == 4 for r in reqs)
        # more requests than slots → the pool must have evicted finished seqs
        assert eng.pool.evictions >= 3
    finally:
        eng.close()

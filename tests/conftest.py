import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Prefer the real hypothesis; fall back to the dependency-free stub so the
# property tests still collect and run in minimal environments.
try:
    import hypothesis  # noqa: F401
except ImportError:
    from repro._compat import hypothesis_stub

    _hyp, _st = hypothesis_stub._as_modules()
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

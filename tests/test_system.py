"""End-to-end behaviour: the paper's runtime drives a real JAX training
workload — tasks, commutative accumulation, comm thread, speculation and
checkpointing all in one flow (the 'system works as a whole' test)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core import (
    SpCommutativeWrite,
    SpComputeEngine,
    SpData,
    SpRead,
    SpTaskGraph,
    SpWorkerTeamBuilder,
    SpWrite,
)
from repro.data import SyntheticLMDataset
from repro.models.config import ShapeSpec
from repro.runtime.train import build_train_step, init_train_state


def test_eager_engine_runs_jax_training_tasks():
    """The *eager* Specx engine (paper-faithful worker threads) orchestrates
    data-parallel gradient work: per-shard grad tasks commutatively
    accumulate, an optimizer task applies the update."""
    cfg = reduced_config("deepseek-7b")
    shape = ShapeSpec("t", "train", 16, 4)
    ds = SyntheticLMDataset(cfg, shape, seed=0)
    from repro.models import init_params, loss_fn
    from repro.optim import adamw_init, adamw_update

    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    grad_fn = jax.jit(jax.value_and_grad(lambda p, b: loss_fn(p, b, cfg)[0]))

    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(2))
    try:
        losses = []
        for step in range(6):
            batch = {k: jnp.asarray(v) for k, v in ds.batch_for_step(step).items()}
            shards = [
                {k: v[i::2] for k, v in batch.items()} for i in range(2)
            ]
            tg = SpTaskGraph().compute_on(eng)
            p_cell = SpData(params, "params")
            g_cell = SpData(jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params), "grads")
            loss_cell = SpData(jnp.float32(0.0), "loss")

            def grad_task(p, b, g_ref, l_ref):
                loss, g = grad_fn(p, b)
                g_ref.value = jax.tree.map(lambda a, x: a + x.astype(a.dtype), g_ref.value, g)
                l_ref.value = l_ref.value + loss

            for sh in shards:
                sh_cell = SpData(sh, "shard")
                tg.task(
                    SpRead(p_cell), SpRead(sh_cell),
                    SpCommutativeWrite(g_cell), SpCommutativeWrite(loss_cell),
                    grad_task,
                )

            def opt_task(g, p_ref):
                nonlocal opt
                gm = jax.tree.map(lambda t: t / 2, g)
                new_p, opt2 = adamw_update(
                    gm, opt, p_ref.value, lr=jnp.float32(1e-3), step=jnp.int32(step)
                )
                opt = opt2
                p_ref.value = new_p

            tg.task(SpRead(g_cell), SpWrite(p_cell), opt_task, name="opt")
            tg.wait_all_tasks()
            params = p_cell.value
            losses.append(float(loss_cell.value) / 2)
        assert losses[-1] < losses[0], losses
    finally:
        eng.stop()


def test_staged_and_eager_agree():
    """One staged train step == the eager engine running the same math."""
    cfg = reduced_config("deepseek-7b")
    shape = ShapeSpec("t", "train", 16, 4)
    ds = SyntheticLMDataset(cfg, shape, seed=3)
    batch = {k: jnp.asarray(v) for k, v in ds.batch_for_step(0).items()}

    state = init_train_state(jax.random.PRNGKey(5), cfg)
    art = build_train_step(cfg, n_microbatches=2, donate=False)
    s_staged, m = art(state, batch)

    # eager: same microbatch split, same optimizer math
    from repro.models import loss_fn
    from repro.optim import adamw_update
    from repro.optim.optimizer import global_norm

    grad_fn = jax.jit(jax.value_and_grad(lambda p, b: loss_fn(p, b, cfg)[0]))
    mb = jax.tree.map(lambda t: t.reshape((2, t.shape[0] // 2) + t.shape[1:]), batch)
    g_acc = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), state.params)
    for i in range(2):
        _, g = grad_fn(state.params, jax.tree.map(lambda t: t[i], mb))
        g_acc = jax.tree.map(lambda a, x: a + x.astype(a.dtype), g_acc, g)
    g_mean = jax.tree.map(lambda t: t / 2, g_acc)
    gn = global_norm(g_mean)
    scale = jnp.minimum(1.0, 1.0 / jnp.maximum(gn, 1e-9))
    g_clip = jax.tree.map(lambda t: t * scale, g_mean)
    p_ref, _ = adamw_update(
        g_clip, state.opt, state.params, lr=jnp.float32(3e-4), step=jnp.int32(0)
    )
    a = jax.tree.leaves(s_staged.params)[1].astype(jnp.float32)
    b = jax.tree.leaves(p_ref)[1].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)

"""Pallas kernels vs pure-jnp oracles — interpret-mode shape/dtype sweeps
(assignment: per-kernel allclose against the ref.py oracle)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd.kernel import ssd_intra_chunk_pallas
from repro.kernels.ssd.ref import ssd_chunk_ref
from repro.kernels.ssd.ops import ssd_chunked_pallas
from repro.models.ssm import ssd_naive


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,KH,L,D,bq,bk",
    [
        (1, 4, 4, 64, 32, 16, 16),   # MHA
        (2, 8, 2, 128, 64, 32, 64),  # GQA, rectangular blocks
        (1, 4, 1, 64, 16, 64, 16),   # MQA, single q block
    ],
)
@pytest.mark.parametrize("causal,window", [(True, None), (True, 40), (False, None)])
def test_flash_attention_sweep(dtype, B, H, KH, L, D, bq, bk, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, L, D), dtype)
    k = jax.random.normal(ks[1], (B, KH, L, D), dtype)
    v = jax.random.normal(ks[2], (B, KH, L, D), dtype)
    out = flash_attention_pallas(
        q, k, v, causal=causal, window=window, block_q=bq, block_kv=bk, interpret=True
    )
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("pos", [0, 17, 127, 255])
@pytest.mark.parametrize("B,H,KH,S,D", [(2, 8, 2, 256, 64), (1, 4, 4, 128, 32)])
def test_decode_attention_sweep(dtype, pos, B, H, KH, S, D):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k = jax.random.normal(ks[1], (B, KH, S, D), dtype)
    v = jax.random.normal(ks[2], (B, KH, S, D), dtype)
    out = decode_attention_pallas(q, k, v, jnp.int32(pos), block_s=64, interpret=True)
    ref = decode_attention_ref(q, k, v, jnp.int32(pos))
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("cs,P,N", [(16, 8, 12), (32, 16, 16)])
def test_ssd_intra_chunk(cs, P, N):
    BH, nc = 3, 4
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    x = jax.random.normal(ks[0], (BH, nc, cs, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (BH, nc, cs)) - 1)
    cum = jnp.cumsum(-dt * 0.4, axis=2)
    B = jax.random.normal(ks[2], (BH, nc, cs, N))
    C = jax.random.normal(ks[3], (BH, nc, cs, N))
    y, st = ssd_intra_chunk_pallas(x, dt, cum, B, C, interpret=True)
    for b in range(BH):
        for c in range(nc):
            y0, st0 = ssd_chunk_ref(x[b, c], dt[b, c], cum[b, c], B[b, c], C[b, c])
            np.testing.assert_allclose(np.asarray(y[b, c]), np.asarray(y0), rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(np.asarray(st[b, c]), np.asarray(st0), rtol=1e-4, atol=1e-4)


def test_ssd_full_pipeline_vs_naive_recurrence():
    Bm, L, H, P, N = 2, 64, 4, 8, 12
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    xh = jax.random.normal(ks[0], (Bm, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bm, L, H)) - 1)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.2)
    Bc = jax.random.normal(ks[3], (Bm, L, H, N))
    Cc = jax.random.normal(ks[4], (Bm, L, H, N))
    y_ref, s_ref = ssd_naive(xh, dt, A, Bc, Cc)
    y, s = ssd_chunked_pallas(xh, dt, A, Bc, Cc, chunk=16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,D", [(8, 64), (64, 256), (100, 128)])
def test_rmsnorm_sweep(dtype, T, D):
    x = jax.random.normal(jax.random.PRNGKey(4), (T, D), dtype)
    s = (jax.random.normal(jax.random.PRNGKey(5), (D,)) * 0.1).astype(dtype)
    out = rmsnorm_pallas(x, s, interpret=True)
    ref = rmsnorm_ref(x, s)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_model_flash_matches_kernel_oracle():
    """The model's pure-JAX flash path and the Pallas kernel agree."""
    from repro.models.attention import blockwise_attention

    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (2, 64, 8, 16))  # model layout (B,L,H,D)
    k = jax.random.normal(ks[1], (2, 64, 2, 16))
    v = jax.random.normal(ks[2], (2, 64, 2, 16))
    a = blockwise_attention(q, k, v, causal=True, block_kv=16)
    b = flash_attention_pallas(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
        causal=True, block_q=16, block_kv=16, interpret=True,
    ).swapaxes(1, 2)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_attn_mode_auto_resolution():
    """'auto' picks tri when heads divide the mesh model axis, masked
    otherwise (the §Perf llama4 refutation, codified)."""
    import jax.numpy as jnp

    from repro.configs import reduced_config
    from repro.models.attention import full_attention

    cfg = reduced_config("deepseek-7b").replace(
        attn_mode="auto", attn_blockwise_min_seq=32, attn_block_q=16, attn_block_kv=16
    )
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 64, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 4, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 4, 16))
    auto = full_attention(q, k, v, cfg, causal=True)
    masked = full_attention(q, k, v, cfg.replace(attn_mode="masked"), causal=True)
    np.testing.assert_allclose(
        np.asarray(auto, np.float32), np.asarray(masked, np.float32), rtol=2e-5, atol=2e-5
    )

"""Self-healing runtime (ISSUE 8): task-level retry/timeout/quarantine
policies and the hung-task watchdog, the elastic ``SpRuntime`` that
recovers from a real SIGKILLed OS rank *inside* the runtime (the training
script has zero failure handling), serving deadlines / per-request
cancellation, configurable heartbeats, and the seeded chaos soak harness."""
from __future__ import annotations

import os
import threading
import time
from concurrent.futures import CancelledError

import numpy as np
import pytest

from repro.core import (
    SocketTransport,
    SpComputeEngine,
    SpData,
    SpRuntime,
    SpTaskPolicy,
    SpTaskTimeoutError,
    SpWorkerTeamBuilder,
    sp_task,
)

# The SIGKILL acceptance test spawns real OS ranks; raise the CI per-test cap.
pytestmark = pytest.mark.timeout(240)


# ---------------------------------------------------------------------------
# SpTaskPolicy: declaration and validation
# ---------------------------------------------------------------------------

def test_policy_validation():
    p = SpTaskPolicy(retries=2, timeout=1.0)
    assert p.on_failure == "retry"  # auto: retries imply retry
    assert SpTaskPolicy().on_failure == "raise"
    with pytest.raises(ValueError):
        SpTaskPolicy(retries=-1)
    with pytest.raises(ValueError):
        SpTaskPolicy(timeout=0.0)
    with pytest.raises(ValueError):
        SpTaskPolicy(on_failure="explode")


def test_timeout_error_type():
    # catchable both as the runtime's typed error and the stdlib family
    assert issubclass(SpTaskTimeoutError, TimeoutError)


# ---------------------------------------------------------------------------
# retry: transient failures re-execute in place
# ---------------------------------------------------------------------------

def test_retry_transient_failure_recovers():
    calls = {"n": 0}

    @sp_task(write=("out",), retries=3, name="flaky")
    def flaky(out):
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        out.value = calls["n"]

    out = SpData(None, "out")
    with SpRuntime(workers=2) as rt:
        view = flaky(out)
        assert view.result(timeout=10.0) is None  # writes via slot
    assert out.value == 3
    assert view.task.retries_used == 2


def test_retry_exhaustion_surfaces_original_error():
    @sp_task(read=("x",), retries=2, name="doomed")
    def doomed(x):
        raise ValueError(f"always fails on {x}")

    with SpRuntime(workers=2) as rt:
        view = doomed(SpData(7, "x"))
        with pytest.raises(ValueError, match="always fails on 7"):
            view.result(timeout=10.0)
        assert view.task.retries_used == 2


def test_per_call_policy_overrides_codelet_default():
    calls = {"n": 0}

    @sp_task(read=("x",), name="once")  # no retries declared
    def once(x):
        calls["n"] += 1
        if calls["n"] < 2:
            raise OSError("transient")
        return x * 2

    with SpRuntime(workers=2) as rt:
        view = once(SpData(21, "x"), retries=2)  # call-site policy wins
        assert view.result(timeout=10.0) == 42


# ---------------------------------------------------------------------------
# watchdog: hung tasks fail with SpTaskTimeoutError; zombies can't write back
# ---------------------------------------------------------------------------

def test_watchdog_times_out_hung_task():
    release = threading.Event()

    @sp_task(read=("x",), timeout=0.2, on_failure="quarantine", name="hung")
    def hung(x):
        release.wait(30.0)

    t0 = time.perf_counter()
    with SpRuntime(workers=2) as rt:
        view = hung(SpData(1, "x"))
        with pytest.raises(SpTaskTimeoutError, match="hung"):
            view.result(timeout=10.0)
        waited = time.perf_counter() - t0
        assert 0.2 <= waited < 5.0  # detected promptly, not at scope teardown
        release.set()  # unblock the zombie so shutdown is clean


def test_zombie_writeback_is_discarded():
    gate = threading.Event()

    @sp_task(write=("out",), timeout=0.1, on_failure="quarantine", name="zombie")
    def zombie(out):
        gate.wait(10.0)  # hang past the timeout...
        out.value = "from the grave"  # ...then try to write anyway

    out = SpData(None, "out")
    with SpRuntime(workers=2) as rt:
        view = zombie(out)
        with pytest.raises(SpTaskTimeoutError):
            view.result(timeout=10.0)
        gate.set()  # let the zombie body finish its write attempt
        time.sleep(0.2)
    assert out.value is None  # the abandoned body's write never landed


# ---------------------------------------------------------------------------
# quarantine: poison tasks are isolated, dependents cancel, graph survives
# ---------------------------------------------------------------------------

def test_quarantine_cancels_dependents_spares_siblings():
    @sp_task(write=("a",), on_failure="quarantine", name="poison")
    def poison(a):
        raise RuntimeError("poison pill")

    @sp_task(read=("a",), write=("b",), name="dependent")
    def dependent(a, b):
        b.value = a + 1

    @sp_task(write=("c",), name="sibling")
    def sibling(c):
        c.value = "fine"

    a, b, c = SpData(None, "a"), SpData(None, "b"), SpData(None, "c")
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(2))
    try:
        with SpRuntime(engine=eng) as rt:
            pv = poison(a)
            dv = dependent(a, b)
            sv = sibling(c)
            with pytest.raises(RuntimeError, match="poison pill"):
                pv.result(timeout=10.0)
            with pytest.raises(CancelledError):
                dv.result(timeout=10.0)
            assert sv.result(timeout=10.0) is None
            assert c.value == "fine" and b.value is None
            # the graph is still alive: new work runs after the quarantine
            assert sibling(SpData(None, "c2")).result(timeout=10.0) is None
    finally:
        report = eng.stop()
    # the shutdown report names the quarantined task
    assert any("poison" in name for name in report), report


def test_quarantine_error_does_not_fail_the_scope():
    @sp_task(read=("x",), on_failure="quarantine", name="contained")
    def contained(x):
        raise RuntimeError("contained failure")

    # no .result() observation anywhere: a quarantined error must still not
    # re-raise at scope exit (that is the difference from on_failure="raise")
    with SpRuntime(workers=2) as rt:
        contained(SpData(1, "x"))
        rt.wait_all_tasks(timeout=10.0)
        assert [t.name for t in rt.graph.quarantined] == ["contained"]


# ---------------------------------------------------------------------------
# configurable heartbeat (SocketTransport knobs + env override)
# ---------------------------------------------------------------------------

def test_heartbeat_knobs_resolution():
    t = SocketTransport(0, 1, heartbeat=0.1, staleness_factor=5.0)
    try:
        assert t._hb_interval == pytest.approx(0.1)
        assert t._router._hb_timeout == pytest.approx(0.5)
    finally:
        t.close()
    with pytest.raises(ValueError):
        SocketTransport(0, 1, heartbeat=0.1, heartbeat_interval=0.2)
    with pytest.raises(ValueError):
        SocketTransport(0, 1, heartbeat_timeout=3.0, staleness_factor=4.0)
    with pytest.raises(ValueError):
        SocketTransport(0, 1, heartbeat=0.0)


def test_heartbeat_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_HB_INTERVAL", "0.25")
    t = SocketTransport(0, 1)
    try:
        assert t._hb_interval == pytest.approx(0.25)
        assert t._router._hb_timeout == pytest.approx(5.0)  # interval x 20
    finally:
        t.close()


# ---------------------------------------------------------------------------
# the acceptance run: a real OS rank SIGKILLed mid-training; the training
# loop contains no try/except — recovery happens inside SpRuntime — and the
# survivors' final params are bit-exact vs the survivors-only oracle
# ---------------------------------------------------------------------------

def test_sigkill_rank_mid_training_recovers_in_runtime_bit_exact():
    from repro.launch.rendezvous import elastic_train_oracle, run_elastic_train

    size, n, steps, lr = 3, 257, 5, 0.01
    results, info = run_elastic_train(size=size, n=n, steps=steps, fail_at=2, lr=lr)
    assert set(results) == {0, 1}
    resumes = {rep["resume_step"] for rep in results.values()}
    assert len(resumes) == 1 and None not in resumes
    resume = resumes.pop()
    expected = elastic_train_oracle(
        size, n, steps, lr, resume_step=resume, dead=(info["victim"],)
    )
    for rank, rep in results.items():
        assert rep["recoveries"] == 1
        assert rep["dead"] == [info["victim"]]
        # detection latency: dead within seconds of the SIGKILL, never before
        lat = rep["detect_at"] - info["t_kill"]
        assert -0.05 < lat < 5.0, lat
        assert rep["reroll_s"] < 30.0
        np.testing.assert_array_equal(rep["params"], expected)


# ---------------------------------------------------------------------------
# chaos soak harness (seeded; CI runs 3 seeds x 20 iterations via the CLI)
# ---------------------------------------------------------------------------

def test_chaos_collectives_bit_exact_under_link_faults():
    from repro.dist.chaos import chaos_collectives

    stats = chaos_collectives(seed=0, iters=6)
    assert stats["escalations"] == 0
    assert sum(stats["faults"].values()) > 0  # the schedule actually injected


def test_chaos_elastic_inprocess_rank_death():
    from repro.dist.chaos import chaos_elastic

    stats = chaos_elastic(seed=0, iters=5)
    assert stats["resume"] is not None


def test_chaos_serve_invariants():
    from repro.dist.chaos import chaos_serve

    stats = chaos_serve(seed=0, iters=4)
    assert stats["completed"] > 0
    assert stats["requests"] == stats["completed"] + stats["deadline_shed"] \
        + stats["shed"] + stats["cancels"] + stats["cancelled_q"]

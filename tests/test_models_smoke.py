"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of each family runs one forward/train step on CPU; output shapes + no NaNs.
Plus prefill→decode consistency against the full forward."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, reduced_config
from repro.models import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)
from repro.runtime.serve import prime_cache

# JAX compile time per architecture dominates; raise the CI per-test cap.
pytestmark = pytest.mark.timeout(180)

B, L = 2, 32


def _batch(cfg, rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    if cfg.frontend == "audio":
        return {
            "embeds": jax.random.normal(k1, (B, L, 512), jnp.bfloat16),
            "mask": jnp.zeros((B, L), bool).at[:, ::4].set(True),
            "labels": jax.random.randint(k2, (B, L), 0, cfg.vocab),
        }
    if cfg.frontend == "vision":
        lt = L - cfg.n_patches
        return {
            "tokens": jax.random.randint(k1, (B, lt), 0, cfg.vocab),
            "patch_embeds": jax.random.normal(k3, (B, cfg.n_patches, 1024), jnp.bfloat16),
            "labels": jax.random.randint(k2, (B, lt), 0, cfg.vocab),
        }
    return {
        "tokens": jax.random.randint(k1, (B, L), 0, cfg.vocab),
        "labels": jax.random.randint(k2, (B, L), 0, cfg.vocab),
    }


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced_config(arch)
    rng = jax.random.PRNGKey(0)
    params = init_params(rng, cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    x, _, aux = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
    exp_len = L
    assert x.shape == (B, exp_len, cfg.d_model)
    assert bool(jnp.isfinite(x.astype(jnp.float32)).all()), "NaN/Inf in hidden states"

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p, b: loss_fn(p, b, cfg), has_aux=True)
    )(params, batch)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(
        float(jnp.sum(jnp.square(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads)
    )
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize(
    "arch", [a for a in ARCH_NAMES if reduced_config(a).supports_decode]
)
def test_prefill_decode_matches_forward(arch):
    """Teacher-forced decode after prefill must reproduce the full-sequence
    forward logits position by position (fp32 for tight tolerance)."""
    cfg = reduced_config(arch).replace(dtype="float32")
    rng = jax.random.PRNGKey(0)
    params = init_params(rng, cfg)
    T0, STEPS, SMAX = 16, 4, 32

    full_batch = _batch(cfg, jax.random.PRNGKey(1))
    if cfg.frontend == "vision":
        tokens = full_batch["tokens"]
    else:
        tokens = full_batch["tokens"]

    # full forward over T0+STEPS tokens → logits at each position
    fb = dict(full_batch)
    fb["tokens"] = tokens[:, : T0 + STEPS]
    x, _, _ = forward(params, fb, cfg)
    from repro.models.layers import logits_apply

    logits_full = logits_apply(params, x, cfg).astype(jnp.float32)

    # prefill on T0, then teacher-forced decode
    pb = dict(full_batch)
    pb["tokens"] = tokens[:, :T0]
    logits_p, caches = prefill(params, pb, cfg)
    offset = cfg.n_patches if cfg.frontend == "vision" else 0
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0].astype(jnp.float32)),
        np.asarray(logits_full[:, offset + T0 - 1]),
        rtol=2e-4,
        atol=2e-4,
    )
    caches = prime_cache(cfg, caches, offset + T0, offset + SMAX)
    for s in range(STEPS - 1):
        pos = offset + T0 + s
        tok = tokens[:, T0 + s : T0 + s + 1]
        logits_d, caches = decode_step(params, tok, caches, jnp.int32(pos), cfg)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0].astype(jnp.float32)),
            np.asarray(logits_full[:, pos]),
            rtol=2e-4,
            atol=2e-4,
            err_msg=f"{arch} step {s}",
        )

"""KV-cache slot pool with LRU eviction — the TPU-idiomatic home of Specx's
device-memory LRU policy (paper §4.3: "we employ the Least Recently Used
policy to determine which memory blocks should be evicted from the devices
when they are full").

On TPU, XLA owns HBM for tensors, so the *software-managed* memory level is
the serving KV cache: a fixed budget of cache slots (each one sequence's
decode state).  The pool tracks residency, evicts least-recently-used
*inactive* sequences when full, and remembers evicted prefixes so a
returning request is re-prefilled (the "copy back to host" analogue —
recomputation instead of transfer, the TPU-appropriate trade).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional


class PageError(RuntimeError):
    pass


@dataclass
class SlotInfo:
    seq_id: int
    last_used: float
    active: bool = True  # actively decoding (not evictable)
    tokens_cached: int = 0


class KVPagePool:
    """Fixed-capacity slot pool with LRU eviction of inactive sequences."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self._slots: dict[int, Optional[SlotInfo]] = {i: None for i in range(n_slots)}
        self._by_seq: dict[int, int] = {}
        self.evictions = 0

    # ------------------------------------------------------------------ alloc

    def acquire(self, seq_id: int, tokens_cached: int = 0) -> int:
        """Return a slot index for ``seq_id``, evicting LRU if needed."""
        if seq_id in self._by_seq:
            slot = self._by_seq[seq_id]
            info = self._slots[slot]
            info.last_used = time.monotonic()
            info.active = True
            return slot
        slot = self._free_slot()
        if slot is None:
            slot = self._evict_lru()
        self._slots[slot] = SlotInfo(seq_id, time.monotonic(), True, tokens_cached)
        self._by_seq[seq_id] = slot
        return slot

    def _free_slot(self) -> Optional[int]:
        for i, info in self._slots.items():
            if info is None:
                return i
        return None

    def _evict_lru(self) -> int:
        candidates = [
            (info.last_used, slot)
            for slot, info in self._slots.items()
            if info is not None and not info.active
        ]
        if not candidates:
            raise PageError(
                f"all {self.n_slots} KV slots active; cannot admit a new sequence"
            )
        _, slot = min(candidates)
        victim = self._slots[slot]
        del self._by_seq[victim.seq_id]
        self._slots[slot] = None
        self.evictions += 1
        return slot

    # ----------------------------------------------------------------- status

    def touch(self, seq_id: int) -> None:
        info = self._slots[self._by_seq[seq_id]]
        info.last_used = time.monotonic()

    def release(self, seq_id: int, *, keep_resident: bool = True) -> None:
        """Finish decoding; optionally keep the prefix resident (evictable)."""
        slot = self._by_seq.get(seq_id)
        if slot is None:
            return
        if keep_resident:
            self._slots[slot].active = False
        else:
            del self._by_seq[seq_id]
            self._slots[slot] = None

    def resident(self, seq_id: int) -> bool:
        return seq_id in self._by_seq

    def slot_of(self, seq_id: int) -> int:
        return self._by_seq[seq_id]

    @property
    def n_free(self) -> int:
        return sum(1 for v in self._slots.values() if v is None)

    @property
    def n_active(self) -> int:
        return sum(1 for v in self._slots.values() if v is not None and v.active)

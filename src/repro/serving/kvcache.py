"""Paged KV cache — fixed-size blocks, free-list reuse, prefix sharing with
copy-on-write, and deterministic block-granularity LRU eviction.

This is the serving tier's software-managed memory level (paper §4.3: "we
employ the Least Recently Used policy to determine which memory blocks
should be evicted from the devices when they are full"), promoted from the
old one-slot-per-sequence pool to real paging:

* **Blocks** — every sequence's KV state is accounted in fixed-size blocks
  of ``block_size`` tokens.  The pool holds at most ``n_blocks`` live
  blocks; block ids are never reused, so a stale block table cannot alias a
  recycled block.

* **Block tables** — each sequence maps to an ordered list of block ids
  (:class:`BlockTable`).  ``table.n_tokens`` counts the tokens whose KV
  rows exist (tokens *fed* to the model, not tokens merely sampled).

* **Prefix sharing** — full blocks are content-addressed by a chain key
  (the token contents of every block before them plus their own), so two
  sequences with a common prompt prefix reference the *same* blocks with a
  refcount.  A partial tail block is shared only on an exact content match.
  Appending to a shared partial block triggers **copy-on-write**: the
  appender gets a private copy, the other referents keep the original.

* **LRU eviction** — when a new block is needed and the pool is full, the
  least-recently-used block with ``refcount == 0`` (released or resident
  sequences) is evicted.  Recency is a monotonically increasing use counter
  stamped on every touch — not a wall-clock timestamp — so eviction order
  is deterministic under test and equal-time touches cannot tie.

* **Payloads** — each block may carry an opaque payload (the engine stores
  the numpy KV rows for the block's tokens at writeback time).  A future
  request whose prompt is fully covered by payload-backed blocks restores
  the rows instead of re-running prefill; an evicted block drops its
  payload, so an evict-then-resume goes back through prefill (the
  recompute-instead-of-transfer trade that suits XLA-owned HBM).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Iterable, Optional, Sequence


class PageError(RuntimeError):
    """The pool cannot satisfy an allocation (every block is pinned)."""


#: Chain key of the empty prefix (the root of the content-addressed trie).
_ROOT = ("kv-root",)


@dataclass
class KVBlock:
    """One fixed-capacity block of cached tokens.

    ``parent_key`` is the chain key of the prefix before this block;
    ``key`` (parent_key, own tokens) content-addresses the block.  The
    ``payload`` slot is opaque to the pool — the engine stores extracted
    KV rows there so a prefix hit can skip prefill.
    """

    block_id: int
    capacity: int
    tokens: list[int]
    parent_key: Any
    refcount: int = 1
    stamp: int = 0
    payload: Any = None

    @property
    def full(self) -> bool:
        return len(self.tokens) >= self.capacity

    @property
    def key(self) -> tuple:
        return (self.parent_key, tuple(self.tokens))


@dataclass
class BlockTable:
    """Ordered block ids of one sequence + the number of KV rows present."""

    seq_id: int
    block_ids: list[int] = field(default_factory=list)
    n_tokens: int = 0


class KVPagePool:
    """Fixed-budget paged KV pool: free-list allocation, prefix sharing with
    refcounts and copy-on-write, deterministic LRU eviction of unreferenced
    blocks.  Pure bookkeeping + payload store — tensor movement is the
    engine's job (``serving/engine.py``)."""

    def __init__(self, n_blocks: int, block_size: int = 16):
        if n_blocks < 1 or block_size < 1:
            raise ValueError("n_blocks and block_size must be >= 1")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self._blocks: dict[int, KVBlock] = {}
        self._tables: dict[int, BlockTable] = {}     # actively decoding
        self._resident: dict[int, BlockTable] = {}   # released, resumable
        self._full_index: dict[tuple, int] = {}      # chain key -> block id
        self._partial_index: dict[Any, list[int]] = {}  # parent key -> ids
        self._ids = itertools.count()
        self._use = itertools.count(1)  # deterministic LRU clock
        self._staged: dict[int, tuple] = {}  # seq -> (start, rows), uncommitted
        self.evictions = 0
        self.shared_hits = 0
        self.cow_copies = 0
        self.allocated_blocks = 0
        self.staged_rounds = 0
        self.staged_drops = 0

    # ------------------------------------------------------------- internals

    def _touch(self, blk: KVBlock) -> None:
        blk.stamp = next(self._use)

    def _index_add(self, blk: KVBlock) -> None:
        if blk.full:
            self._full_index.setdefault(blk.key, blk.block_id)
        else:
            self._partial_index.setdefault(blk.parent_key, []).append(blk.block_id)

    def _index_remove(self, blk: KVBlock) -> None:
        if blk.full:
            if self._full_index.get(blk.key) == blk.block_id:
                del self._full_index[blk.key]
        else:
            bucket = self._partial_index.get(blk.parent_key)
            if bucket and blk.block_id in bucket:
                bucket.remove(blk.block_id)
                if not bucket:
                    del self._partial_index[blk.parent_key]

    def _drop_block(self, blk: KVBlock) -> None:
        self._index_remove(blk)
        del self._blocks[blk.block_id]
        blk.payload = None

    def _evict_one(self) -> bool:
        candidates = [b for b in self._blocks.values() if b.refcount == 0]
        if not candidates:
            return False
        victim = min(candidates, key=lambda b: b.stamp)
        self._drop_block(victim)
        self.evictions += 1
        return True

    def _new_block(self, tokens: Iterable[int], parent_key: Any) -> KVBlock:
        while len(self._blocks) >= self.n_blocks:
            if not self._evict_one():
                raise PageError(
                    f"KV pool exhausted: all {self.n_blocks} blocks referenced "
                    f"by active sequences"
                )
        blk = KVBlock(next(self._ids), self.block_size, list(tokens), parent_key)
        self._touch(blk)
        self._blocks[blk.block_id] = blk
        self._index_add(blk)
        self.allocated_blocks += 1
        return blk

    def _lookup(self, parent_key: Any, seg: tuple) -> Optional[KVBlock]:
        """A live block holding exactly ``seg`` after prefix ``parent_key``."""
        if len(seg) == self.block_size:
            bid = self._full_index.get((parent_key, seg))
            return self._blocks.get(bid) if bid is not None else None
        for bid in self._partial_index.get(parent_key, ()):
            blk = self._blocks.get(bid)
            if blk is not None and tuple(blk.tokens) == seg:
                return blk
        return None

    def _chain_key_of(self, table: BlockTable) -> Any:
        """Chain key after the table's trailing full blocks (for appends)."""
        if not table.block_ids:
            return _ROOT
        last = self._blocks[table.block_ids[-1]]
        return last.key if last.full else last.parent_key

    # ----------------------------------------------------------------- alloc

    def allocate(self, seq_id: int, tokens: Sequence[int]) -> BlockTable:
        """Build a block table for ``tokens``, sharing every content-matched
        block (refcount++) and allocating the rest; atomic — on PageError the
        partial allocation is rolled back and the pool is unchanged."""
        if seq_id in self._tables:
            raise PageError(f"sequence {seq_id} already allocated")
        self._resident.pop(seq_id, None)
        table = BlockTable(seq_id)
        shared: list[KVBlock] = []
        created: list[KVBlock] = []
        parent = _ROOT
        try:
            i = 0
            while i < len(tokens):
                seg = tuple(int(t) for t in tokens[i : i + self.block_size])
                blk = self._lookup(parent, seg)
                if blk is not None:
                    blk.refcount += 1
                    self._touch(blk)
                    shared.append(blk)
                    self.shared_hits += 1
                else:
                    blk = self._new_block(seg, parent)
                    created.append(blk)
                table.block_ids.append(blk.block_id)
                table.n_tokens += len(seg)
                if len(seg) == self.block_size:
                    parent = (parent, seg)
                i += len(seg)
        except PageError:
            for b in shared:
                b.refcount -= 1
            for b in created:
                self._drop_block(b)
            raise
        self._tables[seq_id] = table
        return table

    def append_token(self, seq_id: int, token: int) -> dict:
        """Record one more fed token for ``seq_id``.  May allocate a fresh
        block (last one full) or copy-on-write a shared partial block.
        Returns an event dict: ``{"new_block": bool, "cow": (old, new)|None}``.
        """
        table = self._tables[seq_id]
        token = int(token)
        ev = {"new_block": False, "cow": None}
        last = self._blocks[table.block_ids[-1]] if table.block_ids else None
        if last is None or last.full:
            blk = self._new_block((token,), self._chain_key_of(table))
            table.block_ids.append(blk.block_id)
            ev["new_block"] = True
        else:
            if last.refcount > 1:
                # divergent write into a shared partial block: copy-on-write
                copy = self._new_block(tuple(last.tokens), last.parent_key)
                copy.payload = last.payload  # snapshot; replaced at writeback
                last.refcount -= 1
                table.block_ids[-1] = copy.block_id
                self.cow_copies += 1
                ev["cow"] = (last.block_id, copy.block_id)
                last = copy
            self._index_remove(last)
            last.tokens.append(token)
            self._touch(last)
            self._index_add(last)
        table.n_tokens += 1
        return ev

    # ---------------------------------------------------------------- staging
    #
    # Uncommitted payload rows for speculative decoding: a verify step parks
    # the KV rows of *drafted* positions here; the commit path promotes the
    # accepted prefix into block payloads and the rest is dropped.  Rows are
    # opaque to the pool (same contract as ``KVBlock.payload``); at most one
    # staged range per sequence — re-staging overwrites (a rolled-back verify
    # re-runs and restages idempotently).

    def stage_rows(self, seq_id: int, start: int, rows: Any) -> None:
        """Park uncommitted KV rows for positions ``[start, start+len)``."""
        if seq_id not in self._tables:
            raise PageError(f"sequence {seq_id} not active; cannot stage rows")
        self._staged[seq_id] = (int(start), rows)
        self.staged_rounds += 1

    def staged(self, seq_id: int) -> Optional[tuple]:
        """Peek the staged ``(start, rows)`` for a sequence, if any."""
        return self._staged.get(seq_id)

    def take_staged(self, seq_id: int) -> Optional[tuple]:
        """Pop and return the staged ``(start, rows)`` (commit path)."""
        return self._staged.pop(seq_id, None)

    def drop_staged(self, seq_id: int) -> None:
        """Discard uncommitted rows (rollback / cancel / preemption)."""
        if self._staged.pop(seq_id, None) is not None:
            self.staged_drops += 1

    # --------------------------------------------------------------- release

    def release(self, seq_id: int, *, keep_resident: bool = True) -> None:
        """Drop the sequence's references.  ``keep_resident=True`` keeps the
        table resumable and the blocks cached (evictable once unreferenced);
        ``False`` frees unreferenced blocks immediately."""
        self.drop_staged(seq_id)  # uncommitted rows never outlive the slot
        table = self._tables.pop(seq_id, None)
        if table is None:
            self._resident.pop(seq_id, None)
            return
        for bid in table.block_ids:
            blk = self._blocks.get(bid)
            if blk is not None:
                blk.refcount -= 1
                self._touch(blk)
        if keep_resident:
            self._resident[seq_id] = table
        else:
            for bid in table.block_ids:
                blk = self._blocks.get(bid)
                if blk is not None and blk.refcount == 0:
                    self._drop_block(blk)

    def resume(self, seq_id: int) -> Optional[BlockTable]:
        """Re-pin a released sequence's blocks.  Returns its table if every
        block survived eviction, else None (caller must re-prefill)."""
        table = self._resident.pop(seq_id, None)
        if table is None:
            return None
        if not all(bid in self._blocks for bid in table.block_ids):
            return None
        for bid in table.block_ids:
            blk = self._blocks[bid]
            blk.refcount += 1
            self._touch(blk)
        self._tables[seq_id] = table
        return table

    # ---------------------------------------------------------------- lookup

    def probe_restore(self, tokens: Sequence[int]) -> bool:
        """True when every block that :meth:`allocate` would share for
        ``tokens`` is live *and payload-backed* — i.e. the engine can restore
        the KV rows instead of recomputing prefill."""
        if not len(tokens):
            return False
        parent = _ROOT
        i = 0
        while i < len(tokens):
            seg = tuple(int(t) for t in tokens[i : i + self.block_size])
            blk = self._lookup(parent, seg)
            if blk is None or blk.payload is None:
                return False
            if len(seg) == self.block_size:
                parent = (parent, seg)
            i += len(seg)
        return True

    def block(self, block_id: int) -> KVBlock:
        return self._blocks[block_id]

    def refcount(self, block_id: int) -> int:
        return self._blocks[block_id].refcount

    def table_of(self, seq_id: int) -> Optional[BlockTable]:
        return self._tables.get(seq_id)

    def blocks_of(self, seq_id: int) -> list[KVBlock]:
        table = self._tables.get(seq_id) or self._resident.get(seq_id)
        if table is None:
            return []
        return [self._blocks[b] for b in table.block_ids if b in self._blocks]

    def resident(self, seq_id: int) -> bool:
        table = self._resident.get(seq_id)
        return table is not None and all(b in self._blocks for b in table.block_ids)

    # ----------------------------------------------------------------- stats

    @property
    def n_live(self) -> int:
        return len(self._blocks)

    @property
    def n_free(self) -> int:
        return self.n_blocks - len(self._blocks)

    @property
    def n_evictable(self) -> int:
        return sum(1 for b in self._blocks.values() if b.refcount == 0)

    @property
    def occupancy(self) -> float:
        return len(self._blocks) / self.n_blocks

    def stats(self) -> dict:
        return {
            "n_blocks": self.n_blocks,
            "block_size": self.block_size,
            "live_blocks": self.n_live,
            "evictable_blocks": self.n_evictable,
            "occupancy": self.occupancy,
            "allocated_blocks": self.allocated_blocks,
            "shared_hits": self.shared_hits,
            "cow_copies": self.cow_copies,
            "evictions": self.evictions,
            "staged_rounds": self.staged_rounds,
            "staged_drops": self.staged_drops,
        }

"""Admission control and continuous-batch scheduling for the serve engine.

DuctTeip's lesson (PAPERS.md) applies directly here: at serving scale the
bottleneck is the data/admission plane, not the task graph.  This module is
that plane:

* :class:`AdmissionQueue` semantics live inside :class:`ServeScheduler` — a
  **bounded** wait queue with an overload policy: ``"reject"`` raises
  :class:`AdmissionError` at submit time (backpressure to the caller),
  ``"shed-oldest"`` drops the longest-waiting request (marked
  ``req.rejected``) to make room for the newcomer.  Every rejected request
  carries ``req.reject_reason`` — ``"queue_full"`` (reject policy),
  ``"shed"`` (overflow victim), or ``"deadline"`` (expired before it could
  be served) — so callers can distinguish overload from latency misses.

* Deadlines (ISSUE 8): a request submitted with ``deadline=`` (absolute
  ``time.perf_counter()`` seconds) is shed instead of admitted once the
  deadline passes — serving a request nobody is still waiting for wastes
  slots and KV blocks that on-time requests need.  The overflow shed is
  deadline-aware too: an already-expired waiter is preferred as the victim
  over the oldest viable one.  Mid-decode expiry and user ``cancel()`` are
  handled engine-side in the collect codelet (the only place slot state
  may be mutated), which releases the sequence's KV blocks immediately.

* :meth:`ServeScheduler.plan` decides, between engine iterations, which
  waiting requests join the decode batch.  A request is admitted only when
  a batch slot is free **and** the paged pool can hold its prompt blocks —
  a failed block allocation leaves the request queued (backpressure under
  memory pressure) rather than crashing the serve loop.  For each admission
  it picks one of three data paths:

  - ``"restore"`` — every needed block is live and payload-backed
    (prefix-cache hit, or a preempted sequence resuming): the engine
    scatters saved KV rows back into the slot and skips prefill entirely.
  - ``"prefill"`` — fresh request: run prefill, sample the first token
    from its logits.
  - ``"prefill-resume"`` — a preempted sequence whose blocks were evicted:
    re-prefill prompt + generated-so-far to rebuild the KV rows (the next
    token is already known, so prefill logits are discarded).

* Preemption: when a mid-decode block append cannot be satisfied, the
  engine asks :meth:`preemption_victim` — youngest-admitted-first, the
  request that has sunk the least work.

The scheduler exposes ``queue_depth`` and per-counter stats so overload is
observable (``ServeEngine.stats()`` merges them with pool occupancy).
"""
from __future__ import annotations

import collections
import itertools
import threading
import time
from dataclasses import dataclass
from typing import Optional

from repro.serving.kvcache import KVPagePool, PageError


class AdmissionError(RuntimeError):
    """Submit rejected: the bounded admission queue is full."""


@dataclass
class Admission:
    """One planned admission: the request, its batch slot, and the data path
    (``"restore"`` / ``"prefill"`` / ``"prefill-resume"``)."""

    req: object
    slot: int
    mode: str


class ServeScheduler:
    """Bounded admission queue + slot/block-aware admission planning."""

    def __init__(
        self,
        pool: KVPagePool,
        n_slots: int,
        *,
        max_queue: int = 64,
        overload: str = "reject",
        max_batch: Optional[int] = None,
        admit_max_wait: float = 0.0,
        draft_k: int = 0,
    ):
        if overload not in ("reject", "shed-oldest"):
            raise ValueError(f"unknown overload policy {overload!r}")
        if max_batch is not None and not (1 <= max_batch <= n_slots):
            raise ValueError(f"max_batch must be in [1, {n_slots}]")
        self.pool = pool
        self.n_slots = n_slots
        self.max_queue = max_queue
        self.overload = overload
        #: cap on concurrently decoding sequences (None = all slots); lets a
        #: deployment trade per-request latency against batch efficiency
        self.max_batch = max_batch
        #: hold admissions up to this many seconds so near-simultaneous
        #: arrivals join the batch together (NeMo-style batching timeout);
        #: 0.0 admits greedily
        self.admit_max_wait = float(admit_max_wait)
        #: speculative-decoding draft depth policy knob (0 = disabled);
        #: :meth:`draft_depth` sheds speculation under pool pressure
        self.draft_k = int(draft_k)
        self._waiting: collections.deque = collections.deque()
        self._free_slots: list[int] = list(range(n_slots))
        self._lock = threading.Lock()
        self._admit_seq = itertools.count()
        self.rejected = 0
        self.shed = 0
        self.admitted = 0
        self.preemptions = 0
        self.deadline_shed = 0
        self.cancelled = 0

    # ------------------------------------------------------------- queueing

    def submit(self, req) -> None:
        """Enqueue; on overflow apply the overload policy.  The shed is
        deadline-aware: an already-expired waiter is evicted in preference
        to the oldest still-viable one."""
        with self._lock:
            if len(self._waiting) >= self.max_queue:
                if self.overload == "reject":
                    self.rejected += 1
                    req.rejected = True
                    req.reject_reason = "queue_full"
                    req.done = True
                    raise AdmissionError(
                        f"admission queue full ({self.max_queue} waiting); "
                        "request rejected"
                    )
                idx, reason = self._pick_shed_victim()
                victim = self._waiting[idx]
                del self._waiting[idx]  # by index: Request.__eq__ is not usable
                self._drop(victim, reason)
            self._waiting.append(req)

    def _pick_shed_victim(self):
        """(index, reason) under shed-oldest overflow: the first expired
        waiter if any, else the longest-waiting one.  Caller holds _lock."""
        now = time.perf_counter()
        for i, cand in enumerate(self._waiting):
            dl = getattr(cand, "deadline", None)
            if dl is not None and now > dl:
                return i, "deadline"
        return 0, "shed"

    def _drop(self, req, reason: str) -> None:
        """Mark a waiting request rejected and count it.  Caller holds _lock."""
        req.rejected = True
        req.reject_reason = reason
        req.done = True
        if reason == "deadline":
            self.deadline_shed += 1
        else:
            self.shed += 1

    def requeue(self, req) -> None:
        """Put a preempted request back at the head of the queue."""
        with self._lock:
            self._waiting.appendleft(req)
        self.preemptions += 1

    @property
    def queue_depth(self) -> int:
        return len(self._waiting)

    @property
    def slot_occupancy(self) -> float:
        with self._lock:
            return (self.n_slots - len(self._free_slots)) / self.n_slots

    def free_slot(self, slot: int) -> None:
        with self._lock:
            self._free_slots.append(slot)
            self._free_slots.sort()

    # ------------------------------------------------------------- planning

    def plan(self, *, pageable: bool) -> list[Admission]:
        """Admit waiting requests while slots and blocks allow.  Block
        allocation happens here (driver thread, graph drained) so the
        admission either fully reserves its memory or stays queued.
        Cancelled and deadline-expired waiters are dropped first — admitting
        them would spend prefill compute and KV blocks on dead work."""
        out: list[Admission] = []
        now = time.perf_counter()
        with self._lock:
            keep: collections.deque = collections.deque()
            for req in self._waiting:
                if getattr(req, "cancelled", False):
                    req.done = True
                    self.cancelled += 1
                elif getattr(req, "deadline", None) is not None and now > req.deadline:
                    self._drop(req, "deadline")
                else:
                    keep.append(req)
            self._waiting = keep
            while self._waiting and self._free_slots:
                running = self.n_slots - len(self._free_slots)
                if self.max_batch is not None and running >= self.max_batch:
                    break
                if self.admit_max_wait > 0.0:
                    # batching window: hold off while the batch could still
                    # fill AND nobody has waited past the window
                    capacity = len(self._free_slots)
                    if self.max_batch is not None:
                        capacity = min(capacity, self.max_batch - running)
                    oldest = self._waiting[0]
                    waited = now - (getattr(oldest, "t_arrival", None) or now)
                    if waited < self.admit_max_wait and len(self._waiting) < capacity:
                        break
                req = self._waiting[0]
                try:
                    mode = self._reserve(req, pageable)
                except PageError:
                    break  # backpressure: pool full, keep the request queued
                self._waiting.popleft()
                slot = self._free_slots.pop(0)
                req.admit_order = next(self._admit_seq)
                self.admitted += 1
                out.append(Admission(req, slot, mode))
        return out

    def _reserve(self, req, pageable: bool) -> str:
        """Pin blocks for ``req`` and pick its data path (may raise PageError,
        leaving the pool unchanged)."""
        pool = self.pool
        prompt = [int(t) for t in req.prompt]
        if req.out_tokens:  # resuming a preempted sequence
            table = pool.resume(req.req_id)
            if table is not None:
                if all(
                    pool.block(b).payload is not None for b in table.block_ids
                ):
                    return "restore"
                # blocks survived but carry no rows (non-pageable model):
                # drop the pins and rebuild the KV state through prefill
                pool.release(req.req_id, keep_resident=False)
            fed = prompt + [int(t) for t in req.out_tokens[:-1]]
            pool.allocate(req.req_id, fed)
            return "prefill-resume"
        if pageable and len(prompt) > 1 and pool.probe_restore(prompt[:-1]):
            # prefix-cache hit: KV rows for prompt[:-1] are all saved;
            # the last prompt token is fed through the normal decode step
            pool.allocate(req.req_id, prompt[:-1])
            return "restore"
        pool.allocate(req.req_id, prompt)
        return "prefill"

    def draft_depth(self, n_spec: int = 1) -> int:
        """Speculative draft depth for the next round: the configured
        ``draft_k``, or 0 (speculation shed) when the pool lacks headroom
        to absorb ``n_spec`` sequences each drafting k tokens — drafted
        positions allocate blocks just like committed ones, and spending
        the last free blocks on tokens that may be rolled back would force
        preemptions of committed work.  Cheap enough to consult mid-chain:
        a draft task re-checks between feeds and aborts its round if
        admission pressure arrived after the round started."""
        k = self.draft_k
        if k <= 0 or n_spec <= 0:
            return 0
        bs = self.pool.block_size
        need = n_spec * ((k + bs) // bs + 1)
        if self.pool.n_free + self.pool.n_evictable < need:
            return 0
        return k

    def preemption_victim(self, running: dict, exclude: int | None = None):
        """(slot, req) to preempt: youngest admission first; None if only the
        excluded slot is running."""
        candidates = [
            (slot, req) for slot, req in running.items() if slot != exclude
        ]
        if not candidates:
            return None
        return max(candidates, key=lambda kv: kv[1].admit_order)

    def stats(self) -> dict:
        return {
            "queue_depth": self.queue_depth,
            "max_queue": self.max_queue,
            "overload": self.overload,
            "max_batch": self.max_batch,
            "admit_max_wait": self.admit_max_wait,
            "draft_k": self.draft_k,
            "slot_occupancy": self.slot_occupancy,
            "admitted": self.admitted,
            "rejected": self.rejected,
            "shed": self.shed,
            "deadline_shed": self.deadline_shed,
            "cancelled": self.cancelled,
            "preemptions": self.preemptions,
        }

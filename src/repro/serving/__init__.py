"""Serving tier: paged KV cache, admission control, continuous batching.

``kvcache``    — :class:`KVPagePool`: fixed-size blocks, prefix sharing with
                 refcounts + copy-on-write, deterministic LRU eviction.
``scheduler``  — :class:`ServeScheduler`: bounded admission queue with
                 reject / shed-oldest overload policies, slot+block-aware
                 admission planning, preemption victims.
``engine``     — :class:`ServeEngine`: continuously-batched decoding on one
                 persistent SpTaskGraph; per-request sampling controls.
``spec``       — :class:`SpecDecoder`: draft-model speculative decoding as
                 SP_MODEL_2 uncertain-writer chains on the engine's
                 batch-state cell (commit/rollback via the runtime's
                 speculation machinery).
``loadgen``    — seeded Poisson load generator + latency metrics for
                 ``benchmarks/serving_bench.py``.
"""
from repro.serving.engine import Request, ServeEngine
from repro.serving.kvcache import BlockTable, KVBlock, KVPagePool, PageError
from repro.serving.loadgen import LoadSpec, build_workload, run_load
from repro.serving.scheduler import Admission, AdmissionError, ServeScheduler
from repro.serving.spec import SpecDecoder, shrunken_draft

__all__ = [
    "Admission",
    "AdmissionError",
    "BlockTable",
    "KVBlock",
    "KVPagePool",
    "LoadSpec",
    "PageError",
    "Request",
    "ServeEngine",
    "ServeScheduler",
    "SpecDecoder",
    "build_workload",
    "run_load",
    "shrunken_draft",
]

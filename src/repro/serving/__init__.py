from .kvcache import KVPagePool, PageError
from .engine import ServeEngine, Request

__all__ = ["KVPagePool", "PageError", "ServeEngine", "Request"]

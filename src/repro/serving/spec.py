"""Speculative decoding on the runtime's commit/rollback speculation engine.

The paper's central mechanism (§4.6) is speculative task execution: a chain
of *uncertain writers* (``maybe``-write accesses) shares one snapshot under
``SP_MODEL_2``; a reader of the uncertain cell is rewritten into a
speculative body that runs on the snapshot plus a commit task that either
promotes the speculative result (no writer wrote) or re-executes the body
on the real value (rollback).  Draft-model speculative decoding maps onto
that machinery exactly — see the "Speculative decoding" section of
``core/speculation.py`` for the full mapping:

* ``spec.draft`` (×k) — one draft-model decode step per task, chained as
  ``maybe``-writers on the engine's batch-state cell.  In the normal case a
  draft never writes the state (drafted tokens are *proposals*, not state);
  when speculation must be abandoned mid-chain (pool pressure shed, forced
  rollback) the draft *does* write, poisoning the chain.
* ``spec.verify`` — reads the uncertain state cell, so the machinery turns
  it into a speculative body + commit task.  The body runs ONE multi-
  position target forward (``models.verify_step``) over the k drafted
  positions plus the pending token, samples the target's token at every
  position, and accepts the longest matching draft prefix plus one bonus
  token.  The body is pure with respect to engine state because the
  machinery may run it twice: speculatively, and again on rollback (where
  it sees ``round.abort`` and degrades to a plain one-token decode).
* ``spec.commit`` — a *certain* write on the state cell: installs the
  advanced state (tearing down the uncertainty chain for the next round)
  and performs every externally visible effect exactly once — pool block
  appends, ``out_tokens``, streaming callbacks, staged-payload promotion.

Greedy verification is bit-exact with non-speculative decode: the verify
forward's per-position math is literally ``decode_step`` unrolled, so the
target tokens it samples are the tokens the plain engine would have
produced, and only target-sampled tokens are ever committed.  The same
argument covers temperature sampling because sampling keys are folded by
absolute sequence position (not engine step), so position ``p`` samples
identically no matter how many draft rounds, rollbacks, or preemptions
preceded it.

Draft KV state: the draft model keeps its own dense cache per slot,
self-healed across rounds — rows written for rejected drafts sit beyond
the committed cursor, where the causal mask hides them until the row is
overwritten by the next feed at that position.  This is also why both the
target and the draft must be families with per-token KV rows
(``cache_layout(cfg) is not None``): a recurrent state cannot rewind.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SpData, sp_task
from repro.models import cache_layout, init_cache
from repro.runtime.serve import build_verify_fn, extract_cache_rows
from repro.serving.kvcache import PageError


def shrunken_draft(cfg, params=None, *, n_layers: int = 1):
    """Default draft preset: the target config truncated to its first
    ``n_layers`` layers (same vocab, same family, same cache geometry).
    With ``params`` given, the draft reuses the target's embedding/head and
    the leading layer stack — a free low-quality draft; without, the caller
    fits or initializes the draft itself.  → (draft_cfg, draft_params)."""
    draft_cfg = cfg.replace(n_layers=n_layers)
    if cache_layout(draft_cfg) is None:
        raise ValueError(
            f"family {cfg.family!r} has no per-token KV rows to rewind; "
            "speculative drafting needs cache_layout(cfg) is not None"
        )
    draft_params = None
    if params is not None:
        draft_params = dict(params)
        draft_params["layers"] = jax.tree.map(
            lambda t: t[:n_layers], params["layers"]
        )
    return draft_cfg, draft_params


@dataclass
class _RoundSlot:
    """Per-slot drafting state for one speculation round."""

    P: int                    # verify anchor: eng._pos[slot] at round start
    queue: list               # committed-but-unfed draft tokens, pending last
    dp: int                   # next draft-cache feed position
    proposals: list = field(default_factory=list)
    last_tok: int = 0
    fed_log: list = field(default_factory=list)  # [(pos, tok)] feeds performed


@dataclass
class SpecRound:
    """One speculation round: k draft feeds chained as uncertain writers,
    one verify, one commit.  ``abort`` flips when a draft poisons the chain
    (shed / forced rollback) — the machinery then rolls the verify back."""

    k: int
    per_slot: dict = field(default_factory=dict)  # slot -> _RoundSlot
    n_feeds: int = 0
    abort: bool = False

    @property
    def slots(self):
        return self.per_slot


# ---------------------------------------------------------------------------
# Codelets (``eng``/``rnd`` are static parameters; data slots carry the
# engine's batch-state cell plus two per-round cells).
# ---------------------------------------------------------------------------

@sp_task(maybe=("state",), write=("prop",), name="spec.draft", cost=2.0)
def _draft_codelet(state, prop, *, eng, rnd, j):
    """One draft-model decode feed.  ``maybe``-write on the batch state:
    normally it never assigns (drafts are proposals, committed only by
    ``spec.commit``); on shed/forced-rollback it poisons the chain so the
    machinery re-executes the verify on the real state."""
    if not rnd.abort and (
        eng._force_rollback > 0
        or eng.scheduler.draft_depth(len(rnd.per_slot)) <= 0
    ):
        rnd.abort = True
    if rnd.abort:
        state.value = state.value  # uncertain write -> machinery rollback
    else:
        eng._spec._draft_feed(rnd)
    prop.value = j


@sp_task(read=("state", "prop"), write=("vout",), name="spec.verify", cost=10.0)
def _verify_codelet(state, prop, vout, *, eng, rnd):
    """Speculated reader of the uncertain state cell.  Pure w.r.t. engine
    state — the machinery may run this body twice (speculatively, then on
    rollback); all effects live in ``spec.commit``."""
    vout.value = eng._spec._verify(rnd, state)


@sp_task(write=("state",), read=("vout",), name="spec.commit")
def _commit_codelet(state, vout, *, eng, rnd):
    """Certain write on the state cell: installs the advanced batch state
    (clearing the uncertainty chain) and applies all external effects."""
    eng._spec._commit(rnd, vout, state)


class SpecDecoder:
    """Draft-model speculative decoding bolted onto a :class:`ServeEngine`.

    Owns the draft model (config/params/jitted steps), the per-slot draft
    KV cache, and the round lifecycle.  The engine consults it from
    ``step()`` when any running request opted into speculation.
    """

    def __init__(self, eng, draft_cfg, draft_params, k: int = 4):
        if k < 1:
            raise ValueError("draft depth k must be >= 1")
        if cache_layout(eng.cfg) is None:
            raise ValueError(
                "speculative decoding needs a pageable target family "
                "(cache_layout(cfg) is not None): stale KV rows beyond the "
                "accepted position must be maskable and overwritable"
            )
        if cache_layout(draft_cfg) is None:
            raise ValueError("draft family must have per-token KV rows too")
        if draft_cfg.vocab != eng.cfg.vocab:
            raise ValueError(
                f"draft vocab {draft_cfg.vocab} != target vocab {eng.cfg.vocab}"
            )
        from repro.serving.engine import _jitted_serve_ops, _jitted_steps

        self.eng = eng
        self.cfg = draft_cfg
        self.params = draft_params
        self.k = int(k)
        self._decode, _ = _jitted_steps(draft_cfg)
        self._prime, self._install = _jitted_serve_ops(draft_cfg, eng.max_seq)
        self._caches = init_cache(draft_cfg, eng.n_slots, eng.max_seq)
        self._dummy_tok = jnp.zeros((eng.n_slots, 1), jnp.int32)
        # the verify forward must NOT donate the state caches: on rollback
        # the body re-runs against the same state value
        self._verify_jit = jax.jit(build_verify_fn(eng.cfg, jit=False))
        self._next_pos: dict[int, int] = {}  # slot -> draft rows valid below
        # slot -> (start, rows): committed verify rows carried across rounds
        # so blocks that straddle a round boundary can still be promoted
        self._staged_tail: dict[int, tuple] = {}
        self.rounds = 0
        self.rollback_rounds = 0
        self.sheds = 0
        self.proposed = 0
        self.accepted = 0
        self.committed_tokens = 0
        self.draft_feeds = 0
        self.staged_promotions = 0

    # -------------------------------------------------------------- lifecycle

    def prime_slot(self, slot: int, req) -> None:
        """Build the draft model's KV rows for everything the target has
        already fed in this slot (admission, restore, preemption resume).
        The draft prefill is one cheap call — the draft is small by
        construction."""
        n = int(self.eng._pos[slot])
        if n >= 1:
            full = [int(t) for t in req.prompt] + [int(t) for t in req.out_tokens]
            toks = np.asarray(full[:n], np.int32)[None, :]
            _, primed = self._prime(self.params, {"tokens": jnp.asarray(toks)})
            self._caches, _ = self._install(
                self._caches, primed, self._dummy_tok, jnp.int32(slot), jnp.int32(0)
            )
        self._next_pos[slot] = n

    def drop_slot(self, slot: int) -> None:
        self._next_pos.pop(slot, None)
        self._staged_tail.pop(slot, None)

    def insert_round(self, spec_slots: list, k: int) -> SpecRound:
        """Chain one round's draft/verify/commit codelets onto the engine's
        graph (caller holds ``graph_scope``)."""
        eng = self.eng
        rnd = SpecRound(k=k)
        for slot in spec_slots:
            req = eng._slot_req[slot]
            full = [int(t) for t in req.prompt] + [int(t) for t in req.out_tokens]
            P = int(eng._pos[slot])
            npos = min(self._next_pos.get(slot, 0), P)
            rnd.per_slot[slot] = _RoundSlot(P=P, queue=full[npos:P + 1], dp=npos)
        rnd.n_feeds = max(len(s.queue) - 1 for s in rnd.per_slot.values()) + k
        prop = SpData(None, f"spec.prop.{eng.steps}")
        vout = SpData(None, f"spec.vout.{eng.steps}")
        for j in range(rnd.n_feeds):
            _draft_codelet(eng._state, prop, eng=eng, rnd=rnd, j=j)
        _verify_codelet(eng._state, prop, vout, eng=eng, rnd=rnd)
        _commit_codelet(eng._state, vout, eng=eng, rnd=rnd)
        return rnd

    # --------------------------------------------------------------- drafting

    def _draft_feed(self, rnd: SpecRound) -> None:
        """One batched draft decode step.  Each spec slot feeds its next
        token — catch-up (committed but not yet in the draft cache), the
        pending token, or its own last proposal — at its own position; a
        slot already holding k proposals re-feeds its last token at the
        same position (an idempotent KV row rewrite)."""
        eng = self.eng
        B = eng.n_slots
        toks = np.zeros((B, 1), np.int32)
        pos = np.zeros(B, np.int32)
        gen = {}
        for slot, s in rnd.per_slot.items():
            if s.queue:
                t = s.queue.pop(0)
                p, s.dp = s.dp, s.dp + 1
                gen[slot] = not s.queue and len(s.proposals) < rnd.k
                s.fed_log.append((p, t))
            elif len(s.proposals) < rnd.k:
                t = s.proposals[-1]
                p, s.dp = s.dp, s.dp + 1
                gen[slot] = True
                s.fed_log.append((p, t))
            else:
                t, p = s.last_tok, s.dp - 1  # idempotent re-feed
                gen[slot] = False
            s.last_tok = t
            toks[slot, 0] = t
            pos[slot] = min(p, eng.max_seq - 1)
        logits, self._caches = self._decode(
            self.params, jnp.asarray(toks), self._caches, jnp.asarray(pos)
        )
        self.draft_feeds += 1
        arg = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for slot, s in rnd.per_slot.items():
            if gen[slot]:
                s.proposals.append(int(arg[slot]))

    # ------------------------------------------------------------ verify body

    def _verify(self, rnd: SpecRound, st) -> dict:
        """One batched multi-position target forward + per-position target
        sampling + acceptance.  Pure w.r.t. engine state: may run twice
        (speculative body, then rollback re-execution)."""
        eng = self.eng
        B = eng.n_slots
        tok0 = np.asarray(st["tok"]).copy()
        if rnd.abort:
            T = 1
            toks = tok0
            adv = np.zeros(B, np.int32)
        else:
            T = rnd.k + 1
            toks = np.repeat(tok0, T, axis=1)
            adv = np.zeros(B, np.int32)
            for slot, s in rnd.per_slot.items():
                adv[slot] = 1
                for j, d in enumerate(s.proposals):
                    toks[slot, 1 + j] = d
        pos = np.asarray(eng._pos, np.int32)
        logits, new_caches = self._verify_jit(
            eng.params, jnp.asarray(toks), st["caches"],
            jnp.asarray(pos), jnp.asarray(adv),
        )
        tgt = self._sample_positions(logits, pos, T)
        new_tok = tok0.copy()
        per = {}
        for slot, req in eng._slot_req.items():
            s = None if rnd.abort else rnd.per_slot.get(slot)
            if s is None:
                nxt = int(tgt[slot, 0])
                per[slot] = {
                    "fed": [int(tok0[slot, 0])], "out": [nxt], "accepted": 0,
                }
                new_tok[slot, 0] = nxt
                continue
            a = 0
            while a < rnd.k and int(tgt[slot, a]) == s.proposals[a]:
                a += 1
            out = [int(t) for t in tgt[slot, : a + 1]]
            per[slot] = {
                "fed": [int(tok0[slot, 0])] + s.proposals[:a],
                "out": out,
                "accepted": a,
            }
            new_tok[slot, 0] = out[-1]
            if eng._pageable:
                # the k+1 freshly computed target KV rows are *uncommitted*
                # until spec.commit promotes the accepted prefix
                stop = min(s.P + rnd.k + 1, eng.max_seq)
                rows = extract_cache_rows(new_caches, slot, s.P, stop)
                eng.pool.stage_rows(req.req_id, s.P, rows)
        return {
            "abort": rnd.abort,
            "state": {"caches": new_caches, "tok": jnp.asarray(new_tok)},
            "per": per,
        }

    def _sample_positions(self, logits, pos, T: int) -> np.ndarray:
        """Target tokens for every (slot, sub-step): greedy argmax, or the
        engine's sampler with keys folded by absolute sequence position —
        the same key the plain decode path would fold for that position."""
        eng = self.eng
        reqs = eng._slot_req
        if all(r.temperature <= 0.0 for r in reqs.values()):
            return np.asarray(jnp.argmax(logits, axis=-1))
        B = logits.shape[0]
        cols = []
        for t in range(T):
            temps = np.zeros(B, np.float32)
            topks = np.zeros(B, np.int32)
            keys = np.zeros((B, 2), np.uint32)
            for slot, r in reqs.items():
                temps[slot] = r.temperature
                topks[slot] = r.top_k
                if r.temperature > 0.0:
                    keys[slot] = np.asarray(jax.random.fold_in(
                        jax.random.PRNGKey(r.seed), int(pos[slot]) + t + 1
                    ))
            cols.append(np.asarray(eng._sample_jit(
                logits[:, t], jnp.asarray(temps), jnp.asarray(topks),
                jnp.asarray(keys),
            )))
        return np.stack(cols, axis=1)

    # ------------------------------------------------------------ commit body

    def _commit(self, rnd: SpecRound, v: dict, state) -> None:
        """All externally visible effects of the round, applied exactly
        once: install the advanced state (certain write → chain teardown),
        account fed tokens into the pool, append committed tokens, fire
        streaming callbacks, promote staged KV payloads, finish/cancel."""
        eng = self.eng
        self.rounds += 1
        if v["abort"]:
            self.rollback_rounds += 1
            if eng._force_rollback > 0:
                eng._force_rollback -= 1
        state.value = v["state"]
        eng._caches = v["state"]["caches"]
        eng._last_tok = v["state"]["tok"]
        now = time.perf_counter()
        for slot in sorted(eng._slot_req):
            req = eng._slot_req.get(slot)
            if req is None:  # preempted as a victim earlier in this loop
                continue
            if req.cancelled:
                eng.pool.drop_staged(req.req_id)
                eng._cancel_slot(slot, reason=None)
                continue
            if req.deadline is not None and now > req.deadline:
                eng.pool.drop_staged(req.req_id)
                eng._cancel_slot(slot, reason="deadline")
                continue
            info = v["per"][slot]
            s = rnd.per_slot.get(slot)
            if s is not None and not v["abort"]:
                self.proposed += rnd.k
                self.accepted += info["accepted"]
                req.spec_rounds += 1
                req.spec_accepted += info["accepted"]
            alive = True
            for ftok, ntok in zip(info["fed"], info["out"]):
                try:
                    eng.pool.append_token(req.req_id, ftok)
                except PageError:
                    if not eng._preempt_for(slot):
                        eng._preempt(slot)
                        alive = False
                        break
                    eng.pool.append_token(req.req_id, ftok)
                eng._pos[slot] += 1
                req.out_tokens.append(int(ntok))
                req.pending_tok = int(ntok)
                if req.t_first is None:
                    req.t_first = now
                req.t_tokens.append(now)
                eng._emit_token(req, int(ntok))
                self.committed_tokens += 1
                if (len(req.out_tokens) >= req.max_new_tokens
                        or eng._pos[slot] >= eng.max_seq):
                    self._promote_staged(slot, req)
                    eng._finish(slot)
                    alive = False
                    break
            if not alive:
                continue
            self._promote_staged(slot, req)
            if s is not None:
                self._advance_draft_cursor(slot, req, s)

    def _advance_draft_cursor(self, slot: int, req, s: _RoundSlot) -> None:
        """Draft rows are valid up to the first fed token that disagrees
        with the committed sequence (rejected proposals leave stale rows,
        self-healed by later overwrites)."""
        full = [int(t) for t in req.prompt] + [int(t) for t in req.out_tokens]
        cur = self._next_pos.get(slot, 0)
        for p, t in s.fed_log:
            if p < cur:
                continue  # idempotent re-feed of an already-valid row
            if p == cur and p < len(full) and full[p] == t:
                cur += 1
            else:
                break
        self._next_pos[slot] = cur

    def _promote_staged(self, slot: int, req) -> None:
        """Move accepted uncommitted KV rows into block payloads: any block
        that fills up with committed rows becomes payload-backed immediately
        (restorable without waiting for the finish-time writeback).  Rounds
        rarely align with block boundaries, so the committed trailing rows
        of each round are retained and merged into the next round's window
        — a straddling block still gets promoted once its last row lands."""
        eng = self.eng
        st = eng.pool.take_staged(req.req_id)
        if st is None or not eng._pageable:
            return
        start, rows = st
        n_rows = jax.tree.leaves(rows)[0].shape[1]
        # rows past the committed position came from rejected proposals:
        # their tokens are not what will occupy those positions
        end = min(start + n_rows, int(eng._pos[slot]))
        if end <= start:
            self._staged_tail.pop(slot, None)
            return
        tail = self._staged_tail.pop(slot, None)
        if tail is not None:
            t_start, t_rows = tail
            t_end = t_start + jax.tree.leaves(t_rows)[0].shape[1]
            if t_start < start <= t_end:  # contiguous: prepend retained rows
                keep = start - t_start
                rows = jax.tree.map(
                    lambda a, b: jnp.concatenate([a[:, :keep], b], axis=1),
                    t_rows, rows,
                )
                start = t_start
        table = eng.pool.table_of(req.req_id)
        if table is None:
            return
        bs = eng.pool.block_size
        for i, bid in enumerate(table.block_ids):
            blk = eng.pool.block(bid)
            a, b = i * bs, i * bs + len(blk.tokens)
            if (blk.full and blk.payload is None
                    and a >= start and b <= end):
                blk.payload = jax.tree.map(
                    lambda t: t[:, a - start:b - start], rows
                )
                self.staged_promotions += 1
        # carry the committed rows of the still-partial trailing block
        t_start = max(start, (end // bs) * bs)
        if t_start < end:
            self._staged_tail[slot] = (
                t_start,
                jax.tree.map(lambda t: t[:, t_start - start:end - start], rows),
            )

    # ------------------------------------------------------------------ stats

    def stats(self) -> dict:
        return {
            "draft_k": self.k,
            "rounds": self.rounds,
            "rollback_rounds": self.rollback_rounds,
            "sheds": self.sheds,
            "draft_feeds": self.draft_feeds,
            "proposed": self.proposed,
            "accepted": self.accepted,
            "accept_rate": self.accepted / max(self.proposed, 1),
            "committed_tokens": self.committed_tokens,
            "accepted_per_round": self.committed_tokens / max(self.rounds, 1),
            "staged_promotions": self.staged_promotions,
        }

"""Load generator for the serving tier.

Builds a seeded, reproducible open-loop workload — Poisson arrivals
(exponential inter-arrival gaps), mixed prompt/output lengths drawn from
small fixed sets (bounding the number of jit shape specializations), and an
optional duplicated-prompt fraction that exercises the paged pool's prefix
sharing — then drives a :class:`~repro.serving.engine.ServeEngine` through
it in one of two modes:

* ``"continuous"`` — requests are submitted the moment they arrive; the
  engine admits them mid-flight (continuous batching).
* ``"drain"`` — the generation-wide-barrier baseline this PR removes
  (static batching): when the engine is idle, up to ``n_slots`` arrived
  requests form a generation, and that batch runs to completion before the
  next batch is admitted.

Both modes run the *same* workload through the *same* engine build, so the
metric deltas (tokens/s, p50/p99 time-to-first-token, p50/p99 inter-token
latency) isolate the scheduling policy.  TTFT is measured from the
request's *arrival* time, not its submit time — in drain mode the queueing
delay before submission is precisely the cost being measured.
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.serving.engine import ServeEngine
from repro.serving.scheduler import AdmissionError


@dataclass(frozen=True)
class LoadSpec:
    """Reproducible workload description (everything derives from ``seed``)."""

    seed: int = 0
    n_requests: int = 24
    rate_rps: float = 40.0
    prompt_lens: tuple = (5, 9, 13, 17)
    out_lens: tuple = (4, 8, 12)
    vocab: int = 64
    dup_frac: float = 0.25  # fraction of requests reusing an earlier prompt
    temperature: float = 0.0
    top_k: int = 0
    #: submit requests with speculative decoding (requires an engine built
    #: with a draft model); committed output is bit-identical either way,
    #: so spec-vs-plain runs of the same workload isolate the speedup
    speculative: bool = False


@dataclass
class Arrival:
    at: float  # seconds after workload start
    prompt: np.ndarray
    max_new_tokens: int


def build_workload(spec: LoadSpec) -> list[Arrival]:
    """Materialize the arrival schedule.  Same spec → same workload."""
    rng = np.random.default_rng(spec.seed)
    arrivals: list[Arrival] = []
    t = 0.0
    for i in range(spec.n_requests):
        t += float(rng.exponential(1.0 / spec.rate_rps))
        if arrivals and rng.random() < spec.dup_frac:
            prompt = arrivals[int(rng.integers(len(arrivals)))].prompt
        else:
            L = int(rng.choice(spec.prompt_lens))
            prompt = rng.integers(0, spec.vocab, size=L).astype(np.int32)
        out = int(rng.choice(spec.out_lens))
        arrivals.append(Arrival(t, prompt, out))
    return arrivals


def _percentiles_ms(xs: list[float]) -> dict:
    if not xs:
        return {"p50": 0.0, "p99": 0.0}
    a = np.asarray(xs) * 1e3
    return {"p50": float(np.percentile(a, 50)), "p99": float(np.percentile(a, 99))}


def warm_up(engine: ServeEngine, spec: LoadSpec) -> None:
    """Trigger the jit specializations the workload will hit (one prefill
    shape per prompt length + the decode step) so compile time stays out of
    the measured window.  Warmup prompts use a disjoint token range so they
    cannot donate prefix hits to the measured run."""
    for L in spec.prompt_lens:
        prompt = np.full(L, spec.vocab + 1, np.int32)
        engine.submit(prompt, 2, temperature=spec.temperature,
                      top_k=spec.top_k, seed=0, speculative=spec.speculative)
    engine.run_until_drained()
    # repeat one prompt so the restore (prefix-hit) path is warm too
    engine.submit(np.full(spec.prompt_lens[0], spec.vocab + 1, np.int32), 2,
                  temperature=spec.temperature, top_k=spec.top_k, seed=0,
                  speculative=spec.speculative)
    engine.run_until_drained()


def run_load(
    engine: ServeEngine,
    workload: list[Arrival],
    *,
    mode: str = "continuous",
    spec: Optional[LoadSpec] = None,
    warmup: bool = True,
) -> dict:
    """Drive ``engine`` through ``workload`` and return latency metrics."""
    if mode not in ("continuous", "drain"):
        raise ValueError(f"unknown load mode {mode!r}")
    if warmup and spec is not None:
        warm_up(engine, spec)

    sampling = dict(
        temperature=spec.temperature if spec else 0.0,
        top_k=spec.top_k if spec else 0,
        speculative=spec.speculative if spec else None,
    )
    t0 = time.perf_counter()
    upcoming = list(workload)
    live: list = []
    rejected = 0
    while upcoming or engine.scheduler.queue_depth or engine.n_running:
        now = time.perf_counter() - t0
        # drain mode only feeds the engine when it is completely idle, and
        # at most one slot-sized generation at a time — the static-batching
        # barrier the continuous scheduler removes
        gate = (
            len(workload)
            if mode == "continuous"
            else (
                engine.n_slots
                if engine.n_running == 0 and engine.scheduler.queue_depth == 0
                else 0
            )
        )
        while upcoming and upcoming[0].at <= now and gate > 0:
            gate -= 1
            arr = upcoming.pop(0)
            try:
                req = engine.submit(
                    arr.prompt, arr.max_new_tokens,
                    seed=len(live), **sampling,
                )
            except AdmissionError:
                rejected += 1
                continue
            req.t_arrival = t0 + arr.at  # charge queueing from *arrival*
            live.append(req)
        if engine.n_running or engine.scheduler.queue_depth:
            engine.step()
        elif upcoming:
            time.sleep(max(0.0, upcoming[0].at - (time.perf_counter() - t0)))
    elapsed = time.perf_counter() - t0

    done = [r for r in live if r.done and not r.rejected]
    ttfts = [r.t_first - r.t_arrival for r in done if r.t_first is not None]
    itls = [
        b - a for r in done for a, b in zip(r.t_tokens, r.t_tokens[1:])
    ]
    n_tokens = sum(len(r.out_tokens) for r in done)
    ttft = _percentiles_ms(ttfts)
    itl = _percentiles_ms(itls)
    # order-independent fingerprint of committed output: two runs of the
    # same workload (e.g. speculative vs plain greedy decode) must match
    digest = hashlib.sha256(
        repr(sorted(
            (tuple(int(t) for t in r.prompt), tuple(r.out_tokens))
            for r in done
        )).encode()
    ).hexdigest()[:16]
    return {
        "output_checksum": digest,
        "mode": mode,
        "requests": len(done),
        "rejected": rejected,
        "tokens": n_tokens,
        "elapsed_s": elapsed,
        "tokens_per_s": n_tokens / elapsed if elapsed > 0 else 0.0,
        "ttft_p50_ms": ttft["p50"],
        "ttft_p99_ms": ttft["p99"],
        "itl_p50_ms": itl["p50"],
        "itl_p99_ms": itl["p99"],
        "engine": engine.stats(),
    }

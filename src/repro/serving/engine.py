"""Continuous-batching serve engine driven by the Specx eager runtime.

Requests are admitted into a fixed decode batch of ``n_slots`` sequences
(the KV pool's capacity).  Each engine iteration is expressed as STF tasks
— three codelets declared once at module level and instantiated per step:

    admit      write(state)  — prefill newly admitted requests into
                               their slots (host task calling the
                               jitted prefill; C3 data movement)
    decode     write(state)  — one fused decode step for the whole
                               batch (jitted serve step)
    collect    read(state)   — emit finished sequences, free slots

The KV cache lives as one batched pytree (slot-major); admission writes a
slot via masked updates.  LRU eviction (kvcache.py) frees slots of finished
sequences when the pool saturates — Specx's device-memory policy at the
level TPUs actually manage (DESIGN.md §2 C3).
"""
from __future__ import annotations

import collections
import itertools
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    SpComputeEngine,
    SpData,
    SpTaskGraph,
    SpWorkerTeamBuilder,
    graph_scope,
    sp_task,
)
from repro.models import decode_step, init_cache, prefill
from repro.models.config import ArchConfig
from repro.runtime.serve import prime_cache
from repro.serving.kvcache import KVPagePool

_req_ids = itertools.count()


# ---------------------------------------------------------------------------
# The per-iteration task shapes (codelets; ``eng`` is the ServeEngine).
# ---------------------------------------------------------------------------

@sp_task(write=("state",), name="admit")
def _admit_codelet(state, *, eng):
    while eng._queue and eng.pool.n_active < eng.n_slots:
        eng._admit_one(eng._queue.popleft())
    state.value = {"caches": eng._caches, "tok": eng._last_tok}


@sp_task(write=("state",), name="decode", cost=10.0)
def _decode_codelet(state, *, eng):
    if not eng._slot_req:
        return
    st = state.value
    logits, new_caches = eng._decode(
        eng.params, st["tok"], st["caches"], jnp.asarray(eng._pos)
    )
    toks = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]
    state.value = {"caches": new_caches, "tok": toks}


@sp_task(read=("state",), name="collect")
def _collect_codelet(state, *, eng):
    if not eng._slot_req:
        return
    eng._caches = state["caches"]
    eng._last_tok = state["tok"]
    toks = np.asarray(state["tok"][:, 0])
    for slot, req in list(eng._slot_req.items()):
        req.out_tokens.append(int(toks[slot]))
        eng._pos[slot] += 1
        eng.pool.touch(req.req_id)
        if len(req.out_tokens) >= req.max_new_tokens:
            req.done = True
            eng.pool.release(req.req_id, keep_resident=True)
            del eng._slot_req[slot]


@dataclass
class Request:
    prompt: np.ndarray  # (L,) int32
    max_new_tokens: int = 16
    req_id: int = field(default_factory=lambda: next(_req_ids))
    out_tokens: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Batched greedy-decoding server over a fixed slot pool."""

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        n_slots: int = 4,
        max_seq: int = 128,
        engine: Optional[SpComputeEngine] = None,
    ):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.pool = KVPagePool(n_slots)
        self._queue: collections.deque[Request] = collections.deque()
        self._slot_req: dict[int, Request] = {}
        self._pos = np.zeros(n_slots, np.int32)
        self._caches = init_cache(cfg, n_slots, max_seq)
        self._last_tok = jnp.zeros((n_slots, 1), jnp.int32)
        self._own_engine = engine is None
        self.engine = engine or SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(2))
        self.steps = 0

        self._decode = jax.jit(
            lambda p, t, c, pos: decode_step(p, t, c, pos, cfg), donate_argnums=(2,)
        )
        self._prefill = jax.jit(lambda p, b: prefill(p, b, cfg))

    # ------------------------------------------------------------------ API

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> Request:
        req = Request(np.asarray(prompt, np.int32), max_new_tokens)
        self._queue.append(req)
        return req

    def run_until_drained(self, max_iters: int = 1000) -> None:
        it = 0
        while (self._queue or self._slot_req) and it < max_iters:
            self.step()
            it += 1
        if self._queue or self._slot_req:
            raise RuntimeError("serve loop did not drain")

    # ----------------------------------------------------------------- inner

    def _admit_one(self, req: Request) -> None:
        slot = self.pool.acquire(req.req_id)
        self._slot_req[slot] = req
        prompt = req.prompt[None, :]  # (1, L)
        logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(prompt)})
        primed = prime_cache(self.cfg, caches, prompt.shape[1], self.max_seq)
        # write slot: every cache leaf is slot-major on axis (layers, slot, ...)
        def write_slot(full, one):
            return full.at[:, slot].set(one[:, 0].astype(full.dtype))

        self._caches = jax.tree.map(write_slot, self._caches, primed)
        tok = int(jnp.argmax(logits[0, -1]))
        req.out_tokens.append(tok)
        self._last_tok = self._last_tok.at[slot, 0].set(tok)
        self._pos[slot] = prompt.shape[1]

    def step(self) -> None:
        """One serve iteration as an STF task graph (the three codelets)."""
        tg = SpTaskGraph().compute_on(self.engine)
        state_cell = SpData(
            {"caches": self._caches, "tok": self._last_tok}, "serve_state"
        )
        with graph_scope(tg):
            _admit_codelet(state_cell, eng=self)
            _decode_codelet(state_cell, eng=self)
            _collect_codelet(state_cell, eng=self)
        tg.wait_all_tasks()
        self.steps += 1

    def close(self) -> None:
        if self._own_engine:
            self.engine.stop()

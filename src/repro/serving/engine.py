"""Continuous-batching serve engine on ONE persistent STF task graph.

The production serving tier (ROADMAP "millions of users" axis): requests
join and leave the decode batch mid-flight — there is no generation-wide
barrier anywhere.  Every engine iteration inserts chained codelets into a
single long-lived :class:`SpTaskGraph` owned by the engine (not one graph
per step); the WRITE chain on the shared batch-state cell serializes what
must be serialized and nothing else:

    decode      write(state)  — one fused decode step + per-request
                                sampling for the whole batch
    collect     read(state)   — account fed tokens into the paged pool
                                (block appends, copy-on-write, preemption),
                                emit finished sequences, free slots
    prefill     write(out)    — prompt prefill for ONE admitted request;
                                touches no shared state, so it runs
                                concurrently with in-flight decode steps
    install     write(state), read(out)
                              — scatter the prefilled KV into the slot
    restore     write(state)  — prefix-cache hit / resume: scatter saved
                                block payloads instead of recomputing

A new request's prefill therefore starts the moment it is admitted, while
other sequences keep decoding — the continuous-batching property the
benchmark (`benchmarks/serving_bench.py`) measures against a drain-barrier
baseline.

Memory is managed by the paged KV cache (``kvcache.py``): block tables per
sequence, prefix sharing with refcounts + copy-on-write, and deterministic
block-granularity LRU eviction — the paper's §4.3 device-memory policy at
the level the serving tier actually manages.  Admission control and
backpressure live in ``scheduler.py``.

Threading model: ``submit()`` is thread-safe; ``step()``/``run_until_drained``
must be driven from one thread (the planner mutates pool state with the
graph drained).
"""
from __future__ import annotations

import itertools
import math
import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    SpComputeEngine,
    SpData,
    SpSpeculativeModel,
    SpTaskGraph,
    SpWorkerTeamBuilder,
    graph_scope,
    sp_task,
)
from repro.models import cache_layout, decode_step, init_cache, prefill
from repro.models.config import ArchConfig
from repro.runtime.serve import (
    concat_cache_rows,
    extract_cache_rows,
    insert_cache_rows,
    prime_cache,
)
from repro.serving.kvcache import KVPagePool, PageError
from repro.serving.scheduler import Admission, ServeScheduler

_req_ids = itertools.count()

#: jitted (decode, prefill) per config — shared across engines so repeated
#: engine builds (tests, benchmark modes) reuse XLA compilation caches
_JIT_CACHE: dict = {}


def _jitted_steps(cfg):
    key = repr(cfg)
    fns = _JIT_CACHE.get(key)
    if fns is None:
        fns = (
            jax.jit(
                lambda p, t, c, pos: decode_step(p, t, c, pos, cfg),
                donate_argnums=(2,),
            ),
            jax.jit(lambda p, b: prefill(p, b, cfg)),
        )
        _JIT_CACHE[key] = fns
    return fns


def _jitted_serve_ops(cfg, max_seq: int):
    """Admission hot path, fused into XLA: (prefill → prime) in one call and
    the slot install scatter in another.  Op-by-op these cost ~10 ms per
    admission — more than several decode steps — which would make continuous
    admission slower than the drain barrier it replaces."""
    key = (repr(cfg), max_seq)
    fns = _JIT_CACHE.get(key)
    if fns is None:

        def prefill_prime(p, b):
            logits, caches = prefill(p, b, cfg)
            return logits[:, -1], prime_cache(cfg, caches, b["tokens"].shape[1], max_seq)

        def install(full, one, tok, slot, pending):
            caches = jax.tree.map(
                lambda f, o: f.at[:, slot].set(o[:, 0].astype(f.dtype)), full, one
            )
            return caches, tok.at[slot, 0].set(pending)

        fns = (
            jax.jit(prefill_prime),
            jax.jit(install, donate_argnums=(0,)),
        )
        _JIT_CACHE[key] = fns
    return fns


@dataclass
class Request:
    """One serving request.  ``temperature == 0`` (default) decodes greedily;
    otherwise tokens are drawn from the temperature-scaled, top-k-filtered
    distribution with a PRNG stream seeded per request (``seed``) and folded
    per step — two runs with the same seed produce the same tokens.

    ``deadline`` is an absolute ``time.perf_counter()`` timestamp: once it
    passes, the request is shed from the queue or cancelled mid-decode
    (KV blocks released) rather than finishing work nobody will read.
    ``reject_reason`` says why a rejected request was turned away:
    ``"queue_full"``, ``"shed"``, or ``"deadline"``.

    ``speculative`` requests decode through draft/verify/commit rounds when
    the engine has a draft model; ``out_tokens``/``t_tokens``/``on_token``
    only ever see *committed* tokens (drafted-but-unverified tokens live in
    the speculation machinery's uncommitted state)."""

    prompt: np.ndarray  # (L,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0  # 0 = no top-k filter
    seed: int = 0
    deadline: Optional[float] = None  # absolute perf_counter seconds
    speculative: bool = False
    on_token: Optional[callable] = None  # per committed token, engine thread
    req_id: int = field(default_factory=lambda: next(_req_ids))
    out_tokens: list = field(default_factory=list)
    done: bool = False
    rejected: bool = False
    reject_reason: Optional[str] = None
    cancelled: bool = False
    # continuous-batching bookkeeping
    pending_tok: Optional[int] = None  # sampled (or prompt tail) token not yet fed
    admit_order: int = -1
    preemptions: int = 0
    # speculative-decoding telemetry
    spec_rounds: int = 0
    spec_accepted: int = 0
    # latency telemetry (perf_counter seconds), consumed by the load generator
    t_arrival: Optional[float] = None
    t_first: Optional[float] = None
    t_tokens: list = field(default_factory=list)

    def stream(self, poll: float = 0.001, timeout: Optional[float] = None):
        """Incremental iterator over committed tokens: yields each token of
        ``out_tokens`` as it lands, returning when the request finishes.
        Drive it from a different thread than the engine loop (the engine
        must keep stepping for tokens to arrive); ``out_tokens`` is
        append-only, so a plain cursor is race-free under the GIL."""
        i = 0
        t0 = time.perf_counter()
        while True:
            while i < len(self.out_tokens):
                yield self.out_tokens[i]
                i += 1
            if self.done:
                return
            if timeout is not None and time.perf_counter() - t0 > timeout:
                raise TimeoutError(f"request {self.req_id}: stream timed out")
            time.sleep(poll)

    def cancel(self) -> None:
        """Withdraw the request.  Safe from any thread: the flag is acted on
        at the next scheduling point — a waiting request is dropped by
        ``plan()``, a running one is evicted by the collect codelet with its
        KV blocks released mid-decode."""
        self.cancelled = True


# ---------------------------------------------------------------------------
# Codelets (``eng`` is the ServeEngine, bound as a static parameter).
# ---------------------------------------------------------------------------

@sp_task(write=("state",), name="serve.decode", cost=10.0)
def _decode_codelet(state, *, eng):
    if not eng._slot_req:
        return
    st = state.value
    logits, new_caches = eng._decode(
        eng.params, st["tok"], st["caches"], jnp.asarray(eng._pos)
    )
    toks = eng._sample_batch(logits[:, 0])
    state.value = {"caches": new_caches, "tok": toks[:, None]}


@sp_task(read=("state",), name="serve.collect")
def _collect_codelet(state, *, eng):
    if not eng._slot_req:
        return
    eng._caches = state["caches"]
    eng._last_tok = state["tok"]
    toks = np.asarray(state["tok"][:, 0])
    now = time.perf_counter()
    for slot in sorted(eng._slot_req):
        req = eng._slot_req.get(slot)
        if req is None:  # preempted as a victim earlier in this loop
            continue
        if req.cancelled:
            eng._cancel_slot(slot, reason=None)
            continue
        if req.deadline is not None and now > req.deadline:
            eng._cancel_slot(slot, reason="deadline")
            continue
        # the token decoded this step was ``pending_tok``; its KV row now
        # exists, so account it into the block table (may COW / preempt)
        try:
            eng.pool.append_token(req.req_id, req.pending_tok)
        except PageError:
            if not eng._preempt_for(slot):
                eng._preempt(slot)  # nothing else to preempt: park itself
                continue
            eng.pool.append_token(req.req_id, req.pending_tok)
        eng._pos[slot] += 1
        new = int(toks[slot])
        req.out_tokens.append(new)
        req.pending_tok = new
        if req.t_first is None:
            req.t_first = now
        req.t_tokens.append(now)
        eng._emit_token(req, new)
        if len(req.out_tokens) >= req.max_new_tokens or eng._pos[slot] >= eng.max_seq:
            eng._finish(slot)


@sp_task(write=("out",), name="serve.prefill", cost=5.0)
def _prefill_codelet(out, *, eng, req, sample_first):
    """Prefill one request.  No access to the shared batch state — it runs
    concurrently with whatever decode steps are in flight."""
    fed = req.prompt if sample_first else np.concatenate(
        [req.prompt, np.asarray(req.out_tokens[:-1], np.int32)]
    )
    prompt = np.asarray(fed, np.int32)[None, :]
    logits_last, primed = eng._prefill_prime(eng.params, {"tokens": jnp.asarray(prompt)})
    first = eng._sample_one(req, logits_last[0]) if sample_first else None
    out.value = (primed, first, prompt.shape[1])


@sp_task(write=("state",), read=("out",), name="serve.install")
def _install_codelet(state, out, *, eng, req, slot):
    primed, first, n_fed = out
    st = state.value
    if first is not None:
        req.out_tokens.append(first)
        req.pending_tok = first
        req.t_first = time.perf_counter()
        req.t_tokens.append(req.t_first)
        eng._emit_token(req, first)
    caches, tok = eng._install(
        st["caches"], primed, st["tok"], jnp.int32(slot), jnp.int32(req.pending_tok)
    )
    eng._pos[slot] = n_fed
    eng._slot_req[slot] = req
    state.value = {"caches": caches, "tok": tok}
    eng._caches = caches
    eng._last_tok = tok
    if eng._spec is not None and req.speculative:
        eng._spec.prime_slot(slot, req)


@sp_task(write=("state",), name="serve.restore")
def _restore_codelet(state, *, eng, req, slot, rows, n_rows):
    """Prefix-cache hit / resume: scatter saved KV rows into the slot and
    join the decode batch with no prefill at all."""
    st = state.value
    caches = insert_cache_rows(st["caches"], slot, rows, 0)
    tok = st["tok"].at[slot, 0].set(req.pending_tok)
    eng._pos[slot] = n_rows
    eng._slot_req[slot] = req
    state.value = {"caches": caches, "tok": tok}
    eng._caches = caches
    eng._last_tok = tok
    if eng._spec is not None and req.speculative:
        eng._spec.prime_slot(slot, req)


class ServeEngine:
    """Continuously-batched decoding server over a paged KV cache.

    Context manager: ``with ServeEngine(cfg, params) as eng: ...`` stops the
    owned compute engine on exit even if the body raises.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        n_slots: int = 4,
        max_seq: int = 128,
        block_size: int = 8,
        n_blocks: Optional[int] = None,
        max_queue: int = 64,
        overload: str = "reject",
        max_batch: Optional[int] = None,
        admit_max_wait: float = 0.0,
        draft_cfg=None,
        draft_params=None,
        draft_k: int = 4,
        engine: Optional[SpComputeEngine] = None,
    ):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        if n_blocks is None:
            n_blocks = n_slots * math.ceil(max_seq / block_size)
        self.pool = KVPagePool(n_blocks, block_size)
        self.scheduler = ServeScheduler(
            self.pool, n_slots, max_queue=max_queue, overload=overload,
            max_batch=max_batch, admit_max_wait=admit_max_wait,
            draft_k=draft_k if draft_cfg is not None else 0,
        )
        self._layout = cache_layout(cfg)
        self._pageable = self._layout is not None
        self._slot_req: dict[int, Request] = {}
        self._pos = np.zeros(n_slots, np.int32)
        self._caches = init_cache(cfg, n_slots, max_seq)
        self._last_tok = jnp.zeros((n_slots, 1), jnp.int32)
        self._own_engine = engine is None
        self.engine = engine or SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(2))
        self._force_rollback = 0
        self.stream_errors = 0
        self.steps = 0
        self.prefills = 0
        self.restores = 0
        self.cancels = 0
        self.closed = False

        self._decode, self._prefill = _jitted_steps(cfg)
        self._prefill_prime, self._install = _jitted_serve_ops(cfg, max_seq)
        self._sample_jit = _SAMPLE_JIT
        # ONE persistent graph for the engine's lifetime; every iteration
        # chains its codelets onto the same batch-state cell.  With a draft
        # model the graph runs under SP_MODEL_2 so speculation rounds
        # (spec.py) flow through the uncertain-writer chain machinery; the
        # plain decode path is unaffected (its certain writes clear any
        # uncertainty immediately).
        spec_model = (
            SpSpeculativeModel.SP_MODEL_2 if draft_cfg is not None
            else SpSpeculativeModel.SP_NO_SPEC
        )
        self._tg = SpTaskGraph(spec_model, trace=False).compute_on(self.engine)
        self._state = SpData(
            {"caches": self._caches, "tok": self._last_tok}, "serve_state"
        )
        self._spec = None
        if draft_cfg is not None:
            from repro.serving.spec import SpecDecoder

            self._spec = SpecDecoder(self, draft_cfg, draft_params, k=draft_k)

    # ------------------------------------------------------------------ API

    def submit(
        self,
        prompt: np.ndarray,
        max_new_tokens: int = 16,
        *,
        temperature: float = 0.0,
        top_k: int = 0,
        seed: int = 0,
        deadline: Optional[float] = None,
        speculative: Optional[bool] = None,
        on_token: Optional[callable] = None,
    ) -> Request:
        """Enqueue a request (thread-safe).  Raises AdmissionError when the
        bounded queue is full under the ``"reject"`` overload policy.
        ``deadline`` is *relative* seconds from now; past it the request is
        shed (queued) or cancelled with its KV blocks freed (running).

        ``speculative`` opts the request in/out of draft-model speculative
        decoding; the default (None) opts in iff the engine has a draft
        model.  Speculative and plain requests share one decode batch.
        ``on_token`` is invoked with each *committed* token as it lands
        (engine thread — it must be fast and must not raise; exceptions are
        swallowed and counted in ``stream_errors``)."""
        if self.closed:
            raise RuntimeError("ServeEngine is closed")
        if speculative is None:
            speculative = self._spec is not None
        elif speculative and self._spec is None:
            raise ValueError(
                "speculative=True needs an engine with a draft model "
                "(ServeEngine(draft_cfg=, draft_params=))"
            )
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) + max_new_tokens > self.max_seq:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds max_seq ({self.max_seq})"
            )
        now = time.perf_counter()
        req = Request(
            prompt,
            max_new_tokens,
            temperature=float(temperature),
            top_k=int(top_k),
            seed=int(seed),
            deadline=None if deadline is None else now + float(deadline),
            speculative=bool(speculative),
            on_token=on_token,
        )
        req.t_arrival = now
        self.scheduler.submit(req)
        return req

    @property
    def n_running(self) -> int:
        return len(self._slot_req)

    def step(self, wait: bool = True) -> None:
        """One engine iteration: chain this iteration's codelets onto the
        persistent graph.  Decode/collect for the current batch go in first,
        then admissions — so a newly admitted request's prefill overlaps the
        in-flight decode and its KV installs right after collect.

        When any running request opted into speculation (and the scheduler's
        draft-depth policy allows it), the decode/collect pair is replaced by
        one speculation round — k chained ``spec.draft`` uncertain writers,
        one ``spec.verify`` speculated reader, one ``spec.commit`` — which
        advances speculative slots by up to k+1 committed tokens while plain
        slots ride along at one token per round.  Rounds force ``wait``:
        round planning reads slot state the previous round must have
        committed."""
        spec_round = False
        with graph_scope(self._tg):
            if self._slot_req:
                spec_slots = [
                    s for s, r in self._slot_req.items() if r.speculative
                ] if self._spec is not None else []
                k = 0
                if spec_slots:
                    k = self.scheduler.draft_depth(len(spec_slots))
                    if k <= 0:
                        self._spec.sheds += 1  # pool pressure: plain decode
                if spec_slots and k > 0:
                    self._spec.insert_round(spec_slots, k)
                    spec_round = True
                else:
                    _decode_codelet(self._state, eng=self)
                    _collect_codelet(self._state, eng=self)
            for adm in self.scheduler.plan(pageable=self._pageable):
                self._insert_admission(adm)
        if wait or spec_round:
            self._tg.wait_all_tasks()
        self.steps += 1

    def run_until_drained(self, max_iters: int = 1000) -> None:
        """Pump until queue and batch are empty.  This is a convenience loop,
        not a barrier: submissions made while it runs are admitted mid-flight."""
        it = 0
        while (self.scheduler.queue_depth or self._slot_req) and it < max_iters:
            self.step()
            it += 1
        if self.scheduler.queue_depth or self._slot_req:
            raise RuntimeError("serve loop did not drain")

    def stats(self) -> dict:
        out = {
            "steps": self.steps,
            "prefills": self.prefills,
            "restores": self.restores,
            "cancels": self.cancels,
            "running": self.n_running,
            "pageable": self._pageable,
            "stream_errors": self.stream_errors,
        }
        out.update(self.scheduler.stats())
        out["pool"] = self.pool.stats()
        if self._spec is not None:
            out["spec"] = self._spec.stats()
            out["spec"]["graph"] = dict(self._tg.spec_stats)
        return out

    def close(self) -> None:
        if self.closed:
            return
        self.closed = True
        if self._own_engine:
            self.engine.stop()

    def __enter__(self) -> "ServeEngine":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # ----------------------------------------------------------------- inner

    def _insert_admission(self, adm: Admission) -> None:
        req, slot, mode = adm.req, adm.slot, adm.mode
        if mode == "restore":
            table = self.pool.table_of(req.req_id)
            payloads = [self.pool.block(b).payload for b in table.block_ids]
            rows = concat_cache_rows(payloads)
            if not req.out_tokens:
                # fresh request via prefix cache: rows cover prompt[:-1];
                # the final prompt token rides the normal decode step
                req.pending_tok = int(req.prompt[-1])
            _restore_codelet(
                self._state, eng=self, req=req, slot=slot,
                rows=rows, n_rows=table.n_tokens,
            )
            self.restores += 1
        else:
            out = SpData(None, f"prefill.{req.req_id}")
            _prefill_codelet(
                out, eng=self, req=req, sample_first=(mode == "prefill")
            )
            _install_codelet(self._state, out, eng=self, req=req, slot=slot)
            self.prefills += 1

    def _writeback(self, slot: int, req: Request) -> None:
        """Save the slot's computed KV rows into the block payloads so a
        later prefix hit / resume can restore instead of re-prefilling."""
        if not self._pageable:
            return
        table = self.pool.table_of(req.req_id)
        if table is None:
            return
        bs = self.pool.block_size
        for i, bid in enumerate(table.block_ids):
            blk = self.pool.block(bid)
            a = i * bs
            b = min(a + len(blk.tokens), table.n_tokens)
            if blk.payload is None or blk.refcount <= 1:
                blk.payload = extract_cache_rows(self._caches, slot, a, b)

    def _emit_token(self, req: Request, tok: int) -> None:
        """Fire the streaming callback for one committed token."""
        if req.on_token is None:
            return
        try:
            req.on_token(tok)
        except Exception:
            self.stream_errors += 1

    def force_rollback(self, n: int = 1) -> None:
        """Poison the next ``n`` speculation rounds: their draft chains
        write the state cell, so the machinery rolls the verify back and
        re-executes it as a plain decode.  Output is unchanged (that is the
        point of the commit/rollback protocol); used by tests and chaos
        schedules."""
        if self._spec is None:
            raise RuntimeError("engine has no draft model; nothing to roll back")
        self._force_rollback += int(n)

    def _finish(self, slot: int) -> None:
        req = self._slot_req.pop(slot)
        req.done = True
        self._writeback(slot, req)
        self.pool.release(req.req_id, keep_resident=True)
        self.scheduler.free_slot(slot)
        if self._spec is not None:
            self._spec.drop_slot(slot)

    def _cancel_slot(self, slot: int, *, reason: Optional[str]) -> None:
        """Evict a running sequence whose output is no longer wanted
        (user ``cancel()`` or expired deadline): its KV blocks are freed
        immediately — no resumable writeback, unreferenced blocks returned
        to the pool mid-decode — and the slot rejoins the free list."""
        req = self._slot_req.pop(slot)
        req.done = True
        if reason is not None:
            req.rejected = True
            req.reject_reason = reason
        self.pool.release(req.req_id, keep_resident=False)
        self.scheduler.free_slot(slot)
        self.cancels += 1
        if self._spec is not None:
            self._spec.drop_slot(slot)

    def _preempt(self, slot: int) -> None:
        """Evict a running sequence: save its KV rows, release its blocks
        (resumable), and requeue it at the head of the admission queue."""
        req = self._slot_req.pop(slot)
        self._writeback(slot, req)
        self.pool.release(req.req_id, keep_resident=True)
        self.scheduler.free_slot(slot)
        req.preemptions += 1
        self.scheduler.requeue(req)
        if self._spec is not None:
            self._spec.drop_slot(slot)

    def _preempt_for(self, needy_slot: int) -> bool:
        victim = self.scheduler.preemption_victim(self._slot_req, exclude=needy_slot)
        if victim is None:
            return False
        self._preempt(victim[0])
        return True

    # -------------------------------------------------------------- sampling

    def _sample_batch(self, logits: jax.Array) -> jax.Array:
        """Per-slot sampling: greedy unless the slot's request asks for
        temperature/top-k, each with its own seeded key folded by the
        *absolute sequence position* of the token being sampled — not the
        engine step — so a position re-decoded after a speculation rollback
        or a preemption resume resamples the identical token, and the
        multi-position verify step can reproduce future positions' draws."""
        reqs = self._slot_req
        if all(r.temperature <= 0.0 for r in reqs.values()):
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        B = logits.shape[0]
        temps = np.zeros(B, np.float32)
        topks = np.zeros(B, np.int32)
        keys = np.zeros((B, 2), np.uint32)
        for slot, r in reqs.items():
            temps[slot] = r.temperature
            topks[slot] = r.top_k
            if r.temperature > 0.0:
                keys[slot] = np.asarray(jax.random.fold_in(
                    jax.random.PRNGKey(r.seed), len(r.prompt) + len(r.out_tokens)
                ))
        return self._sample_jit(
            logits, jnp.asarray(temps), jnp.asarray(topks), jnp.asarray(keys)
        )

    def _sample_one(self, req: Request, logits: jax.Array) -> int:
        if req.temperature <= 0.0:
            return int(jnp.argmax(logits))
        key = jax.random.fold_in(
            jax.random.PRNGKey(req.seed), len(req.prompt) + len(req.out_tokens)
        )
        tok = self._sample_jit(
            logits[None, :],
            jnp.asarray([req.temperature], jnp.float32),
            jnp.asarray([req.top_k], jnp.int32),
            jnp.asarray(key, jnp.uint32)[None, :],
        )
        return int(tok[0])


def _sample_logits(logits, temps, topks, keys):
    """Batched sampling: temperature scaling + top-k filter + categorical,
    falling back to argmax where ``temps == 0``.  (B, V) -> (B,) int32."""
    V = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1)
    scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
    sorted_desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    k_idx = jnp.clip(jnp.where(topks > 0, topks, V) - 1, 0, V - 1)
    thresh = jnp.take_along_axis(sorted_desc, k_idx[:, None], axis=-1)
    masked = jnp.where(scaled >= thresh, scaled, -jnp.inf)
    sampled = jax.vmap(jax.random.categorical)(keys, masked)
    return jnp.where(temps > 0, sampled, greedy).astype(jnp.int32)


_SAMPLE_JIT = jax.jit(_sample_logits)

"""Execution-trace SVG export (paper §4.8).

One horizontal lane per worker; each executed task is a rectangle scaled to
its duration, hoverable (``<title>``) for name/duration; a polyline under
the lanes shows the number of ready tasks over time — the paper's
"number of tasks available during the execution" track.
"""
from __future__ import annotations

import colorsys


def _color(uid: int) -> str:
    h = (uid * 0.6180339887) % 1.0
    r, g, b = colorsys.hsv_to_rgb(h, 0.45, 0.92)
    return f"#{int(r * 255):02x}{int(g * 255):02x}{int(b * 255):02x}"


def trace_to_svg(graph, show_dependencies: bool = True, width: int = 1200) -> str:
    events = sorted(graph.trace_events, key=lambda e: e["t0"])
    if not events:
        return (
            '<svg xmlns="http://www.w3.org/2000/svg" width="400" height="40">'
            "<text x='10' y='25'>empty trace</text></svg>"
        )
    t0 = min(e["t0"] for e in events)
    t1 = max(e["t1"] for e in events)
    span = max(t1 - t0, 1e-9)
    workers = sorted({e["worker"] for e in events})
    lane_h, pad, label_w = 26, 6, 110
    plot_w = width - label_w - 2 * pad
    ready_h = 60
    height = pad * 3 + lane_h * len(workers) + ready_h + 30

    def x(t: float) -> float:
        return label_w + pad + (t - t0) / span * plot_w

    out = [
        f'<svg xmlns="http://www.w3.org/2000/svg" width="{width}" height="{height}" '
        'font-family="monospace" font-size="11">',
        f'<rect width="{width}" height="{height}" fill="white"/>',
    ]
    lane_y = {w: pad + i * lane_h for i, w in enumerate(workers)}
    for w, y in lane_y.items():
        out.append(f'<text x="4" y="{y + lane_h * 0.7:.1f}">{w}</text>')
        out.append(
            f'<line x1="{label_w}" y1="{y + lane_h:.1f}" x2="{width - pad}" '
            f'y2="{y + lane_h:.1f}" stroke="#ddd"/>'
        )
    for e in events:
        y = lane_y[e["worker"]]
        xa, xb = x(e["t0"]), x(e["t1"])
        wdt = max(xb - xa, 0.75)
        fill = "#9ecae1" if e.get("comm") else ("#fee391" if e.get("spec") else _color(e["uid"]))
        dur_us = (e["t1"] - e["t0"]) * 1e6
        out.append(
            f'<rect x="{xa:.2f}" y="{y + 2}" width="{wdt:.2f}" height="{lane_h - 4}" '
            f'fill="{fill}" stroke="#555" stroke-width="0.4">'
            f"<title>{e['task']} ({dur_us:.1f} us)</title></rect>"
        )
    # ready-tasks-over-time track
    ry = pad * 2 + lane_h * len(workers)
    max_ready = max(1, max(e.get("ready", 0) for e in events))
    out.append(f'<text x="4" y="{ry + 12}">ready</text>')
    pts = []
    for e in events:
        yy = ry + ready_h - e.get("ready", 0) / max_ready * (ready_h - 10)
        pts.append(f"{x(e['t0']):.1f},{yy:.1f}")
    if len(pts) >= 2:
        out.append(
            f'<polyline points="{" ".join(pts)}" fill="none" stroke="#e6550d" stroke-width="1.2"/>'
        )
    out.append(
        f'<text x="{label_w}" y="{height - 8}">span={span * 1e3:.3f} ms, '
        f"tasks={len(events)}, max_ready={max_ready}</text>"
    )
    out.append("</svg>")
    return "\n".join(out)


def trace_metrics(graph) -> dict:
    """Concise execution-quality metrics — the paper's §4.8 "next release"
    feature ("export metrics that will provide concise but meaningful
    numbers on execution quality, such as the idle time")."""
    events = sorted(graph.trace_events, key=lambda e: e["t0"])
    if not events:
        return {"n_tasks": 0}
    t0 = min(e["t0"] for e in events)
    t1 = max(e["t1"] for e in events)
    span = max(t1 - t0, 1e-12)
    workers = sorted({e["worker"] for e in events})
    busy = {w: 0.0 for w in workers}
    for e in events:
        busy[e["worker"]] += e["t1"] - e["t0"]
    idle = {w: span - b for w, b in busy.items()}
    total_busy = sum(busy.values())
    durations = [e["t1"] - e["t0"] for e in events]
    return {
        "n_tasks": len(events),
        "n_workers": len(workers),
        "span_s": span,
        "busy_s": total_busy,
        "utilization": total_busy / (span * len(workers)),
        "idle_per_worker_s": idle,
        "mean_task_us": 1e6 * sum(durations) / len(durations),
        "max_task_us": 1e6 * max(durations),
        "comm_tasks": sum(1 for e in events if e.get("comm")),
        "speculative_tasks": sum(1 for e in events if e.get("spec")),
    }

"""Data handles and dependency generations (paper §4.7 internals).

Specx keeps one *data handle* per address used as a dependency; the handle
owns the ordered list of accesses applied to the object.  "In terms of
implementation, we do not construct a graph; instead we have one data handle
per address ... when a task is finished, we increment a counter on the
dependency list and access the next tasks."  We reproduce that design:

* one :class:`DataHandle` per :class:`SpData` cell (keyed by ``id`` — note
  DESIGN.md §8: keying on logical cells removes the paper's
  same-address-reuse undefined behaviour);
* each handle holds a list of :class:`Generation` — maximal runs of
  group-compatible accesses (all-READ, all-ATOMIC, all-COMMUTATIVE, or a
  single WRITE / MAYBE_WRITE);
* a task is *ready* when every one of its accesses sits in the currently
  active generation of its handle;
* when a generation completes, the next generation activates and its tasks'
  pending counters decrement — the counter walk from the paper.

Commutative writes: members of a COMMUTATIVE generation are all *released*
together (order-free) but must be mutually exclusive at runtime; the engine
acquires :attr:`DataHandle.commutative_lock` (multi-handle acquisition in
sorted-uid order — the paper's deadlock-avoidance-by-address-sort).
"""
from __future__ import annotations

import threading
from typing import TYPE_CHECKING, Optional

from .access import AccessMode, CONCURRENT_MODES, SpData

if TYPE_CHECKING:  # pragma: no cover
    from .task import Task


def _compatible(kind: AccessMode, mode: AccessMode) -> bool:
    """May ``mode`` join a generation whose kind is ``kind``?"""
    if kind in CONCURRENT_MODES and mode in CONCURRENT_MODES and kind is mode:
        return True
    if kind is AccessMode.COMMUTATIVE_WRITE and mode is AccessMode.COMMUTATIVE_WRITE:
        return True
    return False


class Generation:
    """One maximal run of group-compatible accesses on a handle."""

    __slots__ = ("kind", "tasks", "done", "active")

    def __init__(self, kind: AccessMode):
        self.kind = kind
        self.tasks: list["Task"] = []
        self.done = 0
        self.active = False

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (
            f"Gen({self.kind.name}, {self.done}/{len(self.tasks)},"
            f" {'active' if self.active else 'pending'})"
        )


class DataHandle:
    """Per-SpData dependency bookkeeping."""

    __slots__ = ("data", "generations", "cursor", "commutative_lock", "lock")

    def __init__(self, data: SpData):
        self.data = data
        self.generations: list[Generation] = []
        self.cursor = 0  # index of the active generation
        # runtime mutual exclusion for commutative writers (paper §4.7)
        self.commutative_lock = threading.Lock()
        # protects generation bookkeeping
        self.lock = threading.Lock()

    # -- insertion-time (single inserter thread; STF) -------------------------

    def append_access(self, task: "Task", mode: AccessMode) -> bool:
        """Record ``task``'s access.  Returns True iff the access lands in the
        currently active generation (i.e. does not block readiness).

        Insertion happens on the single STF inserter thread, but workers may
        concurrently :meth:`complete` earlier generations — hence the lock.
        """
        with self.lock:
            gens = self.generations
            if gens and _compatible(gens[-1].kind, mode) and gens[-1].done == 0:
                gen = gens[-1]
            else:
                gen = Generation(mode)
                gens.append(gen)
                if len(gens) - 1 == self.cursor:
                    gen.active = True
            gen.tasks.append(task)
            return gen.active

    # -- run-time --------------------------------------------------------------

    def complete(self, task: "Task") -> list["Task"]:
        """Mark ``task``'s access on this handle complete.

        Returns the list of tasks whose pending counters were decremented to
        zero *by this handle* (newly ready tasks).  Thread-safe.
        """
        newly_ready: list["Task"] = []
        with self.lock:
            gen = self.generations[self.cursor]
            gen.done += 1
            if gen.kind.is_write_like and task.worker_name is not None:
                # locality hint consumed by WorkStealingScheduler.push
                self.data.last_writer = task.worker_name
            if gen.done < len(gen.tasks):
                return newly_ready
            # generation finished → bump data version for write-like gens
            if gen.kind.is_write_like:
                self.data.version += 1
            self.cursor += 1
            if self.cursor < len(self.generations):
                nxt = self.generations[self.cursor]
                nxt.active = True
                for t in nxt.tasks:
                    if t.dec_pending():
                        newly_ready.append(t)
        return newly_ready

    @property
    def active_generation(self) -> Optional[Generation]:
        if self.cursor < len(self.generations):
            return self.generations[self.cursor]
        return None


class HandleRegistry:
    """id(SpData) → DataHandle map (the paper's address-keyed hashmap)."""

    __slots__ = ("_handles",)

    def __init__(self):
        self._handles: dict[int, DataHandle] = {}

    def handle_for(self, data: SpData) -> DataHandle:
        h = self._handles.get(id(data))
        if h is None:
            h = DataHandle(data)
            self._handles[id(data)] = h
        return h

    def maybe_handle(self, data: SpData) -> Optional[DataHandle]:
        return self._handles.get(id(data))

    def __iter__(self):
        return iter(self._handles.values())

    def __len__(self) -> int:
        return len(self._handles)

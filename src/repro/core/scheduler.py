"""Pluggable schedulers (paper §4.5).

Specx adopts StarPU's two-function contract: ``push(task)`` when a task
becomes ready, ``pop(worker)`` when a worker is available.  New schedulers
are classes deriving from :class:`SpAbstractScheduler` — no runtime changes
needed (the paper's explicit design goal).

Shipped policies:

* :class:`FifoScheduler` — the paper's default.
* :class:`LifoScheduler` — depth-first; better cache reuse on chains.
* :class:`PriorityScheduler` — honors :class:`~repro.core.access.SpPriority`.
* :class:`CriticalPathScheduler` — HEFT-flavoured: pops the ready task with
  the longest downstream cost (upward rank); ranks are computed by
  :func:`compute_upward_ranks` over the finished graph (used by the staged
  backend and by benchmarks; in eager streaming mode it degrades gracefully
  to priority order).
* :class:`WorkStealingScheduler` — per-worker deques with locality-aware
  pushes and randomized stealing (see below).

The same policies drive the *staged* backend's linearization
(:func:`repro.core.staged.linearize`), where "scheduling" means choosing the
program order of the compiled SPMD step (DESIGN.md §2).

Scheduling policies & locality
------------------------------

Paper §4.5 deliberately leaves the placement policy open ("the scheduler is
free to use it").  Our work-stealing policy fills that gap the way StarPU's
``dmda``-family and Heteroflow's per-worker queues do — by making the
*common* case lock-cheap and data-local, and the *rare* case (stealing)
correct:

* **One deque per registered worker, one lock per deque.**  ``push`` and
  ``pop`` touch only the deque they operate on; there is no global lock on
  the hot path (a small registration lock guards worker attach/detach only).
* **Locality push.**  Every :class:`~repro.core.handle.DataHandle` records
  the worker that last ran a write-like access on its
  :class:`~repro.core.access.SpData` (``data.last_writer``, stamped on
  generation completion).  ``push`` tallies the last writers of a ready
  task's accesses and routes the task to the deque of the *dominant* input's
  last writer — the worker most likely to still hold that data warm.  Tasks
  with no usable hint fall back to the least-loaded deque.
* **Owner-LIFO / thief-FIFO.**  Owners pop newest-first (depth-first, warm
  caches); thieves steal oldest-first (breadth-first, coarse work).
* **Steal order.**  An idle worker first drains the *overflow* deque (tasks
  orphaned by worker detach — never left to languish behind random victim
  choice), then retries the victim it last stole from successfully, then
  scans the remaining deques in randomized order.
* **Counters.**  ``stats()`` exposes push/pop/steal/locality counters so
  benchmarks (``benchmarks/engine_bench.py`` → ``BENCH_engine.json``) can
  track hit rates across PRs.
"""
from __future__ import annotations

import collections
import heapq
import itertools
import random
import threading
from typing import Optional

from .task import Task


class SpAbstractScheduler:
    """Interface: push / pop / __len__.  Implementations must be thread-safe
    (the engine calls them under its own condition variable, but requeues and
    multi-graph use can interleave)."""

    def push(self, task: Task) -> Optional[str]:
        """Queue a ready task.  May return the name of the worker whose
        deque received it (the engine then unparks that worker); policies
        without per-worker queues return None."""
        raise NotImplementedError

    def pop(self, worker_kind: str = "ref") -> Optional[Task]:
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError


class FifoScheduler(SpAbstractScheduler):
    """First-in-first-out — Specx's current default (paper §4.5)."""

    def __init__(self):
        self._q: collections.deque[Task] = collections.deque()
        self._lock = threading.Lock()

    def push(self, task: Task) -> None:
        with self._lock:
            self._q.append(task)

    def pop(self, worker_kind: str = "ref") -> Optional[Task]:
        with self._lock:
            return self._q.popleft() if self._q else None

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)


class LifoScheduler(SpAbstractScheduler):
    def __init__(self):
        self._q: collections.deque[Task] = collections.deque()
        self._lock = threading.Lock()

    def push(self, task: Task) -> None:
        with self._lock:
            self._q.append(task)

    def pop(self, worker_kind: str = "ref") -> Optional[Task]:
        with self._lock:
            return self._q.pop() if self._q else None

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)


class PriorityScheduler(SpAbstractScheduler):
    """Max-heap on ``task.priority``; FIFO among equal priorities."""

    def __init__(self):
        self._heap: list[tuple[int, int, Task]] = []
        self._lock = threading.Lock()
        self._counter = itertools.count()

    def push(self, task: Task) -> None:
        with self._lock:
            heapq.heappush(self._heap, (-task.priority, next(self._counter), task))

    def pop(self, worker_kind: str = "ref") -> Optional[Task]:
        with self._lock:
            if not self._heap:
                return None
            return heapq.heappop(self._heap)[2]

    def __len__(self) -> int:
        with self._lock:
            return len(self._heap)


class CriticalPathScheduler(PriorityScheduler):
    """Pops by upward rank when available (``task._rank``), else priority.

    Use :func:`compute_upward_ranks` once the graph is fully inserted to fill
    ranks; in streaming mode unranked tasks fall back to their priority.
    """

    def push(self, task: Task) -> None:
        rank = getattr(task, "_rank", None)
        key = rank if rank is not None else float(task.priority)
        with self._lock:
            heapq.heappush(self._heap, (-key, next(self._counter), task))


class _WorkerDeque:
    """A worker's run queue: its own lock so push/pop never serialize
    scheduler-wide.  ``closed`` marks a deque whose worker detached mid-push
    (the pusher re-routes; see :meth:`WorkStealingScheduler.push`)."""

    __slots__ = ("q", "lock", "closed")

    def __init__(self):
        self.q: collections.deque[Task] = collections.deque()
        self.lock = threading.Lock()
        self.closed = False


class WorkStealingScheduler(SpAbstractScheduler):
    """Per-worker deques; owner pops LIFO, thieves steal FIFO.

    The engine registers each attached worker (by thread name) via
    :meth:`register_worker`.  ``push`` routes a ready task to the deque of
    its dominant input's last writer (``locality=True``, the default; see
    the module docstring), falling back to the least-loaded deque.  Before
    any worker is registered (or after all detach) tasks land in an
    overflow deque that idle poppers drain *before* stealing.
    """

    _OVERFLOW = "w0"

    def __init__(self, seed: int = 0, locality: bool = True):
        self._locality = locality
        # _reg_lock guards membership (register/unregister); the hot path
        # reads the _workers snapshot and _deques entries without it.
        self._reg_lock = threading.Lock()
        self._workers: tuple[str, ...] = ()
        self._overflow_dq = _WorkerDeque()
        self._deques: dict[str, _WorkerDeque] = {self._OVERFLOW: self._overflow_dq}
        self._rr = itertools.count()  # probe cursor for hint-less pushes
        self._rng = random.Random(seed)
        self._rng_lock = threading.Lock()
        self._last_victim: dict[str, str] = {}
        # hot-path counters are plain ints bumped without a lock: a lost
        # increment under GIL interleaving is harmless for monitoring, and
        # the hot path stays lock-free outside the deque ops themselves
        self._pushes = 0
        self._locality_hits = 0   # pushed onto the last-writer's own deque
        self._pops_local = 0      # owner popped its own deque
        self._pops_overflow = 0   # drained an orphaned task
        self._steals = 0          # popped from another worker's deque
        self._failed_pops = 0     # found nothing anywhere

    # ------------------------------------------------------------ membership

    def register_worker(self, worker_name: str) -> None:
        with self._reg_lock:
            if worker_name not in self._workers:
                dq = self._deques.get(worker_name)
                if dq is None or dq.closed:
                    self._deques[worker_name] = _WorkerDeque()
                self._workers = self._workers + (worker_name,)

    def unregister_worker(self, worker_name: str) -> None:
        """Detach a worker; its unfinished tasks move to the overflow deque."""
        if worker_name == self._OVERFLOW:
            return
        with self._reg_lock:
            self._workers = tuple(w for w in self._workers if w != worker_name)
            dq = self._deques.pop(worker_name, None)
            if dq is None:
                return
            overflow = self._deques[self._OVERFLOW]
            # lock order: victim deque then overflow — nothing else ever
            # holds two deque locks, so this cannot deadlock
            with dq.lock:
                dq.closed = True
                orphans = list(dq.q)
                dq.q.clear()
            if orphans:
                with overflow.lock:
                    overflow.q.extend(orphans)

    # ------------------------------------------------------------------ push

    def _locality_owner(self, task: Task) -> Optional[str]:
        """Dominant input's last writer, if it is a registered worker.
        Single-access tasks are resolved inline in :meth:`push`; this handles
        the multi-access vote."""
        tally: dict[str, int] = {}
        for acc in task.accesses:
            w = acc.data.last_writer
            if w is not None:
                tally[w] = tally.get(w, 0) + 1
        if not tally:
            return None
        workers = self._workers
        best = None
        best_n = 0
        for w, n in tally.items():
            if n > best_n and w in workers:
                best, best_n = w, n
        return best

    def push(self, task: Task) -> Optional[str]:
        """Queue a ready task; returns the deque (worker name) it landed on
        so the engine can unpark that specific worker."""
        owner = None
        if self._locality:
            accesses = task.accesses
            if len(accesses) == 1:  # inline fast path: 1-access tasks
                w = accesses[0].data.last_writer
                if w is not None and w in self._workers:
                    owner = w
            else:
                owner = self._locality_owner(task)
        hit = owner is not None
        while True:
            if owner is None:
                workers = self._workers
                n = len(workers)
                if n == 0:
                    owner = self._OVERFLOW
                elif n == 1:
                    owner = workers[0]
                else:
                    # hint-less fallback: power-of-two-choices — probe two
                    # deques and take the shorter (near-least-loaded balance
                    # at O(1) cost instead of a full scan per push)
                    i = next(self._rr)
                    a = workers[i % n]
                    b = workers[(i + 1 + (i >> 3)) % n]
                    da, db = self._deques.get(a), self._deques.get(b)
                    la = len(da.q) if da is not None else 1 << 30
                    lb = len(db.q) if db is not None else 1 << 30
                    owner = a if la <= lb else b
            dq = self._deques.get(owner)
            if dq is None:
                owner = self._OVERFLOW
                continue
            with dq.lock:
                if not dq.closed:
                    dq.q.append(task)
                    break
            owner = None  # raced with unregister — re-route
        self._pushes += 1
        if hit:
            self._locality_hits += 1
        return owner

    # ------------------------------------------------------------------- pop

    def _try_pop(self, name: str, lifo: bool) -> Optional[Task]:
        dq = self._deques.get(name)
        if dq is None or not dq.q:
            return None
        with dq.lock:
            if not dq.q:
                return None
            return dq.q.pop() if lifo else dq.q.popleft()

    def pop(self, worker_kind: str = "ref", worker_name: str = "w0") -> Optional[Task]:
        # 1. own deque, newest-first (warm caches) — inlined hot path
        dq = self._deques.get(worker_name)
        if dq is not None and dq.q:
            with dq.lock:
                if dq.q:
                    self._pops_local += 1
                    return dq.q.pop()
        # 2. orphaned work first — overflow never waits on victim luck
        ov = self._overflow_dq
        if ov.q and worker_name != self._OVERFLOW:
            with ov.lock:
                if ov.q:
                    self._pops_overflow += 1
                    return ov.q.popleft()
        # 3. last successful victim, then a scan from a random start point
        #    (cheaper than a full shuffle, same anti-convoy effect); steal
        #    oldest-first
        last = self._last_victim.get(worker_name)
        if last is not None:
            t = self._try_pop(last, lifo=False)
            if t is not None:
                self._steals += 1
                return t
        # list(dict) snapshots atomically; iterating the live dict would race
        # with register/unregister mutating it from other threads
        candidates = [
            v for v in list(self._deques) if v not in (worker_name, self._OVERFLOW, last)
        ]
        if candidates:
            with self._rng_lock:
                start = self._rng.randrange(len(candidates))
            for i in range(len(candidates)):
                victim = candidates[(start + i) % len(candidates)]
                t = self._try_pop(victim, lifo=False)
                if t is not None:
                    self._last_victim[worker_name] = victim
                    self._steals += 1
                    return t
        self._failed_pops += 1
        return None

    def __len__(self) -> int:
        # snapshot sum — len(deque) is atomic; exactness is not required here
        return sum(len(d.q) for d in list(self._deques.values()))

    def stats(self) -> dict:
        out = {
            "pushes": self._pushes,
            "locality_hits": self._locality_hits,
            "pops_local": self._pops_local,
            "pops_overflow": self._pops_overflow,
            "steals": self._steals,
            "failed_pops": self._failed_pops,
            "queued": len(self),
        }
        pops = out["pops_local"] + out["pops_overflow"] + out["steals"]
        out["local_hit_rate"] = out["pops_local"] / pops if pops else 0.0
        out["steal_rate"] = out["steals"] / pops if pops else 0.0
        out["locality_push_rate"] = (
            out["locality_hits"] / out["pushes"] if out["pushes"] else 0.0
        )
        return out


def compute_upward_ranks(tasks: list[Task], successors: dict[int, list[Task]]) -> None:
    """HEFT upward rank: rank(t) = cost(t) + max over successors of rank(s).

    ``successors`` maps task uid → successor tasks (derivable from the graph
    via :meth:`SpTaskGraph.successor_map`).  Sets ``task._rank`` in place.
    """
    memo: dict[int, float] = {}

    order = list(tasks)
    # iterative reverse-topological accumulation (tasks are inserted in a
    # valid topological order by STF construction, so reverse insertion
    # order is a valid reverse-topological order)
    for t in sorted(order, key=lambda x: x.inserted_index, reverse=True):
        succ = successors.get(t.uid, ())
        best = 0.0
        for s in succ:
            best = max(best, memo.get(s.uid, 0.0))
        memo[t.uid] = t.cost + best
        t._rank = memo[t.uid]


SCHEDULERS = {
    "fifo": FifoScheduler,
    "lifo": LifoScheduler,
    "priority": PriorityScheduler,
    "critical_path": CriticalPathScheduler,
    "work_stealing": WorkStealingScheduler,
}


def make_scheduler(name: str, **kw) -> SpAbstractScheduler:
    try:
        return SCHEDULERS[name](**kw)
    except KeyError:
        raise ValueError(f"unknown scheduler {name!r}; have {sorted(SCHEDULERS)}") from None

"""Pluggable schedulers (paper §4.5).

Specx adopts StarPU's two-function contract: ``push(task)`` when a task
becomes ready, ``pop(worker)`` when a worker is available.  New schedulers
are classes deriving from :class:`SpAbstractScheduler` — no runtime changes
needed (the paper's explicit design goal).

Shipped policies:

* :class:`FifoScheduler` — the paper's default.
* :class:`LifoScheduler` — depth-first; better cache reuse on chains.
* :class:`PriorityScheduler` — honors :class:`~repro.core.access.SpPriority`.
* :class:`CriticalPathScheduler` — HEFT-flavoured: pops the ready task with
  the longest downstream cost (upward rank); ranks are computed by
  :func:`compute_upward_ranks` over the finished graph (used by the staged
  backend and by benchmarks; in eager streaming mode it degrades gracefully
  to priority order).
* :class:`WorkStealingScheduler` — per-worker deques with random steal.

The same policies drive the *staged* backend's linearization
(:func:`repro.core.staged.linearize`), where "scheduling" means choosing the
program order of the compiled SPMD step (DESIGN.md §2).
"""
from __future__ import annotations

import collections
import heapq
import itertools
import random
import threading
from typing import Optional

from .task import Task


class SpAbstractScheduler:
    """Interface: push / pop / __len__.  Implementations must be thread-safe
    (the engine calls them under its own condition variable, but requeues and
    multi-graph use can interleave)."""

    def push(self, task: Task) -> None:
        raise NotImplementedError

    def pop(self, worker_kind: str = "ref") -> Optional[Task]:
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError


class FifoScheduler(SpAbstractScheduler):
    """First-in-first-out — Specx's current default (paper §4.5)."""

    def __init__(self):
        self._q: collections.deque[Task] = collections.deque()
        self._lock = threading.Lock()

    def push(self, task: Task) -> None:
        with self._lock:
            self._q.append(task)

    def pop(self, worker_kind: str = "ref") -> Optional[Task]:
        with self._lock:
            return self._q.popleft() if self._q else None

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)


class LifoScheduler(SpAbstractScheduler):
    def __init__(self):
        self._q: collections.deque[Task] = collections.deque()
        self._lock = threading.Lock()

    def push(self, task: Task) -> None:
        with self._lock:
            self._q.append(task)

    def pop(self, worker_kind: str = "ref") -> Optional[Task]:
        with self._lock:
            return self._q.pop() if self._q else None

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)


class PriorityScheduler(SpAbstractScheduler):
    """Max-heap on ``task.priority``; FIFO among equal priorities."""

    def __init__(self):
        self._heap: list[tuple[int, int, Task]] = []
        self._lock = threading.Lock()
        self._counter = itertools.count()

    def push(self, task: Task) -> None:
        with self._lock:
            heapq.heappush(self._heap, (-task.priority, next(self._counter), task))

    def pop(self, worker_kind: str = "ref") -> Optional[Task]:
        with self._lock:
            if not self._heap:
                return None
            return heapq.heappop(self._heap)[2]

    def __len__(self) -> int:
        return len(self._heap)


class CriticalPathScheduler(PriorityScheduler):
    """Pops by upward rank when available (``task._rank``), else priority.

    Use :func:`compute_upward_ranks` once the graph is fully inserted to fill
    ranks; in streaming mode unranked tasks fall back to their priority.
    """

    def push(self, task: Task) -> None:
        rank = getattr(task, "_rank", None)
        key = rank if rank is not None else float(task.priority)
        with self._lock:
            heapq.heappush(self._heap, (-key, next(self._counter), task))


class WorkStealingScheduler(SpAbstractScheduler):
    """Per-worker deques; owner pops LIFO, thieves steal FIFO.

    The engine registers each attached worker (by thread name) via
    :meth:`register_worker`; pushes round-robin over the registered workers
    so every deque actually belongs to a live popper.  Before any worker is
    registered (or after all detach) tasks land in an overflow deque that
    any popper can steal from.
    """

    _OVERFLOW = "w0"

    def __init__(self, seed: int = 0):
        self._deques: dict[str, collections.deque[Task]] = collections.defaultdict(collections.deque)
        self._workers: list[str] = []
        self._lock = threading.Lock()
        self._rng = random.Random(seed)
        self._rr = itertools.count()

    def register_worker(self, worker_name: str) -> None:
        with self._lock:
            if worker_name not in self._workers:
                self._workers.append(worker_name)
                self._deques.setdefault(worker_name, collections.deque())

    def unregister_worker(self, worker_name: str) -> None:
        """Detach a worker; its unfinished tasks move to the overflow deque."""
        with self._lock:
            if worker_name in self._workers:
                self._workers.remove(worker_name)
            dq = self._deques.pop(worker_name, None)
            if dq:
                self._deques[self._OVERFLOW].extend(dq)

    def push(self, task: Task) -> None:
        with self._lock:
            if self._workers:
                owner = self._workers[next(self._rr) % len(self._workers)]
            else:
                owner = self._OVERFLOW
            self._deques[owner].append(task)

    def pop(self, worker_kind: str = "ref", worker_name: str = "w0") -> Optional[Task]:
        with self._lock:
            dq = self._deques.get(worker_name)
            if dq:
                return dq.pop()
            victims = [k for k, d in self._deques.items() if d]
            if not victims:
                return None
            victim = self._rng.choice(victims)
            return self._deques[victim].popleft()

    def __len__(self) -> int:
        with self._lock:
            return sum(len(d) for d in self._deques.values())


def compute_upward_ranks(tasks: list[Task], successors: dict[int, list[Task]]) -> None:
    """HEFT upward rank: rank(t) = cost(t) + max over successors of rank(s).

    ``successors`` maps task uid → successor tasks (derivable from the graph
    via :meth:`SpTaskGraph.successor_map`).  Sets ``task._rank`` in place.
    """
    memo: dict[int, float] = {}

    order = list(tasks)
    # iterative reverse-topological accumulation (tasks are inserted in a
    # valid topological order by STF construction, so reverse insertion
    # order is a valid reverse-topological order)
    for t in sorted(order, key=lambda x: x.inserted_index, reverse=True):
        succ = successors.get(t.uid, ())
        best = 0.0
        for s in succ:
            best = max(best, memo.get(s.uid, 0.0))
        memo[t.uid] = t.cost + best
        t._rank = memo[t.uid]


SCHEDULERS = {
    "fifo": FifoScheduler,
    "lifo": LifoScheduler,
    "priority": PriorityScheduler,
    "critical_path": CriticalPathScheduler,
    "work_stealing": WorkStealingScheduler,
}


def make_scheduler(name: str, **kw) -> SpAbstractScheduler:
    try:
        return SCHEDULERS[name](**kw)
    except KeyError:
        raise ValueError(f"unknown scheduler {name!r}; have {sorted(SCHEDULERS)}") from None

"""Tasks and task viewers (paper §4.1, §4.3).

A task owns: its access list, a priority, one callable per implementation
kind (``ref`` / ``pallas`` / ``host`` — the SpCpu/SpCuda adaptation, see
DESIGN.md §2 C3), and bookkeeping for readiness, execution and tracing.

Calling convention (DESIGN.md §2): the callable receives one argument per
declared access, in declaration order — the raw value for ``SpRead``, an
:class:`~repro.core.access.SpWriteRef` proxy for write-like modes, and a
list thereof for ``Sp*Array`` accesses.  The callable's return value is the
task's *result* (paper: "getting the value produced by the task"),
independent of the writes — mirroring C++ reference semantics.
"""
from __future__ import annotations

import itertools
import threading
from concurrent.futures import CancelledError
from typing import Any, Callable, Optional, Sequence

from .access import AccessMode, SpAccess, SpImpl, SpWriteRef

_task_ids = itertools.count()


class SpTaskTimeoutError(TimeoutError):
    """A task exceeded its policy ``timeout`` and was failed by the engine's
    watchdog.  The worker thread that ran it may still be stuck inside the
    body (a *zombie*): its eventual return is discarded — no result, no
    writebacks — so the graph's view of the data stays consistent."""


class SpTaskPolicy:
    """Per-task robustness policy (ISSUE 8): stamped on a :class:`Task` by
    the codelet frontend (``@sp_task(retries=..., timeout=...)``) and
    enforced by the eager engine.

    * ``retries`` — re-run the body up to this many extra times when it
      raises (``CancelledError`` and watchdog timeouts are terminal).
    * ``retry_backoff`` — sleep ``retry_backoff * 2**(attempt-1)`` seconds
      between attempts.
    * ``timeout`` — wall-clock budget per attempt; on expiry the watchdog
      fails the task with :class:`SpTaskTimeoutError` while the hung body
      keeps running as a discarded zombie.
    * ``on_failure`` — what a *terminal* failure does to the graph:
      ``"raise"`` parks the error for ``wait_all_tasks`` (the default);
      ``"retry"`` is the same after the retry budget is spent (the spelling
      implied by ``retries>0``); ``"quarantine"`` records the task on
      ``graph.quarantined``, cancels its dependents with ``CancelledError``
      and keeps the graph alive — poison tasks no longer wedge the run.
    """

    __slots__ = ("retries", "retry_backoff", "timeout", "on_failure")

    MODES = ("raise", "retry", "quarantine")

    def __init__(
        self,
        retries: int = 0,
        retry_backoff: float = 0.0,
        timeout: float | None = None,
        on_failure: str | None = None,
    ):
        if on_failure is None:
            on_failure = "retry" if retries else "raise"
        if on_failure not in self.MODES:
            raise ValueError(
                f"on_failure must be one of {self.MODES}, got {on_failure!r}"
            )
        if retries < 0:
            raise ValueError(f"retries must be >= 0, got {retries}")
        if timeout is not None and timeout <= 0:
            raise ValueError(f"timeout must be positive, got {timeout}")
        self.retries = int(retries)
        self.retry_backoff = float(retry_backoff)
        self.timeout = timeout
        self.on_failure = on_failure

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (
            f"SpTaskPolicy(retries={self.retries}, "
            f"retry_backoff={self.retry_backoff}, timeout={self.timeout}, "
            f"on_failure={self.on_failure!r})"
        )


class TaskState:
    NOT_READY = "not-ready"
    READY = "ready"
    RUNNING = "running"
    FINISHED = "finished"
    CANCELLED = "cancelled"  # straggler-mitigation loser (DESIGN.md §2 C6)


class Task:
    """Internal task object.  Users interact through :class:`TaskView`."""

    def __init__(
        self,
        impls: dict[str, Callable],
        accesses: Sequence[SpAccess],
        arg_layout: Sequence[tuple[str, Any]],
        priority: int = 0,
        name: str | None = None,
        *,
        is_comm: bool = False,
        cost: float = 1.0,
        speculative: bool = False,
    ):
        self.uid = next(_task_ids)
        self.name = name or f"task{self.uid}"
        self.impls = impls  # kind -> callable
        self.accesses = list(accesses)
        # arg_layout: how to build callable arguments: list of
        # ("single", SpAccess) | ("array", [SpAccess, ...]) in declaration order
        self.arg_layout = list(arg_layout)
        self.priority = priority
        self.is_comm = is_comm
        self.cost = cost  # scheduler cost estimate (CriticalPath)
        self.speculative = speculative

        self.state = TaskState.NOT_READY
        self.pending = 0  # number of handle-generations not yet active
        self._pending_lock = threading.Lock()
        self.result: Any = None
        self.exception: BaseException | None = None
        self._done_event = threading.Event()
        # trace metadata
        self.worker_name: str | None = None
        self.t_start: float = 0.0
        self.t_end: float = 0.0
        # maybe-write outcomes, filled after execution: SpData uid -> bool
        self.maybe_written: dict[int, bool] = {}
        # successors cache for dot export (filled lazily by graph)
        self.inserted_index: int = -1
        # commutative-write handles in sorted-uid order, precomputed at
        # insert (graph._insert) so the engine hot path takes no per-task
        # detour through the registry (paper §4.7 runtime mutual exclusion)
        self.commutative_handles: tuple = ()
        # codelet-frontend metadata (core/api.py): the hidden cell holding
        # the body's return value (enables TaskView.then chaining) and the
        # platform-preferred impl kind resolved at bind time
        self.result_cell = None
        self.preferred_kind: str | None = None
        # robustness policy (ISSUE 8): enforced by the eager engine
        self.policy: SpTaskPolicy | None = None
        self.retries_used = 0
        self.timed_out = False  # set by the watchdog; the body is a zombie
        self.quarantined = False
        self.poisoned = False  # a quarantined/timed-out predecessor: cancel
        self._completion_claimed = False

    # -- readiness bookkeeping --------------------------------------------------

    def add_pending(self, n: int = 1) -> None:
        with self._pending_lock:
            self.pending += n

    def dec_pending(self) -> bool:
        """Decrement; return True when the task just became ready."""
        with self._pending_lock:
            self.pending -= 1
            ready = self.pending == 0 and self.state == TaskState.NOT_READY
            if ready:
                self.state = TaskState.READY
            return ready

    # -- execution ---------------------------------------------------------------

    def pick_impl(self, preferred: str = "ref") -> Callable:
        if preferred in self.impls:
            return self.impls[preferred]
        if "ref" in self.impls:
            return self.impls["ref"]
        raise KeyError(
            f"task {self.name!r} has no {preferred!r} implementation and no "
            f"'ref' fallback; registered kinds: {sorted(self.impls)}"
        )

    def build_args(self) -> tuple[list, list[tuple[SpAccess, SpWriteRef]]]:
        """Materialize callable arguments.  Returns (args, writebacks)."""
        args: list = []
        writebacks: list[tuple[SpAccess, SpWriteRef]] = []
        for kind, payload in self.arg_layout:
            if kind == "single":
                acc: SpAccess = payload
                if acc.mode is AccessMode.READ:
                    args.append(acc.data.value)
                else:
                    ref = SpWriteRef(acc.data.value, acc.data.name)
                    writebacks.append((acc, ref))
                    args.append(ref)
            else:  # "array"
                sub_args = []
                for acc in payload:
                    if acc.mode is AccessMode.READ:
                        sub_args.append(acc.data.value)
                    else:
                        ref = SpWriteRef(acc.data.value, acc.data.name)
                        writebacks.append((acc, ref))
                        sub_args.append(ref)
                args.append(sub_args)
        return args, writebacks

    def claim_completion(self) -> bool:
        """First caller wins the right to complete this task.  Arbitrates
        the race between the executing worker and the engine watchdog: a
        timed-out task is completed by the watchdog, and the zombie worker's
        eventual return must not complete it a second time."""
        with self._pending_lock:
            if self._completion_claimed:
                return False
            self._completion_claimed = True
            return True

    def run(self, preferred_impl: str = "ref") -> None:
        """Execute the task body and write back results.  No dependency
        release here — the engine/graph drives that."""
        fn = self.pick_impl(preferred_impl)
        args, writebacks = self.build_args()
        out = fn(*args)
        if self.timed_out:
            # the watchdog already failed this task and released its
            # dependents; a zombie's late result/writebacks would clobber
            # data that successors (or a re-submitted step) now own
            return
        self.result = out
        for acc, ref in writebacks:
            if acc.mode is AccessMode.MAYBE_WRITE:
                self.maybe_written[acc.data.uid] = ref.written
                if ref.written:
                    acc.data.value = ref.value
            else:
                # WRITE / COMMUTATIVE / ATOMIC: adopt the proxy value.  If the
                # body never assigned, the value is unchanged (identity write).
                acc.data.value = ref.value

    def mark_finished(self) -> None:
        self.state = TaskState.FINISHED
        self._done_event.set()

    def mark_cancelled(self) -> None:
        self.state = TaskState.CANCELLED
        self._done_event.set()

    def wait(self, timeout: float | None = None) -> bool:
        return self._done_event.wait(timeout)

    @property
    def is_done(self) -> bool:
        return self._done_event.is_set()

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Task({self.name!r}, {self.state}, prio={self.priority})"


class TaskView:
    """User-facing viewer (paper §4.1 "Task Viewer") with a future-like API.

    Allows naming the task, waiting for completion and fetching the produced
    value (``get_value`` — paper spelling — or the concurrent.futures-style
    :meth:`result` / :meth:`done` / :meth:`exception`), and chaining
    follow-up work with :meth:`then`.  On a staged runtime, asking for the
    result forces the pending graph to execute (the graph's flush hook).
    The paper notes the pitfall that names may be set after execution —
    unchanged here, and equally harmless.
    """

    __slots__ = ("_task",)

    def __init__(self, task: Task):
        self._task = task

    def set_task_name(self, name: str) -> "TaskView":
        self._task.name = name
        return self

    # C++ API spelling
    setTaskName = set_task_name

    def get_task_name(self) -> str:
        return self._task.name

    def wait(self, timeout: float | None = None) -> bool:
        ok = self._task.wait(timeout)
        if self._task.exception is not None:
            raise self._task.exception
        return ok

    def get_value(self) -> Any:
        self.wait()
        return self._task.result

    getValue = get_value

    # -- future-like API (codelet frontend, core/api.py) ---------------------

    def _maybe_flush(self) -> None:
        """On a staged runtime the graph only executes when flushed; asking
        for a result is such a trigger (SpRuntime installs the hook)."""
        if self._task.is_done:
            return
        hook = getattr(getattr(self._task, "graph", None), "_flush_hook", None)
        if hook is not None:
            hook()

    def done(self) -> bool:
        return self._task.is_done

    def result(self, timeout: float | None = None) -> Any:
        """Block until done; raise the task's exception (or CancelledError —
        concurrent.futures semantics) or return its value."""
        self._maybe_flush()
        if not self._task.wait(timeout):
            raise TimeoutError(f"task {self._task.name!r} still pending")
        if self._task.exception is not None:
            self._mark_error_observed()
            raise self._task.exception
        if self._task.state == TaskState.CANCELLED:
            raise CancelledError(f"task {self._task.name!r} was cancelled")
        return self._task.result

    def exception(self, timeout: float | None = None) -> BaseException | None:
        self._maybe_flush()
        if not self._task.wait(timeout):
            raise TimeoutError(f"task {self._task.name!r} still pending")
        if self._task.exception is not None:
            self._mark_error_observed()
            return self._task.exception
        if self._task.state == TaskState.CANCELLED:
            raise CancelledError(f"task {self._task.name!r} was cancelled")
        return None

    def _mark_error_observed(self) -> None:
        """An exception delivered through the future API counts as handled:
        drop it from the graph's error list so wait_all_tasks / scope exit
        does not re-raise what the caller already saw."""
        graph = getattr(self._task, "graph", None)
        if graph is not None:
            try:
                graph.errors.remove(self._task.exception)
            except ValueError:
                pass

    def then(self, fn, *, name: str | None = None, cost: float = 1.0) -> "TaskView":
        """Chain ``fn`` over this task's result: inserts a follow-up task
        reading the hidden result cell (so the dependency is ordinary data
        flow, honored by both backends) and returns its view."""
        task = self._task
        cell = getattr(task, "result_cell", None)
        graph = getattr(task, "graph", None)
        if cell is None or graph is None:
            raise RuntimeError(
                "then() requires a task inserted through the codelet frontend "
                "(sp_task / SpCodelet), which records a result cell; a "
                "result=False (fire-and-forget) call has none — chain off a "
                "written cell instead"
            )
        from .access import AccessMode, SpAccess, SpData

        nm = name or f"{task.name}.then"
        out = SpData(None, f"{nm}.result")
        in_acc = SpAccess(cell, AccessMode.READ)
        out_acc = SpAccess(out, AccessMode.WRITE)

        def body(v, res_ref):
            r = fn(v)
            res_ref.value = r
            return r

        view = graph.insert_task(
            {"ref": body},
            [in_acc, out_acc],
            [("single", in_acc), ("single", out_acc)],
            name=nm,
            cost=cost,
        )
        view.task.result_cell = out
        return view

    @property
    def state(self) -> str:
        return self._task.state

    @property
    def task(self) -> Task:
        return self._task

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"TaskView({self._task.name!r}, {self._task.state})"


def normalize_impls(raw: Sequence) -> dict[str, Callable]:
    """Accept bare callables (→ ref) and SpImpl wrappers."""
    impls: dict[str, Callable] = {}
    for item in raw:
        if isinstance(item, SpImpl):
            impls[item.kind] = item.fn
        elif callable(item):
            impls.setdefault("ref", item)
        else:  # pragma: no cover - defensive
            raise TypeError(f"not a callable or SpImpl: {item!r}")
    if not impls:
        raise ValueError("task needs at least one callable")
    return impls

"""Speculative execution over uncertain data accesses (paper §4.6, [Bramas'19]).

``SpMaybeWrite`` marks a task as an *uncertain writer*: at insertion time it
is unknown whether it will modify the data.  In a speculative graph
(``SpSpeculativeModel.SP_MODEL_1``) the runtime then rewrites the stream so
that a later reader can run *in parallel with* the uncertain writer:

  insertion stream        rewritten graph
  ---------------         ----------------------------------------------
  U: maybe-write X        C: read X → write X̂      (snapshot, pre-U value)
                          U: maybe-write X          (unchanged)
  R: read X, write Y      CY: read Y → write Ŷ      (pre-R value of Y)
                          R̂: read X̂ → write Ŷ, r̂   (speculative body)
                          K: read X (post-U), read Ŷ → write Y
                             commit Ŷ→Y if U did not write (r ← r̂),
                             else re-run R's body on the real X (rollback)

Because JAX arrays are immutable, snapshots are reference copies — the cost
of speculation here is task-management overhead plus possible re-execution,
never a deep copy (hardware-adaptation note, DESIGN.md §2).

The paper's two speculative models are both implemented:

* ``SP_MODEL_1`` — speculate past the most recent uncertain writer only;
  chained maybe-writers each get a fresh snapshot taken *after* the
  previous writer resolves (readers overlap one writer at a time).
* ``SP_MODEL_2`` — speculate past whole *chains*: one snapshot before the
  first writer of the chain, readers overlap every writer, commit checks
  them all (more overlap, more rollback exposure — the paper's trade-off).

Commutative/atomic accesses and array views in the reader bail out to
normal insertion.  Communication tasks refuse speculation entirely (paper
§4.4 limitation, enforced in ``comm.py``).

Speculative **decoding** (``repro.serving.spec``) is this machinery applied
to LM serving — the mapping from the paper's abstractions to the decoder:

* each *draft* step is an uncertain writer (``maybe``) on the engine's
  per-batch decode-state cell: it proposes tokens with a cheap draft model
  and normally leaves the real state untouched (``written == False``); it
  writes only to poison the round when the scheduler sheds speculation or
  a rollback is forced;
* the *verify* task reads that cell, so under ``SP_MODEL_2`` it overlaps
  the whole k-deep draft chain, running the target model's batched
  multi-position forward against the chain's shared snapshot;
* *commit* performs the certain WRITE that clears the uncertainty marker
  and publishes accepted tokens + KV rows — or, when a drafter wrote, the
  runtime re-runs verify's body on the real state (rollback) before commit
  sees its output, exactly case (b) above.

Acceptance/rejection of individual drafted tokens happens *inside* the
verify body (committed tokens are always the target model's own samples,
which keeps greedy and seeded-sampling decode bit-exact with the
non-speculative engine); the graph-level commit/rollback handles the
coarser question of whether the whole round's snapshot was stale.
"""
from __future__ import annotations

from typing import Any, Optional

from .access import AccessMode, SpAccess, SpData
from .task import Task, TaskView


def _copy_task(graph, src: SpData, dst: SpData, tag: str) -> Task:
    """Insert a hidden snapshot task: dst.value ← src.value (reference copy)."""

    def body(src_val, dst_ref):
        dst_ref.value = src_val

    t = Task(
        {"ref": body},
        [SpAccess(src, AccessMode.READ), SpAccess(dst, AccessMode.WRITE)],
        [("single", SpAccess(src, AccessMode.READ)),
         ("single", SpAccess(dst, AccessMode.WRITE))],
        name=f"spec-copy[{tag}]",
        cost=0.01,
    )
    # NB: accesses in Task and arg_layout must be the *same* SpAccess objects
    t.arg_layout = [("single", t.accesses[0]), ("single", t.accesses[1])]
    graph._insert(t)
    return t


def maybe_speculative_insert(
    graph,
    impls: dict,
    accesses: list[SpAccess],
    arg_layout: list[tuple[str, Any]],
    priority: int,
    name: str | None,
    cost: float,
) -> Optional[TaskView]:
    """Called by ``SpTaskGraph.task`` before normal insertion.

    Returns a TaskView if the insertion was handled speculatively (either as
    an uncertain writer or as a speculated reader); None to fall through to
    normal insertion.
    """
    maybe_accs = [a for a in accesses if a.mode is AccessMode.MAYBE_WRITE]

    # Any certain write clears the uncertainty marker: later readers must see
    # the certain writer's value, never speculate against the stale snapshot.
    for a in accesses:
        if a.mode in (AccessMode.WRITE, AccessMode.COMMUTATIVE_WRITE, AccessMode.ATOMIC_WRITE):
            a.data._uncertain_writer = None

    # ---- Case A: this task is an uncertain writer --------------------------
    if maybe_accs:
        from .graph import SpSpeculativeModel

        chain = graph.spec_model is SpSpeculativeModel.SP_MODEL_2
        snaps: dict[int, SpData] = {}
        prior: dict[int, list] = {}
        for a in maybe_accs:
            uw = a.data._uncertain_writer
            if chain and uw is not None:
                # MODEL 2: extend the uncertain chain — reuse the snapshot
                # taken before the FIRST writer; readers overlap all of them
                prior[a.data.uid] = list(uw[0])
                snaps[a.data.uid] = uw[1]
            else:
                snap = SpData(None, name=f"{a.data.name}.snap")
                _copy_task(graph, a.data, snap, a.data.name)
                prior[a.data.uid] = []
                snaps[a.data.uid] = snap
        task = Task(impls, accesses, arg_layout, priority, name, cost=cost)
        view = graph._insert(task)
        for a in maybe_accs:
            a.data._uncertain_writer = (prior[a.data.uid] + [task], snaps[a.data.uid])
        return view

    # ---- Case B: reader of uncertain data -> speculate ---------------------
    uncertain_reads = [
        a
        for a in accesses
        if a.mode is AccessMode.READ and a.data._uncertain_writer is not None
    ]
    if not uncertain_reads:
        return None
    # bail out on shapes we do not speculate on
    if any(kind == "array" for kind, _ in arg_layout):
        return None
    if any(
        a.mode in (AccessMode.COMMUTATIVE_WRITE, AccessMode.ATOMIC_WRITE)
        for a in accesses
    ):
        return None

    graph.spec_stats["speculated"] += 1
    # uid → (writer task list, snapshot cell)
    writers = {a.data.uid: a.data._uncertain_writer for a in uncertain_reads}

    writes = [a for a in accesses if a.mode is AccessMode.WRITE]
    reads_certain = [
        a
        for a in accesses
        if a.mode is AccessMode.READ and a.data.uid not in writers
    ]

    # snapshot each written cell's pre-value (so the speculative body mutates
    # a shadow, never the real cell)
    shadow: dict[int, SpData] = {}
    for a in writes:
        y_spec = SpData(None, name=f"{a.data.name}.shadow")
        _copy_task(graph, a.data, y_spec, a.data.name)
        shadow[a.data.uid] = y_spec

    res_cell = SpData(None, name=f"{name or 'task'}.res")
    fn = impls.get("ref") or next(iter(impls.values()))

    # ---- speculative body R̂ -------------------------------------------------
    spec_accesses: list[SpAccess] = []
    spec_slot_for: list[SpAccess] = []  # aligned with original arg_layout
    for kind, acc in arg_layout:
        if acc.mode is AccessMode.READ and acc.data.uid in writers:
            s = SpAccess(writers[acc.data.uid][1], AccessMode.READ)  # snapshot
        elif acc.mode is AccessMode.READ:
            s = SpAccess(acc.data, AccessMode.READ)
        else:  # WRITE → shadow
            s = SpAccess(shadow[acc.data.uid], AccessMode.WRITE)
        spec_accesses.append(s)
        spec_slot_for.append(s)
    res_acc = SpAccess(res_cell, AccessMode.WRITE)
    spec_accesses.append(res_acc)

    def spec_body(*args):
        *user_args, res_ref = args
        res_ref.value = fn(*user_args)

    spec_task = Task(
        {"ref": spec_body},
        spec_accesses,
        [("single", a) for a in spec_accesses],
        priority,
        name=f"{name or 'task'}.spec",
        cost=cost,
        speculative=True,
    )
    graph._insert(spec_task)

    # ---- commit / rollback K -------------------------------------------------
    # access order: [uncertain X (post-U) ...] [certain Z ...] [shadow Ŷ ...]
    #               [res_cell] [Y writes ...]
    k_accesses: list[SpAccess] = []
    x_accs = [SpAccess(a.data, AccessMode.READ) for a in uncertain_reads]
    z_accs = [SpAccess(a.data, AccessMode.READ) for a in reads_certain]
    s_accs = [SpAccess(shadow[a.data.uid], AccessMode.READ) for a in writes]
    r_acc = SpAccess(res_cell, AccessMode.READ)
    y_accs = [SpAccess(a.data, AccessMode.WRITE) for a in writes]
    k_accesses = x_accs + z_accs + s_accs + [r_acc] + y_accs

    n_x, n_z, n_s = len(x_accs), len(z_accs), len(s_accs)
    uncertain_uids = [a.data.uid for a in uncertain_reads]
    writer_tasks = {uid: list(writers[uid][0]) for uid in uncertain_uids}

    # map original slots → (source, index) for the rollback re-execution
    plan: list[tuple[str, int]] = []
    xi = {a.data.uid: i for i, a in enumerate(uncertain_reads)}
    zi = {a.data.uid: i for i, a in enumerate(reads_certain)}
    yi = {a.data.uid: i for i, a in enumerate(writes)}
    for kind, acc in arg_layout:
        if acc.mode is AccessMode.READ and acc.data.uid in xi:
            plan.append(("x", xi[acc.data.uid]))
        elif acc.mode is AccessMode.READ:
            plan.append(("z", zi[acc.data.uid]))
        else:
            plan.append(("y", yi[acc.data.uid]))

    def commit_body(*args):
        xs = args[:n_x]
        zs = args[n_x : n_x + n_z]
        shs = args[n_x + n_z : n_x + n_z + n_s]
        res_val = args[n_x + n_z + n_s]
        y_refs = args[n_x + n_z + n_s + 1 :]
        rolled = any(
            w.maybe_written.get(uid, False)
            for uid in uncertain_uids
            for w in writer_tasks[uid]
        )
        if not rolled:
            graph.spec_stats["commits"] += 1
            for ref, sh in zip(y_refs, shs):
                ref.value = sh
            return res_val
        graph.spec_stats["rollbacks"] += 1
        call_args = []
        for src, i in plan:
            if src == "x":
                call_args.append(xs[i])
            elif src == "z":
                call_args.append(zs[i])
            else:
                call_args.append(y_refs[i])
        return fn(*call_args)

    commit = Task(
        {"ref": commit_body},
        k_accesses,
        [("single", a) for a in k_accesses],
        priority,
        name=name or f"task{spec_task.uid}.commit",
        cost=0.05,
    )
    return graph._insert(commit)

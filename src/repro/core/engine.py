"""Compute engines and teams of workers (paper §4.2).

A :class:`SpComputeEngine` owns a team of workers (threads).  Each worker
continuously pops tasks from the engine's (pluggable) scheduler and executes
them.  Engines may drive several task graphs; workers can be *moved between
engines at runtime* ("dynamically adjust the capabilities of the compute
engine during execution", paper §4.2).

Communication tasks never run on workers: a dedicated background thread
starts non-blocking operations and polls for completion, releasing
dependencies as early as possible (paper §4.4) — see ``comm.py``.

Hardware-adaptation (DESIGN.md §2): worker *kinds* replace CPU-vs-GPU
workers.  A ``ref`` worker prefers the pure-jnp/XLA implementation of a
task, a ``pallas`` worker prefers the TPU-kernel implementation (falling
back to ``ref`` off-TPU), a ``host`` worker is meant for I/O-ish tasks
(checkpoint commits).  On this CPU container all kinds execute; on a real
pod the staged backend (``staged.py``) is the production path.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Optional

from .scheduler import FifoScheduler, SpAbstractScheduler, WorkStealingScheduler
from .task import Task, TaskState


class SpWorker(threading.Thread):
    _ids = iter(range(1 << 30))

    def __init__(self, engine: "SpComputeEngine", kind: str = "ref"):
        self.wid = next(SpWorker._ids)
        super().__init__(name=f"spworker-{self.wid}", daemon=True)
        self.kind = kind
        self.engine = engine
        self.target_engine: Optional["SpComputeEngine"] = None  # pending move
        self.alive = True

    def run(self) -> None:  # pragma: no branch - loop
        while self.alive:
            eng = self.engine
            if self.target_engine is not None:
                new_eng = self.target_engine
                self.target_engine = None
                eng._detach_worker(self)
                new_eng._attach_worker(self)
                continue
            task = eng._next_task(self)
            if task is None:
                continue  # woke for stop/move
            eng._execute(task, self)

    def retire(self) -> None:
        self.alive = False


class SpWorkerTeam:
    """A collection of workers assignable to compute engines."""

    def __init__(self, kinds: list[str]):
        self.kinds = kinds

    def __len__(self) -> int:
        return len(self.kinds)


class SpWorkerTeamBuilder:
    """Paper-spelling builders (Code 5)."""

    @staticmethod
    def default_num_threads() -> int:
        return max(2, min(8, os.cpu_count() or 2))

    DefaultNumThreads = default_num_threads

    @staticmethod
    def team_of_cpu_workers(n: int | None = None) -> SpWorkerTeam:
        n = n or SpWorkerTeamBuilder.default_num_threads()
        return SpWorkerTeam(["ref"] * n)

    TeamOfCpuWorkers = team_of_cpu_workers

    @staticmethod
    def team_of_cpu_cuda_workers(n_cpu: int | None = None, n_dev: int = 1) -> SpWorkerTeam:
        """Mixed team: ``ref`` workers + ``pallas``(device-kernel) workers."""
        n_cpu = n_cpu or SpWorkerTeamBuilder.default_num_threads()
        return SpWorkerTeam(["ref"] * n_cpu + ["pallas"] * n_dev)

    TeamOfCpuCudaWorkers = team_of_cpu_cuda_workers


class SpComputeEngine:
    def __init__(
        self,
        team: SpWorkerTeam | None = None,
        scheduler: SpAbstractScheduler | None = None,
        name: str = "ce",
    ):
        self.name = name
        self.scheduler = scheduler or FifoScheduler()
        self._cv = threading.Condition()
        self._running = True
        self._workers: list[SpWorker] = []
        self._graphs: list = []
        self._comm = None  # lazily created CommThread (comm.py)
        team = team or SpWorkerTeamBuilder.team_of_cpu_workers()
        for kind in team.kinds:
            w = SpWorker(self, kind)
            self._workers.append(w)
            self._register_with_scheduler(w)
            w.start()

    def _register_with_scheduler(self, w: SpWorker) -> None:
        reg = getattr(self.scheduler, "register_worker", None)
        if reg is not None:
            reg(w.name)

    def _unregister_from_scheduler(self, w: SpWorker) -> None:
        unreg = getattr(self.scheduler, "unregister_worker", None)
        if unreg is not None:
            unreg(w.name)

    # ------------------------------------------------------------- graph API

    def register_graph(self, graph) -> None:
        with self._cv:
            if graph not in self._graphs:
                self._graphs.append(graph)

    @staticmethod
    def _is_async_comm(task: Task) -> bool:
        # only tasks with a non-blocking start protocol go to the comm
        # thread; comm-*flagged* compute tasks (staged scheduling hints)
        # run on normal workers
        return task.is_comm and hasattr(task, "comm_start")

    def push_task(self, task: Task) -> None:
        if self._is_async_comm(task):
            self._comm_thread().submit(task)
            return
        with self._cv:
            self.scheduler.push(task)
            self._cv.notify()

    def push_many(self, tasks: list[Task]) -> None:
        if not tasks:
            return
        with self._cv:
            n = 0
            for t in tasks:
                if self._is_async_comm(t):
                    self._comm_thread().submit(t)
                else:
                    self.scheduler.push(t)
                    n += 1
            if n:
                self._cv.notify(n)

    # ------------------------------------------------------------ worker side

    def _next_task(self, worker: SpWorker) -> Optional[Task]:
        with self._cv:
            while self._running and worker.alive and worker.target_engine is None:
                if isinstance(self.scheduler, WorkStealingScheduler):
                    t = self.scheduler.pop(worker.kind, worker.name)
                else:
                    t = self.scheduler.pop(worker.kind)
                if t is not None:
                    return t
                self._cv.wait(timeout=0.1)
        return None

    def _execute(self, task: Task, worker: SpWorker) -> None:
        graph = getattr(task, "graph", None)
        token = getattr(task, "cancel_token", None)
        if token is not None and token.is_set():
            on_cancel = getattr(task, "on_cancel", None)
            if on_cancel is not None:
                try:
                    on_cancel(task)
                except BaseException as e:  # pragma: no cover - defensive
                    task.exception = e
            task.mark_cancelled()
            if graph is not None:
                self.push_many(graph.on_task_finished(task))
            return

        # paper §4.7: commutative accesses require runtime mutual exclusion;
        # multi-handle locks are taken in sorted-uid order (deadlock freedom).
        locks = []
        if graph is not None:
            from .access import AccessMode

            comm_handles = sorted(
                (
                    graph.registry.handle_for(a.data)
                    for a in task.accesses
                    if a.mode is AccessMode.COMMUTATIVE_WRITE
                ),
                key=lambda h: h.data.uid,
            )
            locks = [h.commutative_lock for h in comm_handles]
        for lk in locks:
            lk.acquire()
        task.state = TaskState.RUNNING
        task.worker_name = worker.name
        task.t_start = time.perf_counter()
        try:
            task.run(preferred_impl=worker.kind)
        except BaseException as e:
            task.exception = e
        finally:
            task.t_end = time.perf_counter()
            for lk in reversed(locks):
                lk.release()
        if token is not None:
            if task.exception is None:
                token.set(task)
            else:
                # a crashed replica must not win the race: park the error on
                # the token (surfaced by the select task only if every copy
                # fails) and let the healthy copies keep going
                record = getattr(token, "record_failure", None)
                if record is not None:
                    record(task.exception)
                    task.exception = None
        if graph is not None:
            graph.trace_events.append(
                {
                    "task": task.name,
                    "uid": task.uid,
                    "worker": worker.name,
                    "t0": task.t_start,
                    "t1": task.t_end,
                    "ready": len(self.scheduler),
                    "comm": task.is_comm,
                    "spec": task.speculative,
                }
            )
            newly = graph.on_task_finished(task)
            task.mark_finished()
            self.push_many(newly)
        else:  # pragma: no cover - tasks always carry a graph backref
            task.mark_finished()

    # ------------------------------------------------------------- team mgmt

    @property
    def n_workers(self) -> int:
        return len(self._workers)

    def _attach_worker(self, w: SpWorker) -> None:
        with self._cv:
            self._workers.append(w)
            w.engine = self
            self._register_with_scheduler(w)
            self._cv.notify()

    def _detach_worker(self, w: SpWorker) -> None:
        with self._cv:
            if w in self._workers:
                self._workers.remove(w)
            self._unregister_from_scheduler(w)

    def add_workers(self, n: int, kind: str = "ref") -> None:
        for _ in range(n):
            w = SpWorker(self, kind)
            with self._cv:
                self._workers.append(w)
                self._register_with_scheduler(w)
            w.start()

    def send_workers_to(self, other: "SpComputeEngine", n: int) -> int:
        """Move up to ``n`` workers to ``other`` (paper §4.2 dynamic teams)."""
        moved = 0
        with self._cv:
            movable = [w for w in self._workers if w.target_engine is None]
            for w in movable[:n]:
                w.target_engine = other
                moved += 1
            self._cv.notify_all()
        return moved

    # ------------------------------------------------------------------ comm

    def _comm_thread(self):
        if self._comm is None:
            from .comm import CommThread

            self._comm = CommThread(self)
            self._comm.start()
        return self._comm

    # ------------------------------------------------------------------ stop

    def stop(self) -> None:
        with self._cv:
            self._running = False
            for w in self._workers:
                w.alive = False
            self._cv.notify_all()
        me = threading.current_thread()
        for w in list(self._workers):
            if w is not me:
                w.join(timeout=5.0)
        if self._comm is not None:
            self._comm.stop()

    stopIfNotAlreadyStopped = stop

    def __enter__(self) -> "SpComputeEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

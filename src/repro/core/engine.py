"""Compute engines and teams of workers (paper §4.2).

A :class:`SpComputeEngine` owns a team of workers (threads).  Each worker
continuously pops tasks from the engine's (pluggable) scheduler and executes
them.  Engines may drive several task graphs; workers can be *moved between
engines at runtime* ("dynamically adjust the capabilities of the compute
engine during execution", paper §4.2).

Communication tasks never run on workers: a dedicated background thread
starts non-blocking operations and polls for completion, releasing
dependencies as early as possible (paper §4.4) — see ``comm.py``.

Hardware-adaptation (DESIGN.md §2): worker *kinds* replace CPU-vs-GPU
workers.  A ``ref`` worker prefers the pure-jnp/XLA implementation of a
task, a ``pallas`` worker prefers the TPU-kernel implementation (falling
back to ``ref`` off-TPU), a ``host`` worker is meant for I/O-ish tasks
(checkpoint commits).  On this CPU container all kinds execute; on a real
pod the staged backend (``staged.py``) is the production path.
"""
from __future__ import annotations

import os
import threading
import time
from concurrent.futures import CancelledError
from typing import Optional

from .scheduler import FifoScheduler, SpAbstractScheduler, WorkStealingScheduler
from .task import SpTaskTimeoutError, Task, TaskState


class SpWorker(threading.Thread):
    _ids = iter(range(1 << 30))

    def __init__(self, engine: "SpComputeEngine", kind: str = "ref"):
        self.wid = next(SpWorker._ids)
        super().__init__(name=f"spworker-{self.wid}", daemon=True)
        self.kind = kind
        self.engine = engine
        self.target_engine: Optional["SpComputeEngine"] = None  # pending move
        self.alive = True
        # per-worker parking spot: the engine sets this to hand the worker
        # new work / a stop / a move, instead of broadcasting on one global
        # condition variable (paper §4.2 workers are individually addressable)
        self.wakeup = threading.Event()

    def run(self) -> None:  # pragma: no branch - loop
        while self.alive:
            eng = self.engine
            if self.target_engine is not None:
                new_eng = self.target_engine
                self.target_engine = None
                eng._detach_worker(self)
                new_eng._attach_worker(self)
                continue
            task = eng._next_task(self)
            if task is None:
                continue  # woke for stop/move
            eng._execute(task, self)

    def retire(self) -> None:
        self.alive = False


class SpWorkerTeam:
    """A collection of workers assignable to compute engines."""

    def __init__(self, kinds: list[str]):
        self.kinds = kinds

    def __len__(self) -> int:
        return len(self.kinds)


class SpWorkerTeamBuilder:
    """Paper-spelling builders (Code 5)."""

    @staticmethod
    def default_num_threads() -> int:
        return max(2, min(8, os.cpu_count() or 2))

    DefaultNumThreads = default_num_threads

    @staticmethod
    def team_of_cpu_workers(n: int | None = None) -> SpWorkerTeam:
        n = n or SpWorkerTeamBuilder.default_num_threads()
        return SpWorkerTeam(["ref"] * n)

    TeamOfCpuWorkers = team_of_cpu_workers

    @staticmethod
    def team_of_cpu_cuda_workers(n_cpu: int | None = None, n_dev: int = 1) -> SpWorkerTeam:
        """Mixed team: ``ref`` workers + ``pallas``(device-kernel) workers."""
        n_cpu = n_cpu or SpWorkerTeamBuilder.default_num_threads()
        return SpWorkerTeam(["ref"] * n_cpu + ["pallas"] * n_dev)

    TeamOfCpuCudaWorkers = team_of_cpu_cuda_workers


class _Watchdog(threading.Thread):
    """Hung-task monitor (ISSUE 8).  Workers arm a deadline per attempt of a
    policy-timed task; on expiry the engine fails the task with
    :class:`SpTaskTimeoutError` and completes it *externally* — the worker
    thread stuck in the body becomes a discarded zombie and
    ``wait_all_tasks`` never wedges on it.  Lazily started on the first
    timed task, so untimed workloads pay nothing."""

    _ids = iter(range(1 << 20))

    def __init__(self, engine: "SpComputeEngine"):
        super().__init__(name=f"spwatchdog-{next(_Watchdog._ids)}", daemon=True)
        self.engine = engine
        self._lock = threading.Lock()
        self._armed: dict[Task, float] = {}
        self._kick = threading.Event()
        self._running = True

    def arm(self, task: Task, deadline: float) -> None:
        with self._lock:
            self._armed[task] = deadline
        self._kick.set()  # re-evaluate the sleep against the new deadline

    def disarm(self, task: Task) -> None:
        with self._lock:
            self._armed.pop(task, None)

    def stop(self) -> None:
        self._running = False
        self._kick.set()

    def run(self) -> None:
        while self._running:
            now = time.monotonic()
            expired: list[Task] = []
            nxt: Optional[float] = None
            with self._lock:
                for t, d in list(self._armed.items()):
                    if d <= now:
                        expired.append(t)
                        del self._armed[t]
                    elif nxt is None or d < nxt:
                        nxt = d
            for t in expired:
                self.engine._fail_hung_task(t)
            self._kick.clear()
            wait = 0.05 if nxt is None else min(0.05, max(0.0005, nxt - time.monotonic()))
            self._kick.wait(wait)


class SpComputeEngine:
    def __init__(
        self,
        team: SpWorkerTeam | None = None,
        scheduler: SpAbstractScheduler | None = None,
        name: str = "ce",
    ):
        self.name = name
        # NB: ``scheduler or Fifo...`` would be wrong — schedulers define
        # __len__, so a freshly-created (empty) scheduler is falsy and would
        # be silently swapped for FIFO
        self.scheduler = scheduler if scheduler is not None else FifoScheduler()
        # per-worker-deque schedulers take the popping worker's name
        self._pop_by_name = isinstance(self.scheduler, WorkStealingScheduler)
        # engine-structure lock (worker list, graph list) — NOT on the
        # push/pop hot path; the scheduler carries its own locking and idle
        # workers park on their own events (see _next_task)
        self._lock = threading.Lock()
        self._idle_lock = threading.Lock()
        self._idle: list[SpWorker] = []  # LIFO: most-recently-parked first
        self._running = True
        self._workers: list[SpWorker] = []
        self._graphs: list = []
        self._comm = None  # lazily created CommThread (comm.py)
        self._wd: Optional[_Watchdog] = None  # lazily created hung-task monitor
        self._stop_report: list[str] | None = None  # set by the first stop()
        if team is None:  # (SpWorkerTeam also defines __len__ — same trap)
            team = SpWorkerTeamBuilder.team_of_cpu_workers()
        for kind in team.kinds:
            w = SpWorker(self, kind)
            self._workers.append(w)
            self._register_with_scheduler(w)
            w.start()

    def _register_with_scheduler(self, w: SpWorker) -> None:
        reg = getattr(self.scheduler, "register_worker", None)
        if reg is not None:
            reg(w.name)

    def _unregister_from_scheduler(self, w: SpWorker) -> None:
        unreg = getattr(self.scheduler, "unregister_worker", None)
        if unreg is not None:
            unreg(w.name)

    # ------------------------------------------------------------- graph API

    def register_graph(self, graph) -> None:
        with self._lock:
            if graph not in self._graphs:
                self._graphs.append(graph)

    @staticmethod
    def _is_async_comm(task: Task) -> bool:
        # only tasks with a non-blocking start protocol go to the comm
        # thread; comm-*flagged* compute tasks (staged scheduling hints)
        # run on normal workers
        return task.is_comm and hasattr(task, "comm_start")

    def push_task(self, task: Task) -> None:
        if self._is_async_comm(task):
            self._comm_thread().submit(task)
            return
        owner = self.scheduler.push(task)
        self._wake_one(owner)

    def push_many(self, tasks: list[Task]) -> None:
        if not tasks:
            return
        owners = []
        for t in tasks:
            if self._is_async_comm(t):
                self._comm_thread().submit(t)
            else:
                owners.append(self.scheduler.push(t))
        for owner in owners:
            if not self._wake_one(owner):
                break  # nobody parked; workers will find the tasks on poll

    # ------------------------------------------------------------ worker side

    def _wake_one(self, owner: Optional[str] = None) -> bool:
        """Unpark one idle worker — preferably ``owner``, the worker whose
        deque just received the task (locality-aware schedulers return it
        from ``push``)."""
        if not self._idle:  # lock-free fast path; parking workers re-check
            return False    # the scheduler before waiting, so a miss here
            #                 costs at most one bounded backoff timeout
        with self._idle_lock:
            w = None
            if owner is not None:
                for i, cand in enumerate(self._idle):
                    if cand.name == owner:
                        w = self._idle.pop(i)
                        break
            if w is None and self._idle:
                w = self._idle.pop()
        if w is not None:
            w.wakeup.set()
            return True
        return False

    # Idle wait: ~1 ms first park doubling to 50 ms.  The timeout is a
    # safety net — pushes normally unpark a worker explicitly — so the cap
    # bounds worst-case dispatch latency when a wake is missed (the old
    # fixed poll burned a 100 ms round trip on EVERY dispatch race).
    _BACKOFF_MIN = 0.001
    _BACKOFF_MAX = 0.05

    def _pop(self, worker: SpWorker) -> Optional[Task]:
        if self._pop_by_name:
            return self.scheduler.pop(worker.kind, worker.name)
        return self.scheduler.pop(worker.kind)

    def _next_task(self, worker: SpWorker) -> Optional[Task]:
        backoff = self._BACKOFF_MIN
        while self._running and worker.alive and worker.target_engine is None:
            t = self._pop(worker)
            if t is not None:
                # if more work is queued and someone is parked, chain-wake so
                # a burst push fans out even when only one wake landed (the
                # unlocked _idle peek keeps this free at steady state)
                if self._idle and len(self.scheduler) > 0:
                    self._wake_one()
                return t
            # park: register as idle *before* the re-check so a concurrent
            # push either sees us on the idle list or we see its task
            worker.wakeup.clear()
            with self._idle_lock:
                self._idle.append(worker)
            t = self._pop(worker)
            if t is not None:
                with self._idle_lock:
                    if worker in self._idle:
                        self._idle.remove(worker)
                return t
            worker.wakeup.wait(timeout=backoff)
            with self._idle_lock:
                if worker in self._idle:
                    self._idle.remove(worker)
            backoff = min(backoff * 2.0, self._BACKOFF_MAX)
        return None

    def _execute(self, task: Task, worker: SpWorker) -> None:
        graph = getattr(task, "graph", None)
        if task.poisoned:
            # a quarantined/timed-out predecessor: its output never
            # materialized, so running this task would propagate garbage —
            # cancel instead (waiters see CancelledError)
            task.mark_cancelled()
            if graph is not None:
                self.push_many(graph.on_task_finished(task))
            return
        token = getattr(task, "cancel_token", None)
        if token is not None and token.is_set():
            on_cancel = getattr(task, "on_cancel", None)
            if on_cancel is not None:
                try:
                    on_cancel(task)
                except BaseException as e:  # pragma: no cover - defensive
                    task.exception = e
            task.mark_cancelled()
            if graph is not None:
                self.push_many(graph.on_task_finished(task))
            return

        # paper §4.7: commutative accesses require runtime mutual exclusion;
        # handles were sorted by uid at insert (deadlock freedom), so the hot
        # path just walks the precomputed tuple
        locks = [h.commutative_lock for h in task.commutative_handles]
        for lk in locks:
            lk.acquire()
        policy = task.policy
        watched = policy is not None and policy.timeout is not None
        task.state = TaskState.RUNNING
        task.worker_name = worker.name
        task.t_start = time.perf_counter()
        try:
            attempt = 0
            while True:
                if watched:
                    self._watchdog().arm(task, time.monotonic() + policy.timeout)
                try:
                    task.run(preferred_impl=worker.kind)
                    task.exception = None
                    break
                except BaseException as e:
                    task.exception = e
                finally:
                    if watched:
                        self._watchdog().disarm(task)
                if task.timed_out:
                    break  # the watchdog already failed + completed the task
                attempt += 1
                if (
                    policy is None
                    or attempt > policy.retries
                    or isinstance(task.exception, CancelledError)
                    or not self._running
                ):
                    break
                # retry: fresh write-refs are rebuilt by run(); a raising
                # body never reached its writebacks, so inputs are intact
                task.retries_used = attempt
                task.exception = None
                if policy.retry_backoff > 0.0:
                    time.sleep(policy.retry_backoff * (2 ** (attempt - 1)))
        finally:
            task.t_end = time.perf_counter()
            for lk in reversed(locks):
                lk.release()
        if watched and not task.claim_completion():
            return  # zombie return: the watchdog completed this task
        if token is not None:
            if task.exception is None:
                token.set(task)
            else:
                # a crashed replica must not win the race: park the error on
                # the token (surfaced by the select task only if every copy
                # fails) and let the healthy copies keep going
                record = getattr(token, "record_failure", None)
                if record is not None:
                    record(task.exception)
                    task.exception = None
        if graph is not None:
            if getattr(graph, "trace", True):
                graph.trace_events.append(
                    {
                        "task": task.name,
                        "uid": task.uid,
                        "worker": worker.name,
                        "t0": task.t_start,
                        "t1": task.t_end,
                        "ready": len(self.scheduler),
                        "comm": task.is_comm,
                        "spec": task.speculative,
                    }
                )
            if (
                task.exception is not None
                and policy is not None
                and policy.on_failure == "quarantine"
            ):
                # poison-task containment: park the error off the graph's
                # error list and cancel dependents (before their release)
                graph.quarantine(task)
            newly = graph.on_task_finished(task)
            task.mark_finished()
            self.push_many(newly)
        else:  # pragma: no cover - tasks always carry a graph backref
            task.mark_finished()

    # --------------------------------------------------------------- watchdog

    def _watchdog(self) -> _Watchdog:
        if self._wd is None:
            with self._lock:
                if self._wd is None:
                    wd = _Watchdog(self)
                    wd.start()
                    self._wd = wd
        return self._wd

    def _fail_hung_task(self, task: Task) -> None:
        """Watchdog expiry: fail ``task`` with :class:`SpTaskTimeoutError`
        and complete it while the worker is still stuck inside the body.
        The zombie's eventual return is discarded (completion claim +
        writeback guard in ``Task.run``).  Timeouts are terminal — no retry:
        the zombie may still be mutating whatever wedged it."""
        task.timed_out = True
        if not task.claim_completion():
            return  # the worker finished inside the race window
        policy = task.policy
        task.exception = SpTaskTimeoutError(
            f"task {task.name!r} exceeded its {policy.timeout}s timeout "
            f"(watchdog); the hung body is abandoned as a zombie"
        )
        graph = getattr(task, "graph", None)
        if graph is None:  # pragma: no cover - tasks always carry a graph
            task.mark_finished()
            return
        if policy.on_failure == "quarantine":
            graph.quarantine(task)
        else:
            # even on "raise", dependents must not run on garbage inputs
            graph.poison_dependents(task)
        newly = graph.on_task_finished(task)
        task.mark_finished()
        self.push_many(newly)

    # ------------------------------------------------------------- team mgmt

    @property
    def n_workers(self) -> int:
        return len(self._workers)

    def _attach_worker(self, w: SpWorker) -> None:
        with self._lock:
            self._workers.append(w)
            w.engine = self
            self._register_with_scheduler(w)

    def _detach_worker(self, w: SpWorker) -> None:
        with self._lock:
            if w in self._workers:
                self._workers.remove(w)
            self._unregister_from_scheduler(w)
        with self._idle_lock:
            if w in self._idle:
                self._idle.remove(w)
        # orphans may have been drained to the scheduler's overflow deque —
        # make sure somebody looks at them
        self._wake_one()

    def add_workers(self, n: int, kind: str = "ref") -> None:
        for _ in range(n):
            w = SpWorker(self, kind)
            with self._lock:
                self._workers.append(w)
                self._register_with_scheduler(w)
            w.start()

    def send_workers_to(self, other: "SpComputeEngine", n: int) -> int:
        """Move up to ``n`` workers to ``other`` (paper §4.2 dynamic teams)."""
        moved = []
        with self._lock:
            movable = [w for w in self._workers if w.target_engine is None]
            for w in movable[:n]:
                w.target_engine = other
                moved.append(w)
        for w in moved:  # unpark so the move is taken promptly
            w.wakeup.set()
        return len(moved)

    # ------------------------------------------------------------------ comm

    def _comm_thread(self):
        if self._comm is None:
            from .comm import CommThread

            self._comm = CommThread(self)
            self._comm.start()
        return self._comm

    # ------------------------------------------------------------------ stop

    def _drain_cancel_leftovers(self) -> int:
        """Cancel tasks still queued after the workers are gone — work
        pushed in the stop() race window (or released by the comm thread's
        grace period) would otherwise strand ``wait_all_tasks`` forever.
        Successors released by the cancellations are cancelled too."""
        stack: list[Task] = []
        while True:
            if self._pop_by_name:
                t = self.scheduler.pop("ref", "__drain__")
            else:
                t = self.scheduler.pop("ref")
            if t is None:
                break
            stack.append(t)
        n = 0
        while stack:
            t = stack.pop()
            if t.is_done:  # pragma: no cover - raced with a live worker
                continue
            t.mark_cancelled()
            n += 1
            graph = getattr(t, "graph", None)
            if graph is not None:
                stack.extend(graph.on_task_finished(t))
        return n

    def stop(self) -> list[str]:
        """Stop workers, then the comm thread, then cancel any stranded
        queued tasks.  Returns the names of comm tasks whose requests had
        to be aborted (empty in a clean shutdown) plus the names of tasks
        quarantined by their failure policy; aborted tasks carry an
        ``SpCommAbortedError`` so their waiters see a real error instead of
        hanging on a leaked daemon thread.

        Idempotent: a second call (recovery path + ``atexit``, or an
        explicit ``stop()`` followed by ``__exit__``) returns the first
        call's report without re-joining threads or re-cancelling tasks."""
        with self._lock:
            if self._stop_report is not None:
                return list(self._stop_report)
            self._running = False
            workers = list(self._workers)
            for w in workers:
                w.alive = False
        for w in workers:
            w.wakeup.set()
        me = threading.current_thread()
        for w in workers:
            if w is not me:
                w.join(timeout=5.0)
        aborted: list[str] = []
        if self._comm is not None:
            aborted = self._comm.stop()
        if self._wd is not None:
            self._wd.stop()
        self._drain_cancel_leftovers()
        report = aborted + [
            t.name for g in self._graphs for t in getattr(g, "quarantined", ())
        ]
        with self._lock:
            self._stop_report = list(report)
        return report

    stopIfNotAlreadyStopped = stop

    def __enter__(self) -> "SpComputeEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

"""DOT export of the task graph (paper §4.8, Code 8)."""
from __future__ import annotations


_COLORS = {
    "comm": "lightskyblue",
    "spec": "khaki",
    "normal": "white",
}


def _escape(s: str) -> str:
    return s.replace('"', r"\"")


def graph_to_dot(graph, *, show_accesses: bool = False) -> str:
    lines = ["digraph taskgraph {", "  rankdir=TB;", "  node [shape=box, style=filled];"]
    for t in graph.tasks:
        color = "comm" if t.is_comm else ("spec" if t.speculative else "normal")
        label = _escape(t.name)
        if show_accesses:
            accs = ", ".join(f"{a.mode.value}:{a.data.name}" for a in t.accesses)
            label += rf"\n[{_escape(accs)}]"
        lines.append(f'  t{t.uid} [label="{label}", fillcolor={_COLORS[color]}];')
    seen: set[tuple[int, int]] = set()
    for src, dst in graph.edges():
        k = (src.uid, dst.uid)
        if k not in seen:
            seen.add(k)
            lines.append(f"  t{src.uid} -> t{dst.uid};")
    lines.append("}")
    return "\n".join(lines) + "\n"

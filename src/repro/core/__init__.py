"""repro.core — Specx's task-based runtime, adapted to JAX (DESIGN.md §1–2).

The **codelet frontend** (``api.py``) is the primary spelling: declare a
task once — its named data slots with access modes, plus one implementation
per processing-unit kind — then run the same declaration on either backend::

    from repro.core import SpData, SpRuntime, sp_task

    @sp_task(read=("a",), write=("b",))
    def axpy(a, b, *, alpha=2.0):
        b.value = b.value + alpha * a

    @axpy.impl("pallas", available=lambda: on_tpu())   # SpCpu/SpCuda, §4.3
    def _(a, b, *, alpha=2.0): ...

    a, b = SpData(x, "a"), SpData(y, "b")
    with SpRuntime(backend="eager", workers=4) as rt:  # or backend="staged"
        view = axpy(a, b, alpha=3.0)
        print(view.result())                            # future-like TaskView

Capability dispatch happens per call: variants whose ``available()`` probe
fails are excluded; the eager engine then selects by worker kind, the staged
backend by platform.

The positional paper spelling remains as the compatibility form::

    tg = SpTaskGraph()
    tg.task(SpRead(a), SpWrite(b), fn)     # same insertion path underneath

Public API (paper spellings where sensible)::

    from repro.core import (
        sp_task, SpCodelet, SpRuntime, graph_scope, current_graph,
        SpTaskGraph, SpSpeculativeModel,
        SpData, SpRead, SpWrite, SpCommutativeWrite, SpMaybeWrite, SpAtomicWrite,
        SpReadArray, SpWriteArray, SpPriority,
        SpComputeEngine, SpWorkerTeamBuilder,
        SpCpu, SpCuda, SpRef, SpPallas, SpHost,
    )
"""
from .access import (
    AccessMode,
    SpAccess,
    SpArrayAccess,
    SpAtomicWrite,
    SpAtomicWriteArray,
    SpCommutativeWrite,
    SpCommutativeWriteArray,
    SpCpu,
    SpCuda,
    SpData,
    SpHip,
    SpHost,
    SpImpl,
    SpMaybeWrite,
    SpMaybeWriteArray,
    SpPallas,
    SpPriority,
    SpRead,
    SpReadArray,
    SpRef,
    SpWrite,
    SpWriteArray,
    SpWriteRef,
)
from .comm import (
    ChannelHub,
    SocketTransport,
    SpCommAbortedError,
    SpCommError,
    SpCommGroup,
    SpCommTimeoutError,
    SpCommTransientError,
    SpRankDeadError,
    SpDeserializer,
    SpSerializer,
    SpTransport,
    decode_message,
    default_hub,
    encode_message,
    mpi_broadcast,
    mpi_recv,
    mpi_send,
    register_wire_type,
    reset_default_hub,
)
from .engine import SpComputeEngine, SpWorker, SpWorkerTeam, SpWorkerTeamBuilder
from .graph import SpSpeculativeModel, SpTaskGraph
from .api import (
    ElasticEvent,
    SpCodelet,
    SpRuntime,
    SpSlot,
    current_graph,
    graph_scope,
    sp_task,
)
from .scheduler import (
    CriticalPathScheduler,
    FifoScheduler,
    LifoScheduler,
    PriorityScheduler,
    SpAbstractScheduler,
    WorkStealingScheduler,
    compute_upward_ranks,
    make_scheduler,
)
from .staged import execute_staged, linearize, schedule_summary
from .trace import trace_metrics
from .task import SpTaskPolicy, SpTaskTimeoutError, Task, TaskState, TaskView

__all__ = [
    "AccessMode", "SpAccess", "SpArrayAccess", "SpAtomicWrite", "SpAtomicWriteArray",
    "SpCommutativeWrite", "SpCommutativeWriteArray", "SpCpu", "SpCuda", "SpData",
    "SpHip", "SpHost", "SpImpl", "SpMaybeWrite", "SpMaybeWriteArray", "SpPallas",
    "SpPriority", "SpRead", "SpReadArray", "SpRef", "SpWrite", "SpWriteArray",
    "SpWriteRef", "ChannelHub", "SocketTransport", "SpTransport", "SpCommGroup",
    "SpCommError", "SpCommTimeoutError", "SpCommAbortedError",
    "SpCommTransientError", "SpRankDeadError",
    "SpDeserializer", "SpSerializer", "decode_message", "default_hub",
    "encode_message", "register_wire_type", "reset_default_hub",
    "mpi_broadcast", "mpi_recv", "mpi_send", "SpComputeEngine", "SpWorker",
    "SpWorkerTeam", "SpWorkerTeamBuilder", "SpRuntime", "SpSpeculativeModel",
    "SpTaskGraph", "SpCodelet", "SpSlot", "sp_task", "graph_scope", "current_graph",
    "CriticalPathScheduler", "FifoScheduler", "LifoScheduler",
    "PriorityScheduler", "SpAbstractScheduler", "WorkStealingScheduler",
    "compute_upward_ranks", "make_scheduler", "execute_staged", "linearize",
    "schedule_summary", "trace_metrics", "Task", "TaskState", "TaskView",
    # robustness (ISSUE 8): task policies, watchdog timeout, elastic runtime
    "ElasticEvent", "SpTaskPolicy", "SpTaskTimeoutError",
]

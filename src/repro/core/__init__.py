"""repro.core — Specx's task-based runtime, adapted to JAX (DESIGN.md §1–2).

Public API mirrors the paper's spelling where sensible::

    from repro.core import (
        SpTaskGraph, SpSpeculativeModel, SpRuntime,
        SpData, SpRead, SpWrite, SpCommutativeWrite, SpMaybeWrite, SpAtomicWrite,
        SpReadArray, SpWriteArray, SpPriority,
        SpComputeEngine, SpWorkerTeamBuilder,
        SpCpu, SpCuda, SpRef, SpPallas, SpHost,
    )
"""
from .access import (
    AccessMode,
    SpAccess,
    SpArrayAccess,
    SpAtomicWrite,
    SpAtomicWriteArray,
    SpCommutativeWrite,
    SpCommutativeWriteArray,
    SpCpu,
    SpCuda,
    SpData,
    SpHip,
    SpHost,
    SpImpl,
    SpMaybeWrite,
    SpMaybeWriteArray,
    SpPallas,
    SpPriority,
    SpRead,
    SpReadArray,
    SpRef,
    SpWrite,
    SpWriteArray,
    SpWriteRef,
)
from .comm import (
    ChannelHub,
    SpCommGroup,
    SpDeserializer,
    SpSerializer,
    mpi_broadcast,
    mpi_recv,
    mpi_send,
)
from .engine import SpComputeEngine, SpWorker, SpWorkerTeam, SpWorkerTeamBuilder
from .graph import SpRuntime, SpSpeculativeModel, SpTaskGraph
from .scheduler import (
    CriticalPathScheduler,
    FifoScheduler,
    LifoScheduler,
    PriorityScheduler,
    SpAbstractScheduler,
    WorkStealingScheduler,
    compute_upward_ranks,
    make_scheduler,
)
from .staged import execute_staged, linearize, schedule_summary
from .trace import trace_metrics
from .task import Task, TaskState, TaskView

__all__ = [
    "AccessMode", "SpAccess", "SpArrayAccess", "SpAtomicWrite", "SpAtomicWriteArray",
    "SpCommutativeWrite", "SpCommutativeWriteArray", "SpCpu", "SpCuda", "SpData",
    "SpHip", "SpHost", "SpImpl", "SpMaybeWrite", "SpMaybeWriteArray", "SpPallas",
    "SpPriority", "SpRead", "SpReadArray", "SpRef", "SpWrite", "SpWriteArray",
    "SpWriteRef", "ChannelHub", "SpCommGroup", "SpDeserializer", "SpSerializer",
    "mpi_broadcast", "mpi_recv", "mpi_send", "SpComputeEngine", "SpWorker",
    "SpWorkerTeam", "SpWorkerTeamBuilder", "SpRuntime", "SpSpeculativeModel",
    "SpTaskGraph", "CriticalPathScheduler", "FifoScheduler", "LifoScheduler",
    "PriorityScheduler", "SpAbstractScheduler", "WorkStealingScheduler",
    "compute_upward_ranks", "make_scheduler", "execute_staged", "linearize",
    "schedule_summary", "trace_metrics", "Task", "TaskState", "TaskView",
]

"""``SpTaskGraph`` — STF task insertion and dependency resolution (paper §4.1).

A single thread inserts tasks, declaring data accesses; the graph derives
the DAG (via per-handle generations, see ``handle.py``) and guarantees the
parallel execution matches the sequential insertion order.  The graph is
dissociated from the compute engine (paper §4.2): bind one with
:meth:`compute_on`; tasks that became ready earlier are buffered.

Speculative execution (paper §4.6) is enabled by constructing the graph with
``SpSpeculativeModel.SP_MODEL_1`` — see ``speculation.py``.
"""
from __future__ import annotations

import enum
import threading
from typing import Any, Optional, Sequence

from .access import (
    AccessMode,
    SpAccess,
    SpArrayAccess,
    SpData,
    SpImpl,
    SpPriority,
)
from .handle import HandleRegistry
from .task import Task, TaskView, normalize_impls


class SpSpeculativeModel(enum.Enum):
    SP_NO_SPEC = 0
    SP_MODEL_1 = 1  # speculate past the most recent uncertain writer
    SP_MODEL_2 = 2  # speculate past whole CHAINS of uncertain writers:
    #                 one snapshot before the first writer; readers overlap
    #                 the entire chain and roll back if ANY writer wrote


class SpTaskGraph:
    """Task graph with STF semantics.

    Example (mirrors paper Code 2)::

        tg = SpTaskGraph()
        a, b = SpData(1.0, "a"), SpData(2.0, "b")
        view = tg.task(SpRead(a), SpWrite(b), lambda a_v, b_ref: b_ref.__setattr__("value", a_v + b_ref.value))
        tg.compute_on(engine)
        tg.wait_all_tasks()
    """

    def __init__(
        self,
        speculative_model: SpSpeculativeModel = SpSpeculativeModel.SP_NO_SPEC,
        *,
        trace: bool = True,
    ):
        self.spec_model = speculative_model
        self.registry = HandleRegistry()
        self.tasks: list[Task] = []
        self._task_by_uid: dict[int, Task] = {}
        self.engine = None  # SpComputeEngine once bound
        self._ready_backlog: list[Task] = []
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._unfinished = 0
        self.errors: list[BaseException] = []
        # poison tasks parked by the failure policy (ISSUE 8): their errors
        # do NOT surface through wait_all_tasks — the graph stays alive,
        # dependents are cancelled, and engine.stop() reports them by name
        self.quarantined: list[Task] = []
        # trace events appended by the engine: dicts with task/worker/t0/t1.
        # ``trace=False`` turns recording off so the production hot path
        # allocates nothing per task; exports then see an empty trace.
        self.trace = trace
        self.trace_events: list[dict] = []
        self.spec_stats = {"speculated": 0, "commits": 0, "rollbacks": 0}
        # set by a staged SpRuntime (core/api.py): zero-arg callable that
        # executes the pending graph; TaskView.result() triggers it
        self._flush_hook = None

    # ------------------------------------------------------------------ insert

    def task(
        self,
        *args,
        name: str | None = None,
        cost: float = 1.0,
        priority: int = 0,
        comm: bool = False,
    ) -> TaskView:
        """Insert a task.  Positional args may be, in any order:
        ``SpPriority``, ``SpAccess`` / ``SpArrayAccess`` (argument slots, in
        declaration order), and one or more callables / ``SpImpl`` variants.

        ``comm=True`` marks a communication task: the eager engine routes it
        to the background comm thread only when it carries a ``comm_start``
        (see comm.py); in the staged backend the flag steers the ``overlap``
        linearization policy (collectives issued as early as possible).

        This positional spelling is the compatibility form; the declarative
        codelet frontend (``repro.core.api``) inserts through the same
        :meth:`insert_task` path.
        """
        prio = priority
        accesses: list[SpAccess] = []
        arg_layout: list[tuple[str, Any]] = []
        impl_raw: list = []
        for a in args:
            if isinstance(a, SpPriority):
                prio = a.value
            elif isinstance(a, SpAccess):
                accesses.append(a)
                arg_layout.append(("single", a))
            elif isinstance(a, SpArrayAccess):
                accesses.extend(a.accesses)
                arg_layout.append(("array", a.accesses))
            elif isinstance(a, SpImpl) or callable(a):
                impl_raw.append(a)
            else:
                raise TypeError(f"unsupported task() argument: {a!r}")
        impls = normalize_impls(impl_raw)
        return self.insert_task(
            impls, accesses, arg_layout, priority=prio, name=name, cost=cost, comm=comm
        )

    def insert_task(
        self,
        impls: dict,
        accesses: Sequence[SpAccess],
        arg_layout: Sequence[tuple[str, Any]],
        *,
        priority: int = 0,
        name: str | None = None,
        cost: float = 1.0,
        comm: bool = False,
    ) -> TaskView:
        """Insert a fully-resolved task (impl dict + accesses + argument
        layout).  Shared lower half of :meth:`task` and the codelet frontend
        — runs the speculation pass, then wires dependencies."""
        self._check_duplicate_handles(accesses)

        if self.spec_model is not SpSpeculativeModel.SP_NO_SPEC:
            from .speculation import maybe_speculative_insert

            view = maybe_speculative_insert(
                self, impls, list(accesses), list(arg_layout), priority, name, cost
            )
            if view is not None:
                return view

        task = Task(impls, accesses, arg_layout, priority, name, cost=cost, is_comm=comm)
        return self._insert(task)

    def _check_duplicate_handles(self, accesses: Sequence[SpAccess]) -> None:
        seen: set[int] = set()
        for acc in accesses:
            if acc.data.uid in seen:
                raise ValueError(
                    f"task declares {acc.data.name!r} twice; merge the accesses"
                )
            seen.add(acc.data.uid)

    def _insert(self, task: Task) -> TaskView:
        """Wire dependencies and dispatch if ready.  Internal: speculation and
        comm layers call this to bypass re-speculation."""
        task.inserted_index = len(self.tasks)
        task.graph = self
        self.tasks.append(task)
        self._task_by_uid[task.uid] = task
        with self._cv:
            self._unfinished += 1

        # Insertion guard: keeps ``pending`` above zero until every access is
        # wired, so a worker completing a predecessor generation mid-insert
        # cannot mark the task ready prematurely.
        task.add_pending(1)
        commutative = []
        for acc in task.accesses:
            h = self.registry.handle_for(acc.data)
            if acc.mode is AccessMode.COMMUTATIVE_WRITE:
                commutative.append(h)
            task.add_pending(1)
            if h.append_access(task, acc.mode):
                # landed in the already-active generation
                task.dec_pending()
        if commutative:
            # sorted-uid lock order (paper §4.7 deadlock freedom), fixed
            # here once so the engine never re-derives it per execution
            commutative.sort(key=lambda h: h.data.uid)
            task.commutative_handles = tuple(commutative)
        if task.dec_pending():  # drop the guard
            self._dispatch(task)
        return TaskView(task)

    # ------------------------------------------------------------------ engine

    def _dispatch(self, task: Task) -> None:
        if self.engine is not None:
            self.engine.push_task(task)
        else:
            with self._lock:
                self._ready_backlog.append(task)

    def compute_on(self, engine) -> "SpTaskGraph":
        """Bind a compute engine (paper §4.2 ``tg.computeOn(ce)``)."""
        self.engine = engine
        engine.register_graph(self)
        with self._lock:
            backlog, self._ready_backlog = self._ready_backlog, []
        for t in backlog:
            engine.push_task(t)
        return self

    computeOn = compute_on

    # ------------------------------------------------------------- completion

    def on_task_finished(self, task: Task) -> list[Task]:
        """Release ``task``'s dependencies; return newly ready tasks."""
        newly: list[Task] = []
        for acc in task.accesses:
            h = self.registry.maybe_handle(acc.data)
            if h is not None:
                newly.extend(h.complete(task))
        with self._cv:
            self._unfinished -= 1
            if task.exception is not None and not task.quarantined:
                self.errors.append(task.exception)
            self._cv.notify_all()
        return newly

    # ----------------------------------------------------- failure policies

    def quarantine(self, task: Task) -> None:
        """Park ``task`` as poison (ISSUE 8 ``on_failure="quarantine"``):
        its exception stays off the error list (``wait_all_tasks`` keeps
        working), its transitive dependents are poisoned so the engine
        cancels them with ``CancelledError`` instead of running them on
        garbage inputs, and sibling branches proceed untouched.  Call
        *before* :meth:`on_task_finished` releases the dependents."""
        task.quarantined = True
        with self._cv:
            if task not in self.quarantined:
                self.quarantined.append(task)
        self.poison_dependents(task)

    def poison_dependents(self, task: Task) -> None:
        """Mark every transitive dependent inserted so far as poisoned.
        Poisoned tasks are cancelled by the engine when they become ready —
        the marking must happen before the failed task's dependencies are
        released, so no dependent can slip through the race window."""
        succ = self.successor_map()
        stack = list(succ.get(task.uid, []))
        seen: set[int] = set()
        while stack:
            t = stack.pop()
            if t.uid in seen or t.is_done:
                continue
            seen.add(t.uid)
            t.poisoned = True
            stack.extend(succ.get(t.uid, []))

    def wait_all_tasks(self, timeout: float | None = None, raise_errors: bool = True) -> None:
        with self._cv:
            ok = self._cv.wait_for(lambda: self._unfinished == 0, timeout)
        if not ok:
            raise TimeoutError(
                f"wait_all_tasks timed out with {self._unfinished} unfinished tasks"
            )
        if raise_errors and self.errors:
            raise self.errors[0]

    waitAllTasks = wait_all_tasks

    @property
    def unfinished(self) -> int:
        return self._unfinished

    # ------------------------------------------------------------- structure

    def successor_map(self) -> dict[int, list[Task]]:
        """uid → successor tasks, derived from handle generations."""
        succ: dict[int, list[Task]] = {}
        for h in self.registry:
            gens = h.generations
            for gi in range(len(gens) - 1):
                for t in gens[gi].tasks:
                    succ.setdefault(t.uid, []).extend(gens[gi + 1].tasks)
        # dedupe, preserve order
        for k, v in succ.items():
            seen: set[int] = set()
            out = []
            for t in v:
                if t.uid not in seen:
                    seen.add(t.uid)
                    out.append(t)
            succ[k] = out
        return succ

    def task_by_uid(self, uid: int) -> Task:
        """O(1) uid → task lookup (index maintained by :meth:`_insert`)."""
        return self._task_by_uid[uid]

    def predecessor_counts(self, succ: dict[int, list[Task]] | None = None) -> dict[int, int]:
        """uid → number of predecessors.  Pass an existing ``successor_map()``
        to avoid rebuilding it (O(V+E) either way)."""
        if succ is None:
            succ = self.successor_map()
        pred: dict[int, int] = {t.uid: 0 for t in self.tasks}
        for _, vs in succ.items():
            for v in vs:
                pred[v.uid] = pred.get(v.uid, 0) + 1
        return pred

    def edges(self) -> list[tuple[Task, Task]]:
        out = []
        by_uid = self._task_by_uid
        for u, vs in self.successor_map().items():
            src = by_uid[u]
            for v in vs:
                out.append((src, v))
        return out

    # --------------------------------------------------------------- exports

    def generate_dot(self, path: str, *, show_accesses: bool = False) -> str:
        from .dot import graph_to_dot

        text = graph_to_dot(self, show_accesses=show_accesses)
        with open(path, "w") as f:
            f.write(text)
        return text

    generateDot = generate_dot

    def generate_trace(self, path: str, show_dependencies: bool = True) -> str:
        from .trace import trace_to_svg

        text = trace_to_svg(self, show_dependencies=show_dependencies)
        with open(path, "w") as f:
            f.write(text)
        return text

    generateTrace = generate_trace


# NB: SpRuntime (paper Code 1) lives in ``core/api.py`` — the unified
# eager/staged façade grew out of the legacy engine+graph pair that used to
# be defined here.  ``SpRuntime(n)`` still spells the old behaviour.

"""Communication tasks + background progress thread (paper §4.4).

Specx integrates MPI into the task graph: send/recv become *communication
tasks* whose execution is delegated to a dedicated background thread that
starts non-blocking operations, polls them (MPI ``test``-style), and
releases dependencies as soon as a request completes — "the progression is
done as early as possible".

Adaptation (DESIGN.md §2): inside one Python process there is no MPI; the
"wire" is an in-process :class:`ChannelHub` connecting Specx *instances*
(rank-tagged graph+engine pairs), with the same non-blocking start/test
protocol so the background-thread design is exercised faithfully.  On a real
multi-host JAX cluster the hub's role is played by the `jax.distributed`
transfer layer; in the *staged* backend cross-device communication lowers to
compiled XLA collectives instead (see ``staged.py`` and
``repro/dist/collectives.py``).

Note on access modes: the paper's prose says a send "does a write access"
and a receive "performs a read access"; that is logically inverted (a recv
must order subsequent readers after it).  We implement send=READ,
recv=WRITE, which matches the paper's *behavioural* description of
dependency release.

Speculation is refused on communication (paper §4.4 last paragraph).
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Any

import numpy as np

from .access import AccessMode, SpAccess, SpData
from .graph import SpSpeculativeModel, SpTaskGraph
from .task import Task, TaskState, TaskView


# ---------------------------------------------------------------------------
# Serialization (paper §4.4 rules 1–3).
# ---------------------------------------------------------------------------

class SpSerializer:
    """Utility serializer: packs arrays/scalars into one flat byte buffer —
    the paper's "single array suitable for communication"."""

    def __init__(self):
        self._chunks: list[bytes] = []

    def append_array(self, arr) -> None:
        a = np.asarray(arr)
        header = f"{a.dtype.str}|{','.join(map(str, a.shape))}|".encode()
        self._chunks.append(len(header).to_bytes(4, "little") + header + a.tobytes())

    def append_scalar(self, x) -> None:
        self.append_array(np.asarray(x))

    def buffer(self) -> bytes:
        return b"".join(self._chunks)


class SpDeserializer:
    def __init__(self, buf: bytes):
        self._buf = buf
        self._pos = 0

    def next_array(self) -> np.ndarray:
        hlen = int.from_bytes(self._buf[self._pos : self._pos + 4], "little")
        self._pos += 4
        header = self._buf[self._pos : self._pos + hlen].decode()
        self._pos += hlen
        dtype_str, shape_str, _ = header.split("|")
        shape = tuple(int(s) for s in shape_str.split(",") if s)
        dt = np.dtype(dtype_str)
        n = int(np.prod(shape, dtype=np.int64)) * dt.itemsize if shape else dt.itemsize
        a = np.frombuffer(self._buf[self._pos : self._pos + n], dtype=dt).reshape(shape)
        self._pos += n
        return a


def pack(obj: Any) -> Any:
    """Apply the paper's three rules: (1) trivially-copyable values (arrays,
    scalars, pytrees of them) pass through; (2) objects exposing
    ``comm_buffer()`` send that buffer; (3) objects with ``sp_serialize``
    use the serializer."""
    if hasattr(obj, "sp_serialize"):
        s = SpSerializer()
        obj.sp_serialize(s)
        return ("__serialized__", type(obj), s.buffer())
    if hasattr(obj, "comm_buffer"):
        return ("__buffer__", type(obj), obj.comm_buffer())
    return obj  # rule 1: values are immutable — in-process "copy" is free


def unpack(msg: Any) -> Any:
    if isinstance(msg, tuple) and len(msg) == 3 and msg[0] == "__serialized__":
        _, cls, buf = msg
        return cls.sp_deserialize(SpDeserializer(buf))
    if isinstance(msg, tuple) and len(msg) == 3 and msg[0] == "__buffer__":
        _, cls, buf = msg
        return cls.from_comm_buffer(buf)
    return msg


# ---------------------------------------------------------------------------
# The in-process wire.
# ---------------------------------------------------------------------------

class ChannelHub:
    """Mailboxes keyed by (src, dst, tag)."""

    def __init__(self):
        self._boxes: dict[tuple, collections.deque] = collections.defaultdict(collections.deque)
        self._lock = threading.Lock()

    def post(self, key: tuple, msg: Any) -> None:
        with self._lock:
            self._boxes[key].append(msg)

    def poll(self, key: tuple):
        """Return (True, msg) if available else (False, None)."""
        with self._lock:
            box = self._boxes.get(key)
            if box:
                return True, box.popleft()
        return False, None


_default_hub = ChannelHub()


class SpCommGroup:
    """A communicator: (hub, rank, size) — one per Specx 'instance'."""

    def __init__(self, rank: int, size: int, hub: ChannelHub | None = None):
        self.rank = rank
        self.size = size
        self.hub = hub or _default_hub
        self._bcast_seq = 0  # paper: same broadcasts, same order on all ranks


# ---------------------------------------------------------------------------
# Non-blocking requests.
# ---------------------------------------------------------------------------

class CommRequest:
    def test(self) -> bool:
        raise NotImplementedError

    def complete(self) -> None:
        pass


class _DoneRequest(CommRequest):
    def test(self) -> bool:
        return True


class _RecvRequest(CommRequest):
    def __init__(self, hub: ChannelHub, key: tuple, ref):
        self.hub = hub
        self.key = key
        self.ref = ref
        self._msg = None
        self._have = False

    def test(self) -> bool:
        if not self._have:
            ok, msg = self.hub.poll(self.key)
            if ok:
                self._msg = msg
                self._have = True
        return self._have

    def complete(self) -> None:
        self.ref.value = unpack(self._msg)


# ---------------------------------------------------------------------------
# Comm task constructors.
# ---------------------------------------------------------------------------

def _no_spec(graph: SpTaskGraph) -> None:
    if graph.spec_model is not SpSpeculativeModel.SP_NO_SPEC:
        raise ValueError(
            "MPI-style communications are incompatible with speculative "
            "execution (paper §4.4); use a SP_NO_SPEC graph."
        )


def mpi_send(graph: SpTaskGraph, group: SpCommGroup, x: SpData, dest: int, tag: int) -> TaskView:
    _no_spec(graph)
    acc = SpAccess(x, AccessMode.READ)
    task = Task({"ref": lambda v: None}, [acc], [("single", acc)],
                name=f"send(to={dest},tag={tag})", is_comm=True, cost=0.1)

    def start(args):
        group.hub.post((group.rank, dest, tag), pack(args[0]))
        return _DoneRequest()

    task.comm_start = start
    return graph._insert(task)


def mpi_recv(graph: SpTaskGraph, group: SpCommGroup, x: SpData, src: int, tag: int) -> TaskView:
    _no_spec(graph)
    acc = SpAccess(x, AccessMode.WRITE)
    task = Task({"ref": lambda v: None}, [acc], [("single", acc)],
                name=f"recv(from={src},tag={tag})", is_comm=True, cost=0.1)

    def start(args):
        return _RecvRequest(group.hub, (src, group.rank, tag), args[0])

    task.comm_start = start
    return graph._insert(task)


def mpi_broadcast(graph: SpTaskGraph, group: SpCommGroup, x: SpData, root: int) -> TaskView:
    """Paper: Specx supports MPI broadcast; all instances must issue the same
    broadcasts in the same order — enforced via a per-group sequence tag."""
    _no_spec(graph)
    seq = group._bcast_seq
    group._bcast_seq += 1
    tag = ("bcast", seq)
    if group.rank == root:
        acc = SpAccess(x, AccessMode.READ)
        task = Task({"ref": lambda v: None}, [acc], [("single", acc)],
                    name=f"bcast(root={root},seq={seq})", is_comm=True, cost=0.1)

        def start(args):
            msg = pack(args[0])
            for r in range(group.size):
                if r != root:
                    group.hub.post((root, r, tag), msg)
            return _DoneRequest()

        task.comm_start = start
    else:
        acc = SpAccess(x, AccessMode.WRITE)
        task = Task({"ref": lambda v: None}, [acc], [("single", acc)],
                    name=f"bcast(root={root},seq={seq})", is_comm=True, cost=0.1)

        def start(args):
            return _RecvRequest(group.hub, (root, group.rank, tag), args[0])

        task.comm_start = start
    return graph._insert(task)


# ---------------------------------------------------------------------------
# The background progress thread (one per engine).
# ---------------------------------------------------------------------------

class CommThread(threading.Thread):
    """Starts non-blocking ops and polls a request list — the analogue of the
    paper's MPI thread calling test-any in a loop."""

    _ids = iter(range(1 << 20))

    def __init__(self, engine):
        super().__init__(name=f"spcomm-{next(CommThread._ids)}", daemon=True)
        self.engine = engine
        self._incoming: collections.deque[Task] = collections.deque()
        self._cv = threading.Condition()
        self._running = True

    def submit(self, task: Task) -> None:
        with self._cv:
            self._incoming.append(task)
            self._cv.notify()

    def run(self) -> None:
        in_flight: list[tuple[Task, CommRequest, list]] = []
        while True:
            with self._cv:
                if not self._running and not self._incoming and not in_flight:
                    return
                while self._incoming:
                    task = self._incoming.popleft()
                    task.state = TaskState.RUNNING
                    task.t_start = time.perf_counter()
                    args, writebacks = task.build_args()
                    req = task.comm_start(args)
                    in_flight.append((task, req, writebacks))
                if not in_flight and self._running:
                    self._cv.wait(timeout=0.05)
                    continue
            progressed = False
            for item in list(in_flight):
                task, req, writebacks = item
                if req.test():
                    req.complete()
                    for acc, ref in writebacks:
                        acc.data.value = ref.value
                    task.t_end = time.perf_counter()
                    graph = getattr(task, "graph", None)
                    if graph is not None:
                        if getattr(graph, "trace", True):
                            graph.trace_events.append(
                                {
                                    "task": task.name,
                                    "uid": task.uid,
                                    "worker": self.name,
                                    "t0": task.t_start,
                                    "t1": task.t_end,
                                    "ready": 0,
                                    "comm": True,
                                    "spec": False,
                                }
                            )
                        newly = graph.on_task_finished(task)
                        task.mark_finished()
                        self.engine.push_many(newly)
                    else:  # pragma: no cover
                        task.mark_finished()
                    in_flight.remove(item)
                    progressed = True
            if not progressed and in_flight:
                time.sleep(0.0005)

    def stop(self) -> None:
        with self._cv:
            self._running = False
            self._cv.notify()
        self.join(timeout=5.0)

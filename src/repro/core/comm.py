"""Communication tasks, transports, and the background progress thread
(paper §4.4).

Specx integrates MPI into the task graph: send/recv become *communication
tasks* whose execution is delegated to a dedicated background thread that
starts non-blocking operations, polls them (MPI ``test``-style), and
releases dependencies as soon as a request completes — "the progression is
done as early as possible".

Transport architecture (ISSUE 10: the peer-to-peer data plane)
==============================================================

* :class:`SpTransport` is the wire abstraction: ``post(key, msg)`` /
  ``poll(key)`` mailboxes keyed by ``(src, dst, tag)``.  ``poll`` is
  **non-blocking by contract** — the comm thread's start/test loop calls it
  on every request tick and must never sleep inside a transport.

* :class:`ChannelHub` is the in-process transport: rank-tagged Specx
  *instances* inside one process exchange live Python objects through
  locked deques.  Drained mailboxes are pruned on ``poll`` so per-step
  tags do not accumulate across a training run.

* :class:`SocketTransport` is the cross-process TCP transport — a true
  peer-to-peer data plane.  Payload bytes move over *direct* per-pair
  connections; rank 0 is only special during rendezvous and as a
  control-plane relay, never on the data path.

Address-exchange rendezvous
---------------------------
Every rank — including rank 0 — binds its **own data listener** on an
OS-assigned port, then dials rank 0's rendezvous socket (:class:`_Router`,
demoted from the old frame switch to an address server) and sends an
8-byte hello ``[u32 rank][u32 data_port]``.  Once all ``size`` hellos have
landed, the router broadcasts the **address book** — ``(rank, ip,
data_port)`` triples — to each rank over its rendezvous connection, which
stays open afterwards as that rank's *control link*.  Data connections are
dialed **lazily**: the first ``post`` to a peer dials its listener (a
4-byte hello carries the dialer's rank), and the connection is cached in a
per-peer link table for the life of the transport, so an N-rank job opens
only the links its communication pattern actually uses.

Frame wire format
-----------------
Every link — control or data — carries length-prefixed frames::

    [u32 len][u32 src][u32 dst][u32 taglen][tag bytes][payload bytes]

``tag`` is the canonical :func:`encode_message` spelling of the mailbox
tag; ``dst == _CTRL_RANK`` marks control frames (heartbeats, byes, death
gossip, the address book), whose tag tuple ``("__spctrl__", kind, ...)``
carries the whole message.  Senders never concatenate payload bytes:
:class:`SpSerializer` keeps a **scatter-gather segment list** (header
``bytes`` interleaved with zero-copy ``memoryview`` s of large array
buffers) and the transport hands the whole list to ``socket.sendmsg`` —
writev-style vectored I/O, batched at ``IOV_MAX`` entries with partial
sends resumed mid-segment (:func:`_sendv`).  Large tensors are *chunk
pipelined* one level up: ``dist.collectives.ring_all_reduce(...,
chunk_bytes=...)`` splits each ring step into fixed-size pieces that
travel as independent frames, so step *k+1* of one piece overlaps the
reduction of step *k* of another (transfer/compute overlap across the
ring, paper §4.4's comm-as-tasks made load-bearing).

Peer heartbeat / gossip contract
--------------------------------
Failure detection is **peer-observed**; no router sits on the data path
to observe it for you:

* Every transport's heartbeat thread sends ``hb`` control frames on *all*
  of its live links — the control link (so the rank-0 relay can watch
  ranks nobody has dialed) and every direct data link (so peers watch
  each other).  Each transport runs its own staleness monitor over its
  data links; the router runs one over the control links.
* **EOF without a goodbye** on any link (a SIGKILLed process's kernel
  closes its sockets) declares the peer dead at whichever endpoint saw it
  — in milliseconds, independent of heartbeat knobs.  A refused direct
  dial to a non-departed peer is the same signal.
* A locally-declared death is **gossiped**: a ``("dead", rank)`` control
  frame goes out on the control link and every data link; receivers mark
  the rank dead and forward once (the dead-set makes gossip idempotent,
  so storms terminate).  The router re-broadcasts to all control links,
  guaranteeing delivery even to pairs that never dialed each other.
  Graceful ``close()`` sends ``bye`` on every link first, and the router
  relays byes, so planned departures are never declared deaths.

Detection-latency knobs
-----------------------
``SocketTransport(heartbeat=interval, staleness_factor=k)`` declares a
silent rank dead after ``interval * k`` seconds (default ``0.5 s × 20 =
10 s``; ``REPRO_HB_INTERVAL`` overrides the interval fleet-wide, and
``heartbeat_timeout=`` pins the window directly).  Smaller windows
tighten elastic-recovery latency but risk false positives on loaded
hosts — a declared-dead rank is permanently evicted (its dials and
hellos are refused), so keep ``interval * k`` several times the worst
GC/GIL pause you expect.  EOF detection needs no tuning and dominates in
practice (SIGKILL → few ms, see ``BENCH_recovery.json``); heartbeats only
bound detection of alive-but-wedged (SIGSTOP'd) ranks.  Per-request
*recv* patience is a separate axis: ``timeout=`` on
``mpi_recv``/``mpi_broadcast`` or ``SpCommGroup(default_timeout=...)``.

:class:`RouterTransport` preserves the old hub-and-spoke star (every
frame forwarded through rank 0) purely as the measured baseline for
``benchmarks/comm_bench.py``; new code should never use it.

Wire format payload encoding: :func:`encode_message` / :func:`decode_message`
are the single canonical encoding used whenever a message must leave the
process — a typed, self-describing byte stream (``SpSerializer.append_obj``)
covering arrays, scalars, strings/bytes, pytrees (tuple/list/dict), and
tagged ``sp_serialize`` / ``comm_buffer`` objects.  Classes cross the wire
as *registered type names* (``register_wire_type``; auto-registered at pack
time and resolved by import on the receiving side), never as pickled
``type`` objects.

Timeout semantics: ``mpi_recv`` / ``mpi_broadcast`` accept ``timeout=``
(seconds, default :attr:`SpCommGroup.default_timeout`); a request whose
peer never posts fails with :class:`SpCommTimeoutError` *as the task's
exception* — observable via ``TaskView.exception()`` and re-raised by
``wait_all_tasks`` — instead of spinning the comm thread forever.
``CommThread.stop()`` likewise no longer abandons in-flight requests: after
a grace period it aborts them with :class:`SpCommAbortedError` and reports
the affected task names.

Once a rank is dead (ISSUE 6 semantics, unchanged): ``post`` to it and
``poll`` of an empty mailbox whose source is dead raise
:class:`SpRankDeadError` — so every *pending* receive fails on its next
comm-thread tick and every *future* request fails immediately, and
dependent tasks cancel transitively exactly as timeouts do.
:class:`SpCommTransientError` marks retryable link faults (used by the
fault-injection harness in ``repro.dist.fault``, which wraps per-peer
streams; retry/backoff lives there in ``RetryingTransport``).  All
communication failures derive from :class:`SpCommError`, so callers can
catch one type.

Note on access modes: the paper's prose says a send "does a write access"
and a receive "performs a read access"; that is logically inverted (a recv
must order subsequent readers after it).  We implement send=READ,
recv=WRITE, which matches the paper's *behavioural* description of
dependency release.

Speculation is refused on communication (paper §4.4 last paragraph).
"""
from __future__ import annotations

import collections
import functools
import importlib
import os
import socket
import struct
import threading
import time
import warnings
from typing import Any, Callable, Optional, Sequence

import numpy as np

from .access import AccessMode, SpAccess, SpData
from .graph import SpSpeculativeModel, SpTaskGraph
from .task import Task, TaskState, TaskView


class SpCommError(RuntimeError):
    """Base class for communication-layer failures."""


class SpCommTimeoutError(SpCommError):
    """A receive's deadline passed with no matching message posted."""


class SpCommAbortedError(SpCommError):
    """The comm thread was stopped while this request was still in flight."""


class SpRankDeadError(SpCommError):
    """A peer rank died (EOF without goodbye, missed heartbeats, or a retry
    budget exhausted) — requests addressed to it will never complete."""


class SpCommTransientError(SpCommError):
    """A retryable link fault: a send that failed in a way a bounded
    retry-with-backoff may recover from (injected drops, flaky links)."""


# ---------------------------------------------------------------------------
# Registered-type table: classes cross the wire as names, not type objects.
# ---------------------------------------------------------------------------

_WIRE_TYPES: dict[str, type] = {}


def register_wire_type(cls: type | None = None, *, name: str | None = None):
    """Register ``cls`` for tagged (``sp_serialize`` / ``comm_buffer``)
    transfer.  Usable as a decorator.  Registration is automatic at pack
    time; the receiving process resolves unknown names by importing
    ``module:qualname``, so explicit registration is only needed for names
    that are not importable (e.g. classes defined inside a function)."""

    def reg(c: type):
        key = name or f"{c.__module__}:{c.__qualname__}"
        _WIRE_TYPES[key] = c
        c._sp_wire_name_ = key
        return c

    return reg if cls is None else reg(cls)


def _wire_name(cls: type) -> str:
    key = cls.__dict__.get("_sp_wire_name_")
    if key is None or _WIRE_TYPES.get(key) is not cls:
        register_wire_type(cls)
        key = cls.__dict__["_sp_wire_name_"]
    return key


def resolve_wire_type(name: str) -> type:
    """Name → class: registry first, then ``module:qualname`` import."""
    cls = _WIRE_TYPES.get(name)
    if cls is None:
        modname, _, qual = name.partition(":")
        obj: Any = importlib.import_module(modname)
        for part in qual.split("."):
            obj = getattr(obj, part)
        cls = obj
        _WIRE_TYPES[name] = cls
    return cls


# ---------------------------------------------------------------------------
# Serialization (paper §4.4 rules 1–3) — the canonical wire codec.
# ---------------------------------------------------------------------------

_I64_MIN, _I64_MAX = -(1 << 63), (1 << 63) - 1
_U32 = struct.Struct("<I")
_I64 = struct.Struct("<q")
_F64 = struct.Struct("<d")

# Arrays at or above this many bytes travel as zero-copy memoryview
# segments; below it a tobytes() copy is cheaper than an extra iovec.
_SEGMENT_MIN_BYTES = 1024


class SpSerializer:
    """Packs values into one flat byte buffer — the paper's "single array
    suitable for communication".

    ``append_array`` / ``append_scalar`` write the legacy raw array frame
    (header + bytes), used by ``sp_serialize`` implementations.
    ``append_obj`` writes the typed, self-describing encoding used for
    whole messages (:func:`encode_message`).

    Scatter-gather: the serializer holds a *segment list*, not one
    growing buffer.  Small fields are ``bytes``; array payloads at or
    above ``_SEGMENT_MIN_BYTES`` stay as zero-copy ``memoryview`` s of
    the source buffer (kept alive by the view).  :meth:`segments` hands
    the list to vectored sends (``socket.sendmsg``); :meth:`buffer`
    joins it for callers that need one contiguous ``bytes``."""

    def __init__(self):
        self._chunks: list[bytes | memoryview] = []

    def append_array(self, arr) -> None:
        a = np.asarray(arr)
        if not a.flags["C_CONTIGUOUS"]:
            a = np.ascontiguousarray(a)
        header = f"{a.dtype.str}|{','.join(map(str, a.shape))}|".encode()
        self._chunks.append(len(header).to_bytes(4, "little") + header)
        if a.nbytes >= _SEGMENT_MIN_BYTES:
            self._chunks.append(memoryview(a).cast("B"))
        else:
            self._chunks.append(a.tobytes())

    def append_scalar(self, x) -> None:
        self.append_array(np.asarray(x))

    def append_obj(self, obj: Any) -> None:
        """Typed encoding: 1-byte tag, then a tag-specific payload.  Covers
        None/bool/int/float/str/bytes, tuples/lists/dicts (pytrees), numpy
        and numpy-convertible arrays, and tagged serializable objects."""
        c = self._chunks
        if obj is None:
            c.append(b"N")
        elif isinstance(obj, bool):
            c.append(b"b\x01" if obj else b"b\x00")
        elif isinstance(obj, int):
            if _I64_MIN <= obj <= _I64_MAX:
                c.append(b"I" + _I64.pack(obj))
            else:
                enc = str(obj).encode()
                c.append(b"J" + _U32.pack(len(enc)) + enc)
        elif isinstance(obj, float):
            c.append(b"F" + _F64.pack(obj))
        elif isinstance(obj, str):
            enc = obj.encode()
            c.append(b"S" + _U32.pack(len(enc)) + enc)
        elif isinstance(obj, (bytes, bytearray, memoryview)):
            raw = bytes(obj)
            c.append(b"B" + _U32.pack(len(raw)) + raw)
        elif isinstance(obj, tuple):
            c.append(b"T" + _U32.pack(len(obj)))
            for v in obj:
                self.append_obj(v)
        elif isinstance(obj, list):
            c.append(b"L" + _U32.pack(len(obj)))
            for v in obj:
                self.append_obj(v)
        elif isinstance(obj, dict):
            c.append(b"D" + _U32.pack(len(obj)))
            for k, v in obj.items():
                self.append_obj(k)
                self.append_obj(v)
        elif isinstance(obj, (np.ndarray, np.generic)):
            c.append(b"A")
            self.append_array(obj)
        elif hasattr(obj, "sp_serialize"):
            inner = SpSerializer()
            obj.sp_serialize(inner)
            self._append_tagged(b"O", _wire_name(type(obj)), inner.buffer())
        elif hasattr(obj, "comm_buffer"):
            self._append_tagged(b"C", _wire_name(type(obj)), bytes(obj.comm_buffer()))
        else:
            # last resort: anything numpy can view as a numeric array
            # (jax arrays, array-likes) travels as an array
            a = np.asarray(obj)
            if a.dtype == object:
                raise TypeError(
                    f"cannot serialize {type(obj).__name__!r} for the wire; "
                    "use arrays/scalars/pytrees or implement sp_serialize/"
                    "comm_buffer"
                )
            c.append(b"A")
            self.append_array(a)

    def _append_tagged(self, code: bytes, name: str, buf: bytes) -> None:
        enc = name.encode()
        self._chunks.append(
            code + _U32.pack(len(enc)) + enc + _U32.pack(len(buf)) + buf
        )

    def buffer(self) -> bytes:
        return b"".join(self._chunks)

    def segments(self) -> list[bytes | memoryview]:
        """The scatter-gather segment list, in wire order."""
        return list(self._chunks)

    @property
    def nbytes(self) -> int:
        return sum(len(c) for c in self._chunks)


class SpDeserializer:
    """Decodes a wire stream from ``bytes`` *or* any buffer (``bytearray``,
    ``memoryview``) — the receive path hands in the recv buffer directly so
    array payloads are sliced without an intermediate ``bytes`` copy."""

    def __init__(self, buf):
        self._buf = buf if isinstance(buf, (bytes, memoryview)) else memoryview(buf)
        self._pos = 0

    def _take_view(self, n: int):
        """A zero-copy slice of the stream (bytes or memoryview)."""
        out = self._buf[self._pos : self._pos + n]
        self._pos += n
        return out

    def _take(self, n: int) -> bytes:
        return bytes(self._take_view(n))

    def _take_u32(self) -> int:
        return _U32.unpack(self._take(4))[0]

    def next_array(self) -> np.ndarray:
        hlen = self._take_u32()
        header = self._take(hlen).decode()
        dtype_str, shape_str, _ = header.split("|")
        shape = tuple(int(s) for s in shape_str.split(",") if s)
        dt = np.dtype(dtype_str)
        n = int(np.prod(shape, dtype=np.int64)) * dt.itemsize if shape else dt.itemsize
        a = np.frombuffer(self._take_view(n), dtype=dt)
        if not a.flags.writeable:
            # immutable source (a bytes frame): consumers must own mutable
            # arrays, so pay for a private copy.  The p2p receive path
            # hands us a per-frame *bytearray* nobody else holds — there
            # frombuffer's writable view is already exclusively ours and
            # the copy is skipped (zero-copy decode).
            a = a.copy()
        return a.reshape(shape)

    def next_obj(self) -> Any:
        code = self._take(1)
        if code == b"N":
            return None
        if code == b"b":
            return self._take(1) == b"\x01"
        if code == b"I":
            return _I64.unpack(self._take(8))[0]
        if code == b"J":
            return int(self._take(self._take_u32()).decode())
        if code == b"F":
            return _F64.unpack(self._take(8))[0]
        if code == b"S":
            return self._take(self._take_u32()).decode()
        if code == b"B":
            return self._take(self._take_u32())
        if code == b"T":
            n = self._take_u32()
            return tuple(self.next_obj() for _ in range(n))
        if code == b"L":
            n = self._take_u32()
            return [self.next_obj() for _ in range(n)]
        if code == b"D":
            n = self._take_u32()
            return {self.next_obj(): self.next_obj() for _ in range(n)}
        if code == b"A":
            return self.next_array()
        if code == b"O":
            name = self._take(self._take_u32()).decode()
            inner = self._take_view(self._take_u32())
            return resolve_wire_type(name).sp_deserialize(SpDeserializer(inner))
        if code == b"C":
            name = self._take(self._take_u32()).decode()
            buf = self._take(self._take_u32())
            return resolve_wire_type(name).from_comm_buffer(buf)
        raise ValueError(f"corrupt wire stream: unknown type code {code!r}")


def encode_message(obj: Any) -> bytes:
    """Canonical wire encoding of one message (any :meth:`append_obj`-able
    value, including :func:`pack`'s tagged tuples)."""
    s = SpSerializer()
    s.append_obj(obj)
    return s.buffer()


def encode_segments(obj: Any) -> tuple[list[bytes | memoryview], int]:
    """Scatter-gather encoding of one message: ``(segments, total_bytes)``.
    Large array payloads stay zero-copy ``memoryview`` s of their source
    buffers — valid until the next mutation of those arrays, so send
    before releasing the message."""
    s = SpSerializer()
    s.append_obj(obj)
    segs = s.segments()
    return segs, sum(len(c) for c in segs)


def decode_message(buf) -> Any:
    """Decode one message from ``bytes`` or any readable buffer."""
    return SpDeserializer(buf).next_obj()


def pack(obj: Any) -> Any:
    """Apply the paper's three rules: (1) trivially-copyable values (arrays,
    scalars, pytrees of them) pass through; (2) objects exposing
    ``comm_buffer()`` send that buffer; (3) objects with ``sp_serialize``
    use the serializer.  Tagged payloads carry the *registered type name*
    (a string), so they survive :func:`encode_message` across processes."""
    if hasattr(obj, "sp_serialize"):
        s = SpSerializer()
        obj.sp_serialize(s)
        return ("__serialized__", _wire_name(type(obj)), s.buffer())
    if hasattr(obj, "comm_buffer"):
        return ("__buffer__", _wire_name(type(obj)), obj.comm_buffer())
    return obj  # rule 1: values are immutable — in-process "copy" is free


def _resolve(cls_or_name) -> type:
    # raw type objects still accepted for in-process backward compatibility
    return resolve_wire_type(cls_or_name) if isinstance(cls_or_name, str) else cls_or_name


def unpack(msg: Any) -> Any:
    if isinstance(msg, tuple) and len(msg) == 3 and msg[0] == "__serialized__":
        _, cls, buf = msg
        return _resolve(cls).sp_deserialize(SpDeserializer(buf))
    if isinstance(msg, tuple) and len(msg) == 3 and msg[0] == "__buffer__":
        _, cls, buf = msg
        return _resolve(cls).from_comm_buffer(buf)
    return msg


# ---------------------------------------------------------------------------
# Transports.
# ---------------------------------------------------------------------------

class SpTransport:
    """Abstract wire: mailboxes keyed by ``(src, dst, tag)``.

    ``poll`` must be non-blocking — it is called from the comm thread's
    test loop on every tick."""

    def post(self, key: tuple, msg: Any) -> None:
        raise NotImplementedError

    def post_all(self, keys: list, msg: Any) -> None:
        """Post one message to many keys (broadcast fan-out).  Encoding
        transports override this to serialize the payload once."""
        for key in keys:
            self.post(key, msg)

    def poll(self, key: tuple) -> tuple[bool, Any]:
        """Return ``(True, msg)`` if a message is queued for ``key``, else
        ``(False, None)`` — immediately, never waiting on a peer.  May
        raise :class:`SpRankDeadError` when the key's source rank is known
        dead and nothing is queued."""
        raise NotImplementedError

    # -- failure detection (no-ops on transports without a notion of ranks)

    @property
    def dead_ranks(self) -> frozenset:
        """Ranks this transport knows to be dead."""
        return frozenset()

    def mark_dead(self, rank: int) -> None:
        """Record ``rank`` as dead (idempotent)."""

    def is_dead(self, rank: int) -> bool:
        return rank in self.dead_ranks

    def death_detected_at(self, rank: int) -> Optional[float]:
        """``time.monotonic()`` of the moment ``rank`` was marked dead
        here, or None — the detection-latency probe for benchmarks."""
        return None

    def stats(self) -> dict:
        return {}

    def reset(self) -> None:
        pass

    def close(self) -> None:
        pass


class _LockedMailboxes(SpTransport):
    """Shared mailbox half of both transports: locked deques keyed by the
    transport's spelling of ``(src, dst, tag)`` (:meth:`_box_key`), with
    prune-on-drain — per-step tags (every ring collective step mints fresh
    ones) must not leak across a training run."""

    def __init__(self):
        self._boxes: dict[tuple, collections.deque] = {}
        self._lock = threading.Lock()
        self._posted = 0
        self._delivered = 0
        self._dead: set[int] = set()
        self._dead_at: dict[int, float] = {}

    def _box_key(self, key: tuple) -> tuple:
        return key

    @property
    def dead_ranks(self) -> frozenset:
        with self._lock:
            return frozenset(self._dead)

    def mark_dead(self, rank: int) -> None:
        with self._lock:
            if rank in self._dead:
                return
            self._dead.add(rank)
            self._dead_at[rank] = time.monotonic()

    def death_detected_at(self, rank: int) -> Optional[float]:
        with self._lock:
            return self._dead_at.get(rank)

    def _deposit(self, boxkey: tuple, msg: Any, counter: str | None = None) -> None:
        with self._lock:
            self._boxes.setdefault(boxkey, collections.deque()).append(msg)
            if counter is not None:  # counted under the lock: stats must not
                setattr(self, counter, getattr(self, counter) + 1)  # drop updates

    def poll(self, key: tuple):
        boxkey = self._box_key(key)
        with self._lock:
            box = self._boxes.get(boxkey)
            if box:
                msg = box.popleft()
                if not box:  # prune: drained keys must not accumulate
                    del self._boxes[boxkey]
                self._delivered += 1
                return True, msg
            # already-queued messages from a now-dead rank stay deliverable;
            # an *empty* mailbox whose source is dead will never fill — fail
            # the poller fast instead of letting it wait out its timeout
            src = key[0]
            if src in self._dead:
                raise SpRankDeadError(
                    f"rank {src} is dead; nothing further will arrive"
                )
        return False, None

    def stats(self) -> dict:
        with self._lock:
            return {
                "boxes": len(self._boxes),
                "queued": sum(len(b) for b in self._boxes.values()),
                "posted": self._posted,
                "delivered": self._delivered,
            }

    def reset(self) -> None:
        """Drop all queued messages, counters, and dead-rank state
        (fresh-run hygiene for shared hubs, notably the module default)."""
        with self._lock:
            self._boxes.clear()
            self._posted = 0
            self._delivered = 0
            self._dead.clear()
            self._dead_at.clear()


class ChannelHub(_LockedMailboxes):
    """In-process transport: messages are live Python objects (rule 1: no
    copy inside one process) dropped straight into the local mailboxes."""

    def post(self, key: tuple, msg: Any) -> None:
        dst = key[1]
        with self._lock:
            dead = dst in self._dead
        if dead:
            raise SpRankDeadError(f"cannot send to rank {dst}: rank is dead")
        self._deposit(key, msg, "_posted")


_default_hub = ChannelHub()


def default_hub() -> ChannelHub:
    """The module-wide fallback hub used by :class:`SpCommGroup` when no
    transport is passed.  Call :func:`reset_default_hub` between runs that
    share it — undelivered messages otherwise survive into the next run."""
    return _default_hub


def reset_default_hub() -> None:
    _default_hub.reset()


# --------------------------------------------------------------- TCP star

_FRAME_HDR = struct.Struct("<III")  # src, dst, len(tag_bytes)

# control-plane pseudo-rank: frames to/from the router itself.  Transports
# send ("__spctrl__", "hb") / ("__spctrl__", "bye") frames *to* it; the
# router sends ("__spctrl__", "dead", rank) frames *from* it.
_CTRL_RANK = 0xFFFFFFFF


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError("peer closed the connection")
        buf += chunk
    return bytes(buf)


@functools.lru_cache(maxsize=4096)
def _tag_bytes(tag: Any) -> bytes:
    """Canonical on-wire spelling of a tag (int / str / tuple / ...) —
    both ends derive mailbox keys from this, so any encodable tag matches.
    Memoized: the comm thread re-polls pending receives every tick, and
    re-encoding the same tag thousands of times per second is pure waste
    (tags are hashable by construction — they already key mailbox dicts)."""
    return encode_message(tag)


def _recv_into(sock: socket.socket, n: int) -> memoryview:
    """Receive exactly ``n`` bytes into one fresh buffer (no per-chunk
    joins).  The buffer is ``np.empty`` rather than ``bytearray(n)`` —
    malloc without the memset: a ``bytearray`` zero-fills every frame
    before ``recv_into`` overwrites it, a full extra pass over large
    tensor payloads.  Returned as a writable memoryview so the zero-copy
    decode path (``SpDeserializer``) can hand out views instead of
    copies."""
    buf = memoryview(np.empty(n, dtype=np.uint8)).cast("B")
    got = 0
    while got < n:
        r = sock.recv_into(buf[got:])
        if r == 0:
            raise ConnectionError("peer closed the connection")
        got += r
    return buf


# Linux's writev/sendmsg vector-count ceiling; longer segment lists are
# sent in batches of this many iovecs.
_IOV_MAX = 1024


def _sendv(sock: socket.socket, segments: Sequence) -> None:
    """Vectored (writev-style) send of a scatter-gather segment list via
    ``socket.sendmsg`` — no join, no payload copy.  Handles partial sends
    by resuming mid-segment and batches at :data:`_IOV_MAX` entries."""
    views = [s if isinstance(s, memoryview) else memoryview(s) for s in segments]
    if not hasattr(sock, "sendmsg"):  # pragma: no cover - non-POSIX fallback
        sock.sendall(b"".join(views))
        return
    i = 0
    while i < len(views):
        n = sock.sendmsg(views[i : i + _IOV_MAX])
        while n > 0:
            first = views[i]
            if n >= len(first):
                n -= len(first)
                i += 1
            else:
                views[i] = first[n:]
                n = 0


def _ctrl_frame(src: int, dst: int, tag_b: bytes) -> bytes:
    body = _FRAME_HDR.pack(src, dst, len(tag_b)) + tag_b
    return _U32.pack(len(body)) + body


def _resolve_hb_knobs(
    heartbeat: float | None,
    staleness_factor: float | None,
    heartbeat_interval: float | None,
    heartbeat_timeout: float | None,
) -> tuple[float, float]:
    """Resolve the detection-latency knobs (ISSUE 8).  ``heartbeat`` is the
    short spelling, ``heartbeat_interval`` the original one — passing both
    is ambiguous.  Precedence: explicit kwarg > REPRO_HB_INTERVAL env >
    0.5 s default.  The staleness window defaults to 20 heartbeats so the
    historical 0.5 s → 10 s pairing is preserved; an explicit
    ``heartbeat_timeout`` wins over ``staleness_factor``."""
    if heartbeat is not None and heartbeat_interval is not None:
        raise ValueError("pass heartbeat= or heartbeat_interval=, not both")
    if heartbeat_timeout is not None and staleness_factor is not None:
        raise ValueError("pass heartbeat_timeout= or staleness_factor=, not both")
    interval = heartbeat if heartbeat is not None else heartbeat_interval
    if interval is None:
        env = os.environ.get("REPRO_HB_INTERVAL", "").strip()
        interval = float(env) if env else 0.5
    if interval <= 0.0:
        raise ValueError(f"heartbeat interval must be > 0, got {interval}")
    if heartbeat_timeout is None:
        factor = 20.0 if staleness_factor is None else staleness_factor
        if factor <= 1.0:
            raise ValueError(f"staleness_factor must be > 1, got {factor}")
        heartbeat_timeout = interval * factor
    return interval, heartbeat_timeout


class _Router(threading.Thread):
    """Rank 0's *address-exchange* rendezvous and control-plane relay —
    demoted from the old frame switch; it never touches payload bytes.

    Accepts one connection per rank (hello = ``[u32 rank][u32
    data_port]``), and once all ``size`` ranks are in, sends each the
    address book — ``(rank, ip, data_port)`` triples, the ip observed on
    the rendezvous connection — over that same connection, which then
    stays open as the rank's *control link*.  Afterwards it only relays
    control gossip: ``hb`` refreshes the sender's last-seen stamp (its
    monitor declares staleness deaths for ranks nobody dialed), ``bye``
    marks a graceful leave and is re-broadcast, and ``dead`` declarations
    — local EOF, staleness, or peer-reported — are re-broadcast to every
    control link so death news reaches pairs with no direct link."""

    def __init__(self, host: str, port: int, size: int, *, heartbeat_timeout: float = 10.0):
        super().__init__(name="sprendezvous", daemon=True)
        self._size = size
        self._hb_timeout = heartbeat_timeout
        self._listener = socket.create_server((host, port), backlog=size)
        self.port = self._listener.getsockname()[1]
        self._conns: dict[int, socket.socket] = {}
        self._send_locks: dict[int, threading.Lock] = {}
        self._lock = threading.Lock()  # conns / ports / last_seen / dead / graceful
        self._ports: dict[int, tuple[str, int]] = {}  # rank -> (ip, data_port)
        self._all_in = threading.Event()
        self._closing = False
        self._last_seen: dict[int, float] = {}
        self._graceful: set[int] = set()
        self.dead: set[int] = set()
        self._readers: list[threading.Thread] = []

    def run(self) -> None:
        try:
            while not self._closing:
                conn, addr = self._listener.accept()
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                rank, data_port = struct.unpack("<II", _recv_exact(conn, 8))
                with self._lock:
                    refuse = rank in self.dead or rank in self._conns
                    if not refuse:
                        self._conns[rank] = conn
                        self._send_locks[rank] = threading.Lock()
                        self._last_seen[rank] = time.monotonic()
                        self._ports[rank] = (addr[0], data_port)
                        n_in = len(self._conns)
                if refuse:  # protocol breach: duplicate hello / dead rank
                    warnings.warn(
                        f"router: refusing hello for rank {rank} "
                        "(duplicate or already declared dead)",
                        RuntimeWarning,
                    )
                    conn.close()
                    continue
                if self._all_in.is_set():
                    # late joiner (elastic rejoin): refresh everyone's book
                    self._broadcast_book()
                    self._start_reader(rank, conn)
                elif n_in == self._size:
                    self._all_in.set()
                    self._broadcast_book()
                    with self._lock:
                        ready = list(self._conns.items())
                    for r, c in ready:
                        self._start_reader(r, c)
                    threading.Thread(
                        target=self._monitor, name="sprendezvous-hb", daemon=True
                    ).start()
        except (ConnectionError, OSError) as e:
            if not self._closing and not self._all_in.is_set():
                # a rank died mid-rendezvous: the job cannot form — fail
                # loudly instead of leaving a half-dead router thread behind
                warnings.warn(
                    f"router: rendezvous failed ({e!r}); closing all connections",
                    RuntimeWarning,
                )
                with self._lock:
                    conns = list(self._conns.values())
                for c in conns:
                    c.close()
        finally:
            try:
                self._listener.close()
            except OSError:  # pragma: no cover - already closed
                pass
        for t in list(self._readers):
            t.join()

    def _start_reader(self, rank: int, conn: socket.socket) -> None:
        t = threading.Thread(
            target=self._ctrl_from, args=(rank, conn),
            name=f"sprendz-{rank}", daemon=True,
        )
        self._readers.append(t)
        t.start()

    def soft_close(self) -> None:
        """Stop accepting and monitoring; control links stay up until each
        peer hangs up (rank 0 may finish first)."""
        self._closing = True
        try:
            self._listener.close()
        except OSError:  # pragma: no cover - already closed
            pass

    # -- control plane -------------------------------------------------------

    def _broadcast_book(self) -> None:
        with self._lock:
            book = [[r, ip, p] for r, (ip, p) in sorted(self._ports.items())]
            targets = [
                (r, self._conns[r], self._send_locks[r]) for r in self._conns
            ]
        tag_b = encode_message(("__spctrl__", "book", book))
        for r, c, lk in targets:
            try:
                with lk:
                    c.sendall(_ctrl_frame(_CTRL_RANK, r, tag_b))
            except OSError:  # pragma: no cover - peer already gone
                pass

    def _broadcast_ctrl(self, ctrl: tuple) -> None:
        with self._lock:
            targets = [
                (r, self._conns[r], self._send_locks[r]) for r in self._conns
            ]
        tag_b = encode_message(ctrl)
        for r, c, lk in targets:
            try:
                with lk:
                    c.sendall(_ctrl_frame(_CTRL_RANK, r, tag_b))
            except OSError:  # pragma: no cover - survivor also going away
                pass

    def _ctrl_from(self, rank: int, conn: socket.socket) -> None:
        try:
            while True:
                (n,) = _U32.unpack(_recv_exact(conn, 4))
                body = _recv_exact(conn, n)
                _src, dst, taglen = _FRAME_HDR.unpack_from(body, 0)
                if dst != _CTRL_RANK:
                    continue  # no data forwarding on the control plane
                off = _FRAME_HDR.size
                ctrl = decode_message(body[off : off + taglen])
                kind = ctrl[1]
                if kind == "hb":
                    with self._lock:
                        self._last_seen[rank] = time.monotonic()
                elif kind == "bye":
                    with self._lock:
                        self._graceful.add(rank)
                    self._broadcast_ctrl(("__spctrl__", "bye", rank))
                elif kind == "dead":
                    self._declare_dead(
                        int(ctrl[2]), f"reported dead by rank {rank}"
                    )
        except (ConnectionError, OSError):
            pass  # rank hung up
        finally:
            with self._lock:
                graceful = rank in self._graceful
                current = self._conns.get(rank) is conn
                if current:
                    del self._conns[rank]
                    self._send_locks.pop(rank, None)
            try:
                conn.close()
            except OSError:  # pragma: no cover - already closed
                pass
            if current and not graceful and not self._closing:
                # EOF without a goodbye: the process died under us
                self._declare_dead(rank, "connection lost without goodbye")

    # -- failure detector ----------------------------------------------------

    def _monitor(self) -> None:
        interval = max(self._hb_timeout / 4.0, 0.02)
        while not self._closing:
            time.sleep(interval)
            now = time.monotonic()
            with self._lock:
                stale = [
                    r
                    for r, seen in self._last_seen.items()
                    if r in self._conns
                    and r not in self._graceful
                    and r not in self.dead
                    and now - seen > self._hb_timeout
                ]
            for r in stale:
                self._declare_dead(
                    r, f"no heartbeat for more than {self._hb_timeout}s"
                )

    def _declare_dead(self, rank: int, why: str) -> None:
        with self._lock:
            if rank in self.dead:
                return
            self.dead.add(rank)
            conn = self._conns.pop(rank, None)
            self._send_locks.pop(rank, None)
        if conn is not None:
            try:
                conn.close()
            except OSError:  # pragma: no cover - already closed
                pass
        warnings.warn(
            f"router: declaring rank {rank} dead ({why})", RuntimeWarning
        )
        self._broadcast_ctrl(("__spctrl__", "dead", rank))


class _PeerLink:
    """One cached direct connection to a peer: socket, write lock, and the
    reader thread draining it into the local mailboxes."""

    __slots__ = ("rank", "sock", "wlock", "reader")

    def __init__(self, rank: int, sock: socket.socket):
        self.rank = rank
        self.sock = sock
        self.wlock = threading.Lock()
        self.reader: Optional[threading.Thread] = None


class SocketTransport(_LockedMailboxes):
    """Cross-process TCP transport — the peer-to-peer data plane.

    Rendezvous is address-exchange only (see the module docstring): every
    rank binds its own data listener, rank 0's :class:`_Router` hands out
    the address book, and ``post`` lazily dials the destination's listener
    and caches the connection.  Frames are written with vectored I/O from
    the serializer's scatter-gather segment list; a reader thread per link
    drains frames into local mailboxes, so ``poll`` is a pure dict lookup
    — non-blocking, as the comm thread's test loop requires.  Failure
    detection is peer-observed (EOF / heartbeat staleness on each link)
    with death gossip relayed over the control plane."""

    def __init__(
        self,
        rank: int,
        size: int,
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        connect_timeout: float = 10.0,
        max_dial_retries: int = 100,
        heartbeat: float | None = None,
        staleness_factor: float | None = None,
        heartbeat_interval: float | None = None,
        heartbeat_timeout: float | None = None,
    ):
        super().__init__()
        interval, hb_timeout = _resolve_hb_knobs(
            heartbeat, staleness_factor, heartbeat_interval, heartbeat_timeout
        )
        self.rank, self.size, self.host = rank, size, host
        self._received = 0
        self._closed = False
        self._connect_timeout = connect_timeout
        self._hb_interval = interval
        self._hb_timeout = hb_timeout
        self._router: Optional[_Router] = None
        if rank == 0:
            self._router = _Router(host, port, size, heartbeat_timeout=hb_timeout)
            self._router.start()
            port = self._router.port
        elif port == 0:
            raise ValueError("non-root ranks must be told the rendezvous port")
        self.port = port

        # the p2p plane: every rank is a server for its peers
        self._listener = socket.create_server((host, 0), backlog=max(size, 8))
        self.data_port = self._listener.getsockname()[1]

        # the rendezvous may not be listening yet — dial with a bounded
        # retry count and exponential backoff
        deadline = time.monotonic() + connect_timeout
        delay, attempts = 0.01, 0
        while True:
            try:
                self._sock = socket.create_connection((host, port), timeout=connect_timeout)
                break
            except OSError as e:
                attempts += 1
                if attempts >= max_dial_retries or time.monotonic() + delay > deadline:
                    self._listener.close()
                    raise SpCommError(
                        f"rank {rank}: rendezvous at {host}:{port} unreachable "
                        f"after {attempts} dial attempts over "
                        f"{connect_timeout}s ({e})"
                    ) from e
                time.sleep(delay)
                delay = min(delay * 2.0, 0.5)
        # create_connection leaves connect_timeout armed on the socket;
        # clear it or an idle gap longer than that kills the reader thread
        self._sock.settimeout(None)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._sock.sendall(struct.pack("<II", rank, self.data_port))  # hello

        self._wlock = threading.Lock()  # control-link writes
        self._plock = threading.RLock()  # links / book / graceful / last_seen
        self._links: dict[int, _PeerLink] = {}
        self._extra_links: list[_PeerLink] = []  # simultaneous-dial duplicates
        self._dial_locks: dict[int, threading.Lock] = {}
        self._dials = 0
        self._graceful: set[int] = set()
        self._last_seen: dict[int, float] = {}
        self._book: dict[int, tuple[str, int]] = {}
        self._book_ready = threading.Event()
        self._book_failed: Optional[str] = None
        self._hb_stop = threading.Event()

        self._reader = threading.Thread(
            target=self._ctrl_loop, name=f"sprecv-{rank}", daemon=True
        )
        self._reader.start()
        self._acceptor = threading.Thread(
            target=self._accept_loop, name=f"spaccept-{rank}", daemon=True
        )
        self._acceptor.start()
        self._hb = threading.Thread(
            target=self._hb_loop, name=f"sphb-{rank}", daemon=True
        )
        self._hb.start()
        self._mon = threading.Thread(
            target=self._monitor_loop, name=f"spmon-{rank}", daemon=True
        )
        self._mon.start()

    # -- control link (rank-0 relay) -----------------------------------------

    def _ctrl_loop(self) -> None:
        try:
            while True:
                (n,) = _U32.unpack(_recv_exact(self._sock, 4))
                body = _recv_exact(self._sock, n)
                src, dst, taglen = _FRAME_HDR.unpack_from(body, 0)
                if dst != _CTRL_RANK and src != _CTRL_RANK:
                    continue  # the control link carries no data frames
                off = _FRAME_HDR.size
                ctrl = decode_message(body[off : off + taglen])
                kind = ctrl[1]
                if kind == "book":
                    with self._plock:
                        self._book = {
                            int(r): (ip, int(p)) for r, ip, p in ctrl[2]
                        }
                    self._book_ready.set()
                elif kind == "dead":
                    self._death_news(int(ctrl[2]))
                elif kind == "bye":
                    with self._plock:
                        self._graceful.add(int(ctrl[2]))
        except (ConnectionError, OSError):
            if self._closed:
                return
            # the rendezvous relay (rank 0's process) hung up.  Unlike the
            # old star this does NOT kill the data plane — direct links
            # keep flowing; only rank 0 itself may be gone.
            if not self._book_ready.is_set():
                self._book_failed = (
                    "control link lost before the address book arrived"
                )
                self._book_ready.set()
            with self._plock:
                graceful = 0 in self._graceful
            if self.rank != 0 and not graceful:
                self._declare_peer_dead(0, "control link lost without goodbye")

    # -- data plane: listener + per-peer links -------------------------------

    def _accept_loop(self) -> None:
        while True:
            try:
                conn, _addr = self._listener.accept()
            except OSError:
                return  # listener closed: transport shutting down
            if self._closed:
                conn.close()
                return
            try:
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                (peer,) = _U32.unpack(_recv_exact(conn, 4))  # link hello
            except (ConnectionError, OSError):
                conn.close()
                continue
            if self.is_dead(peer):
                conn.close()  # evicted rank: refuse the hello
                continue
            self._register_link(_PeerLink(peer, conn))

    def _register_link(self, link: _PeerLink) -> _PeerLink:
        """Cache ``link`` (or park it as a duplicate when both sides dialed
        simultaneously) and start its reader.  Returns the canonical link
        for that peer."""
        with self._plock:
            if self._closed:
                link.sock.close()
                return link
            current = self._links.get(link.rank)
            if current is None:
                self._links[link.rank] = link
            else:
                self._extra_links.append(link)
            self._last_seen[link.rank] = time.monotonic()
        link.reader = threading.Thread(
            target=self._link_loop, args=(link,),
            name=f"splink-{self.rank}-{link.rank}", daemon=True,
        )
        link.reader.start()
        return current if current is not None else link

    def _drop_link(self, link: _PeerLink) -> None:
        with self._plock:
            if self._links.get(link.rank) is link:
                del self._links[link.rank]
            elif link in self._extra_links:
                self._extra_links.remove(link)
        try:
            link.sock.close()
        except OSError:  # pragma: no cover - already closed
            pass

    def _link_loop(self, link: _PeerLink) -> None:
        peer = link.rank
        try:
            while True:
                (n,) = _U32.unpack(_recv_exact(link.sock, 4))
                body = _recv_into(link.sock, n)
                src, dst, taglen = _FRAME_HDR.unpack_from(body, 0)
                off = _FRAME_HDR.size
                tag_b = bytes(body[off : off + taglen])
                if dst == _CTRL_RANK:  # peer-to-peer control gossip
                    ctrl = decode_message(tag_b)
                    kind = ctrl[1]
                    if kind == "hb":
                        with self._plock:
                            self._last_seen[peer] = time.monotonic()
                    elif kind == "bye":
                        with self._plock:
                            self._graceful.add(
                                int(ctrl[2]) if len(ctrl) > 2 else peer
                            )
                    elif kind == "dead":
                        self._death_news(int(ctrl[2]))
                    continue
                msg = decode_message(memoryview(body)[off + taglen :])
                self._deposit((src, self.rank, tag_b), msg, "_received")
        except (ConnectionError, OSError):
            pass
        finally:
            self._drop_link(link)
            if not self._closed:
                with self._plock:
                    graceful = peer in self._graceful
                if not graceful and not self.is_dead(peer):
                    # EOF without a goodbye, observed by the peer itself —
                    # no router in the detection path
                    self._declare_peer_dead(
                        peer, "peer connection lost without goodbye"
                    )

    # -- failure detection: peer-observed, gossiped --------------------------

    def _declare_peer_dead(self, rank: int, why: str) -> None:
        if rank == self.rank or self._closed or self.is_dead(rank):
            return
        warnings.warn(
            f"rank {self.rank}: declaring rank {rank} dead ({why})",
            RuntimeWarning,
        )
        self._death_news(rank)

    def _death_news(self, rank: int) -> None:
        """Mark ``rank`` dead, reap its links, and gossip once — the dead
        set dedups re-deliveries, so gossip storms terminate."""
        if rank == self.rank:
            return  # never suicide on relayed gossip
        with self._lock:
            if rank in self._dead:
                return
        self.mark_dead(rank)
        with self._plock:
            link = self._links.pop(rank, None)
            extras = [l for l in self._extra_links if l.rank == rank]
            self._extra_links = [l for l in self._extra_links if l.rank != rank]
        for l in ([link] if link is not None else []) + extras:
            try:
                l.sock.close()
            except OSError:  # pragma: no cover - already closed
                pass
        self._gossip(encode_message(("__spctrl__", "dead", rank)))

    def _gossip(self, tag_b: bytes) -> None:
        frame = _ctrl_frame(self.rank, _CTRL_RANK, tag_b)
        try:
            with self._wlock:
                self._sock.sendall(frame)  # the relay re-broadcasts
        except OSError:
            pass  # control link gone; data links below still carry the news
        with self._plock:
            links = list(self._links.values())
        for link in links:
            try:
                with link.wlock:
                    link.sock.sendall(frame)
            except OSError:
                pass  # that link's reader handles its own fallout

    def _hb_loop(self) -> None:
        tag_b = encode_message(("__spctrl__", "hb"))
        frame = _ctrl_frame(self.rank, _CTRL_RANK, tag_b)
        while not self._hb_stop.wait(self._hb_interval):
            try:
                with self._wlock:
                    self._sock.sendall(frame)
            except OSError:
                pass  # relay gone; direct links still prove liveness
            with self._plock:
                links = list(self._links.values())
            for link in links:
                try:
                    with link.wlock:
                        link.sock.sendall(frame)
                except OSError:
                    pass

    def _monitor_loop(self) -> None:
        interval = max(self._hb_timeout / 4.0, 0.02)
        while not self._hb_stop.wait(interval):
            now = time.monotonic()
            with self._plock:
                stale = [
                    r
                    for r, seen in self._last_seen.items()
                    if r in self._links
                    and r not in self._graceful
                    and now - seen > self._hb_timeout
                ]
            for r in stale:
                self._declare_peer_dead(
                    r, f"no heartbeat for more than {self._hb_timeout}s"
                )

    # -- lazy dial + connection cache ----------------------------------------

    def _dial_lock(self, dst: int) -> threading.Lock:
        with self._plock:
            lock = self._dial_locks.get(dst)
            if lock is None:
                lock = self._dial_locks[dst] = threading.Lock()
            return lock

    def _require_book(self) -> dict[int, tuple[str, int]]:
        if not self._book_ready.wait(self._connect_timeout):
            raise SpCommError(
                f"rank {self.rank}: address book not received within "
                f"{self._connect_timeout}s (rendezvous incomplete?)"
            )
        if self._book_failed is not None:
            raise SpCommError(f"rank {self.rank}: {self._book_failed}")
        with self._plock:
            return dict(self._book)

    def _get_link(self, dst: int) -> Optional[_PeerLink]:
        with self._plock:
            link = self._links.get(dst)
        if link is not None:
            return link
        book = self._require_book()
        with self._plock:
            if dst in self._graceful:
                return None  # departed peer: frames to it are dropped
        with self._dial_lock(dst):
            with self._plock:
                link = self._links.get(dst)
            if link is not None:
                return link  # raced with the peer dialing us
            addr = book.get(dst)
            if addr is None:
                raise SpCommError(
                    f"rank {self.rank}: no address for rank {dst} in the book"
                )
            last: Optional[OSError] = None
            sock = None
            for attempt in range(3):
                if self.is_dead(dst):
                    raise SpRankDeadError(
                        f"cannot send to rank {dst}: rank is dead"
                    )
                try:
                    sock = socket.create_connection(
                        addr, timeout=self._connect_timeout
                    )
                    break
                except OSError as e:
                    last = e
                    with self._plock:
                        if dst in self._graceful:
                            return None
                    time.sleep(0.02 * (attempt + 1))
            if sock is None:
                # a refused direct dial to a non-departed peer is EOF-grade
                # evidence: its listener died with its process
                self._declare_peer_dead(
                    dst, f"direct dial to {addr[0]}:{addr[1]} failed ({last})"
                )
                raise SpRankDeadError(
                    f"cannot send to rank {dst}: rank is dead "
                    f"(direct dial failed: {last})"
                )
            sock.settimeout(None)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            sock.sendall(_U32.pack(self.rank))  # link hello
            with self._plock:
                self._dials += 1
            return self._register_link(_PeerLink(dst, sock))

    # -- mailbox side ---------------------------------------------------------

    def _box_key(self, key: tuple) -> tuple:
        src, dst, tag = key
        return (src, dst, _tag_bytes(tag))

    def _post_segments(self, key: tuple, segments: list, nbytes: int) -> None:
        src, dst, tag = key
        if self._closed:
            raise SpCommError("transport is closed")
        if self.is_dead(dst):
            raise SpRankDeadError(f"cannot send to rank {dst}: rank is dead")
        tag_b = _tag_bytes(tag)
        head = (
            _U32.pack(_FRAME_HDR.size + len(tag_b) + nbytes)
            + _FRAME_HDR.pack(src, dst, len(tag_b))
            + tag_b
        )
        link = self._get_link(dst)
        if link is None:
            return  # departed peer: dropped, like the star router did
        try:
            with link.wlock:
                _sendv(link.sock, [head, *segments])
        except OSError as e:
            with self._plock:
                graceful = dst in self._graceful
            if graceful or self._closed:
                return
            self._declare_peer_dead(dst, f"send failed ({e})")
            raise SpRankDeadError(
                f"cannot send to rank {dst}: rank is dead (send failed: {e})"
            ) from e
        with self._lock:
            self._posted += 1

    def post(self, key: tuple, msg: Any) -> None:
        src, dst, tag = key
        if dst == self.rank:
            # self-delivery: straight into the local mailbox (rule 1)
            if self._closed:
                raise SpCommError("transport is closed")
            with self._lock:
                self._posted += 1
            self._deposit(self._box_key(key), msg, "_received")
            return
        segs, nbytes = encode_segments(msg)
        self._post_segments(key, segs, nbytes)

    def post_all(self, keys: list, msg: Any) -> None:
        # broadcast fan-out: serialize once, one vectored frame per link
        segs: Optional[list] = None
        nbytes = 0
        for key in keys:
            if key[1] == self.rank:
                self.post(key, msg)
                continue
            if segs is None:
                segs, nbytes = encode_segments(msg)
            self._post_segments(key, segs, nbytes)

    def stats(self) -> dict:
        out = super().stats()
        out["received"] = self._received
        with self._plock:
            out["links"] = len(self._links) + len(self._extra_links)
            out["dials"] = self._dials
        return out

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._hb_stop.set()
        bye = _ctrl_frame(
            self.rank, _CTRL_RANK, encode_message(("__spctrl__", "bye"))
        )
        with self._plock:
            links = list(self._links.values()) + list(self._extra_links)
        for link in links:  # graceful leave on every direct link
            try:
                with link.wlock:
                    link.sock.sendall(bye)
            except OSError:
                pass
        try:
            with self._wlock:
                self._sock.sendall(bye)  # the relay re-broadcasts the bye
        except OSError:
            pass
        if self._router is not None:
            self._router.soft_close()
        try:
            self._listener.close()
        except OSError:
            pass
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()
        for link in links:
            try:
                link.sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                link.sock.close()
            except OSError:
                pass
        self._reader.join(timeout=2.0)
        self._acceptor.join(timeout=2.0)
        self._hb.join(timeout=2.0)
        self._mon.join(timeout=2.0)
        for link in links:
            if link.reader is not None:
                link.reader.join(timeout=1.0)
        if self._router is not None:
            self._router.join(timeout=2.0)

    def __enter__(self) -> "SocketTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ------------------------------------------------- legacy star (baseline)

class _StarRouter(threading.Thread):
    """LEGACY rank-0 frame switch *and* failure detector — the old
    hub-and-spoke data plane, kept only as :class:`RouterTransport`'s
    router so ``benchmarks/comm_bench.py`` can measure the baseline the
    p2p plane replaced.

    Accepts one connection per rank (hello = the 4-byte rank), then forwards
    every ``[len][src][dst][taglen][tag][payload]`` frame to ``dst``'s
    connection verbatim.  Forwarding starts only once all ``size`` ranks
    have dialed in; frames written earlier sit in kernel socket buffers
    until then.

    Failure detection: frames addressed to :data:`_CTRL_RANK` are consumed
    here — ``hb`` refreshes the sender's last-seen stamp, ``bye`` marks a
    graceful leave.  A rank whose connection EOFs *without* a bye, or whose
    last heartbeat is older than ``heartbeat_timeout``, is declared dead:
    its connection is reaped and a ``dead`` control frame is broadcast to
    every survivor (including rank 0's own transport, which is just another
    connection)."""

    def __init__(self, host: str, port: int, size: int, *, heartbeat_timeout: float = 10.0):
        super().__init__(name="sprouter", daemon=True)
        self._size = size
        self._hb_timeout = heartbeat_timeout
        self._listener = socket.create_server((host, port), backlog=size)
        self.port = self._listener.getsockname()[1]
        self._conns: dict[int, socket.socket] = {}
        self._send_locks: dict[int, threading.Lock] = {}
        self._lock = threading.Lock()  # conns / last_seen / dead / graceful
        self._fwd_lock = threading.Lock()
        self.forwarded = 0
        self._all_in = threading.Event()
        self._closing = False
        self._last_seen: dict[int, float] = {}
        self._graceful: set[int] = set()
        self.dead: set[int] = set()
        self._readers: list[threading.Thread] = []

    def run(self) -> None:
        try:
            while not self._closing:
                conn, _addr = self._listener.accept()
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                (rank,) = _U32.unpack(_recv_exact(conn, 4))
                with self._lock:
                    refuse = rank in self.dead or rank in self._conns
                    if not refuse:
                        self._conns[rank] = conn
                        self._send_locks[rank] = threading.Lock()
                        self._last_seen[rank] = time.monotonic()
                        n_in = len(self._conns)
                if refuse:  # protocol breach: duplicate hello / dead rank
                    warnings.warn(
                        f"router: refusing hello for rank {rank} "
                        "(duplicate or already declared dead)",
                        RuntimeWarning,
                    )
                    conn.close()
                    continue
                if self._all_in.is_set():
                    self._start_reader(rank, conn)  # late joiner post-barrier
                elif n_in == self._size:
                    self._all_in.set()
                    with self._lock:
                        ready = list(self._conns.items())
                    for r, c in ready:
                        self._start_reader(r, c)
                    threading.Thread(
                        target=self._monitor, name="sprouter-hb", daemon=True
                    ).start()
        except (ConnectionError, OSError) as e:
            if not self._closing and not self._all_in.is_set():
                # a rank died mid-rendezvous: the job cannot form — fail
                # loudly instead of leaving a half-dead router thread behind
                warnings.warn(
                    f"router: rendezvous failed ({e!r}); closing all connections",
                    RuntimeWarning,
                )
                with self._lock:
                    conns = list(self._conns.values())
                for c in conns:
                    c.close()
        finally:
            try:
                self._listener.close()
            except OSError:  # pragma: no cover - already closed
                pass
        for t in list(self._readers):
            t.join()

    def _start_reader(self, rank: int, conn: socket.socket) -> None:
        t = threading.Thread(
            target=self._forward_from, args=(rank, conn),
            name=f"sproute-{rank}", daemon=True,
        )
        self._readers.append(t)
        t.start()

    def soft_close(self) -> None:
        """Stop accepting and monitoring; live peer↔peer forwarding keeps
        running until each peer hangs up (rank 0 may finish first)."""
        self._closing = True
        try:
            self._listener.close()
        except OSError:  # pragma: no cover - already closed
            pass

    # -- data plane ----------------------------------------------------------

    def _forward_from(self, rank: int, conn: socket.socket) -> None:
        try:
            while True:
                head = _recv_exact(conn, 4)
                (n,) = _U32.unpack(head)
                body = _recv_exact(conn, n)
                _src, dst, taglen = _FRAME_HDR.unpack_from(body, 0)
                if dst == _CTRL_RANK:
                    off = _FRAME_HDR.size
                    ctrl = decode_message(body[off : off + taglen])
                    with self._lock:
                        if ctrl[1] == "hb":
                            self._last_seen[rank] = time.monotonic()
                        elif ctrl[1] == "bye":
                            self._graceful.add(rank)
                    continue
                with self._lock:
                    out = self._conns.get(dst)
                    lock = self._send_locks.get(dst)
                if out is None:
                    continue  # dst gone (dead or departed): drop the frame
                try:
                    with lock:
                        out.sendall(head + body)
                except OSError:
                    continue  # dst hung up mid-forward; its own EOF handles it
                with self._fwd_lock:
                    self.forwarded += 1
        except (ConnectionError, OSError):
            pass  # rank hung up; in-flight traffic for it is already queued
        finally:
            with self._lock:
                graceful = rank in self._graceful
                current = self._conns.get(rank) is conn
                if current:
                    del self._conns[rank]
                    self._send_locks.pop(rank, None)
            try:
                conn.close()
            except OSError:  # pragma: no cover - already closed
                pass
            if current and not graceful and not self._closing:
                # EOF without a goodbye: the process died under us
                self._declare_dead(rank, "connection lost without goodbye")

    # -- failure detector ----------------------------------------------------

    def _monitor(self) -> None:
        interval = max(self._hb_timeout / 4.0, 0.02)
        while not self._closing:
            time.sleep(interval)
            now = time.monotonic()
            with self._lock:
                stale = [
                    r
                    for r, seen in self._last_seen.items()
                    if r in self._conns
                    and r not in self._graceful
                    and r not in self.dead
                    and now - seen > self._hb_timeout
                ]
            for r in stale:
                self._declare_dead(
                    r, f"no heartbeat for more than {self._hb_timeout}s"
                )

    def _declare_dead(self, rank: int, why: str) -> None:
        with self._lock:
            if rank in self.dead:
                return
            self.dead.add(rank)
            conn = self._conns.pop(rank, None)
            self._send_locks.pop(rank, None)
            targets = [
                (r, self._conns[r], self._send_locks[r]) for r in self._conns
            ]
        if conn is not None:
            try:
                conn.close()
            except OSError:  # pragma: no cover - already closed
                pass
        warnings.warn(
            f"router: declaring rank {rank} dead ({why})", RuntimeWarning
        )
        tag_b = encode_message(("__spctrl__", "dead", rank))
        for r, c, lk in targets:
            body = _FRAME_HDR.pack(_CTRL_RANK, r, len(tag_b)) + tag_b
            try:
                with lk:
                    c.sendall(_U32.pack(len(body)) + body)
            except OSError:  # pragma: no cover - survivor also going away
                pass


class RouterTransport(_LockedMailboxes):
    """LEGACY hub-and-spoke TCP transport — every frame is forwarded
    through rank 0's :class:`_StarRouter`.  Kept verbatim as the measured
    baseline for ``benchmarks/comm_bench.py``; all production paths use
    the peer-to-peer :class:`SocketTransport`."""

    def __init__(
        self,
        rank: int,
        size: int,
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        connect_timeout: float = 10.0,
        max_dial_retries: int = 100,
        heartbeat: float | None = None,
        staleness_factor: float | None = None,
        heartbeat_interval: float | None = None,
        heartbeat_timeout: float | None = None,
    ):
        super().__init__()
        interval, heartbeat_timeout = _resolve_hb_knobs(
            heartbeat, staleness_factor, heartbeat_interval, heartbeat_timeout
        )
        self.rank, self.size, self.host = rank, size, host
        self._received = 0
        self._closed = False
        self._router: Optional[_StarRouter] = None
        if rank == 0:
            self._router = _StarRouter(host, port, size, heartbeat_timeout=heartbeat_timeout)
            self._router.start()
            port = self._router.port
        elif port == 0:
            raise ValueError("non-root ranks must be told the rendezvous port")
        self.port = port

        # rank 0 may not be listening yet — dial with a bounded retry count
        # and exponential backoff instead of hammering until connect_timeout
        deadline = time.monotonic() + connect_timeout
        delay, attempts = 0.01, 0
        while True:
            try:
                self._sock = socket.create_connection((host, port), timeout=connect_timeout)
                break
            except OSError as e:
                attempts += 1
                if attempts >= max_dial_retries or time.monotonic() + delay > deadline:
                    raise SpCommError(
                        f"rank {rank}: rendezvous at {host}:{port} unreachable "
                        f"after {attempts} dial attempts over "
                        f"{connect_timeout}s ({e})"
                    ) from e
                time.sleep(delay)
                delay = min(delay * 2.0, 0.5)
        # create_connection leaves connect_timeout armed on the socket;
        # clear it or an idle gap longer than that kills the receiver
        # thread with a swallowed socket.timeout (an OSError subclass)
        self._sock.settimeout(None)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._sock.sendall(_U32.pack(rank))  # hello
        self._wlock = threading.Lock()
        self._reader = threading.Thread(
            target=self._recv_loop, name=f"sprecv-{rank}", daemon=True
        )
        self._reader.start()
        self._hb_interval = interval
        self._hb_stop = threading.Event()
        self._hb = threading.Thread(
            target=self._hb_loop, name=f"sphb-{rank}", daemon=True
        )
        self._hb.start()

    # -- wire side (receiver thread only) ------------------------------------

    def _recv_loop(self) -> None:
        try:
            while True:
                (n,) = _U32.unpack(_recv_exact(self._sock, 4))
                body = _recv_exact(self._sock, n)
                src, _dst, taglen = _FRAME_HDR.unpack_from(body, 0)
                off = _FRAME_HDR.size
                tag_b = body[off : off + taglen]
                if src == _CTRL_RANK:  # router control plane
                    ctrl = decode_message(tag_b)
                    if ctrl[1] == "dead":
                        self.mark_dead(ctrl[2])
                    continue
                msg = decode_message(body[off + taglen :])
                self._deposit((src, self.rank, tag_b), msg, "_received")
        except (ConnectionError, OSError):
            # transport closed.  If *we* did not close it, the router (and
            # with it rank 0) is gone: the star cannot route anything any
            # more, so every peer is effectively dead from here
            if not self._closed:
                for r in range(self.size):
                    if r != self.rank:
                        self.mark_dead(r)

    # -- control plane -------------------------------------------------------

    def _send_ctrl(self, kind: str) -> None:
        tag_b = encode_message(("__spctrl__", kind))
        body = _FRAME_HDR.pack(self.rank, _CTRL_RANK, len(tag_b)) + tag_b
        with self._wlock:
            self._sock.sendall(_U32.pack(len(body)) + body)

    def _hb_loop(self) -> None:
        while not self._hb_stop.wait(self._hb_interval):
            try:
                self._send_ctrl("hb")
            except OSError:
                return  # wire gone; the receiver thread handles the fallout

    # -- mailbox side ---------------------------------------------------------

    def _box_key(self, key: tuple) -> tuple:
        src, dst, tag = key
        return (src, dst, _tag_bytes(tag))

    def _send_frame(self, key: tuple, payload: bytes) -> None:
        src, dst, tag = key
        with self._lock:
            dead = dst in self._dead
        if dead:
            raise SpRankDeadError(f"cannot send to rank {dst}: rank is dead")
        tag_b = _tag_bytes(tag)
        body = _FRAME_HDR.pack(src, dst, len(tag_b)) + tag_b + payload
        try:
            with self._wlock:
                self._sock.sendall(_U32.pack(len(body)) + body)
                self._posted += 1
        except OSError as e:
            raise SpCommError(
                f"socket send to rank {dst} failed: wire to the router is "
                f"down ({e})"
            ) from e

    def post(self, key: tuple, msg: Any) -> None:
        self._send_frame(key, encode_message(msg))

    def post_all(self, keys: list, msg: Any) -> None:
        # broadcast fan-out: serialize once, frame per destination
        payload = encode_message(msg)
        for key in keys:
            self._send_frame(key, payload)

    def stats(self) -> dict:
        out = super().stats()
        out["received"] = self._received
        return out

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._hb_stop.set()
        try:
            self._send_ctrl("bye")  # graceful leave: not a death
        except OSError:
            pass
        if self._router is not None:
            self._router.soft_close()
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()
        self._reader.join(timeout=2.0)
        self._hb.join(timeout=2.0)
        if self._router is not None:
            self._router.join(timeout=2.0)

    def __enter__(self) -> "RouterTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class SpCommGroup:
    """A communicator: (transport, rank, size) — one per Specx 'instance'.

    ``hub`` may be any :class:`SpTransport`; the in-process default is the
    module-wide :func:`default_hub`.  ``default_timeout`` (seconds) applies
    to every receive issued through this group unless the call overrides it.

    ``members`` (default ``range(size)``) is the *logical* membership: the
    physical ranks participating in this group's collectives, in logical
    order.  ``rank`` / ``size`` stay physical — they are wire identity —
    while ring neighbours etc. are computed in logical coordinates and
    translated via :meth:`to_physical`.  After a rank death, survivors call
    :meth:`shrunk` to get a group over the remaining members without
    re-bootstrapping the transport (the live-reshard recovery path)."""

    def __init__(
        self,
        rank: int,
        size: int,
        hub: SpTransport | None = None,
        *,
        default_timeout: float | None = None,
        members: Sequence[int] | None = None,
    ):
        self.rank = rank
        self.size = size
        self.hub = hub if hub is not None else default_hub()
        self.default_timeout = default_timeout
        self.members = tuple(members) if members is not None else tuple(range(size))
        if rank not in self.members:
            raise ValueError(
                f"rank {rank} is not one of this group's members {self.members}"
            )
        self._logical_rank = self.members.index(rank)
        self._bcast_seq = 0  # paper: same broadcasts, same order on all ranks

    @property
    def transport(self) -> SpTransport:
        return self.hub

    # -- logical coordinates (shrink-aware collectives) -----------------------

    @property
    def logical_size(self) -> int:
        return len(self.members)

    @property
    def logical_rank(self) -> int:
        return self._logical_rank

    def to_physical(self, logical_rank: int) -> int:
        return self.members[logical_rank % len(self.members)]

    def shrunk(self, dead: Sequence[int]) -> "SpCommGroup":
        """A new group over the surviving members (same transport, same
        physical identity); broadcast sequencing carries over so survivors
        stay aligned."""
        gone = set(dead)
        members = tuple(r for r in self.members if r not in gone)
        if self.rank in gone or self.rank not in members:
            raise SpCommError(
                f"rank {self.rank} is itself in the dead set {sorted(gone)}"
            )
        if not members:
            raise SpCommError("no members survive")
        g = SpCommGroup(
            self.rank,
            self.size,
            self.hub,
            default_timeout=self.default_timeout,
            members=members,
        )
        g._bcast_seq = self._bcast_seq
        return g


# ---------------------------------------------------------------------------
# Non-blocking requests.
# ---------------------------------------------------------------------------

class CommRequest:
    def test(self) -> bool:
        raise NotImplementedError

    def timed_out(self) -> bool:
        return False

    def timeout_error(self) -> SpCommError:  # pragma: no cover - overridden
        return SpCommTimeoutError("communication request timed out")

    def complete(self) -> None:
        pass


class _DoneRequest(CommRequest):
    def test(self) -> bool:
        return True


class _RecvRequest(CommRequest):
    def __init__(self, transport: SpTransport, key: tuple, ref, timeout: float | None = None):
        self.transport = transport
        self.key = key
        self.ref = ref
        self._msg = None
        self._have = False
        self._deadline = None if timeout is None else time.monotonic() + timeout
        self._timeout = timeout

    def test(self) -> bool:
        if not self._have:
            try:
                ok, msg = self.transport.poll(self.key)
            except SpRankDeadError as e:
                src, dst, tag = self.key
                raise SpRankDeadError(
                    f"recv(src={src}, dst={dst}, tag={tag!r}) can never "
                    f"complete: {e}"
                ) from e
            if ok:
                self._msg = msg
                self._have = True
        return self._have

    def timed_out(self) -> bool:
        return (
            not self._have
            and self._deadline is not None
            and time.monotonic() > self._deadline
        )

    def timeout_error(self) -> SpCommError:
        src, dst, tag = self.key
        return SpCommTimeoutError(
            f"recv(src={src}, dst={dst}, tag={tag!r}) saw no message within "
            f"{self._timeout}s — peer never posted?"
        )

    def complete(self) -> None:
        self.ref.value = unpack(self._msg)


# ---------------------------------------------------------------------------
# Comm task constructors.
# ---------------------------------------------------------------------------

def _no_spec(graph: SpTaskGraph) -> None:
    if graph.spec_model is not SpSpeculativeModel.SP_NO_SPEC:
        raise ValueError(
            "MPI-style communications are incompatible with speculative "
            "execution (paper §4.4); use a SP_NO_SPEC graph."
        )


def mpi_send(graph: SpTaskGraph, group: SpCommGroup, x: SpData, dest: int, tag) -> TaskView:
    _no_spec(graph)
    acc = SpAccess(x, AccessMode.READ)
    task = Task({"ref": lambda v: None}, [acc], [("single", acc)],
                name=f"send(to={dest},tag={tag})", is_comm=True, cost=0.1)

    def start(args):
        group.hub.post((group.rank, dest, tag), pack(args[0]))
        return _DoneRequest()

    task.comm_start = start
    return graph._insert(task)


def mpi_recv(
    graph: SpTaskGraph,
    group: SpCommGroup,
    x: SpData,
    src: int,
    tag,
    *,
    timeout: float | None = None,
) -> TaskView:
    _no_spec(graph)
    eff_timeout = timeout if timeout is not None else group.default_timeout
    acc = SpAccess(x, AccessMode.WRITE)
    task = Task({"ref": lambda v: None}, [acc], [("single", acc)],
                name=f"recv(from={src},tag={tag})", is_comm=True, cost=0.1)

    def start(args):
        return _RecvRequest(group.hub, (src, group.rank, tag), args[0], eff_timeout)

    task.comm_start = start
    return graph._insert(task)


def mpi_broadcast(
    graph: SpTaskGraph,
    group: SpCommGroup,
    x: SpData,
    root: int,
    *,
    timeout: float | None = None,
) -> TaskView:
    """Paper: Specx supports MPI broadcast; all instances must issue the same
    broadcasts in the same order — enforced via a per-group sequence tag."""
    _no_spec(graph)
    seq = group._bcast_seq
    group._bcast_seq += 1
    tag = ("bcast", seq)
    eff_timeout = timeout if timeout is not None else group.default_timeout
    if group.rank == root:
        acc = SpAccess(x, AccessMode.READ)
        task = Task({"ref": lambda v: None}, [acc], [("single", acc)],
                    name=f"bcast(root={root},seq={seq})", is_comm=True, cost=0.1)

        def start(args):
            msg = pack(args[0])
            group.hub.post_all(
                [(root, r, tag) for r in group.members if r != root], msg
            )
            return _DoneRequest()

        task.comm_start = start
    else:
        acc = SpAccess(x, AccessMode.WRITE)
        task = Task({"ref": lambda v: None}, [acc], [("single", acc)],
                    name=f"bcast(root={root},seq={seq})", is_comm=True, cost=0.1)

        def start(args):
            return _RecvRequest(group.hub, (root, group.rank, tag), args[0], eff_timeout)

        task.comm_start = start
    return graph._insert(task)


# ---------------------------------------------------------------------------
# The background progress thread (one per engine).
# ---------------------------------------------------------------------------

class CommThread(threading.Thread):
    """Starts non-blocking ops and polls a request list — the analogue of the
    paper's MPI thread calling test-any in a loop.

    Lifecycle: :meth:`stop` first waits ``grace`` seconds for in-flight
    requests to drain; if the loop is still busy after that, it *aborts*
    the remaining requests — each affected task fails with
    :class:`SpCommAbortedError` (so waiters unblock and see the error) and
    ``stop`` returns their names instead of silently leaking a daemon
    thread with live requests."""

    _ids = iter(range(1 << 20))

    def __init__(self, engine):
        super().__init__(name=f"spcomm-{next(CommThread._ids)}", daemon=True)
        self.engine = engine
        self._incoming: collections.deque[Task] = collections.deque()
        self._cv = threading.Condition()
        self._running = True
        self._abort = False
        self.aborted: list[str] = []

    def submit(self, task: Task) -> None:
        with self._cv:
            self._incoming.append(task)
            self._cv.notify()

    def _cancel_cascade(self, tasks: list) -> None:
        """Transitively cancel released successors: used whenever work
        becomes ready but no worker will ever run it (engine stopped, or
        the request it depended on was aborted) — otherwise
        ``wait_all_tasks`` hangs forever on any chain behind a dead comm
        task."""
        stack = list(tasks)
        while stack:
            t = stack.pop()
            t.mark_cancelled()
            g = getattr(t, "graph", None)
            if g is not None:
                stack.extend(g.on_task_finished(t))

    def _complete(self, task: Task, *, dispatch: bool) -> None:
        """Common completion path: stamp the end time, trace, release
        dependencies, wake waiters.  Successors are dispatched only for a
        *successful* request on a still-running engine; a failed request
        (timeout, start error, abort) cancels them transitively instead —
        their input never arrived, running them would silently propagate
        garbage — and so does a completion landing inside ``stop()``'s
        grace window, when no worker is left to pop the queue."""
        task.t_end = time.perf_counter()
        graph = getattr(task, "graph", None)
        if graph is None:  # pragma: no cover - tasks always carry a graph
            task.mark_finished()
            return
        if getattr(graph, "trace", True):
            graph.trace_events.append(
                {
                    "task": task.name,
                    "uid": task.uid,
                    "worker": self.name,
                    "t0": task.t_start,
                    "t1": task.t_end,
                    "ready": 0,
                    "comm": True,
                    "spec": False,
                }
            )
        newly = graph.on_task_finished(task)
        task.mark_finished()
        if newly:
            if dispatch and getattr(self.engine, "_running", True):
                self.engine.push_many(newly)
            else:
                self._cancel_cascade(newly)

    def _finish(self, task: Task) -> None:
        self._complete(task, dispatch=True)

    def _fail(self, task: Task, exc: BaseException) -> None:
        task.exception = exc
        self._complete(task, dispatch=False)

    def run(self) -> None:
        in_flight: list[tuple[Task, CommRequest, list]] = []
        while True:
            starts: list[Task] = []
            with self._cv:
                if self._abort:
                    break
                if not self._running and not self._incoming and not in_flight:
                    return
                while self._incoming:
                    starts.append(self._incoming.popleft())
                if not in_flight and not starts and self._running:
                    self._cv.wait(timeout=0.05)
                    continue
            # start requests OUTSIDE the lock: a socket send can block on a
            # full kernel buffer, and _fail releases dependencies, which may
            # re-enter submit() — neither may happen while holding _cv
            for task in starts:
                task.state = TaskState.RUNNING
                task.t_start = time.perf_counter()
                try:
                    args, writebacks = task.build_args()
                    req = task.comm_start(args)
                except BaseException as e:
                    self._fail(task, e)
                    continue
                in_flight.append((task, req, writebacks))
            progressed = False
            for item in list(in_flight):
                task, req, writebacks = item
                try:
                    done = req.test()
                except BaseException as e:
                    self._fail(task, e)
                    in_flight.remove(item)
                    progressed = True
                    continue
                if done:
                    req.complete()
                    for acc, ref in writebacks:
                        acc.data.value = ref.value
                    self._finish(task)
                    in_flight.remove(item)
                    progressed = True
                elif req.timed_out():
                    self._fail(task, req.timeout_error())
                    in_flight.remove(item)
                    progressed = True
            if not progressed and in_flight:
                time.sleep(0.0005)
        # abort path: fail whatever is still queued or in flight so waiters
        # unblock and stop() can report it
        with self._cv:
            pending = list(self._incoming)
            self._incoming.clear()
        for task, _req, _wb in in_flight:
            self.aborted.append(task.name)
            self._fail(task, SpCommAbortedError(
                f"comm thread stopped with {task.name!r} still in flight"))
        for task in pending:
            self.aborted.append(task.name)
            task.t_start = task.t_start or time.perf_counter()
            self._fail(task, SpCommAbortedError(
                f"comm thread stopped before {task.name!r} started"))

    def stop(self, grace: float = 2.0) -> list[str]:
        """Stop the thread; returns the names of aborted tasks ([] when the
        loop drained cleanly within ``grace`` seconds)."""
        was_alive = self.is_alive()
        with self._cv:
            self._running = False
            self._cv.notify()
        self.join(timeout=grace)
        if self.is_alive():
            with self._cv:
                self._abort = True
                self._cv.notify()
            self.join(timeout=2.0)
        if self.aborted and was_alive:
            warnings.warn(
                f"CommThread stopped with in-flight requests aborted: "
                f"{self.aborted}",
                RuntimeWarning,
                stacklevel=2,
            )
        if self.is_alive():  # pragma: no cover - stuck in a blocking send
            warnings.warn(
                "CommThread failed to exit within the grace period",
                RuntimeWarning,
                stacklevel=2,
            )
        return list(self.aborted)

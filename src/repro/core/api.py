"""Codelet frontend — declare a task once, run it anywhere (paper §4.1, §4.3).

Specx's headline API idea is that a task is *declared* with its access modes
and carries multiple implementations (``SpCpu`` / ``SpCuda``) among which the
runtime selects per processing unit — StarPU's codelets, adapted.  This
module is that frontend for the JAX reproduction:

* :func:`sp_task` — a decorator that turns a plain function into a reusable
  :class:`SpCodelet` with *named argument slots*::

      @sp_task(read=("a",), write=("b",))
      def axpy(a, b, *, alpha=2.0):
          b.value = b.value + alpha * a

  or, equivalently, with typed annotations (``SpRead`` / ``SpWrite`` /
  ``SpCommutativeWrite`` / ``SpMaybeWrite`` / ``SpAtomicWrite``)::

      @sp_task
      def axpy(a: SpRead, b: SpWrite, *, alpha=2.0): ...

  Parameters not named in an access spec are *static parameters*, partially
  applied at call time (``axpy(a_cell, b_cell, alpha=3.0)``).

* :meth:`SpCodelet.impl` — register additional implementation variants with
  capability predicates (the SpCpu/SpCuda selection from the paper)::

      @axpy.impl("pallas", available=pallas_available)
      def _(a, b, *, alpha=2.0): ...

  At *call* time the codelet keeps only the variants whose ``available()``
  probe passes; on the eager engine the executing worker's kind picks among
  them, on the staged path the platform does.

* :class:`SpRuntime` — one entry point over both execution backends.  The
  same user code runs threaded-eager or compiled-staged by flipping one
  argument::

      with SpRuntime(backend="eager", workers=4) as rt:   # or backend="staged"
          view = axpy(a_cell, b_cell)
          print(view.result())

  The runtime is a context manager; inside its scope (or an explicit
  :func:`graph_scope`) codelet calls insert tasks into the current graph and
  return future-like :class:`~repro.core.task.TaskView` objects
  (``result()`` / ``done()`` / ``exception()`` / ``then()``).

The positional ``tg.task(SpRead(a), SpWrite(b), fn)`` spelling remains as a
compatibility shim over the same insertion path (``SpTaskGraph.insert_task``).
"""
from __future__ import annotations

import contextlib
import contextvars
import functools
import inspect
import time
from typing import Any, Callable, Optional, Sequence

from .access import (
    AccessMode,
    SpAccess,
    SpAtomicWrite,
    SpCommutativeWrite,
    SpData,
    SpMaybeWrite,
    SpRead,
    SpWrite,
)
from .graph import SpSpeculativeModel, SpTaskGraph
from .task import SpTaskPolicy, TaskView

# ---------------------------------------------------------------------------
# Current-graph scope.
# ---------------------------------------------------------------------------

_scope: contextvars.ContextVar[Optional[SpTaskGraph]] = contextvars.ContextVar(
    "sp_graph_scope", default=None
)


def current_graph() -> Optional[SpTaskGraph]:
    """The innermost active graph scope (None outside any scope)."""
    return _scope.get()


@contextlib.contextmanager
def graph_scope(graph: SpTaskGraph):
    """Make ``graph`` the insertion target for codelet calls in the block."""
    token = _scope.set(graph)
    try:
        yield graph
    finally:
        _scope.reset(token)


# ---------------------------------------------------------------------------
# Slot declaration.
# ---------------------------------------------------------------------------

class SpSlot:
    """One named argument slot of a codelet: (parameter name, access mode)."""

    __slots__ = ("name", "mode")

    def __init__(self, name: str, mode: AccessMode):
        self.name = name
        self.mode = mode

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"SpSlot({self.name!r}, {self.mode.name})"


#: Annotation spellings accepted by the bare-decorator form.  The access
#: constructors themselves double as type markers; strings cover modules with
#: ``from __future__ import annotations`` (where annotations are strings).
_ANNOTATION_MODES: dict[Any, AccessMode] = {
    SpRead: AccessMode.READ,
    SpWrite: AccessMode.WRITE,
    SpCommutativeWrite: AccessMode.COMMUTATIVE_WRITE,
    SpMaybeWrite: AccessMode.MAYBE_WRITE,
    SpAtomicWrite: AccessMode.ATOMIC_WRITE,
    "SpRead": AccessMode.READ,
    "SpWrite": AccessMode.WRITE,
    "SpCommutativeWrite": AccessMode.COMMUTATIVE_WRITE,
    "SpMaybeWrite": AccessMode.MAYBE_WRITE,
    "SpAtomicWrite": AccessMode.ATOMIC_WRITE,
    "read": AccessMode.READ,
    "write": AccessMode.WRITE,
    "commutative": AccessMode.COMMUTATIVE_WRITE,
    "maybe": AccessMode.MAYBE_WRITE,
    "atomic": AccessMode.ATOMIC_WRITE,
}

for _mode in AccessMode:
    _ANNOTATION_MODES[_mode] = _mode


def _mode_from_annotation(ann: Any) -> Optional[AccessMode]:
    if ann is inspect.Parameter.empty:
        return None
    if isinstance(ann, str):
        ann = ann.strip()
    try:
        return _ANNOTATION_MODES.get(ann)
    except TypeError:  # unhashable annotation
        return None


def _as_names(spec) -> tuple[str, ...]:
    if spec is None:
        return ()
    if isinstance(spec, str):
        return (spec,)
    return tuple(spec)


def _positional_params(fn: Callable) -> list[inspect.Parameter]:
    sig = inspect.signature(fn)
    return [
        p
        for p in sig.parameters.values()
        if p.kind
        in (inspect.Parameter.POSITIONAL_ONLY, inspect.Parameter.POSITIONAL_OR_KEYWORD)
    ]


def _build_slots(
    fn: Callable,
    read,
    write,
    commutative,
    maybe,
    atomic,
) -> tuple[list[SpSlot], set[str], bool]:
    """Derive (slots-in-signature-order, static parameter names, has **kwargs)."""
    mode_of: dict[str, AccessMode] = {}
    for names, mode in (
        (read, AccessMode.READ),
        (write, AccessMode.WRITE),
        (commutative, AccessMode.COMMUTATIVE_WRITE),
        (maybe, AccessMode.MAYBE_WRITE),
        (atomic, AccessMode.ATOMIC_WRITE),
    ):
        for n in _as_names(names):
            if n in mode_of:
                raise ValueError(f"parameter {n!r} declared under two access modes")
            mode_of[n] = mode

    params = _positional_params(fn)
    slots: list[SpSlot] = []
    if mode_of:
        by_name = {p.name for p in params}
        unknown = [n for n in mode_of if n not in by_name]
        if unknown:
            raise ValueError(
                f"access spec names {unknown} are not positional parameters of "
                f"{getattr(fn, '__name__', fn)!r}"
            )
        slots = [SpSlot(p.name, mode_of[p.name]) for p in params if p.name in mode_of]
    else:
        for p in params:
            mode = _mode_from_annotation(p.annotation)
            if mode is not None:
                slots.append(SpSlot(p.name, mode))
        if not slots:
            raise ValueError(
                f"codelet {getattr(fn, '__name__', fn)!r} declares no data slots; "
                "pass read=/write=/... or annotate parameters with SpRead/SpWrite/..."
            )

    slot_names = {s.name for s in slots}
    sig = inspect.signature(fn)
    static = {
        p.name
        for p in sig.parameters.values()
        if p.name not in slot_names
        and p.kind
        not in (inspect.Parameter.VAR_POSITIONAL, inspect.Parameter.VAR_KEYWORD)
    }
    has_var_kw = any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in sig.parameters.values()
    )
    return slots, static, has_var_kw


# ---------------------------------------------------------------------------
# The codelet.
# ---------------------------------------------------------------------------

class SpCodelet:
    """A reusable task declaration: named slots + one impl per kind.

    Built by :func:`sp_task`; additional implementation variants register
    through :meth:`impl`.  Calling the codelet binds :class:`SpData` cells
    (or sequences of cells — an array slot) to the slots and inserts one
    task into the current graph scope, returning its :class:`TaskView`.
    """

    #: call-time keywords reserved for the runtime (never static params)
    RESERVED = (
        "graph", "name", "priority", "cost", "result",
        "retries", "retry_backoff", "timeout", "on_failure",
    )

    def __init__(
        self,
        fn: Callable,
        slots: Sequence[SpSlot],
        *,
        static: set[str],
        has_var_kw: bool = False,
        name: str | None = None,
        cost: float = 1.0,
        priority: int = 0,
        comm: bool = False,
        policy: SpTaskPolicy | None = None,
        result: bool = True,
    ):
        self.name = name or getattr(fn, "__name__", "codelet")
        self.slots = list(slots)
        self.cost = cost
        self.priority = priority
        self.comm = comm
        self.result = result  # declare-time default for the hidden result cell
        self.policy = policy  # default robustness policy for inserted tasks
        self.__doc__ = getattr(fn, "__doc__", None)
        self._static = set(static)
        self._has_var_kw = has_var_kw
        # kind -> (callable, availability predicate or None)
        self._impls: dict[str, tuple[Callable, Optional[Callable[[], bool]]]] = {
            "ref": (fn, None)
        }

    # ------------------------------------------------------------ registration

    def impl(self, kind: str, fn: Callable | None = None, *, available=None):
        """Register an implementation variant for ``kind``.

        Usable as a decorator (``@cl.impl("pallas", available=probe)``) or
        directly (``cl.impl("host", host_fn)``).  ``available`` is a zero-arg
        capability probe evaluated at *call* time; an unavailable variant is
        excluded from that call's dispatch table.
        """

        def register(f: Callable):
            self._impls[kind] = (f, available)
            return f

        if fn is not None:
            register(fn)
            return self
        return register

    @property
    def impl_kinds(self) -> list[str]:
        """Registered implementation kinds (regardless of availability)."""
        return sorted(self._impls)

    def available_kinds(self) -> list[str]:
        """Kinds whose capability probe passes right now."""
        return sorted(
            kind
            for kind, (_, avail) in self._impls.items()
            if avail is None or avail()
        )

    # --------------------------------------------------------------- insertion

    def __call__(self, *args, **kwargs) -> TaskView:
        graph = kwargs.pop("graph", None)
        if graph is None:
            graph = current_graph()
        if graph is None:
            raise RuntimeError(
                f"codelet {self.name!r} called outside a graph scope; enter an "
                "SpRuntime (`with SpRuntime(...)`) or graph_scope(tg), or pass "
                "graph=<SpTaskGraph>"
            )
        name = kwargs.pop("name", None) or self.name
        priority = kwargs.pop("priority", self.priority)
        cost = kwargs.pop("cost", self.cost)
        want_result = bool(kwargs.pop("result", self.result))
        # per-call robustness overrides (ISSUE 8); default to the codelet's
        # declared policy
        policy = self.policy
        if any(k in kwargs for k in ("retries", "retry_backoff", "timeout", "on_failure")):
            base = policy
            policy = SpTaskPolicy(
                retries=kwargs.pop(
                    "retries", base.retries if base is not None else 0
                ),
                retry_backoff=kwargs.pop(
                    "retry_backoff", base.retry_backoff if base is not None else 0.0
                ),
                timeout=kwargs.pop(
                    "timeout", base.timeout if base is not None else None
                ),
                on_failure=kwargs.pop(
                    "on_failure", base.on_failure if base is not None else None
                ),
            )

        # -- bind slots (positional first, then by name) ---------------------
        if len(args) > len(self.slots):
            raise TypeError(
                f"{self.name} takes {len(self.slots)} data slots, got "
                f"{len(args)} positional arguments"
            )
        bound: dict[str, Any] = {}
        for slot, val in zip(self.slots, args):
            bound[slot.name] = val
        for slot in self.slots:
            if slot.name in kwargs:
                if slot.name in bound:
                    raise TypeError(f"{self.name}: slot {slot.name!r} bound twice")
                bound[slot.name] = kwargs.pop(slot.name)
        missing = [s.name for s in self.slots if s.name not in bound]
        if missing:
            raise TypeError(f"{self.name}: missing data slots {missing}")

        static = kwargs  # everything left over is a static parameter
        if not self._has_var_kw:
            unknown = sorted(set(static) - self._static)
            if unknown:
                raise TypeError(
                    f"{self.name}: unknown static parameters {unknown}; "
                    f"declared: {sorted(self._static)} "
                    f"(reserved call keywords: {list(self.RESERVED)})"
                )

        # -- build accesses / argument layout --------------------------------
        accesses: list[SpAccess] = []
        arg_layout: list[tuple[str, Any]] = []
        for slot in self.slots:
            val = bound[slot.name]
            if isinstance(val, SpData):
                acc = SpAccess(val, slot.mode)
                accesses.append(acc)
                arg_layout.append(("single", acc))
            elif isinstance(val, (list, tuple)):
                accs = [SpAccess(v, slot.mode) for v in val]
                accesses.extend(accs)
                arg_layout.append(("array", accs))
            else:
                raise TypeError(
                    f"{self.name}: slot {slot.name!r} takes an SpData cell or a "
                    f"sequence of cells, got {type(val).__name__}. "
                    f"Wrap your value: x = SpData(value, {slot.name!r})."
                )
        result_cell = None
        if want_result:
            # the hidden result cell .then()/.result() chaining hangs off;
            # fire-and-forget calls (result=False) skip the cell, its WRITE
            # access, and the per-call SpData allocation entirely
            result_cell = SpData(None, f"{name}.result")
            res_acc = SpAccess(result_cell, AccessMode.WRITE)
            accesses.append(res_acc)
            arg_layout.append(("single", res_acc))

        # -- capability dispatch: keep variants whose probe passes now -------
        impls: dict[str, Callable] = {}
        for kind, (fn, avail) in self._impls.items():
            if avail is not None and not avail():
                continue
            impls[kind] = _wrap_body(fn, static, with_result=want_result)
        if not impls:
            raise RuntimeError(
                f"codelet {self.name!r}: no implementation available here "
                f"(registered kinds: {self.impl_kinds})"
            )
        if "pallas" in impls:
            preferred = "pallas"
        elif "ref" in impls:
            preferred = "ref"
        else:
            preferred = next(iter(impls))

        view = graph.insert_task(
            impls,
            accesses,
            arg_layout,
            priority=priority,
            name=name,
            cost=cost,
            comm=self.comm,
        )
        view.task.result_cell = result_cell
        view.task.preferred_kind = preferred
        view.task.policy = policy
        return view

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        spec = ", ".join(f"{s.name}:{s.mode.name.lower()}" for s in self.slots)
        return f"SpCodelet({self.name!r}, [{spec}], impls={self.impl_kinds})"


def _wrap_body(fn: Callable, static: dict, *, with_result: bool = True) -> Callable:
    """Adapt a codelet body to the Task calling convention: the runtime
    appends a hidden result slot (written with the body's return value so
    TaskView.then() chaining has a data-flow edge to hang off).  With
    ``with_result=False`` there is no hidden slot — the body runs on the
    user arguments alone (the fire-and-forget fast path)."""
    if static:
        fn = functools.partial(fn, **static)
    if not with_result:
        return fn  # no hidden slot to pop: the body is the task body

    def body(*task_args):
        *user_args, res_ref = task_args
        out = fn(*user_args)
        res_ref.value = out
        return out

    return body


def sp_task(
    fn: Callable | None = None,
    *,
    read=(),
    write=(),
    commutative=(),
    maybe=(),
    atomic=(),
    name: str | None = None,
    cost: float = 1.0,
    priority: int = 0,
    comm: bool = False,
    result: bool = True,
    retries: int = 0,
    retry_backoff: float = 0.0,
    timeout: float | None = None,
    on_failure: str | None = None,
):
    """Declare a codelet (see module docstring).

    With access-spec keywords, the named positional parameters become data
    slots in signature order; without them, parameters annotated with
    ``SpRead``/``SpWrite``/... become the slots.  All other parameters are
    static and supplied at call time.  ``comm=True`` marks every inserted
    task as a communication task (scheduling hint, see ``SpTaskGraph.task``).

    ``result=False`` declares the codelet fire-and-forget (ISSUE 10 perf
    satellite): calls skip the hidden result cell, its WRITE access, and
    the return-value capture, shaving per-dispatch overhead for bodies
    whose effect is entirely through their ``write=`` slots.  On such a
    view ``.then()`` / ``.result()`` raise — chain off a written cell
    instead.  Either default can be overridden per call:
    ``codelet(x, y, result=False)``.

    Robustness policy (ISSUE 8): ``retries``/``retry_backoff`` re-run a
    raising body (exponential backoff between attempts), ``timeout`` arms
    the engine watchdog that fails a hung body with ``SpTaskTimeoutError``,
    and ``on_failure`` picks what a terminal failure does — ``"raise"``
    (park the error for ``wait_all_tasks``), ``"retry"`` (the default once
    ``retries > 0``), or ``"quarantine"`` (keep the graph alive: record the
    task on ``graph.quarantined``, cancel dependents with
    ``CancelledError``, let siblings finish).  Every knob can be overridden
    per call: ``codelet(x, y, retries=3, timeout=0.5)``.

    Speculation (ISSUE 9): a ``maybe=`` slot makes every inserted task an
    *uncertain writer* — on a graph built with ``SP_MODEL_1``/``SP_MODEL_2``
    a later codelet reading that cell is speculated past it (chains of
    maybe-writers share one snapshot under ``SP_MODEL_2``; see
    ``core/speculation.py``).  A body that leaves the slot untouched
    resolves as "did not write"; assigning ``slot.value`` — even its own
    current value — forces the reader's rollback re-execution.  Because a
    speculated body may run twice, it must be pure in everything except
    idempotent effects; externally visible mutation belongs in a follow-up
    certain-``write`` codelet, which only runs after the outcome is known
    (``repro.serving.spec`` is the worked example: draft = maybe-writer,
    verify = speculated reader, commit = certain write).
    """

    def wrap(f: Callable) -> SpCodelet:
        slots, static, has_var_kw = _build_slots(
            f, read, write, commutative, maybe, atomic
        )
        policy = None
        if retries or retry_backoff or timeout is not None or on_failure is not None:
            policy = SpTaskPolicy(
                retries=retries,
                retry_backoff=retry_backoff,
                timeout=timeout,
                on_failure=on_failure,
            )
        return SpCodelet(
            f,
            slots,
            static=static,
            has_var_kw=has_var_kw,
            name=name or f.__name__,
            cost=cost,
            priority=priority,
            comm=comm,
            policy=policy,
            result=result,
        )

    if fn is not None:  # bare @sp_task — annotation spelling
        return wrap(fn)
    return wrap


# ---------------------------------------------------------------------------
# One runtime over both backends.
# ---------------------------------------------------------------------------

class ElasticEvent:
    """What the runtime learned in one recovery, handed to ``on_reshard``.

    ``group`` is the *shrunken* :class:`~repro.core.comm.SpCommGroup` (None
    for local/simulated elasticity), ``dead`` the agreed dead set,
    ``payloads`` each survivor's re-roll payload keyed by physical rank,
    ``resume_step`` the minimum exchanged next step (the hook may return an
    int to override it), ``detect_at``/``reroll_s`` the detection timestamp
    and agreement latency."""

    __slots__ = (
        "epoch", "dead", "payloads", "resume_step", "group",
        "detect_at", "reroll_s",
    )

    def __init__(
        self,
        epoch: int,
        dead: frozenset,
        payloads: dict,
        resume_step: int,
        *,
        group=None,
        detect_at: float | None = None,
        reroll_s: float | None = None,
    ):
        self.epoch = epoch
        self.dead = dead
        self.payloads = payloads
        self.resume_step = resume_step
        self.group = group
        self.detect_at = detect_at
        self.reroll_s = reroll_s

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (
            f"ElasticEvent(epoch={self.epoch}, dead={sorted(self.dead)}, "
            f"resume_step={self.resume_step})"
        )


class SpRuntime:
    """Unified entry point (paper Code 1): one constructor, two backends.

    * ``backend="eager"`` — a worker-thread :class:`SpComputeEngine` drives
      the graph; ``workers`` is an int, an ``SpWorkerTeam`` or None
      (default team), ``scheduler`` a name (``make_scheduler``) or instance.
      Pass ``engine=`` to share an existing engine (not stopped on exit).
    * ``backend="staged"`` — tasks accumulate and :meth:`run` (or the first
      ``TaskView.result()``, or scope exit) executes them sequentially in
      the ``policy``-linearized order — trace-safe under ``jax.jit``, so the
      whole graph compiles into one SPMD program (DESIGN.md §2).

    Used as a context manager the runtime opens a graph scope: codelet calls
    inside the block target its graph.  ``SpRuntime(4)`` (a bare int) is the
    legacy spelling for an eager runtime with 4 workers.

    Elastic mode (ISSUE 8): ``SpRuntime(elastic=True, group=...)`` pushes
    rank-death recovery *into* the runtime — :meth:`run_step` /
    :meth:`elastic_loop` catch ``SpRankDeadError``/``SpCommError`` escaping
    a step, drive the epoch-tagged :func:`reroll_ranks` agreement, rebind
    ``self.group`` to the shrunken survivors, invoke the ``on_reshard``
    hook (live resharding, e.g. ``jax.device_put`` of surviving shards) and
    transparently re-execute from the agreed resume step.  User code needs
    zero failure handling.  With ``group=None`` the same loop serves
    *local* elasticity (simulated chip loss): recovery is whatever
    ``on_reshard`` does.
    """

    def __init__(
        self,
        backend: str | int = "eager",
        *,
        scheduler=None,
        workers=None,
        engine=None,
        policy: str = "fifo",
        speculative_model: SpSpeculativeModel = SpSpeculativeModel.SP_NO_SPEC,
        trace: bool = True,
        n_threads: int | None = None,
        elastic: bool = False,
        group=None,
        on_reshard: Callable[["ElasticEvent"], Optional[int]] | None = None,
        reroll_timeout: float = 30.0,
        detect_grace: float = 10.0,
    ):
        if isinstance(backend, int):  # legacy SpRuntime(n_threads)
            n_threads = backend
            backend = "eager"
        if backend not in ("eager", "staged"):
            raise ValueError(f"unknown backend {backend!r}; use 'eager' or 'staged'")
        if elastic and backend != "eager":
            raise ValueError(
                "elastic=True needs the eager backend: recovery re-executes "
                "steps on live worker threads"
            )
        self.backend = backend
        self.policy = policy
        self.elastic = bool(elastic)
        self.group = group
        self.on_reshard = on_reshard
        self.reroll_timeout = reroll_timeout
        self.detect_grace = detect_grace
        self.epoch = 0
        self.recoveries: list[dict] = []  # one record per survived failure
        self.graph = SpTaskGraph(speculative_model, trace=trace)
        self.engine = None
        self._own_engine = False
        self._scope_token = None
        self._order = None  # last staged schedule (list of Tasks)

        if backend == "eager":
            from .engine import SpComputeEngine, SpWorkerTeam, SpWorkerTeamBuilder
            from .scheduler import make_scheduler

            if engine is not None:
                self.engine = engine
            else:
                if isinstance(scheduler, str):
                    scheduler = make_scheduler(scheduler)
                team = workers
                if team is None:
                    team = SpWorkerTeamBuilder.team_of_cpu_workers(n_threads)
                elif isinstance(team, int):
                    team = SpWorkerTeamBuilder.team_of_cpu_workers(team)
                elif not isinstance(team, SpWorkerTeam):
                    raise TypeError(
                        f"workers must be an int or SpWorkerTeam, got {team!r}"
                    )
                self.engine = SpComputeEngine(team, scheduler)
                self._own_engine = True
            self.graph.compute_on(self.engine)
        else:
            if engine is not None or workers is not None or scheduler is not None:
                raise ValueError(
                    "backend='staged' compiles the schedule — it takes "
                    "policy=..., not workers/scheduler/engine"
                )
            # TaskView.result() on an unflushed staged graph triggers this
            self.graph._flush_hook = self.run

    # ------------------------------------------------------------------ tasks

    def task(self, *args, **kw) -> TaskView:
        """Positional-spelling shim (``SpTaskGraph.task`` passthrough)."""
        return self.graph.task(*args, **kw)

    # -------------------------------------------------------------- execution

    def run(self) -> list:
        """Execute pending work; returns the staged schedule (eager: [])."""
        if self.backend == "eager":
            self.graph.wait_all_tasks()
            return []
        return self._flush()

    def _flush(self) -> list:
        from .staged import linearize, run_schedule

        graph = self.graph
        if not graph.tasks:
            return []
        if graph.unfinished == 0:
            return self._order or []
        order = linearize(graph, self.policy)
        self._order = order
        # per-call capability dispatch: the codelet frontend stamps the
        # platform-preferred kind at bind time (pick_impl falls back to
        # 'ref' when the preference is absent).  Errors are parked on the
        # tasks/graph — surfaced by result() or wait_all_tasks, not here.
        run_schedule(
            graph, order, lambda t: getattr(t, "preferred_kind", None) or "ref"
        )
        return order

    @property
    def schedule(self) -> list:
        """The staged task order of the last :meth:`run` (staged backend)."""
        return list(self._order or [])

    def wait_all_tasks(self, timeout: float | None = None, raise_errors: bool = True) -> None:
        if self.backend == "staged":
            self._flush()
        self.graph.wait_all_tasks(timeout, raise_errors=raise_errors)

    waitAllTasks = wait_all_tasks

    def stop(self) -> None:
        if self._own_engine and self.engine is not None:
            self.engine.stop()

    # -------------------------------------------------------------- elasticity

    def _begin_step(self) -> SpTaskGraph:
        """Open a fresh per-step graph on the shared engine and make it the
        insertion scope.  A step that fails mid-collective is abandoned
        wholesale — its lingering receives time out harmlessly on the comm
        thread while the next step inserts into a clean graph."""
        self.graph = SpTaskGraph(trace=False).compute_on(self.engine)
        if self._scope_token is not None:
            _scope.reset(self._scope_token)
            self._scope_token = _scope.set(self.graph)
        return self.graph

    def _await_step(self, tg: SpTaskGraph, timeout: float) -> bool:
        """Wait for the step graph; ``False`` when a group member died
        while we waited (the transport's dead set grew), re-raising
        anything unrelated to rank death."""
        from .comm import SpCommError

        transport = self.group.hub if self.group is not None else None
        deadline = time.monotonic() + timeout
        while True:
            try:
                tg.wait_all_tasks(timeout=0.1)
                return True
            except TimeoutError:
                if transport is not None and (
                    transport.dead_ranks & set(self.group.members)
                ):
                    return False
                if time.monotonic() > deadline:
                    raise
            except SpCommError:
                return False

    def _recover(self, step: int) -> int:
        """One recovery: agree on the dead set, shrink the group, call the
        reshard hook, return the step to resume from."""
        from .comm import SpCommError

        t_fail = time.monotonic()
        if self.group is None:
            # local/simulated elasticity (launch/train.py): nothing to
            # re-roll — recovery is whatever the reshard hook does
            self.epoch += 1
            resume = step
            event = ElasticEvent(self.epoch, frozenset(), {}, step)
            if self.on_reshard is not None:
                override = self.on_reshard(event)
                if override is not None:
                    resume = int(override)
            self.recoveries.append(
                {
                    "epoch": self.epoch,
                    "mode": "local",
                    "step": step,
                    "resume": resume,
                    "seconds": time.monotonic() - t_fail,
                }
            )
            return resume

        from ..launch.rendezvous import reroll_ranks

        transport = self.group.hub
        members = set(self.group.members)
        # the task error can beat the router's death broadcast by a tick —
        # give the failure detector a moment to learn who died
        learn_by = time.monotonic() + self.detect_grace
        while not (transport.dead_ranks & members):
            if time.monotonic() > learn_by:
                raise SpCommError(
                    f"rank {self.group.rank}: step {step} failed but no rank "
                    f"was declared dead within {self.detect_grace}s"
                )
            time.sleep(0.005)
        dead_now = transport.dead_ranks & members
        detect_at = min(
            transport.death_detected_at(r) or time.monotonic() for r in dead_now
        )
        last_exc: Optional[BaseException] = None
        for _ in range(5):
            # a death landing between re-roll rounds diverges the dead set;
            # the protocol says: re-roll with a fresh epoch
            self.epoch += 1
            t0 = time.monotonic()
            try:
                group, dead, payloads = reroll_ranks(
                    self.group,
                    epoch=self.epoch,
                    payload={"next_step": step},
                    timeout=self.reroll_timeout,
                )
                break
            except SpCommError as e:
                last_exc = e
                time.sleep(0.01)
        else:
            raise last_exc  # type: ignore[misc]
        reroll_s = time.monotonic() - t0
        self.group = group
        resume = min(p["next_step"] for p in payloads.values())
        event = ElasticEvent(
            self.epoch,
            dead,
            payloads,
            resume,
            group=group,
            detect_at=detect_at,
            reroll_s=reroll_s,
        )
        if self.on_reshard is not None:
            override = self.on_reshard(event)
            if override is not None:
                resume = int(override)
        self.recoveries.append(
            {
                "epoch": self.epoch,
                "mode": "reroll",
                "step": step,
                "resume": resume,
                "dead": sorted(dead),
                "members": list(group.members),
                "detect_at": detect_at,
                "reroll_s": reroll_s,
                "seconds": time.monotonic() - t_fail,
            }
        )
        return resume

    def barrier(self, timeout: float = 60.0) -> None:
        """Wait for the current step graph to drain.  Raises
        ``SpRankDeadError`` when a group member died while waiting — inside
        :meth:`run_step` / :meth:`elastic_loop` that triggers transparent
        recovery, so a step function can synchronize mid-step (e.g. to read
        a collective's result) without any failure handling of its own."""
        if not self._await_step(self.graph, timeout):
            from .comm import SpRankDeadError

            raise SpRankDeadError(
                f"a member of {sorted(self.group.members)} died during the step"
            )

    def run_step(self, fn: Callable[[int], Any], *, step: int = 0,
                 step_timeout: float = 60.0) -> Any:
        """Execute ``fn(step)`` inside a fresh per-step graph, surviving
        rank death: on failure the runtime re-rolls the group, reshards and
        re-executes the *same* step.  ``fn`` must be re-runnable from its
        inputs (use :meth:`elastic_loop` when survivors may need to rewind
        to an earlier step)."""
        if not self.elastic:
            raise RuntimeError("run_step requires SpRuntime(elastic=True)")
        from .comm import SpCommError, SpRankDeadError

        while True:
            tg = self._begin_step()
            try:
                out = fn(step)
                failed = not self._await_step(tg, step_timeout)
            except (SpRankDeadError, SpCommError):
                failed = True
            if not failed:
                return out
            self._recover(step)

    def elastic_loop(self, fn: Callable[[int], Any], steps: int, *,
                     start: int = 0, step_timeout: float = 60.0) -> dict[int, Any]:
        """Drive ``fn(step)`` for ``step in range(start, steps)`` with
        in-runtime failure recovery: each step gets a fresh graph; a rank
        death re-rolls the group, reshards (``on_reshard``) and resumes
        from the minimum step any survivor still needs — re-executing
        completed steps when a peer was behind, so ``fn`` must be
        deterministic given its step index.  Returns ``{step: result}``
        with the *last* execution of each step."""
        if not self.elastic:
            raise RuntimeError("elastic_loop requires SpRuntime(elastic=True)")
        from .comm import SpCommError, SpRankDeadError

        results: dict[int, Any] = {}
        step = start
        while step < steps:
            tg = self._begin_step()
            try:
                out = fn(step)
                failed = not self._await_step(tg, step_timeout)
            except (SpRankDeadError, SpCommError):
                failed = True
            if failed:
                step = self._recover(step)
                continue
            results[step] = out
            step += 1
        return results

    # ----------------------------------------------------------------- scope

    def __enter__(self) -> "SpRuntime":
        self._scope_token = _scope.set(self.graph)
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if self._scope_token is not None:
            _scope.reset(self._scope_token)
            self._scope_token = None
        try:
            if exc_type is None:
                self.wait_all_tasks()
        finally:
            self.stop()

"""Codelet frontend — declare a task once, run it anywhere (paper §4.1, §4.3).

Specx's headline API idea is that a task is *declared* with its access modes
and carries multiple implementations (``SpCpu`` / ``SpCuda``) among which the
runtime selects per processing unit — StarPU's codelets, adapted.  This
module is that frontend for the JAX reproduction:

* :func:`sp_task` — a decorator that turns a plain function into a reusable
  :class:`SpCodelet` with *named argument slots*::

      @sp_task(read=("a",), write=("b",))
      def axpy(a, b, *, alpha=2.0):
          b.value = b.value + alpha * a

  or, equivalently, with typed annotations (``SpRead`` / ``SpWrite`` /
  ``SpCommutativeWrite`` / ``SpMaybeWrite`` / ``SpAtomicWrite``)::

      @sp_task
      def axpy(a: SpRead, b: SpWrite, *, alpha=2.0): ...

  Parameters not named in an access spec are *static parameters*, partially
  applied at call time (``axpy(a_cell, b_cell, alpha=3.0)``).

* :meth:`SpCodelet.impl` — register additional implementation variants with
  capability predicates (the SpCpu/SpCuda selection from the paper)::

      @axpy.impl("pallas", available=pallas_available)
      def _(a, b, *, alpha=2.0): ...

  At *call* time the codelet keeps only the variants whose ``available()``
  probe passes; on the eager engine the executing worker's kind picks among
  them, on the staged path the platform does.

* :class:`SpRuntime` — one entry point over both execution backends.  The
  same user code runs threaded-eager or compiled-staged by flipping one
  argument::

      with SpRuntime(backend="eager", workers=4) as rt:   # or backend="staged"
          view = axpy(a_cell, b_cell)
          print(view.result())

  The runtime is a context manager; inside its scope (or an explicit
  :func:`graph_scope`) codelet calls insert tasks into the current graph and
  return future-like :class:`~repro.core.task.TaskView` objects
  (``result()`` / ``done()`` / ``exception()`` / ``then()``).

The positional ``tg.task(SpRead(a), SpWrite(b), fn)`` spelling remains as a
compatibility shim over the same insertion path (``SpTaskGraph.insert_task``).
"""
from __future__ import annotations

import contextlib
import contextvars
import functools
import inspect
from typing import Any, Callable, Optional, Sequence

from .access import (
    AccessMode,
    SpAccess,
    SpAtomicWrite,
    SpCommutativeWrite,
    SpData,
    SpMaybeWrite,
    SpRead,
    SpWrite,
)
from .graph import SpSpeculativeModel, SpTaskGraph
from .task import TaskView

# ---------------------------------------------------------------------------
# Current-graph scope.
# ---------------------------------------------------------------------------

_scope: contextvars.ContextVar[Optional[SpTaskGraph]] = contextvars.ContextVar(
    "sp_graph_scope", default=None
)


def current_graph() -> Optional[SpTaskGraph]:
    """The innermost active graph scope (None outside any scope)."""
    return _scope.get()


@contextlib.contextmanager
def graph_scope(graph: SpTaskGraph):
    """Make ``graph`` the insertion target for codelet calls in the block."""
    token = _scope.set(graph)
    try:
        yield graph
    finally:
        _scope.reset(token)


# ---------------------------------------------------------------------------
# Slot declaration.
# ---------------------------------------------------------------------------

class SpSlot:
    """One named argument slot of a codelet: (parameter name, access mode)."""

    __slots__ = ("name", "mode")

    def __init__(self, name: str, mode: AccessMode):
        self.name = name
        self.mode = mode

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"SpSlot({self.name!r}, {self.mode.name})"


#: Annotation spellings accepted by the bare-decorator form.  The access
#: constructors themselves double as type markers; strings cover modules with
#: ``from __future__ import annotations`` (where annotations are strings).
_ANNOTATION_MODES: dict[Any, AccessMode] = {
    SpRead: AccessMode.READ,
    SpWrite: AccessMode.WRITE,
    SpCommutativeWrite: AccessMode.COMMUTATIVE_WRITE,
    SpMaybeWrite: AccessMode.MAYBE_WRITE,
    SpAtomicWrite: AccessMode.ATOMIC_WRITE,
    "SpRead": AccessMode.READ,
    "SpWrite": AccessMode.WRITE,
    "SpCommutativeWrite": AccessMode.COMMUTATIVE_WRITE,
    "SpMaybeWrite": AccessMode.MAYBE_WRITE,
    "SpAtomicWrite": AccessMode.ATOMIC_WRITE,
    "read": AccessMode.READ,
    "write": AccessMode.WRITE,
    "commutative": AccessMode.COMMUTATIVE_WRITE,
    "maybe": AccessMode.MAYBE_WRITE,
    "atomic": AccessMode.ATOMIC_WRITE,
}

for _mode in AccessMode:
    _ANNOTATION_MODES[_mode] = _mode


def _mode_from_annotation(ann: Any) -> Optional[AccessMode]:
    if ann is inspect.Parameter.empty:
        return None
    if isinstance(ann, str):
        ann = ann.strip()
    try:
        return _ANNOTATION_MODES.get(ann)
    except TypeError:  # unhashable annotation
        return None


def _as_names(spec) -> tuple[str, ...]:
    if spec is None:
        return ()
    if isinstance(spec, str):
        return (spec,)
    return tuple(spec)


def _positional_params(fn: Callable) -> list[inspect.Parameter]:
    sig = inspect.signature(fn)
    return [
        p
        for p in sig.parameters.values()
        if p.kind
        in (inspect.Parameter.POSITIONAL_ONLY, inspect.Parameter.POSITIONAL_OR_KEYWORD)
    ]


def _build_slots(
    fn: Callable,
    read,
    write,
    commutative,
    maybe,
    atomic,
) -> tuple[list[SpSlot], set[str], bool]:
    """Derive (slots-in-signature-order, static parameter names, has **kwargs)."""
    mode_of: dict[str, AccessMode] = {}
    for names, mode in (
        (read, AccessMode.READ),
        (write, AccessMode.WRITE),
        (commutative, AccessMode.COMMUTATIVE_WRITE),
        (maybe, AccessMode.MAYBE_WRITE),
        (atomic, AccessMode.ATOMIC_WRITE),
    ):
        for n in _as_names(names):
            if n in mode_of:
                raise ValueError(f"parameter {n!r} declared under two access modes")
            mode_of[n] = mode

    params = _positional_params(fn)
    slots: list[SpSlot] = []
    if mode_of:
        by_name = {p.name for p in params}
        unknown = [n for n in mode_of if n not in by_name]
        if unknown:
            raise ValueError(
                f"access spec names {unknown} are not positional parameters of "
                f"{getattr(fn, '__name__', fn)!r}"
            )
        slots = [SpSlot(p.name, mode_of[p.name]) for p in params if p.name in mode_of]
    else:
        for p in params:
            mode = _mode_from_annotation(p.annotation)
            if mode is not None:
                slots.append(SpSlot(p.name, mode))
        if not slots:
            raise ValueError(
                f"codelet {getattr(fn, '__name__', fn)!r} declares no data slots; "
                "pass read=/write=/... or annotate parameters with SpRead/SpWrite/..."
            )

    slot_names = {s.name for s in slots}
    sig = inspect.signature(fn)
    static = {
        p.name
        for p in sig.parameters.values()
        if p.name not in slot_names
        and p.kind
        not in (inspect.Parameter.VAR_POSITIONAL, inspect.Parameter.VAR_KEYWORD)
    }
    has_var_kw = any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in sig.parameters.values()
    )
    return slots, static, has_var_kw


# ---------------------------------------------------------------------------
# The codelet.
# ---------------------------------------------------------------------------

class SpCodelet:
    """A reusable task declaration: named slots + one impl per kind.

    Built by :func:`sp_task`; additional implementation variants register
    through :meth:`impl`.  Calling the codelet binds :class:`SpData` cells
    (or sequences of cells — an array slot) to the slots and inserts one
    task into the current graph scope, returning its :class:`TaskView`.
    """

    #: call-time keywords reserved for the runtime (never static params)
    RESERVED = ("graph", "name", "priority", "cost")

    def __init__(
        self,
        fn: Callable,
        slots: Sequence[SpSlot],
        *,
        static: set[str],
        has_var_kw: bool = False,
        name: str | None = None,
        cost: float = 1.0,
        priority: int = 0,
        comm: bool = False,
    ):
        self.name = name or getattr(fn, "__name__", "codelet")
        self.slots = list(slots)
        self.cost = cost
        self.priority = priority
        self.comm = comm
        self.__doc__ = getattr(fn, "__doc__", None)
        self._static = set(static)
        self._has_var_kw = has_var_kw
        # kind -> (callable, availability predicate or None)
        self._impls: dict[str, tuple[Callable, Optional[Callable[[], bool]]]] = {
            "ref": (fn, None)
        }

    # ------------------------------------------------------------ registration

    def impl(self, kind: str, fn: Callable | None = None, *, available=None):
        """Register an implementation variant for ``kind``.

        Usable as a decorator (``@cl.impl("pallas", available=probe)``) or
        directly (``cl.impl("host", host_fn)``).  ``available`` is a zero-arg
        capability probe evaluated at *call* time; an unavailable variant is
        excluded from that call's dispatch table.
        """

        def register(f: Callable):
            self._impls[kind] = (f, available)
            return f

        if fn is not None:
            register(fn)
            return self
        return register

    @property
    def impl_kinds(self) -> list[str]:
        """Registered implementation kinds (regardless of availability)."""
        return sorted(self._impls)

    def available_kinds(self) -> list[str]:
        """Kinds whose capability probe passes right now."""
        return sorted(
            kind
            for kind, (_, avail) in self._impls.items()
            if avail is None or avail()
        )

    # --------------------------------------------------------------- insertion

    def __call__(self, *args, **kwargs) -> TaskView:
        graph = kwargs.pop("graph", None)
        if graph is None:
            graph = current_graph()
        if graph is None:
            raise RuntimeError(
                f"codelet {self.name!r} called outside a graph scope; enter an "
                "SpRuntime (`with SpRuntime(...)`) or graph_scope(tg), or pass "
                "graph=<SpTaskGraph>"
            )
        name = kwargs.pop("name", None) or self.name
        priority = kwargs.pop("priority", self.priority)
        cost = kwargs.pop("cost", self.cost)

        # -- bind slots (positional first, then by name) ---------------------
        if len(args) > len(self.slots):
            raise TypeError(
                f"{self.name} takes {len(self.slots)} data slots, got "
                f"{len(args)} positional arguments"
            )
        bound: dict[str, Any] = {}
        for slot, val in zip(self.slots, args):
            bound[slot.name] = val
        for slot in self.slots:
            if slot.name in kwargs:
                if slot.name in bound:
                    raise TypeError(f"{self.name}: slot {slot.name!r} bound twice")
                bound[slot.name] = kwargs.pop(slot.name)
        missing = [s.name for s in self.slots if s.name not in bound]
        if missing:
            raise TypeError(f"{self.name}: missing data slots {missing}")

        static = kwargs  # everything left over is a static parameter
        if not self._has_var_kw:
            unknown = sorted(set(static) - self._static)
            if unknown:
                raise TypeError(
                    f"{self.name}: unknown static parameters {unknown}; "
                    f"declared: {sorted(self._static)} "
                    f"(reserved call keywords: {list(self.RESERVED)})"
                )

        # -- build accesses / argument layout --------------------------------
        accesses: list[SpAccess] = []
        arg_layout: list[tuple[str, Any]] = []
        for slot in self.slots:
            val = bound[slot.name]
            if isinstance(val, SpData):
                acc = SpAccess(val, slot.mode)
                accesses.append(acc)
                arg_layout.append(("single", acc))
            elif isinstance(val, (list, tuple)):
                accs = [SpAccess(v, slot.mode) for v in val]
                accesses.extend(accs)
                arg_layout.append(("array", accs))
            else:
                raise TypeError(
                    f"{self.name}: slot {slot.name!r} takes an SpData cell or a "
                    f"sequence of cells, got {type(val).__name__}. "
                    f"Wrap your value: x = SpData(value, {slot.name!r})."
                )
        result_cell = SpData(None, f"{name}.result")
        res_acc = SpAccess(result_cell, AccessMode.WRITE)
        accesses.append(res_acc)
        arg_layout.append(("single", res_acc))

        # -- capability dispatch: keep variants whose probe passes now -------
        impls: dict[str, Callable] = {}
        for kind, (fn, avail) in self._impls.items():
            if avail is not None and not avail():
                continue
            impls[kind] = _wrap_body(fn, static)
        if not impls:
            raise RuntimeError(
                f"codelet {self.name!r}: no implementation available here "
                f"(registered kinds: {self.impl_kinds})"
            )
        if "pallas" in impls:
            preferred = "pallas"
        elif "ref" in impls:
            preferred = "ref"
        else:
            preferred = next(iter(impls))

        view = graph.insert_task(
            impls,
            accesses,
            arg_layout,
            priority=priority,
            name=name,
            cost=cost,
            comm=self.comm,
        )
        view.task.result_cell = result_cell
        view.task.preferred_kind = preferred
        return view

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        spec = ", ".join(f"{s.name}:{s.mode.name.lower()}" for s in self.slots)
        return f"SpCodelet({self.name!r}, [{spec}], impls={self.impl_kinds})"


def _wrap_body(fn: Callable, static: dict) -> Callable:
    """Adapt a codelet body to the Task calling convention: the runtime
    appends a hidden result slot (written with the body's return value so
    TaskView.then() chaining has a data-flow edge to hang off)."""
    if static:
        fn = functools.partial(fn, **static)

    def body(*task_args):
        *user_args, res_ref = task_args
        out = fn(*user_args)
        res_ref.value = out
        return out

    return body


def sp_task(
    fn: Callable | None = None,
    *,
    read=(),
    write=(),
    commutative=(),
    maybe=(),
    atomic=(),
    name: str | None = None,
    cost: float = 1.0,
    priority: int = 0,
    comm: bool = False,
):
    """Declare a codelet (see module docstring).

    With access-spec keywords, the named positional parameters become data
    slots in signature order; without them, parameters annotated with
    ``SpRead``/``SpWrite``/... become the slots.  All other parameters are
    static and supplied at call time.  ``comm=True`` marks every inserted
    task as a communication task (scheduling hint, see ``SpTaskGraph.task``).
    """

    def wrap(f: Callable) -> SpCodelet:
        slots, static, has_var_kw = _build_slots(
            f, read, write, commutative, maybe, atomic
        )
        return SpCodelet(
            f,
            slots,
            static=static,
            has_var_kw=has_var_kw,
            name=name or f.__name__,
            cost=cost,
            priority=priority,
            comm=comm,
        )

    if fn is not None:  # bare @sp_task — annotation spelling
        return wrap(fn)
    return wrap


# ---------------------------------------------------------------------------
# One runtime over both backends.
# ---------------------------------------------------------------------------

class SpRuntime:
    """Unified entry point (paper Code 1): one constructor, two backends.

    * ``backend="eager"`` — a worker-thread :class:`SpComputeEngine` drives
      the graph; ``workers`` is an int, an ``SpWorkerTeam`` or None
      (default team), ``scheduler`` a name (``make_scheduler``) or instance.
      Pass ``engine=`` to share an existing engine (not stopped on exit).
    * ``backend="staged"`` — tasks accumulate and :meth:`run` (or the first
      ``TaskView.result()``, or scope exit) executes them sequentially in
      the ``policy``-linearized order — trace-safe under ``jax.jit``, so the
      whole graph compiles into one SPMD program (DESIGN.md §2).

    Used as a context manager the runtime opens a graph scope: codelet calls
    inside the block target its graph.  ``SpRuntime(4)`` (a bare int) is the
    legacy spelling for an eager runtime with 4 workers.
    """

    def __init__(
        self,
        backend: str | int = "eager",
        *,
        scheduler=None,
        workers=None,
        engine=None,
        policy: str = "fifo",
        speculative_model: SpSpeculativeModel = SpSpeculativeModel.SP_NO_SPEC,
        trace: bool = True,
        n_threads: int | None = None,
    ):
        if isinstance(backend, int):  # legacy SpRuntime(n_threads)
            n_threads = backend
            backend = "eager"
        if backend not in ("eager", "staged"):
            raise ValueError(f"unknown backend {backend!r}; use 'eager' or 'staged'")
        self.backend = backend
        self.policy = policy
        self.graph = SpTaskGraph(speculative_model, trace=trace)
        self.engine = None
        self._own_engine = False
        self._scope_token = None
        self._order = None  # last staged schedule (list of Tasks)

        if backend == "eager":
            from .engine import SpComputeEngine, SpWorkerTeam, SpWorkerTeamBuilder
            from .scheduler import make_scheduler

            if engine is not None:
                self.engine = engine
            else:
                if isinstance(scheduler, str):
                    scheduler = make_scheduler(scheduler)
                team = workers
                if team is None:
                    team = SpWorkerTeamBuilder.team_of_cpu_workers(n_threads)
                elif isinstance(team, int):
                    team = SpWorkerTeamBuilder.team_of_cpu_workers(team)
                elif not isinstance(team, SpWorkerTeam):
                    raise TypeError(
                        f"workers must be an int or SpWorkerTeam, got {team!r}"
                    )
                self.engine = SpComputeEngine(team, scheduler)
                self._own_engine = True
            self.graph.compute_on(self.engine)
        else:
            if engine is not None or workers is not None or scheduler is not None:
                raise ValueError(
                    "backend='staged' compiles the schedule — it takes "
                    "policy=..., not workers/scheduler/engine"
                )
            # TaskView.result() on an unflushed staged graph triggers this
            self.graph._flush_hook = self.run

    # ------------------------------------------------------------------ tasks

    def task(self, *args, **kw) -> TaskView:
        """Positional-spelling shim (``SpTaskGraph.task`` passthrough)."""
        return self.graph.task(*args, **kw)

    # -------------------------------------------------------------- execution

    def run(self) -> list:
        """Execute pending work; returns the staged schedule (eager: [])."""
        if self.backend == "eager":
            self.graph.wait_all_tasks()
            return []
        return self._flush()

    def _flush(self) -> list:
        from .staged import linearize, run_schedule

        graph = self.graph
        if not graph.tasks:
            return []
        if graph.unfinished == 0:
            return self._order or []
        order = linearize(graph, self.policy)
        self._order = order
        # per-call capability dispatch: the codelet frontend stamps the
        # platform-preferred kind at bind time (pick_impl falls back to
        # 'ref' when the preference is absent).  Errors are parked on the
        # tasks/graph — surfaced by result() or wait_all_tasks, not here.
        run_schedule(
            graph, order, lambda t: getattr(t, "preferred_kind", None) or "ref"
        )
        return order

    @property
    def schedule(self) -> list:
        """The staged task order of the last :meth:`run` (staged backend)."""
        return list(self._order or [])

    def wait_all_tasks(self, timeout: float | None = None, raise_errors: bool = True) -> None:
        if self.backend == "staged":
            self._flush()
        self.graph.wait_all_tasks(timeout, raise_errors=raise_errors)

    waitAllTasks = wait_all_tasks

    def stop(self) -> None:
        if self._own_engine and self.engine is not None:
            self.engine.stop()

    # ----------------------------------------------------------------- scope

    def __enter__(self) -> "SpRuntime":
        self._scope_token = _scope.set(self.graph)
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if self._scope_token is not None:
            _scope.reset(self._scope_token)
            self._scope_token = None
        try:
            if exc_type is None:
                self.wait_all_tasks()
        finally:
            self.stop()

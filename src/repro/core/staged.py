"""Staged backend: compile an STF task graph into a single SPMD program.

This is the TPU-native half of the adaptation (DESIGN.md §2).  On a pod
there are no worker threads to balance — but there *is* a program order to
choose.  The scheduler's freedom (order among ready tasks, placement of
commutative writes, hoisting of communication) becomes the *instruction
schedule of the compiled step*:

1. build an :class:`~repro.core.graph.SpTaskGraph` whose cells hold JAX
   tracers (inside a ``jax.jit``-traced function);
2. :func:`linearize` it — a Kahn topological sort whose tie-break is the
   pluggable scheduling policy;
3. :func:`execute_staged` runs the task bodies in that order, threading
   values through the cells — producing a jaxpr whose op order follows the
   schedule.  XLA's latency-hiding scheduler then overlaps the hoisted
   collectives with adjacent compute.

Policies:

* ``fifo``          — insertion order (paper default; the sequential order).
* ``priority``      — SpPriority-descending among ready tasks.
* ``critical_path`` — HEFT upward rank (longest downstream cost first).
* ``overlap``       — communication-first: a ready comm task is always
  issued before ready compute tasks, so collectives start as early as the
  dependence structure allows (the compiled analogue of the paper's
  background thread progressing communication "as early as possible").
"""
from __future__ import annotations

import collections
import heapq
import itertools
from typing import Callable

from .graph import SpTaskGraph
from .scheduler import compute_upward_ranks
from .task import Task, TaskState


def linearize(graph: SpTaskGraph, policy: str = "fifo") -> list[Task]:
    """Total order of ``graph.tasks`` respecting the STF partial order."""
    succ = graph.successor_map()
    pred = graph.predecessor_counts(succ)
    if policy == "critical_path":
        compute_upward_ranks(graph.tasks, succ)

    counter = itertools.count()

    def key(t: Task):
        if policy == "fifo":
            return t.inserted_index
        if policy == "priority":
            return (-t.priority, t.inserted_index)
        if policy == "critical_path":
            return (-getattr(t, "_rank", 0.0), t.inserted_index)
        if policy == "overlap":
            return (0 if t.is_comm else 1, t.inserted_index)
        raise ValueError(f"unknown staged policy {policy!r}")

    heap: list = []
    for t in graph.tasks:
        if pred.get(t.uid, 0) == 0:
            heapq.heappush(heap, (key(t), next(counter), t))

    order: list[Task] = []
    done: set[int] = set()
    while heap:
        _, _, t = heapq.heappop(heap)
        if t.uid in done:  # pragma: no cover - defensive
            continue
        done.add(t.uid)
        order.append(t)
        for s in succ.get(t.uid, ()):
            pred[s.uid] -= 1
            if pred[s.uid] == 0:
                heapq.heappush(heap, (key(s), next(counter), s))
    if len(order) != len(graph.tasks):
        raise RuntimeError(
            f"linearize produced {len(order)} of {len(graph.tasks)} tasks — cycle?"
        )
    return order


def run_schedule(
    graph: SpTaskGraph,
    order: list[Task],
    impl_for: Callable[[Task], str],
) -> BaseException | None:
    """Run ``order`` sequentially with full graph bookkeeping.

    The single staged executor under both :func:`execute_staged` and
    ``SpRuntime._flush``: each task is run with ``impl_for(task)`` as the
    preferred implementation kind, its handles released and its done event
    set, so ``wait_all_tasks`` / ``TaskView`` work afterwards.  On the first
    exception the remaining not-yet-run tasks are marked *cancelled*
    (``TaskView.result()`` on them raises ``CancelledError``) and the error
    is returned — the caller decides whether to raise now (functional API)
    or defer to ``result()``/``wait_all_tasks`` (runtime API).
    """
    error: BaseException | None = None
    for t in order:
        if t.is_done:
            continue
        if error is not None:
            t.mark_cancelled()
            graph.on_task_finished(t)
            continue
        t.state = TaskState.RUNNING
        try:
            t.run(preferred_impl=impl_for(t))
        except BaseException as e:
            t.exception = e
            error = e
        graph.on_task_finished(t)
        t.mark_finished()
    return error


def execute_staged(
    graph: SpTaskGraph, policy: str = "fifo", impl: str = "ref"
) -> list[Task]:
    """Run every task body sequentially in the linearized order.

    Safe under ``jax.jit`` tracing when all task bodies are trace-pure
    (jnp-only).  Cell values after the call hold the outputs (tracers when
    traced).  Returns the schedule for introspection.  The first task
    exception propagates immediately (remaining tasks are cancelled).
    """
    order = linearize(graph, policy)
    error = run_schedule(graph, order, lambda t: impl)
    if error is not None:
        raise error
    return order


def schedule_summary(order: list[Task]) -> dict:
    """Small introspection helper used by tests and EXPERIMENTS.md §Perf:
    positions of comm tasks in the schedule (earlier = more overlap room)."""
    comm_pos = [i for i, t in enumerate(order) if t.is_comm]
    return {
        "n_tasks": len(order),
        "n_comm": len(comm_pos),
        "comm_positions": comm_pos,
        "mean_comm_pos": (sum(comm_pos) / len(comm_pos)) if comm_pos else None,
    }

"""Data access modes — the heart of Specx's STF (sequential task flow) model.

The paper (§4.1) defines five access modes.  A task declares, at insertion
time, how it will access each piece of data; the runtime derives the DAG from
the *sequential insertion order* plus these modes, guaranteeing that a
parallel execution is observationally identical to the sequential one.

Adaptation note (DESIGN.md §2): in C++ Specx a dependency is the *address* of
the object.  JAX arrays are immutable values, so the unit of dependency here
is an :class:`SpData` cell — a named, versioned, mutable slot holding an
arbitrary pytree.  Write-like accesses hand the task a :class:`SpWriteRef`
proxy (the analogue of a C++ non-const reference); reads hand the raw value
(the analogue of ``const&``).
"""
from __future__ import annotations

import enum
import itertools
from typing import Any, Iterable, Sequence


class AccessMode(enum.Enum):
    """Specx §4.1 access modes."""

    READ = "read"                 # SpRead    — concurrent with other reads
    WRITE = "write"               # SpWrite   — exclusive
    COMMUTATIVE_WRITE = "commut"  # SpCommutativeWrite — order-free, mutually exclusive
    MAYBE_WRITE = "maybe"         # SpMaybeWrite — uncertain; speculation hook
    ATOMIC_WRITE = "atomic"       # SpAtomicWrite — concurrent among themselves.
    #   NB: atomic writers run concurrently on the SAME underlying object —
    #   bodies must mutate it IN PLACE under their own lock (the C++
    #   shared-memory contract); reassigning ``ref.value`` from a stale read
    #   would lose updates, exactly as unsynchronized C++ writes would.

    @property
    def is_write_like(self) -> bool:
        return self is not AccessMode.READ


#: Group-compatibility: accesses that may share a "generation" on a handle.
#: READs run concurrently; ATOMIC_WRITEs run concurrently (user-synchronized,
#: paper: "managed very similarly to a read"); COMMUTATIVE_WRITEs share a
#: generation (order-free) but are mutually exclusive at *runtime*;
#: WRITE / MAYBE_WRITE are exclusive generations of their own.
CONCURRENT_MODES = frozenset({AccessMode.READ, AccessMode.ATOMIC_WRITE})


_data_ids = itertools.count()


class SpData:
    """A named, versioned logical buffer — the unit of dependency tracking.

    ``value`` may hold any pytree (jax arrays, python scalars, ...).  The
    runtime never copies it except for speculation snapshots.
    """

    __slots__ = ("name", "value", "version", "uid", "last_writer", "_uncertain_writer")

    def __init__(self, value: Any = None, name: str | None = None):
        self.uid = next(_data_ids)
        self.name = name if name is not None else f"data{self.uid}"
        self.value = value
        self.version = 0
        # Worker (thread name) that last ran a write-like access on this
        # cell — the locality hint consumed by WorkStealingScheduler.push
        # (stamped by DataHandle.complete).
        self.last_writer: str | None = None
        # Set while a MAYBE_WRITE task has been inserted but whose outcome is
        # not yet known; used by the speculation pass (core/speculation.py).
        self._uncertain_writer = None

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"SpData({self.name!r}, v{self.version})"


class SpWriteRef:
    """Mutable proxy passed to task callables for write-like accesses.

    Mirrors a C++ non-const reference: ``ref.value`` reads the current
    payload; assigning ``ref.value = x`` performs the write.  For
    ``SpMaybeWrite`` accesses the runtime inspects :attr:`written` after the
    task body returns to learn whether the uncertain write actually happened
    (paper §4.6: the speculation outcome).
    """

    __slots__ = ("_value", "written", "name")

    def __init__(self, value: Any, name: str = "?"):
        self._value = value
        self.written = False
        self.name = name

    @property
    def value(self) -> Any:
        return self._value

    @value.setter
    def value(self, new: Any) -> None:
        self._value = new
        self.written = True

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"SpWriteRef({self.name!r}, written={self.written})"


class SpAccess:
    """One (data, mode) pair declared at task insertion."""

    __slots__ = ("data", "mode")

    def __init__(self, data: SpData, mode: AccessMode):
        if not isinstance(data, SpData):
            raise TypeError(
                f"Dependencies must be SpData cells, got {type(data).__name__}. "
                "Wrap your value: x = SpData(value, 'x')."
            )
        self.data = data
        self.mode = mode

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"SpAccess({self.data.name}, {self.mode.name})"


# ----------------------------------------------------------------------------
# Public constructors (paper-faithful spelling).
# ----------------------------------------------------------------------------

def SpRead(x: SpData) -> SpAccess:
    return SpAccess(x, AccessMode.READ)


def SpWrite(x: SpData) -> SpAccess:
    return SpAccess(x, AccessMode.WRITE)


def SpCommutativeWrite(x: SpData) -> SpAccess:
    return SpAccess(x, AccessMode.COMMUTATIVE_WRITE)


def SpMaybeWrite(x: SpData) -> SpAccess:
    return SpAccess(x, AccessMode.MAYBE_WRITE)


def SpAtomicWrite(x: SpData) -> SpAccess:
    return SpAccess(x, AccessMode.ATOMIC_WRITE)


# ----------------------------------------------------------------------------
# Array-of-dependencies (paper §4.1 "Dependencies on a Subset of Objects").
#
# OpenMP cannot express "depend on elements view of this vector" when the
# view is only known at runtime; Specx can.  Here the container is any
# sequence of SpData cells and ``view`` any iterable of indices.  Each
# selected element becomes its own dependency (its own handle), exactly as
# the paper describes ("Specx can iterate over the elements and apply the
# dependencies on the selected ones").
# ----------------------------------------------------------------------------

class SpArrayAccess:
    """Expands to one :class:`SpAccess` per selected element.

    The task callable receives, for this argument slot, a *list* — of raw
    values for reads, of :class:`SpWriteRef` proxies for write-like modes.
    """

    __slots__ = ("accesses",)

    def __init__(self, container: Sequence[SpData], view: Iterable[int], mode: AccessMode):
        idx = list(view)
        self.accesses = [SpAccess(container[i], mode) for i in idx]

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"SpArrayAccess({len(self.accesses)} deps)"


def SpReadArray(x: Sequence[SpData], view: Iterable[int]) -> SpArrayAccess:
    return SpArrayAccess(x, view, AccessMode.READ)


def SpWriteArray(x: Sequence[SpData], view: Iterable[int]) -> SpArrayAccess:
    return SpArrayAccess(x, view, AccessMode.WRITE)


def SpCommutativeWriteArray(x: Sequence[SpData], view: Iterable[int]) -> SpArrayAccess:
    return SpArrayAccess(x, view, AccessMode.COMMUTATIVE_WRITE)


def SpMaybeWriteArray(x: Sequence[SpData], view: Iterable[int]) -> SpArrayAccess:
    return SpArrayAccess(x, view, AccessMode.MAYBE_WRITE)


def SpAtomicWriteArray(x: Sequence[SpData], view: Iterable[int]) -> SpArrayAccess:
    return SpArrayAccess(x, view, AccessMode.ATOMIC_WRITE)


class SpPriority:
    """Task priority hint (paper §4.1): the scheduler is free to use it."""

    __slots__ = ("value",)

    def __init__(self, value: int):
        self.value = int(value)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"SpPriority({self.value})"


# ----------------------------------------------------------------------------
# Per-processing-unit callables (paper §4.3).  ``SpCpu``/``SpCuda`` become
# implementation *variants*: SpRef (pure-jnp / XLA), SpPallas (TPU kernel),
# SpHost (python-only, e.g. I/O or checkpoint commit).  The scheduler or a
# capability probe picks among them (DESIGN.md §2, C3).
# ----------------------------------------------------------------------------

class SpImpl:
    __slots__ = ("fn", "kind")

    def __init__(self, fn, kind: str):
        self.fn = fn
        self.kind = kind


def SpRef(fn) -> SpImpl:
    """Reference implementation — pure jnp / XLA; runs anywhere."""
    return SpImpl(fn, "ref")


def SpPallas(fn) -> SpImpl:
    """TPU Pallas kernel implementation (falls back to ref off-TPU)."""
    return SpImpl(fn, "pallas")


def SpHost(fn) -> SpImpl:
    """Host/python implementation (I/O, checkpoint commit, ...)."""
    return SpImpl(fn, "host")


# Paper-compatible aliases: SpCpu ≙ the reference path, SpCuda/SpHip ≙ the
# accelerator-kernel path.
SpCpu = SpRef
SpCuda = SpPallas
SpHip = SpPallas

"""Common layers: norms, rotary embeddings, gated MLPs, embeddings, losses.

Pure functions over explicit param dicts; logical-axis sharding constraints
via :func:`repro.dist.sharding.shard` (identity off-mesh).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.config import ArchConfig
from repro.models.param import ParamDef


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rmsnorm_def(d: int) -> ParamDef:
    # stored as offset-from-one (gemma convention); init zeros → scale 1
    return ParamDef((d,), (None,), init="zeros")


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., L, n, head_dim); positions: (..., L) int32."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., L, half)
    sin = jnp.sin(angles)[..., None, :]
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU / GELU)
# ---------------------------------------------------------------------------

def mlp_defs(d_model: int, d_ff: int, act: str) -> dict:
    if act in ("swiglu", "geglu"):
        return {
            "wi_gate": ParamDef((d_model, d_ff), ("embed", "ff")),
            "wi_up": ParamDef((d_model, d_ff), ("embed", "ff")),
            "wo": ParamDef((d_ff, d_model), ("ff", "embed")),
        }
    return {
        "wi": ParamDef((d_model, d_ff), ("embed", "ff")),
        "wo": ParamDef((d_ff, d_model), ("ff", "embed")),
    }


def mlp_apply(p: dict, x: jax.Array, act: str) -> jax.Array:
    if act in ("swiglu", "geglu"):
        g = jnp.einsum("...d,df->...f", x, p["wi_gate"])
        u = jnp.einsum("...d,df->...f", x, p["wi_up"])
        g = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g, approximate=True)
        h = g * u
    else:
        h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, p["wi"]), approximate=True)
    h = shard(h, "batch", None, "ff") if h.ndim == 3 else h
    return jnp.einsum("...f,fd->...d", h, p["wo"])


# ---------------------------------------------------------------------------
# Embedding + logits
# ---------------------------------------------------------------------------

def embed_defs(cfg: ArchConfig) -> dict:
    """Embedding (+ untied head) over the PADDED vocab (sharding-friendly)."""
    v, d = cfg.padded_vocab, cfg.d_model
    out = {"embedding": ParamDef((v, d), ("vocab", "embed"), init="embed")}
    if not cfg.tie_embeddings:
        out["unembed"] = ParamDef((d, v), ("embed", "vocab"))
    return out


import functools


@functools.lru_cache(maxsize=32)
def _make_embed_lookup(shape: tuple, dtype_name: str):
    """Embedding gather with a sharded-scatter backward.

    The default gather-transpose scatter-add materializes the FULL table
    gradient replicated per device (≈5 GB fp32 for a 150k×8k table).
    Constraining the zeros operand and the result to the table's logical
    sharding keeps the scatter partitioned over (vocab, embed)."""

    @jax.custom_vjp
    def lookup(table, tokens):
        return table[tokens]

    def fwd(table, tokens):
        return table[tokens], tokens

    def bwd(tokens, dx):
        zeros = shard(jnp.zeros(shape, dx.dtype), "vocab", "embed")
        dE = zeros.at[tokens.reshape(-1)].add(dx.reshape(-1, shape[-1]))
        dE = shard(dE, "vocab", "embed")
        return dE.astype(jnp.dtype(dtype_name)), None

    lookup.defvjp(fwd, bwd)
    return lookup


def embed_apply(p: dict, tokens: jax.Array, cfg: ArchConfig) -> jax.Array:
    table = p["embedding"]
    x = _make_embed_lookup(tuple(table.shape), str(table.dtype))(table, tokens)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    return shard(x.astype(jnp.dtype(cfg.dtype)), "batch", "act_seq", None)


def logits_apply(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    if "unembed" in p:
        logits = jnp.einsum("...d,dv->...v", x, p["unembed"])
    else:
        logits = jnp.einsum("...d,vd->...v", x, p["embedding"])
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    if cfg.padded_vocab != cfg.vocab:  # mask padding classes out of softmax
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab
        logits = jnp.where(pad_mask, logits, -1e30)
    return logits


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_xent(logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None):
    """Mean cross-entropy over (optionally masked) positions; fp32 math."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)


def chunked_softmax_xent(
    x: jax.Array,
    labels: jax.Array,
    p_embed: dict,
    cfg: ArchConfig,
    mask: Optional[jax.Array] = None,
    chunk: int = 1024,
):
    """Cross-entropy without materializing the full (T, vocab) logits tensor:
    scan over sequence chunks (memory-term lever for 150k–256k vocabs)."""
    B, L, D = x.shape
    n = L // chunk
    assert n * chunk == L, f"seq {L} not divisible by logits chunk {chunk}"
    xs = x.reshape(B, n, chunk, D).swapaxes(0, 1)  # (n, B, chunk, D)
    ls = labels.reshape(B, n, chunk).swapaxes(0, 1)
    ms = None if mask is None else mask.reshape(B, n, chunk).swapaxes(0, 1)

    @jax.checkpoint  # recompute chunk logits in bwd: never stack them
    def chunk_nll(xc, lc, mc):
        logits = logits_apply(p_embed, xc, cfg).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * mc), jnp.sum(mc)

    if cfg.probe_unroll:
        # probe mode: unrolled chunks are fully visible to cost_analysis
        tot = jnp.float32(0.0)
        cnt = jnp.float32(0.0)
        for i in range(n):
            mc = jnp.ones(ls[i].shape, jnp.float32) if ms is None else ms[i].astype(jnp.float32)
            t, c = chunk_nll(xs[i], ls[i], mc)
            tot, cnt = tot + t, cnt + c
        return tot / jnp.maximum(cnt, 1.0)

    def body(carry, inp):
        if ms is None:
            xc, lc = inp
            mc = jnp.ones(lc.shape, jnp.float32)
        else:
            xc, lc, mc = inp
            mc = mc.astype(jnp.float32)
        t, c = chunk_nll(xc, lc, mc)
        return (carry[0] + t, carry[1] + c), None

    inps = (xs, ls) if ms is None else (xs, ls, ms)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)), inps)
    return tot / jnp.maximum(cnt, 1.0)

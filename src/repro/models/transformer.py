"""Model assembly: block definitions per family, scan-over-layers stacks,
train/prefill forward, decode step, losses, abstract init, input specs.

One code path serves all 10 assigned architectures (DESIGN.md §4); family
differences are block *kinds*:

* ``attn`` — pre-norm attention + gated MLP (dense / encoder / vlm)
* ``mla``  — multi-head latent attention + MLP (minicpm3)
* ``moe``  — attention + mixture-of-experts (qwen3-moe, llama4-scout)
* ``ssm``  — Mamba-2 SSD block (mamba2)
* ``rec``  — RG-LRU recurrent block + MLP (recurrentgemma, with its
  (rec, rec, attn) pattern scanned as super-blocks)

Compile hygiene: homogeneous stacks are ``lax.scan``-ed over a stacked
parameter pytree (compile one layer, not 94) with a remat policy knob.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models import attention as attn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rec_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ArchConfig, ShapeSpec
from repro.models.layers import (
    chunked_softmax_xent,
    embed_apply,
    embed_defs,
    logits_apply,
    mlp_apply,
    mlp_defs,
    rmsnorm,
    rmsnorm_def,
    softmax_xent,
)
from repro.models.param import (
    ParamDef,
    abstract_tree,
    axes_tree,
    init_tree,
    sharding_tree,
    stack_defs,
)

# ---------------------------------------------------------------------------
# Block definitions
# ---------------------------------------------------------------------------

def block_kind(cfg: ArchConfig) -> str:
    if cfg.family == "ssm":
        return "ssm"
    if cfg.family == "moe":
        return "moe"
    if cfg.mla is not None:
        return "mla"
    return "attn"


def block_defs(cfg: ArchConfig, kind: str) -> dict:
    D = cfg.d_model
    if kind == "ssm":
        return {"ln1": rmsnorm_def(D), "ssm": ssm_mod.ssm_defs(cfg)}
    if kind == "rec":
        return {
            "ln1": rmsnorm_def(D),
            "rec": rec_mod.rglru_defs(cfg),
            "ln2": rmsnorm_def(D),
            "mlp": mlp_defs(D, cfg.d_ff, cfg.act),
        }
    if kind == "moe":
        return {
            "ln1": rmsnorm_def(D),
            "attn": attn_mod.attn_defs(cfg),
            "ln2": rmsnorm_def(D),
            "moe": moe_mod.moe_defs(cfg),
        }
    if kind == "mla":
        return {
            "ln1": rmsnorm_def(D),
            "attn": mla_mod.mla_defs(cfg),
            "ln2": rmsnorm_def(D),
            "mlp": mlp_defs(D, cfg.d_ff, cfg.act),
        }
    return {
        "ln1": rmsnorm_def(D),
        "attn": attn_mod.attn_defs(cfg),
        "ln2": rmsnorm_def(D),
        "mlp": mlp_defs(D, cfg.d_ff, cfg.act),
    }


def _zero_aux() -> dict:
    return {"moe_balance": jnp.float32(0.0), "moe_zloss": jnp.float32(0.0)}


def block_apply(
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ArchConfig,
    kind: str,
    *,
    causal: bool,
    want_cache: bool,
):
    """Returns (y, cache, aux)."""
    aux = _zero_aux()
    if kind == "ssm":
        h, cache = ssm_mod.ssm_apply(p["ssm"], rmsnorm(x, p["ln1"], cfg.norm_eps), cfg, want_cache=want_cache)
        return x + h, cache, aux
    if kind == "rec":
        h, cache = rec_mod.rglru_apply(p["rec"], rmsnorm(x, p["ln1"], cfg.norm_eps), cfg, want_cache=want_cache)
        x = x + h
        x = x + mlp_apply(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg.act)
        return x, cache, aux
    if kind == "mla":
        h, cache = mla_mod.mla_apply(
            p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps), positions, cfg,
            causal=causal, want_cache=want_cache,
        )
        x = x + h
        x = x + mlp_apply(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg.act)
        return x, cache, aux
    # attn / moe
    h, cache = attn_mod.attention_apply(
        p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps), positions, cfg,
        causal=causal, want_cache=want_cache,
    )
    x = x + h
    if kind == "moe":
        h, aux = moe_mod.moe_apply(p["moe"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg)
    else:
        h = mlp_apply(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg.act)
    return x + h, cache, aux


def block_decode(p: dict, x: jax.Array, cache, pos, cfg: ArchConfig, kind: str):
    if kind == "ssm":
        h, cache = ssm_mod.ssm_decode_step(p["ssm"], rmsnorm(x, p["ln1"], cfg.norm_eps), cache, cfg)
        return x + h, cache
    if kind == "rec":
        h, cache = rec_mod.rglru_decode_step(p["rec"], rmsnorm(x, p["ln1"], cfg.norm_eps), cache, cfg)
        x = x + h
        return x + mlp_apply(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg.act), cache
    if kind == "mla":
        h, cache = mla_mod.mla_decode_step(p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps), cache, pos, cfg)
        x = x + h
        return x + mlp_apply(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg.act), cache
    h, cache = attn_mod.attention_decode_step(
        p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps), cache, pos, cfg
    )
    x = x + h
    if kind == "moe":
        h, _ = moe_mod.moe_apply(p["moe"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg)
    else:
        h = mlp_apply(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg.act)
    return x + h, cache


def _block_constraint(cfg: ArchConfig, kind_or_defs) -> Any:
    """Per-layer param sharding constraint applied INSIDE the scan body.

    Constraining the primal layer params makes GSPMD (a) all-gather each
    layer's FSDP-sharded weights just-in-time and (b) — via the transpose of
    ``with_sharding_constraint`` — reduce-scatter each layer's weight
    cotangents immediately, so the stacked grad accumulator stays sharded
    over the data axis instead of materializing replicated (the dominant
    memory term for ≥100B configs; EXPERIMENTS.md §Perf)."""
    from repro.dist.sharding import current_mesh

    if current_mesh() is None:
        return lambda lp: lp
    defs = kind_or_defs if isinstance(kind_or_defs, dict) else block_defs(cfg, kind_or_defs)
    sh = sharding_tree(defs)

    def apply(lp):
        return jax.tree.map(
            lambda t, s: jax.lax.with_sharding_constraint(t, s), lp, sh
        )

    return apply


# ---------------------------------------------------------------------------
# Hybrid (recurrentgemma) layer layout
# ---------------------------------------------------------------------------

def hybrid_layout(cfg: ArchConfig) -> tuple[int, tuple[str, ...]]:
    """(#scanned super-blocks, remainder kinds)."""
    pat = cfg.hybrid.pattern
    n_super = cfg.n_layers // len(pat)
    rem = cfg.n_layers - n_super * len(pat)
    return n_super, pat[:rem]


def _hybrid_window_cfg(cfg: ArchConfig) -> ArchConfig:
    """Inside a hybrid model the attention sub-blocks use the local window."""
    return cfg.replace(attn_window=cfg.hybrid.window)


# ---------------------------------------------------------------------------
# Model definitions
# ---------------------------------------------------------------------------

def model_defs(cfg: ArchConfig) -> dict:
    D = cfg.d_model
    defs: dict[str, Any] = {}
    if cfg.frontend == "audio":
        defs["frontend_proj"] = ParamDef((512, D), (None, "embed"))
        defs["mask_emb"] = ParamDef((D,), (None,))
        defs["head"] = ParamDef((D, cfg.padded_vocab), ("embed", "vocab"))
    elif cfg.frontend == "vision":
        defs["patch_proj"] = ParamDef((1024, D), (None, "embed"))
        defs.update(embed_defs(cfg))
    else:
        defs.update(embed_defs(cfg))

    if cfg.family == "hybrid":
        hcfg = _hybrid_window_cfg(cfg)
        n_super, rem = hybrid_layout(cfg)
        pat = cfg.hybrid.pattern
        super_defs = {f"{k}_{i}": block_defs(hcfg, k) for i, k in enumerate(pat)}
        defs["layers"] = stack_defs(super_defs, n_super)
        for i, k in enumerate(rem):
            defs[f"tail_{i}"] = block_defs(hcfg, k)
    else:
        kind = block_kind(cfg)
        defs["layers"] = stack_defs(block_defs(cfg, kind), cfg.n_layers)
    defs["final_norm"] = rmsnorm_def(D)
    return defs


def init_params(rng: jax.Array, cfg: ArchConfig):
    return init_tree(model_defs(cfg), rng, cfg.dtype)


def abstract_params(cfg: ArchConfig):
    return abstract_tree(model_defs(cfg), cfg.dtype)


def param_shardings(cfg: ArchConfig):
    return sharding_tree(model_defs(cfg))


# ---------------------------------------------------------------------------
# Embedding of inputs (with modality-frontend stubs)
# ---------------------------------------------------------------------------

def embed_inputs(params: dict, batch: dict, cfg: ArchConfig):
    """→ (x (B,L,D), positions (B,L))."""
    if cfg.frontend == "audio":
        x = jnp.einsum("blf,fd->bld", batch["embeds"].astype(jnp.dtype(cfg.dtype)), params["frontend_proj"])
        if "mask" in batch:
            m = batch["mask"][..., None]
            x = jnp.where(m, params["mask_emb"].astype(x.dtype), x)
        B, L = x.shape[:2]
    elif cfg.frontend == "vision":
        patches = jnp.einsum(
            "bpf,fd->bpd", batch["patch_embeds"].astype(jnp.dtype(cfg.dtype)), params["patch_proj"]
        )
        text = embed_apply(params, batch["tokens"], cfg)
        x = jnp.concatenate([patches, text], axis=1)
        B, L = x.shape[:2]
    else:
        x = embed_apply(params, batch["tokens"], cfg)
        B, L = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
    x = shard(x, "batch", "act_seq", None)
    return x, positions


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots_saveable":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn)


def forward(params: dict, batch: dict, cfg: ArchConfig, *, want_cache: bool = False):
    """→ (hidden (B,L,D), caches|None, aux dict)."""
    x, positions = embed_inputs(params, batch, cfg)
    causal = not cfg.is_encoder

    if cfg.family == "hybrid":
        hcfg = _hybrid_window_cfg(cfg)
        pat = cfg.hybrid.pattern
        n_super, rem = hybrid_layout(cfg)

        super_defs = {f"{k}_{i}": block_defs(hcfg, k) for i, k in enumerate(pat)}
        constrain = _block_constraint(hcfg, super_defs)

        def super_fn(x, lp):
            lp = constrain(lp)
            caches = {}
            aux_tot = _zero_aux()
            for i, k in enumerate(pat):
                x, cache, aux = block_apply(
                    lp[f"{k}_{i}"], x, positions, hcfg, k, causal=causal, want_cache=want_cache
                )
                caches[f"{k}_{i}"] = cache
                aux_tot = jax.tree.map(lambda a, b: a + b, aux_tot, aux)
            return x, (caches, aux_tot)

        body = _remat(super_fn, cfg)
        if cfg.scan_layers:
            x, (caches, auxs) = jax.lax.scan(lambda c, lp: body(c, lp), x, params["layers"])
            aux = jax.tree.map(jnp.sum, auxs)
        else:
            caches_l, aux = [], _zero_aux()
            for si in range(n_super):
                lp = jax.tree.map(lambda t: t[si], params["layers"])
                x, (cache, a) = body(x, lp)
                caches_l.append(cache)
                aux = jax.tree.map(lambda u, v: u + v, aux, a)
            caches = (
                jax.tree.map(lambda *xs: jnp.stack(xs), *caches_l) if want_cache else None
            )
        tail_caches = []
        for i, k in enumerate(rem):
            x, cache, a = block_apply(
                params[f"tail_{i}"], x, positions, hcfg, k, causal=causal, want_cache=want_cache
            )
            tail_caches.append(cache)
            aux = jax.tree.map(lambda u, v: u + v, aux, a)
        caches_out = {"scan": caches, "tail": tail_caches} if want_cache else None
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return x, caches_out, aux

    kind = block_kind(cfg)
    constrain = _block_constraint(cfg, kind)

    def layer_fn(x, lp):
        lp = constrain(lp)
        y, cache, aux = block_apply(lp, x, positions, cfg, kind, causal=causal, want_cache=want_cache)
        return y, (cache, aux)

    body = _remat(layer_fn, cfg)
    if cfg.scan_layers:
        x, (caches, auxs) = jax.lax.scan(lambda c, lp: body(c, lp), x, params["layers"])
        aux = jax.tree.map(jnp.sum, auxs)
    else:
        caches_l, aux = [], _zero_aux()
        for li in range(cfg.n_layers):
            lp = jax.tree.map(lambda t: t[li], params["layers"])
            x, (cache, a) = body(x, lp)
            caches_l.append(cache)
            aux = jax.tree.map(lambda u, v: u + v, aux, a)
        caches = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *caches_l) if want_cache else None
        )
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, (caches if want_cache else None), aux


# ---------------------------------------------------------------------------
# Losses / steps
# ---------------------------------------------------------------------------

AUX_WEIGHTS = {"moe_balance": 0.01, "moe_zloss": 1e-3}


def loss_fn(params: dict, batch: dict, cfg: ArchConfig):
    x, _, aux = forward(params, batch, cfg)
    labels = batch["labels"]
    mask = batch.get("mask")
    if cfg.frontend == "audio":
        logits = jnp.einsum("bld,dv->blv", x, params["head"])
        loss = softmax_xent(logits, labels, mask)
    elif cfg.logits_chunk:
        if cfg.frontend == "vision":
            x = x[:, -labels.shape[1] :]
        loss = chunked_softmax_xent(x, labels, params, cfg, mask, chunk=cfg.logits_chunk)
    else:
        if cfg.frontend == "vision":
            x = x[:, -labels.shape[1] :]
        logits = logits_apply(params, x, cfg)
        loss = softmax_xent(logits, labels, mask)
    total = loss
    metrics = {"ce_loss": loss}
    for k, w in AUX_WEIGHTS.items():
        if cfg.family == "moe":
            total = total + w * aux[k] / cfg.n_layers
            metrics[k] = aux[k] / cfg.n_layers
    return total, metrics


def prefill(params: dict, batch: dict, cfg: ArchConfig):
    """→ (last-token logits (B,1,V), caches).  Only the final position's
    logits are computed (memory discipline for 32k×150k-vocab prefill)."""
    x, caches, _ = forward(params, batch, cfg, want_cache=True)
    x_last = x[:, -1:]
    if cfg.frontend == "audio":
        logits = jnp.einsum("bld,dv->blv", x_last, params["head"])
    else:
        logits = logits_apply(params, x_last, cfg)
    return logits, caches


def decode_step(params: dict, tokens: jax.Array, caches, pos, cfg: ArchConfig):
    """One decode step.  tokens (B,1) int32; pos scalar int32 (current
    position).  → (logits (B,1,V), new caches)."""
    x = embed_apply(params, tokens, cfg)

    if cfg.family == "hybrid":
        hcfg = _hybrid_window_cfg(cfg)
        pat = cfg.hybrid.pattern
        n_super, rem = hybrid_layout(cfg)

        def super_fn(x, inp):
            lp, cache = inp
            new = {}
            for i, k in enumerate(pat):
                x, c = block_decode(lp[f"{k}_{i}"], x, cache[f"{k}_{i}"], pos, hcfg, k)
                new[f"{k}_{i}"] = c
            return x, new

        if cfg.scan_layers:
            x, new_scan = jax.lax.scan(super_fn, x, (params["layers"], caches["scan"]))
        else:
            new_l = []
            for si in range(n_super):
                inp = jax.tree.map(lambda t: t[si], (params["layers"], caches["scan"]))
                x, c = super_fn(x, inp)
                new_l.append(c)
            new_scan = jax.tree.map(lambda *xs: jnp.stack(xs), *new_l)
        new_tail = []
        for i, k in enumerate(rem):
            x, c = block_decode(params[f"tail_{i}"], x, caches["tail"][i], pos, hcfg, k)
            new_tail.append(c)
        new_caches = {"scan": new_scan, "tail": new_tail}
    else:
        kind = block_kind(cfg)
        constrain = _block_constraint(cfg, kind)

        def layer_fn(x, inp):
            lp, cache = inp
            y, c = block_decode(constrain(lp), x, cache, pos, cfg, kind)
            return y, c

        if cfg.scan_layers:
            x, new_caches = jax.lax.scan(layer_fn, x, (params["layers"], caches))
        else:
            new_l = []
            for li in range(cfg.n_layers):
                inp = jax.tree.map(lambda t: t[li], (params["layers"], caches))
                x, c = layer_fn(x, inp)
                new_l.append(c)
            new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *new_l)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_apply(params, x, cfg)
    return logits, new_caches


def verify_step(params: dict, tokens: jax.Array, caches, pos, cfg: ArchConfig,
                advance=None):
    """Multi-position decode for speculative-decoding verification.

    Feeds ``tokens`` (B, T) int32 one position at a time starting at ``pos``
    (scalar or (B,) int32) and returns the logits of **every** position:
    ``(logits (B, T, V), new caches)``.  ``advance`` (optional, (B,) int32
    0/1) lets sequences opt out of advancing — a slot with ``advance == 0``
    re-feeds its token at the same position each sub-step (an idempotent KV
    row rewrite), which is how non-speculative requests ride along in a
    mixed verification batch.

    Implementation note: the loop body is *exactly* :func:`decode_step`, so
    per-position numerics (einsum reduction orders, masking, softmax) are
    identical to the plain decode path — this is what makes greedy
    speculative decoding bit-exact against the non-speculative oracle.  The
    whole loop jits into one XLA call (T is static), so the runtime sees a
    single batched verify forward per round.
    """
    T = tokens.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    adv = None if advance is None else jnp.asarray(advance, jnp.int32)
    outs = []
    for j in range(T):
        pj = pos + (j if adv is None else j * adv)
        logits_j, caches = decode_step(params, tokens[:, j:j + 1], caches, pj, cfg)
        outs.append(logits_j)
    return jnp.concatenate(outs, axis=1), caches


# ---------------------------------------------------------------------------
# Cache + input specs (ShapeDtypeStruct stand-ins for the dry-run)
# ---------------------------------------------------------------------------

def _cache_defs_for_kind(cfg: ArchConfig, kind: str, batch: int, max_seq: int) -> dict:
    if kind == "ssm":
        s, d_in, H = ssm_mod._dims(cfg)
        conv_ch = d_in + 2 * s.n_groups * s.d_state
        return {
            "state": ParamDef((batch, H, s.d_state, s.head_dim), ("batch", None, None, None), dtype="float32"),
            "conv": ParamDef((batch, 3, conv_ch), ("batch", None, "ff"), dtype=cfg.dtype),
        }
    if kind == "rec":
        W = cfg.hybrid.lru_width or cfg.d_model
        return {
            "h": ParamDef((batch, W), ("batch", "ff"), dtype="float32"),
            "conv": ParamDef((batch, cfg.hybrid.conv_width - 1, W), ("batch", None, "ff"), dtype=cfg.dtype),
        }
    if kind == "mla":
        shapes = mla_mod.mla_cache_shapes(cfg, batch, max_seq)
        return {k: ParamDef(sh, ax, dtype=cfg.dtype) for k, (sh, ax) in shapes.items()}
    # attention (ring-buffered if windowed)
    sh = attn_mod.kv_cache_shape(cfg, batch, max_seq)
    ax = attn_mod.kv_cache_axes(cfg)
    return {"k": ParamDef(sh, ax, dtype=cfg.dtype), "v": ParamDef(sh, ax, dtype=cfg.dtype)}


def cache_defs(cfg: ArchConfig, batch: int, max_seq: int) -> dict:
    if cfg.family == "hybrid":
        hcfg = _hybrid_window_cfg(cfg)
        pat = cfg.hybrid.pattern
        n_super, rem = hybrid_layout(cfg)
        super_defs = {
            f"{k}_{i}": _cache_defs_for_kind(hcfg, k, batch, max_seq) for i, k in enumerate(pat)
        }
        return {
            "scan": stack_defs(super_defs, n_super),
            "tail": [ _cache_defs_for_kind(hcfg, k, batch, max_seq) for k in rem ],
        }
    kind = block_kind(cfg)
    return stack_defs(_cache_defs_for_kind(cfg, kind, batch, max_seq), cfg.n_layers)


def cache_layout(cfg: ArchConfig) -> Optional[dict]:
    """Per-leaf ``(batch_axis, seq_axis)`` of the *stacked* decode caches —
    the plumbing the paged serving tier needs to slice per-token KV rows
    into block tables (``serving/kvcache.py``).

    Returns None when the family's decode cache has no per-token rows to
    page: ssm/rec carry a recurrent state (one vector per sequence, not per
    token), ring-buffered windowed attention folds positions modulo the
    window, and hybrid stacks mix both.  The serving engine falls back to
    logical block accounting only (no payload save/restore) in that case.
    """
    if cfg.family == "hybrid":
        return None
    kind = block_kind(cfg)
    if kind in ("ssm", "rec"):
        return None
    if cfg.attn_window is not None:
        return None
    # stacked caches: axis 0 = layer, 1 = batch (slot), 2 = sequence
    names = ("c_kv", "k_rope") if cfg.mla is not None else ("k", "v")
    return {n: (1, 2) for n in names}


def init_cache(cfg: ArchConfig, batch: int, max_seq: int):
    defs = cache_defs(cfg, batch, max_seq)

    def mk(d):
        return jnp.zeros(d.shape, jnp.dtype(d.dtype or cfg.dtype))

    return jax.tree.map(mk, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def abstract_cache(cfg: ArchConfig, batch: int, max_seq: int):
    return abstract_tree(cache_defs(cfg, batch, max_seq), cfg.dtype)


def input_defs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ParamDef tree for one batch of inputs under ``shape``."""
    B, L = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"tokens": ParamDef((B, 1), ("batch", None), dtype="int32")}
    if cfg.frontend == "audio":
        return {
            "embeds": ParamDef((B, L, 512), ("batch", None, None), dtype=cfg.dtype),
            "mask": ParamDef((B, L), ("batch", None), dtype="bool"),
            "labels": ParamDef((B, L), ("batch", None), dtype="int32"),
        }
    if cfg.frontend == "vision":
        lt = L - cfg.n_patches
        out = {
            "tokens": ParamDef((B, lt), ("batch", None), dtype="int32"),
            "patch_embeds": ParamDef((B, cfg.n_patches, 1024), ("batch", None, None), dtype=cfg.dtype),
        }
        if shape.kind == "train":
            out["labels"] = ParamDef((B, lt), ("batch", None), dtype="int32")
        return out
    out = {"tokens": ParamDef((B, L), ("batch", None), dtype="int32")}
    if shape.kind == "train":
        out["labels"] = ParamDef((B, L), ("batch", None), dtype="int32")
    return out


def abstract_inputs(cfg: ArchConfig, shape: ShapeSpec):
    return abstract_tree(input_defs(cfg, shape), cfg.dtype)

"""Mixture-of-Experts layer with expert parallelism (qwen3-moe, llama4-scout).

Two dispatch strategies — the central §Perf lever for the MoE cells:

* ``einsum``  — GShard-style grouped one-hot dispatch/combine einsums with a
  per-group capacity.  Simple and numerically exact w.r.t. capacity
  semantics, but the dispatch einsums add ~2× matmul FLOPs and the
  (G, S, E, C) one-hot tensor inflates the memory term.  This is the
  paper-era baseline.
* ``scatter`` — sort-based dispatch: tokens are scatter-added into per-expert
  capacity buffers, expert GEMMs run on the packed (E, C, D) buffer, results
  gather back.  No dispatch-matmul FLOPs; HLO FLOPs ≈ useful FLOPs.

Both shard experts over the ``model`` axis (expert parallelism) and tokens
over ``data``; the router runs in fp32.  Aux losses (load-balance + z-loss)
are returned for the trainer.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.config import ArchConfig
from repro.models.layers import mlp_apply, mlp_defs
from repro.models.param import ParamDef


def moe_defs(cfg: ArchConfig) -> dict:
    m = cfg.moe
    D, F, E = cfg.d_model, m.d_ff_expert, m.n_experts
    defs = {
        "router": ParamDef((D, E), ("embed", None), dtype="float32"),
        "wi_gate": ParamDef((E, D, F), ("experts", "embed", "expert_ff")),
        "wi_up": ParamDef((E, D, F), ("experts", "embed", "expert_ff")),
        "wo": ParamDef((E, F, D), ("experts", "expert_ff", "embed")),
    }
    if m.n_shared_experts:
        defs["shared"] = mlp_defs(D, F * m.n_shared_experts, cfg.act)
    return defs


def _capacity(tokens_per_group: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * tokens_per_group * m.top_k / m.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8, floor 8


def _router(p: dict, xt: jax.Array, cfg: ArchConfig):
    """xt (..., D) → probs/top-k (fp32) + aux losses."""
    m = cfg.moe
    logits = jnp.einsum("...d,de->...e", xt.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)
    # aux: load-balance (Switch) + router z-loss
    E = m.n_experts
    me = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))  # mean prob / expert
    ce = jnp.mean(
        jax.nn.one_hot(top_i[..., 0], E, dtype=jnp.float32),
        axis=tuple(range(top_i.ndim - 1)),
    )
    balance = E * jnp.sum(me * ce)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return top_p, top_i, {"moe_balance": balance, "moe_zloss": z}


# ---------------------------------------------------------------------------
# einsum (GShard) dispatch
# ---------------------------------------------------------------------------

def _einsum_moe(p: dict, x_grp: jax.Array, cfg: ArchConfig):
    """x_grp (G, S, D): G token groups (sharded over data)."""
    m = cfg.moe
    G, S, D = x_grp.shape
    E, K = m.n_experts, m.top_k
    C = _capacity(S, cfg)

    top_p, top_i, aux = _router(p, x_grp, cfg)  # (G,S,K)
    # GShard priority: expert-choice k=0 of every token claims capacity
    # before any k=1 choice; one (G,S,E,C) accumulator, K small einsums.
    dispatch = jnp.zeros((G, S, E, C), jnp.float32)
    combine = jnp.zeros((G, S, E, C), jnp.float32)
    acc_counts = jnp.zeros((G, E), jnp.float32)
    for k in range(K):
        ohk = jax.nn.one_hot(top_i[..., k], E, dtype=jnp.float32)  # (G,S,E)
        pos = jnp.cumsum(ohk, axis=1) - ohk + acc_counts[:, None, :]
        acc_counts = acc_counts + jnp.sum(ohk, axis=1)
        pos_of = jnp.sum(pos * ohk, axis=-1)  # (G,S)
        keep = (pos_of < C).astype(jnp.float32)
        disp_k = ohk * keep[..., None]
        slot_oh = jax.nn.one_hot(pos_of, C, dtype=jnp.float32) * keep[..., None]
        d = jnp.einsum("gse,gsc->gsec", disp_k, slot_oh)
        dispatch = dispatch + d
        combine = combine + d * top_p[..., k][..., None, None]
    dispatch = shard(dispatch.astype(x_grp.dtype), "batch", None, "experts", None)
    combine = shard(combine, "batch", None, "experts", None)

    ein = jnp.einsum("gsec,gsd->gecd", dispatch, x_grp)  # (G,E,C,D)
    ein = shard(ein, "batch", "experts", None, None)
    h = _expert_ffn(p, ein, cfg)  # (G,E,C,D)
    y = jnp.einsum("gsec,gecd->gsd", combine, h.astype(jnp.float32))
    return y.astype(x_grp.dtype), aux


def _expert_ffn(p: dict, t: jax.Array, cfg: ArchConfig) -> jax.Array:
    """t (..., E, C, D) → (..., E, C, D); per-expert gated MLP."""
    g = jnp.einsum("...ecd,edf->...ecf", t, p["wi_gate"])
    u = jnp.einsum("...ecd,edf->...ecf", t, p["wi_up"])
    g = jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g, approximate=True)
    return jnp.einsum("...ecf,efd->...ecd", g * u, p["wo"])


# ---------------------------------------------------------------------------
# scatter (sort-based) dispatch
# ---------------------------------------------------------------------------

def _scatter_moe(p: dict, xt: jax.Array, cfg: ArchConfig):
    """xt (T, D) flat tokens."""
    m = cfg.moe
    T, D = xt.shape
    E, K = m.n_experts, m.top_k
    C = _capacity(T, cfg)

    top_p, top_i, aux = _router(p, xt, cfg)  # (T,K)
    flat_e = top_i.reshape(-1)  # (T*K,)
    flat_g = top_p.reshape(-1)
    order = jnp.argsort(flat_e)
    se = flat_e[order]
    token_of = order // K
    ones = jnp.ones_like(se, jnp.int32)
    counts = jax.ops.segment_sum(ones, se, num_segments=E)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    slot = jnp.arange(T * K, dtype=jnp.int32) - offsets[se]
    keep = slot < C
    dest = se * C + jnp.clip(slot, 0, C - 1)

    gathered = xt[token_of] * keep[:, None].astype(xt.dtype)
    buf = jnp.zeros((E * C, D), xt.dtype).at[dest].add(gathered)
    buf = shard(buf.reshape(E, C, D), "experts", None, None)
    h = _expert_ffn(p, buf, cfg)  # (E,C,D)
    h = h.reshape(E * C, D)
    contrib = h[dest] * (flat_g[order] * keep)[:, None].astype(h.dtype)
    y = jnp.zeros((T, D), xt.dtype).at[token_of].add(contrib.astype(xt.dtype))
    return y, aux


# ---------------------------------------------------------------------------
# Public layer
# ---------------------------------------------------------------------------

def moe_apply(p: dict, x: jax.Array, cfg: ArchConfig):
    """x (B, L, D) → (y, aux).  Groups tokens for einsum dispatch so capacity
    is local (≤4096 tokens per group), flattens for scatter dispatch."""
    m = cfg.moe
    B, L, D = x.shape
    T = B * L
    if m.dispatch == "einsum":
        g_tokens = min(4096, T)
        G = T // g_tokens
        x_grp = x.reshape(G, g_tokens, D)
        y, aux = _einsum_moe(p, x_grp, cfg)
        y = y.reshape(B, L, D)
    elif m.dispatch == "scatter":
        y, aux = _scatter_moe(p, x.reshape(T, D), cfg)
        y = y.reshape(B, L, D)
    else:
        raise ValueError(f"unknown moe dispatch {m.dispatch!r}")
    if m.n_shared_experts:
        y = y + mlp_apply(p["shared"], x, cfg.act)
    return shard(y, "batch", "act_seq", None), aux

"""Multi-head latent attention (MLA) — minicpm3-4b / DeepSeek-V2 style.

Queries and KV are projected through low-rank latents; only the compressed
latent (c_kv) and the shared RoPE key are cached at decode time — the KV
cache is ~(r_kv + d_rope)/(2·H·Dh) the size of a GQA cache.  Decode uses the
*absorbed* formulation: W_UK folds into the query and W_UV into the output,
so attention runs directly in latent space against the compact cache.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.config import ArchConfig
from repro.models.layers import apply_rope, rmsnorm
from repro.models.param import ParamDef
from repro.models.attention import full_attention, kv_cache_update

NEG_INF = -1e30


def mla_defs(cfg: ArchConfig) -> dict:
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": ParamDef((D, m.q_lora_rank), ("embed", None)),
        "q_norm": ParamDef((m.q_lora_rank,), (None,), init="zeros"),
        "wq_b": ParamDef((m.q_lora_rank, H, qk), (None, "heads", None)),
        "wkv_a": ParamDef((D, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", None)),
        "kv_norm": ParamDef((m.kv_lora_rank,), (None,), init="zeros"),
        "wk_b": ParamDef((m.kv_lora_rank, H, m.qk_nope_head_dim), (None, "heads", None)),
        "wv_b": ParamDef((m.kv_lora_rank, H, m.v_head_dim), (None, "heads", None)),
        "wo": ParamDef((H, m.v_head_dim, D), ("heads", None, "embed")),
    }


def _project_q(p: dict, x: jax.Array, positions: jax.Array, cfg: ArchConfig):
    m = cfg.mla
    cq = rmsnorm(jnp.einsum("bld,dr->blr", x, p["wq_a"]), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("blr,rhk->blhk", cq, p["wq_b"])
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(p: dict, x: jax.Array, positions: jax.Array, cfg: ArchConfig):
    m = cfg.mla
    kv = jnp.einsum("bld,dr->blr", x, p["wkv_a"])
    c_kv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope  # (B,L,r_kv), (B,L,d_rope)


def mla_apply(
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ArchConfig,
    *,
    causal: bool = True,
    want_cache: bool = False,
):
    """Training / prefill: expand latents to per-head K/V, run blockwise attn."""
    m = cfg.mla
    q_nope, q_rope = _project_q(p, x, positions, cfg)
    c_kv, k_rope = _project_kv_latent(p, x, positions, cfg)
    k_nope = jnp.einsum("blr,rhk->blhk", c_kv, p["wk_b"])
    v = jnp.einsum("blr,rhv->blhv", c_kv, p["wv_b"])
    H = cfg.n_heads
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :], k_nope.shape[:3] + (m.qk_rope_head_dim,))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "heads", None)
    v = shard(v, "batch", None, "heads", None)
    out = full_attention(q, k, v, cfg, causal=causal, window=cfg.attn_window)
    y = jnp.einsum("blhv,hvd->bld", out, p["wo"])
    y = shard(y, "batch", "act_seq", None)
    if want_cache:
        return y, {"c_kv": c_kv, "k_rope": k_rope}
    return y, None


def mla_cache_shapes(cfg: ArchConfig, batch: int, max_seq: int) -> dict:
    m = cfg.mla
    return {
        "c_kv": ((batch, max_seq, m.kv_lora_rank), ("batch", "kv_seq", None)),
        "k_rope": ((batch, max_seq, m.qk_rope_head_dim), ("batch", "kv_seq", None)),
    }


def mla_decode_step(p: dict, x: jax.Array, cache: dict, pos: jax.Array, cfg: ArchConfig):
    """Absorbed-matmul decode.  x (B,1,D); cache {'c_kv': (B,S,r), 'k_rope': (B,S,d_r)}."""
    m = cfg.mla
    B = x.shape[0]
    positions = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1, 1), (B, 1))
    q_nope, q_rope = _project_q(p, x, positions, cfg)  # (B,1,H,·)
    c_kv_new, k_rope_new = _project_kv_latent(p, x, positions, cfg)

    S = cache["c_kv"].shape[1]
    c_cache = kv_cache_update(
        cache["c_kv"][:, :, None, :], c_kv_new[:, :, None, :], pos, cfg.kv_update
    )[:, :, 0, :]
    r_cache = kv_cache_update(
        cache["k_rope"][:, :, None, :], k_rope_new[:, :, None, :], pos, cfg.kv_update
    )[:, :, 0, :]

    # absorb W_UK into q: q_lat (B,H,r_kv)
    q_lat = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0], p["wk_b"])
    s = jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.float32), c_cache.astype(jnp.float32))
    s = s + jnp.einsum(
        "bhk,bsk->bhs", q_rope[:, 0].astype(jnp.float32), r_cache.astype(jnp.float32)
    )
    s = s / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (B,))
    valid = jnp.arange(S)[None, :] <= pos_b[:, None]
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    lat_out = jnp.einsum("bhs,bsr->bhr", w, c_cache.astype(jnp.float32))
    v_out = jnp.einsum("bhr,rhv->bhv", lat_out.astype(x.dtype), p["wv_b"])
    y = jnp.einsum("bhv,hvd->bd", v_out, p["wo"])[:, None]
    return y, {"c_kv": c_cache, "k_rope": r_cache}

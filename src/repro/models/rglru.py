"""RG-LRU recurrent block — recurrentgemma-9b / Griffin [arXiv:2402.19427].

The Griffin recurrent block: in-proj to two branches — a GeLU gate branch
and a (causal conv → RG-LRU) branch — multiplied and projected out.  The
RG-LRU recurrence per channel::

    r_t = σ(W_a u_t + b_a)          (recurrence gate)
    i_t = σ(W_x u_t + b_x)          (input gate)
    log a_t = −c · softplus(Λ) · r_t          (c = 8)
    h_t = a_t ⊙ h_{t−1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ u_t)

Training/prefill uses ``jax.lax.associative_scan`` over the sequence (the
pair combine (a₂a₁, a₂b₁+b₂)); decode is the single-step update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.config import ArchConfig
from repro.models.param import ParamDef

_C = 8.0


def rglru_defs(cfg: ArchConfig) -> dict:
    h = cfg.hybrid
    D = cfg.d_model
    W = h.lru_width or D
    return {
        "in_x": ParamDef((D, W), ("embed", "ff")),
        "in_gate": ParamDef((D, W), ("embed", "ff")),
        "conv_w": ParamDef((h.conv_width, W), (None, "ff")),
        "conv_b": ParamDef((W,), ("ff",), init="zeros"),
        "w_a": ParamDef((W, W), (None, "ff")),
        "b_a": ParamDef((W,), ("ff",), init="zeros"),
        "w_x": ParamDef((W, W), (None, "ff")),
        "b_x": ParamDef((W,), ("ff",), init="zeros"),
        "Lambda": ParamDef((W,), ("ff",), init="const", scale=4.0),
        "out_proj": ParamDef((W, D), ("ff", "embed")),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array, state=None):
    Wd = w.shape[0]
    if state is not None:
        u_full = jnp.concatenate([state, u], axis=1)
    else:
        u_full = jnp.pad(u, ((0, 0), (Wd - 1, 0), (0, 0)))
    L = u.shape[1]
    y = sum(u_full[:, i : i + L] * w[i] for i in range(Wd))
    new_state = u_full[:, -(Wd - 1) :]
    return y + b, new_state


def _gates(p: dict, u: jax.Array):
    """u (B,L,W) → (log_a (fp32), gated input (fp32))."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("blw,wv->blv", uf, p["w_a"].astype(jnp.float32)) + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("blw,wv->blv", uf, p["w_x"].astype(jnp.float32)) + p["b_x"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["Lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, beta * (i * uf)


def rglru_scan(a: jax.Array, b: jax.Array, h0=None):
    """h_t = a_t h_{t−1} + b_t over axis 1 via associative scan."""
    if h0 is not None:
        # fold the initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_apply(p: dict, x: jax.Array, cfg: ArchConfig, *, want_cache: bool = False):
    """Griffin recurrent block; x (B,L,D) → (y (B,L,D), cache|None)."""
    gate = jax.nn.gelu(jnp.einsum("bld,dw->blw", x, p["in_gate"]), approximate=True)
    u = jnp.einsum("bld,dw->blw", x, p["in_x"])
    u, conv_state = _causal_conv(u, p["conv_w"], p["conv_b"])
    a, b = _gates(p, u)
    h = rglru_scan(a, b)
    y = (h.astype(x.dtype) * gate)
    out = jnp.einsum("blw,wd->bld", y, p["out_proj"])
    out = shard(out, "batch", "act_seq", None)
    if want_cache:
        return out, {"h": h[:, -1].astype(jnp.float32), "conv": conv_state}
    return out, None


def rglru_decode_step(p: dict, x: jax.Array, cache: dict, cfg: ArchConfig):
    """x (B,1,D); cache {'h': (B,W) fp32, 'conv': (B,conv_width-1,W)}."""
    gate = jax.nn.gelu(jnp.einsum("bld,dw->blw", x, p["in_gate"]), approximate=True)
    u = jnp.einsum("bld,dw->blw", x, p["in_x"])
    u, conv_state = _causal_conv(u, p["conv_w"], p["conv_b"], state=cache["conv"])
    a, b = _gates(p, u)
    h = a[:, 0] * cache["h"] + b[:, 0]  # (B,W)
    y = h[:, None].astype(x.dtype) * gate
    out = jnp.einsum("blw,wd->bld", y, p["out_proj"])
    return out, {"h": h, "conv": conv_state}

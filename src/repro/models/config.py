"""Architecture + shape configuration system.

Every assigned architecture (see DESIGN.md §4) is expressed as an
:class:`ArchConfig`; the four assigned input shapes as :class:`ShapeSpec`.
Configs are pure data — models are built functionally from them
(``models/transformer.py``), and perf knobs (remat, dispatch strategy, KV
update strategy, logits chunking) live here so §Perf iterations are
config-diffs, not code forks.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # 'einsum'  — GShard one-hot dispatch (baseline; inflates HLO FLOPs)
    # 'scatter' — sort/scatter dispatch (optimized; matmul FLOPs ≈ useful)
    dispatch: str = "einsum"
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2 / MiniCPM3)."""

    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD (state-space duality)."""

    d_state: int
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma / Griffin: RG-LRU blocks interleaved with local attn."""

    pattern: tuple[str, ...] = ("rec", "rec", "attn")
    lru_width: Optional[int] = None  # defaults to d_model
    conv_width: int = 4
    window: int = 2048


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "swiglu"  # swiglu | geglu | gelu
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    attn_window: Optional[int] = None  # sliding-window size, None = full
    embed_scale: bool = False  # gemma-style sqrt(d) embedding scale
    logit_softcap: Optional[float] = None

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None

    # modality frontend stub: None | 'audio' | 'vision'
    frontend: Optional[str] = None
    # for vlm: number of image patch positions prepended to the text sequence
    n_patches: int = 256

    # ---- numerics / performance knobs (the §Perf levers) -------------------
    dtype: str = "bfloat16"
    remat: str = "full"  # none | full | dots_saveable
    scan_layers: bool = True
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    # 'masked': every (q,kv) block pair computed+masked (baseline)
    # 'tri': causal/window block ranges honoured structurally (~half FLOPs)
    # 'auto': tri when query heads divide the mesh model axis (§Perf)
    attn_mode: str = "masked"
    # materialize-scores threshold: below this seq len the simple reference
    # attention is used; above, the blockwise (flash-style) scan
    attn_blockwise_min_seq: int = 2048
    use_pallas: bool = False
    logits_chunk: Optional[int] = None  # chunked cross-entropy over sequence
    optimizer: str = "adamw"  # adamw | adafactor
    opt_state_dtype: str = "float32"
    kv_update: str = "onehot"  # onehot | dus
    # decode KV cache layout: 'seq' shards the cache sequence dim over the
    # model axis (flash-decoding combine); 'heads' shards kv heads instead
    # (local updates — pairs with kv_update='dus'; needs n_kv % model == 0)
    kv_shard: str = "seq"
    # embedding/logits tables are allocated padded to this multiple so the
    # vocab dim shards on any mesh (Megatron-style vocab padding); pad
    # logits are masked to −inf in the loss. 128 covers model≤128 × lanes.
    vocab_pad_multiple: int = 128
    # probe mode: unroll inner loops (flash kv blocks, CE chunks) so XLA
    # cost_analysis counts them; deployable configs keep lax.scan (memory)
    probe_unroll: bool = False
    # activation sharding for the scan carry: 'seq' (Megatron-SP-like) or
    # 'embed' or 'none'
    act_shard: str = "seq"

    # ------------------------------------------------------------------ utils

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab // m) * m

    @property
    def is_encoder(self) -> bool:
        return self.family == "encoder"

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_decode(self) -> bool:
        return not self.is_encoder

    @property
    def subquadratic(self) -> bool:
        """Can this arch run long_500k (bounded per-token state)?"""
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            return True
        return self.attn_window is not None

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (MODEL_FLOPS = 6·N·D; N_active for MoE) ---------

    def param_count(self) -> int:
        return _count_params(self, active_only=False)

    def active_param_count(self) -> int:
        return _count_params(self, active_only=True)


def _count_params(cfg: ArchConfig, active_only: bool) -> int:
    d = cfg.d_model
    n = 0
    # embeddings (+ untied head)
    n += cfg.vocab * d
    if not cfg.tie_embeddings:
        n += cfg.vocab * d

    def attn_params() -> int:
        if cfg.mla is not None:
            m = cfg.mla
            p = d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * (
                m.qk_nope_head_dim + m.qk_rope_head_dim
            )
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            p += m.kv_lora_rank * cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            p += cfg.n_heads * m.v_head_dim * d
            return p
        q = d * cfg.n_heads * cfg.head_dim
        kv = 2 * d * cfg.n_kv_heads * cfg.head_dim
        o = cfg.n_heads * cfg.head_dim * d
        b = (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim if cfg.qkv_bias else 0
        return q + kv + o + b

    def mlp_params(ff: int) -> int:
        mult = 3 if cfg.act in ("swiglu", "geglu") else 2
        return mult * d * ff

    def ssm_params() -> int:
        s = cfg.ssm
        d_in = s.expand * d
        nh = d_in // s.head_dim
        p = d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)  # in_proj
        p += d_in * d  # out_proj
        p += 4 * (d_in + 2 * s.n_groups * s.d_state)  # conv
        p += 2 * nh  # A_log, D
        return p

    def rglru_params() -> int:
        h = cfg.hybrid
        w = h.lru_width or d
        p = 2 * d * w  # in_proj (x and gate branches)
        p += h.conv_width * w  # temporal conv
        p += 2 * w  # Lambda, input-gate params (diagonal)
        p += 2 * w * w  # recurrent/input gates (per-channel dense blocks, approx)
        p += w * d  # out_proj
        return p

    for li in range(cfg.n_layers):
        n += 2 * d  # two rmsnorm scales
        if cfg.family == "ssm":
            n += ssm_params()
            continue
        if cfg.family == "hybrid":
            kind = cfg.hybrid.pattern[li % len(cfg.hybrid.pattern)]
            n += rglru_params() if kind == "rec" else attn_params()
            n += mlp_params(cfg.d_ff)
            continue
        n += attn_params()
        if cfg.moe is not None:
            e_params = mlp_params(cfg.moe.d_ff_expert)
            n_routed = cfg.moe.top_k if active_only else cfg.moe.n_experts
            n += n_routed * e_params
            n += cfg.moe.n_shared_experts * e_params
            n += d * cfg.moe.n_experts  # router
        else:
            n += mlp_params(cfg.d_ff)
    n += d  # final norm
    return n


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable_shapes(cfg: ArchConfig) -> list[ShapeSpec]:
    """The assigned-cell rules (DESIGN.md §4): encoder-only archs have no
    decode shapes; ``long_500k`` only for sub-quadratic archs."""
    out = []
    for s in SHAPES.values():
        if s.kind == "decode" and not cfg.supports_decode:
            continue
        if s.name == "long_500k" and not cfg.subquadratic:
            continue
        out.append(s)
    return out

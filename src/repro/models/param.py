"""Parameter definition system — single source of truth for shapes, logical
sharding axes, and initializers.

Each model module exposes ``*_defs(cfg) -> dict[str, ParamDef | dict]``;
from one defs tree we derive:

* :func:`init_tree` — materialized arrays (smoke tests, examples);
* :func:`abstract_tree` — ``ShapeDtypeStruct`` stand-ins (dry-run; no
  allocation, the shannon/kernels pattern);
* :func:`axes_tree` / :func:`sharding_tree` — logical axes → NamedShardings
  for ``jax.jit`` in_shardings.

Scanned layer stacks: :func:`stack_defs` prepends a ``layers`` dimension.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import current_mesh, named_sharding


@dataclass
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | embed | small
    dtype: Optional[str] = None  # None → model dtype
    scale: Optional[float] = None  # stddev override

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} / axes {self.axes} rank mismatch")


def stack_defs(defs, n: int):
    """Prepend a scanned ``layers`` dimension to every leaf."""
    if isinstance(defs, ParamDef):
        return ParamDef((n,) + defs.shape, ("layers",) + defs.axes, defs.init, defs.dtype, defs.scale)
    if isinstance(defs, (list, tuple)):
        return type(defs)(stack_defs(v, n) for v in defs)
    return {k: stack_defs(v, n) for k, v in defs.items()}


def _is_leaf(x) -> bool:
    return isinstance(x, ParamDef)


def _map_defs(fn, defs):
    if _is_leaf(defs):
        return fn(defs)
    if isinstance(defs, (list, tuple)):
        return type(defs)(_map_defs(fn, v) for v in defs)
    return {k: _map_defs(fn, v) for k, v in defs.items()}


def _stddev(d: ParamDef) -> float:
    if d.scale is not None:
        return d.scale
    shape = d.shape
    # ignore leading layer-stack dim for fan-in purposes
    core = shape[1:] if (d.axes and d.axes[0] == "layers" and len(shape) > 1) else shape
    if d.init == "embed":
        return 1.0
    fan_in = core[0] if len(core) >= 2 else max(core[-1], 1)
    return 1.0 / math.sqrt(max(fan_in, 1))


def init_tree(defs, rng: jax.Array, dtype: str = "bfloat16"):
    """Materialize parameters (host-scale configs only)."""
    leaves: list[ParamDef] = []
    _map_defs(lambda d: leaves.append(d) or d, defs)
    keys = jax.random.split(rng, max(len(leaves), 1))
    it = iter(range(len(leaves)))

    def mk(d: ParamDef):
        dt = jnp.dtype(d.dtype or dtype)
        i = next(it)
        if d.init == "zeros":
            return jnp.zeros(d.shape, dt)
        if d.init == "ones":
            return jnp.ones(d.shape, dt)
        if d.init == "const":
            return jnp.full(d.shape, d.scale or 0.0, dt)
        std = _stddev(d)
        return (jax.random.normal(keys[i], d.shape, jnp.float32) * std).astype(dt)

    return _map_defs(mk, defs)


def abstract_tree(defs, dtype: str = "bfloat16"):
    """ShapeDtypeStruct tree, sharded when a mesh context is active."""

    def mk(d: ParamDef):
        dt = jnp.dtype(d.dtype or dtype)
        sh = named_sharding(d.shape, d.axes) if current_mesh() is not None else None
        return jax.ShapeDtypeStruct(d.shape, dt, sharding=sh)

    return _map_defs(mk, defs)


def axes_tree(defs):
    return _map_defs(lambda d: d.axes, defs)


def sharding_tree(defs):
    """NamedSharding tree (requires an active mesh context)."""
    return _map_defs(lambda d: named_sharding(d.shape, d.axes), defs)


def count_params(defs) -> int:
    total = 0

    def acc(d: ParamDef):
        nonlocal total
        n = 1
        for s in d.shape:
            n *= s
        total += n
        return d

    _map_defs(acc, defs)
    return total

"""Attention family: GQA/MQA/MHA, sliding-window, blockwise (flash-style)
training/prefill attention, sequence-sharded decode attention, KV caches.

Memory discipline: above ``cfg.attn_blockwise_min_seq`` the O(S²) score
matrix is never materialized — a lax.scan over KV blocks carries online
softmax statistics (the FlashAttention recurrence in pure JAX).  This is the
*reference* path; ``repro/kernels/flash_attention`` is the Pallas TPU
version of the same tiling (VMEM-resident blocks), validated against it.

Two blockwise modes (a §Perf lever):

* ``masked`` — every (q-block, kv-block) pair is computed and masked: simple,
  fully vectorized, but causal masking wastes ~2× FLOPs.
* ``tri``    — per-q-block KV ranges honour causality/window structurally:
  ~half the FLOPs for causal, bounded work for sliding windows.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.config import ArchConfig
from repro.models.layers import apply_rope
from repro.models.param import ParamDef

NEG_INF = -1e30
# unroll threshold for the flash kv-block loops (see _make_flash docstring)
_UNROLL_MAX = 64


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def attn_defs(cfg: ArchConfig) -> dict:
    D, H, KH, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    out = {
        "wq": ParamDef((D, H, Dh), ("embed", "heads", "head_dim")),
        "wk": ParamDef((D, KH, Dh), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((D, KH, Dh), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((H, Dh, D), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        out["bq"] = ParamDef((H, Dh), ("heads", "head_dim"), init="zeros")
        out["bk"] = ParamDef((KH, Dh), ("kv_heads", "head_dim"), init="zeros")
        out["bv"] = ParamDef((KH, Dh), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        out["q_norm"] = ParamDef((Dh,), (None,), init="zeros")
        out["k_norm"] = ParamDef((Dh,), (None,), init="zeros")
    return out


def _qk_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def qkv_project(p: dict, x: jax.Array, positions: jax.Array, cfg: ArchConfig):
    """x (B, L, D) → q (B, L, H, Dh), k/v (B, L, KH, Dh), RoPE applied."""
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"])
    k = jnp.einsum("bld,dhk->blhk", x, p["wk"])
    v = jnp.einsum("bld,dhk->blhk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = _qk_norm(q, p["q_norm"], cfg.norm_eps)
        k = _qk_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    return q, k, v


# ---------------------------------------------------------------------------
# Reference (materializing) attention — small sequences & test oracle
# ---------------------------------------------------------------------------

def reference_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jax.Array:
    B, Lq, H, Dh = q.shape
    _, Lk, KH, _ = k.shape
    G = H // KH
    if G > 1:
        # expand KV to query heads: local per-shard once heads are sharded,
        # and keeps the score tensor cleanly head-sharded (no (KH, G) split
        # that defeats the SPMD partitioner when KH < mesh model size)
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s / math.sqrt(Dh)
    qpos = q_offset + jnp.arange(Lq)
    kpos = jnp.arange(Lk)
    mask = jnp.ones((Lq, Lk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention
# ---------------------------------------------------------------------------

def _block_ranges(nq: int, bq: int, bk: int, causal: bool, window: Optional[int]):
    """Static per-q-block [lo, hi) KV-block ranges for ``tri`` mode."""
    rng = []
    for qi in range(nq):
        q_lo, q_hi = qi * bq, (qi + 1) * bq - 1
        hi_blk = (q_hi // bk) + 1 if causal else None
        lo_blk = 0
        if window is not None:
            lo_blk = max(0, (q_lo - window + 1) // bk)
        rng.append((lo_blk, hi_blk))
    return rng


import functools


@functools.lru_cache(maxsize=64)
def _make_flash(causal: bool, window: Optional[int], block_kv: int, q_offset: int, mode: str, unroll: bool = False):
    """Factory for a custom-VJP blockwise attention with the FlashAttention-2
    backward: residuals are only (q, k, v, out, lse) — scores are recomputed
    per KV block in the backward scan, so memory back through the layer-remat
    boundary is O(L), not O(L²).  This is the pure-JAX mirror of
    ``kernels/flash_attention``."""

    def _mask(qpos, kpos):
        msk = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
        if causal:
            msk &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            msk &= qpos[:, None] - kpos[None, :] < window
        return msk

    def _fwd_scan(q, k, v):
        B, Lq, H, Dh = q.shape
        _, Lk, KH, _ = k.shape
        Dv = v.shape[-1]
        G = H // KH
        if G > 1:  # expand KV to query heads (see reference_attention note)
            k = jnp.repeat(k, G, axis=2)
            v = jnp.repeat(v, G, axis=2)
        bk = min(block_kv, Lk)
        nk = Lk // bk
        scale = 1.0 / math.sqrt(Dh)
        kb = k.reshape(B, nk, bk, H, Dh).swapaxes(0, 1)
        vb = v.reshape(B, nk, bk, H, Dv).swapaxes(0, 1)
        qpos = q_offset + jnp.arange(Lq)

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, k_blk, v_blk = inp
            s = jnp.einsum(
                "bqhd,bkhd->bhqk", q, k_blk, preferred_element_type=jnp.float32
            ) * scale
            kpos = ki * bk + jnp.arange(bk)
            s = jnp.where(_mask(qpos, kpos), s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(v_blk.dtype), v_blk
            ).astype(jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l, acc), None

        m0 = jnp.full((B, H, Lq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, Lq), jnp.float32)
        a0 = jnp.zeros((B, H, Lq, Dv), jnp.float32)
        if unroll and nk <= _UNROLL_MAX:
            # probe mode: XLA cost_analysis sees every block (lax.scan bodies
            # are counted once); deployable configs use the scan (memory)
            carry = (m0, l0, a0)
            for ki in range(nk):
                carry, _ = kv_step(carry, (jnp.int32(ki), kb[ki], vb[ki]))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (jnp.arange(nk), kb, vb))
        l_safe = jnp.maximum(l, 1e-30)
        out = (acc / l_safe[..., None]).transpose(0, 2, 1, 3)  # (B,Lq,H,Dv)
        lse = m + jnp.log(l_safe)  # (B,H,Lq)
        return out.astype(q.dtype), lse

    @jax.custom_vjp
    def flash(q, k, v):
        return _fwd_scan(q, k, v)[0]

    def fwd(q, k, v):
        out, lse = _fwd_scan(q, k, v)
        return out, (q, k, v, out, lse)

    def bwd(res, dout):
        q, k, v, out, lse = res
        B, Lq, H, Dh = q.shape
        _, Lk, KH, _ = k.shape
        Dv = v.shape[-1]
        G = H // KH
        if G > 1:
            k = jnp.repeat(k, G, axis=2)
            v = jnp.repeat(v, G, axis=2)
        bk = min(block_kv, Lk)
        nk = Lk // bk
        scale = 1.0 / math.sqrt(Dh)
        kb = k.reshape(B, nk, bk, H, Dh).swapaxes(0, 1)
        vb = v.reshape(B, nk, bk, H, Dv).swapaxes(0, 1)
        do = dout.transpose(0, 2, 1, 3).astype(jnp.float32)  # (B,H,Lq,Dv)
        og = out.transpose(0, 2, 1, 3).astype(jnp.float32)
        Dvec = jnp.sum(do * og, axis=-1)  # (B,H,Lq)
        qpos = q_offset + jnp.arange(Lq)

        def kv_step(dq_acc, inp):
            ki, k_blk, v_blk = inp
            s = jnp.einsum(
                "bqhd,bkhd->bhqk", q, k_blk, preferred_element_type=jnp.float32
            ) * scale
            kpos = ki * bk + jnp.arange(bk)
            s = jnp.where(_mask(qpos, kpos), s, NEG_INF)
            p = jnp.exp(s - lse[..., None])  # recomputed probabilities
            dv_b = jnp.einsum("bhqk,bhqv->bkhv", p, do)
            dp = jnp.einsum("bhqv,bkhv->bhqk", do, v_blk.astype(jnp.float32))
            ds = p * (dp - Dvec[..., None]) * scale
            dq_acc = dq_acc + jnp.einsum("bhqk,bkhd->bqhd", ds, k_blk.astype(jnp.float32))
            dk_b = jnp.einsum("bhqk,bqhd->bkhd", ds, q.astype(jnp.float32))
            return dq_acc, (dk_b, dv_b)

        dq0 = jnp.zeros((B, Lq, H, Dh), jnp.float32)
        if unroll and nk <= _UNROLL_MAX:
            dq = dq0
            dk_list, dv_list = [], []
            for ki in range(nk):
                dq, (dk_b, dv_b) = kv_step(dq, (jnp.int32(ki), kb[ki], vb[ki]))
                dk_list.append(dk_b)
                dv_list.append(dv_b)
            dks = jnp.stack(dk_list)
            dvs = jnp.stack(dv_list)
        else:
            dq, (dks, dvs) = jax.lax.scan(kv_step, dq0, (jnp.arange(nk), kb, vb))
        dq = dq.astype(q.dtype)
        dk = dks.swapaxes(0, 1).reshape(B, Lk, H, Dh)
        dv = dvs.swapaxes(0, 1).reshape(B, Lk, H, Dv)
        if G > 1:  # fold expanded-head grads back onto the KV heads
            dk = dk.reshape(B, Lk, KH, G, Dh).sum(axis=3)
            dv = dv.reshape(B, Lk, KH, G, Dv).sum(axis=3)
        return dq, dk.astype(res[1].dtype), dv.astype(res[2].dtype)

    flash.defvjp(fwd, bwd)
    return flash


def blockwise_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 512,
    block_kv: int = 1024,
    q_offset: int = 0,
    mode: str = "masked",
    unroll: bool = False,
) -> jax.Array:
    """Online-softmax attention; never materializes (Lq, Lk) scores."""
    if mode == "masked":
        fn = _make_flash(causal, window, block_kv, q_offset, mode, unroll)
        return fn(q, k, v)
    B, Lq, H, Dh = q.shape
    _, Lk, KH, _ = k.shape
    Dv = v.shape[-1]
    if H != KH:  # expand KV to query heads (see reference_attention note)
        k = jnp.repeat(k, H // KH, axis=2)
        v = jnp.repeat(v, H // KH, axis=2)
        KH = H
    G = 1
    bq = min(block_q, Lq)
    bk = min(block_kv, Lk)
    assert Lq % bq == 0 and Lk % bk == 0, (Lq, bq, Lk, bk)
    nq, nk = Lq // bq, Lk // bk
    scale = 1.0 / math.sqrt(Dh)

    qb = q.reshape(B, nq, bq, KH, G, Dh)
    kb = k.reshape(B, nk, bk, KH, Dh)
    vb = v.reshape(B, nk, bk, KH, Dv)

    def step(carry, inp, qi_base, q_blk):
        m, l, acc = carry
        ki, k_blk, v_blk = inp
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk, preferred_element_type=jnp.float32) * scale
        qpos = qi_base + jnp.arange(bq)
        kpos = ki * bk + jnp.arange(bk)
        msk = jnp.ones((bq, bk), bool)
        if causal:
            msk &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            msk &= qpos[:, None] - kpos[None, :] < window
        s = jnp.where(msk, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk).astype(jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    def run_qblock(qi_idx, q_blk, k_sel, v_sel, n_sel, k_idx0):
        qi_base = q_offset + qi_idx * bq
        m0 = jnp.full((B, KH, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KH, G, bq), jnp.float32)
        a0 = jnp.zeros((B, KH, G, bq, Dv), jnp.float32)
        idxs = k_idx0 + jnp.arange(n_sel)
        (m, l, acc), _ = jax.lax.scan(
            lambda c, i: step(c, i, qi_base, q_blk),
            (m0, l0, a0),
            (idxs, k_sel.swapaxes(0, 1), v_sel.swapaxes(0, 1)),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # (B, KH, G, bq, Dh)

    # 'tri': python loop over q blocks with static KV ranges
    ranges = _block_ranges(nq, bq, bk, causal, window)
    blocks = []
    for qi in range(nq):
        lo, hi = ranges[qi]
        hi = nk if hi is None else min(hi, nk)
        k_sel = kb[:, lo:hi]
        v_sel = vb[:, lo:hi]
        o = run_qblock(qi, qb[:, qi], k_sel, v_sel, hi - lo, lo)
        blocks.append(o)
    out = jnp.stack(blocks, axis=0)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Lq, H, Dv)
    return out.astype(q.dtype)


def full_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    cfg: ArchConfig,
    *,
    causal: bool,
    window: Optional[int] = None,
    q_offset: int = 0,
    mode: Optional[str] = None,
) -> jax.Array:
    """Dispatch: reference below the blockwise threshold, blockwise above;
    Pallas kernel when enabled on TPU (kernels/flash_attention/ops.py)."""
    Lq = q.shape[1]
    if cfg.use_pallas:
        from repro.kernels.flash_attention import ops as fa_ops

        if fa_ops.available() and Lq >= cfg.attn_blockwise_min_seq:
            return fa_ops.flash_attention(
                q, k, v, causal=causal, window=window, q_offset=q_offset
            )
    if Lq < cfg.attn_blockwise_min_seq:
        return reference_attention(q, k, v, causal=causal, window=window, q_offset=q_offset)
    mode = mode or cfg.attn_mode
    if mode == "auto":
        # tri needs head-sharded attention (EXPERIMENTS.md §Perf bonus round:
        # replicated heads make the per-q-block buffers explode); eligible
        # when the query heads divide the mesh model axis (or no mesh)
        from repro.dist.sharding import current_mesh

        mesh = current_mesh()
        model_size = mesh.shape.get("model", 1) if mesh is not None else 1
        mode = "tri" if (causal and q.shape[2] % max(model_size, 1) == 0) else "masked"
    return blockwise_attention(
        q,
        k,
        v,
        causal=causal,
        window=window,
        block_q=cfg.attn_block_q,
        block_kv=cfg.attn_block_kv,
        q_offset=q_offset,
        mode=mode,
        unroll=cfg.probe_unroll,
    )


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

def kv_cache_shape(cfg: ArchConfig, batch: int, max_seq: int) -> tuple[int, ...]:
    W = cfg.attn_window
    S = min(max_seq, W) if W is not None else max_seq
    return (batch, S, cfg.n_kv_heads, cfg.head_dim)


def kv_cache_axes(cfg: ArchConfig = None) -> tuple:
    if cfg is not None and cfg.kv_shard == "heads":
        return ("batch", None, "kv_heads", None)
    return ("batch", "kv_seq", "kv_heads", None)


def kv_cache_update(
    cache: jax.Array, new: jax.Array, slot: jax.Array, strategy: str = "onehot"
) -> jax.Array:
    """Write ``new`` (B, 1, KH, Dh) at ``slot`` into the S-dim-sharded cache.
    ``slot`` may be a traced scalar or a per-sequence (B,) vector
    (continuous batching: sequences at different positions).

    * ``onehot``: cache·(1−δ) + new·δ — fully shardable select; writes the
      whole cache (bandwidth-inflated baseline).
    * ``dus``: dynamic-update-slice on the sequence dim (scalar slot only);
      relies on the SPMD partitioner's DUS handling (the §Perf alternative).
    """
    slot = jnp.asarray(slot)
    if strategy == "dus" and slot.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(cache, new.astype(cache.dtype), slot, axis=1)
    S = cache.shape[1]
    if slot.ndim == 0:
        oh = (jnp.arange(S) == slot).astype(cache.dtype)[None, :, None, None]
    else:  # per-sequence slots
        oh = (jnp.arange(S)[None, :] == slot[:, None]).astype(cache.dtype)[:, :, None, None]
    return cache * (1 - oh) + new.astype(cache.dtype) * oh


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    window: Optional[int] = None,
) -> jax.Array:
    """One-token attention against an (optionally ring-buffered) cache.

    q: (B, 1, H, Dh); caches (B, S, KH, Dh) — S is sequence-sharded on the
    ``model`` axis, so the softmax/weighted-sum reductions over S become
    cross-shard collectives (flash-decoding-style combine, inserted by SPMD).
    pos: scalar int32 — tokens processed so far (the new token's position).
    """
    B, _, H, Dh = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    qg = q.reshape(B, KH, G, Dh)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache, preferred_element_type=jnp.float32)
    s = s / math.sqrt(Dh)
    slots = jnp.arange(S)
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (B,))  # scalar or (B,)
    if window is None:
        valid = slots[None, :] <= pos_b[:, None]
    else:
        # ring buffer: slot i holds absolute position p ≡ i (mod S) with
        # p in (pos−S, pos]; everything stored is within the window by
        # construction once S == window
        valid = slots[None, :] < jnp.minimum(pos_b + 1, S)[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", w.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, Dh)


def attention_decode_step(
    p: dict,
    x: jax.Array,
    cache: dict,
    pos: jax.Array,
    cfg: ArchConfig,
):
    """x: (B, 1, D) new-token activations; cache: {'k','v'} ring or full.

    Returns (out (B,1,D), new_cache).
    """
    positions = jnp.broadcast_to(
        jnp.asarray(pos, jnp.int32).reshape(-1, 1), (x.shape[0], 1)
    )
    q, k, v = qkv_project(p, x, positions, cfg)
    S = cache["k"].shape[1]
    slot = pos % S if cfg.attn_window is not None else pos
    k_cache = kv_cache_update(cache["k"], k, slot, cfg.kv_update)
    v_cache = kv_cache_update(cache["v"], v, slot, cfg.kv_update)
    out = decode_attention(q, k_cache, v_cache, pos, window=cfg.attn_window)
    out = jnp.einsum("blhk,hkd->bld", out, p["wo"])
    return out, {"k": k_cache, "v": v_cache}


def attention_apply(
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ArchConfig,
    *,
    causal: bool = True,
    want_cache: bool = False,
):
    """Training / prefill attention over the full sequence."""
    q, k, v = qkv_project(p, x, positions, cfg)
    out = full_attention(q, k, v, cfg, causal=causal, window=cfg.attn_window)
    y = jnp.einsum("blhk,hkd->bld", out, p["wo"])
    y = shard(y, "batch", "act_seq", None)
    if want_cache:
        return y, {"k": k, "v": v}
    return y, None

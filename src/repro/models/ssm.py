"""Mamba-2 SSD (state-space duality) layer — mamba2-130m [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: within chunks the output is
an attention-like masked matmul (MXU-friendly); across chunks a short
recurrence over per-chunk states (lax.scan over L/chunk steps).  Decode is
the O(1) state update.  ``repro/kernels/ssd`` holds the Pallas version of
the intra-chunk kernel; this module is the pure-jnp reference path.

Shapes: x (B, L, D) → in_proj → z (gate), xh (B,L,H,P), B̄/C̄ (B,L,G,N),
dt (B,L,H); state (B,H,P,N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.config import ArchConfig
from repro.models.param import ParamDef


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    return s, d_in, n_heads


def ssm_defs(cfg: ArchConfig) -> dict:
    s, d_in, H = _dims(cfg)
    D = cfg.d_model
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return {
        "in_proj": ParamDef((D, 2 * d_in + 2 * s.n_groups * s.d_state + H), ("embed", "ff")),
        "conv_w": ParamDef((4, conv_ch), (None, "ff")),
        "conv_b": ParamDef((conv_ch,), ("ff",), init="zeros"),
        "A_log": ParamDef((H,), (None,), init="ones"),
        "D": ParamDef((H,), (None,), init="ones"),
        "dt_bias": ParamDef((H,), (None,), init="const", scale=-4.0),
        "norm": ParamDef((d_in,), ("ff",), init="zeros"),
        "out_proj": ParamDef((d_in, D), ("ff", "embed")),
    }


def _split_proj(p: dict, x: jax.Array, cfg: ArchConfig):
    s, d_in, H = _dims(cfg)
    gn = s.n_groups * s.d_state
    zxbcdt = jnp.einsum("bld,df->blf", x, p["in_proj"])
    z, xh, Bc, Cc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in, 2 * d_in + gn, 2 * d_in + 2 * gn], axis=-1)
    return z, xh, Bc, Cc, dt


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array, state=None):
    """Depthwise causal conv, width 4, via shifted adds.  u (B, L, C).
    If ``state`` (B, 3, C) is given (decode), prepends it."""
    W = w.shape[0]
    if state is not None:
        u_full = jnp.concatenate([state, u], axis=1)
    else:
        u_full = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    L = u.shape[1]
    y = sum(u_full[:, i : i + L] * w[i] for i in range(W))
    new_state = u_full[:, -(W - 1) :] if W > 1 else None
    return jax.nn.silu(y + b), new_state


def _expand_groups(t: jax.Array, H: int, G: int, N: int) -> jax.Array:
    """(B, L, G*N) → (B, L, H, N) broadcasting groups across their heads."""
    B, L, _ = t.shape
    t = t.reshape(B, L, G, N)
    return jnp.repeat(t, H // G, axis=2)


def ssd_chunked(xh, dt, A, Bc, Cc, chunk: int, initial_state=None):
    """Chunked SSD scan.  xh (B,L,H,P), dt (B,L,H) [post-softplus],
    A (H,) [negative], Bc/Cc (B,L,H,N).  Returns (y (B,L,H,P), final_state).
    """
    B, L, H, P = xh.shape
    N = Bc.shape[-1]
    L0 = L
    if L % chunk:
        # pad to a chunk multiple: dt=0 padding means decay exp(0)=1 and zero
        # state update — the recurrence is unaffected, padded y is discarded
        pad = chunk - L % chunk
        zpad = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        xh, dt, Bc, Cc = zpad(xh), zpad(dt), zpad(Bc), zpad(Cc)
        L = L + pad
    nc = L // chunk

    r = lambda t: t.reshape((B, nc, chunk) + t.shape[2:])
    xc, dtc, Bcc, Ccc = r(xh), r(dt), r(Bc), r(Cc)
    lg = dtc * A  # (B,nc,cs,H) log-decay, negative
    cum = jnp.cumsum(lg, axis=2)  # within-chunk cumulative decay

    # ---- intra-chunk (the "attention-like" quadratic part) ------------------
    # decay matrix Lmat[i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,i,j,H)
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    Lmat = jnp.where(causal, jnp.exp(diff), 0.0)  # fp32
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Ccc.astype(jnp.float32), Bcc.astype(jnp.float32))
    w = scores * Lmat * dtc[:, :, None, :, :]  # weight x_j by dt_j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xc.astype(jnp.float32))

    # ---- per-chunk states ----------------------------------------------------
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,cs,H)
    states = jnp.einsum(
        "bcjhn,bcjh,bcjhp->bchnp",
        Bcc.astype(jnp.float32),
        (decay_to_end * dtc).astype(jnp.float32),
        xc.astype(jnp.float32),
    )  # (B,nc,H,N,P)

    # ---- inter-chunk recurrence ---------------------------------------------
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H)
    s0 = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((B, H, N, P), jnp.float32)
    )

    def body(s_prev, inp):
        dec, st = inp  # dec (B,H), st (B,H,N,P)
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    (s_final, s_prevs) = jax.lax.scan(
        body, s0, (chunk_decay.swapaxes(0, 1), states.swapaxes(0, 1))
    )
    s_prevs = s_prevs.swapaxes(0, 1)  # (B,nc,H,N,P) state entering each chunk

    # ---- inter-chunk contribution --------------------------------------------
    y_inter = jnp.einsum(
        "bcihn,bcih,bchnp->bcihp",
        Ccc.astype(jnp.float32),
        jnp.exp(cum),
        s_prevs,
    )
    y = (y_intra + y_inter).reshape(B, L, H, P)
    return y[:, :L0], s_final


def ssd_naive(xh, dt, A, Bc, Cc, initial_state=None):
    """O(L) sequential recurrence — test oracle for ``ssd_chunked``."""
    B, L, H, P = xh.shape
    N = Bc.shape[-1]
    s = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((B, H, N, P), jnp.float32)
    )

    def body(s, t):
        x_t, dt_t, B_t, C_t = t
        a = jnp.exp(dt_t * A)  # (B,H)
        upd = jnp.einsum("bhn,bh,bhp->bhnp", B_t, dt_t, x_t)
        s = s * a[..., None, None] + upd
        y = jnp.einsum("bhn,bhnp->bhp", C_t, s)
        return s, y

    xs = (
        xh.swapaxes(0, 1).astype(jnp.float32),
        dt.swapaxes(0, 1).astype(jnp.float32),
        Bc.swapaxes(0, 1).astype(jnp.float32),
        Cc.swapaxes(0, 1).astype(jnp.float32),
    )
    s_final, ys = jax.lax.scan(body, s, xs)
    return ys.swapaxes(0, 1), s_final


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return yf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))


def ssm_apply(p: dict, x: jax.Array, cfg: ArchConfig, *, want_cache: bool = False):
    """Training / prefill path.  x (B,L,D) → (y (B,L,D), cache|None)."""
    s, d_in, H = _dims(cfg)
    z, xh, Bc, Cc, dt = _split_proj(p, x, cfg)
    conv_in = jnp.concatenate([xh, Bc, Cc], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    xh, Bc, Cc = jnp.split(conv_out, [d_in, d_in + s.n_groups * s.d_state], axis=-1)
    B_, L, _ = x.shape
    xh = xh.reshape(B_, L, H, s.head_dim)
    Bh = _expand_groups(Bc, H, s.n_groups, s.d_state)
    Ch = _expand_groups(Cc, H, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, s_final = ssd_chunked(xh, dt, A, Bh, Ch, chunk=min(s.chunk_size, L))
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[:, None]
    y = y.reshape(B_, L, d_in)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps).astype(x.dtype)
    out = jnp.einsum("blf,fd->bld", y, p["out_proj"])
    out = shard(out, "batch", "act_seq", None)
    if want_cache:
        return out, {"state": s_final.astype(jnp.float32), "conv": conv_state}
    return out, None


def ssm_decode_step(p: dict, x: jax.Array, cache: dict, cfg: ArchConfig):
    """x (B,1,D); cache {'state': (B,H,N,P) fp32, 'conv': (B,3,C)}."""
    s, d_in, H = _dims(cfg)
    z, xh, Bc, Cc, dt = _split_proj(p, x, cfg)
    conv_in = jnp.concatenate([xh, Bc, Cc], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, p["conv_w"], p["conv_b"], state=cache["conv"])
    xh, Bc, Cc = jnp.split(conv_out, [d_in, d_in + s.n_groups * s.d_state], axis=-1)
    B_ = x.shape[0]
    xh = xh.reshape(B_, 1, H, s.head_dim)[:, 0]
    Bh = _expand_groups(Bc, H, s.n_groups, s.d_state)[:, 0]
    Ch = _expand_groups(Cc, H, s.n_groups, s.d_state)[:, 0]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))[:, 0]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A)  # (B,H)
    upd = jnp.einsum("bhn,bh,bhp->bhnp", Bh.astype(jnp.float32), dt, xh.astype(jnp.float32))
    state = cache["state"] * a[..., None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), state)
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[:, None]
    y = y.reshape(B_, 1, d_in)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps).astype(x.dtype)
    out = jnp.einsum("blf,fd->bld", y, p["out_proj"])
    return out, {"state": state, "conv": conv_state}

from repro.models.config import (
    ArchConfig,
    HybridConfig,
    MLAConfig,
    MoEConfig,
    SHAPES,
    ShapeSpec,
    SSMConfig,
    applicable_shapes,
)
from repro.models.transformer import (
    abstract_cache,
    abstract_inputs,
    abstract_params,
    cache_layout,
    decode_step,
    forward,
    init_cache,
    init_params,
    input_defs,
    loss_fn,
    model_defs,
    param_shardings,
    prefill,
    verify_step,
)

__all__ = [
    "ArchConfig", "HybridConfig", "MLAConfig", "MoEConfig", "SHAPES",
    "ShapeSpec", "SSMConfig", "applicable_shapes", "abstract_cache",
    "abstract_inputs", "abstract_params", "cache_layout", "decode_step",
    "forward", "init_cache", "init_params", "input_defs", "loss_fn",
    "model_defs", "param_shardings", "prefill", "verify_step",
]

"""Compatibility shims for optional third-party dependencies.

Nothing here is imported by library code; ``tests/conftest.py`` installs the
shims into ``sys.modules`` only when the real package is absent.
"""

"""A minimal, dependency-free stand-in for the ``hypothesis`` API surface
our tests use (``given``, ``settings``, ``strategies``).

The real hypothesis (declared in ``pyproject.toml``'s test extra) is
preferred whenever it is importable; ``tests/conftest.py`` only registers
this stub when it is not.  The stub does deterministic random sampling —
same seeds every run — with a bias toward boundary values.  No shrinking:
a falsifying example is re-raised with the drawn arguments attached.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
from types import ModuleType
from typing import Any, Callable, Optional, Sequence


class SearchStrategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example(self, rng: random.Random) -> Any:
        return self._draw(rng)


def integers(min_value: int = 0, max_value: int = 100) -> SearchStrategy:
    def draw(rng):
        r = rng.random()
        if r < 0.1:
            return min_value
        if r < 0.2:
            return max_value
        return rng.randint(min_value, max_value)

    return SearchStrategy(draw)


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5)


def floats(
    min_value: Optional[float] = None,
    max_value: Optional[float] = None,
    allow_nan: bool = True,
    allow_infinity: Optional[bool] = None,
    width: int = 64,
) -> SearchStrategy:
    lo = -1e9 if min_value is None else float(min_value)
    hi = 1e9 if max_value is None else float(max_value)

    def draw(rng):
        r = rng.random()
        if r < 0.08:
            return lo
        if r < 0.16:
            return hi
        if r < 0.24 and lo <= 0.0 <= hi:
            return 0.0
        return rng.uniform(lo, hi)

    return SearchStrategy(draw)


def lists(
    elements: SearchStrategy,
    min_size: int = 0,
    max_size: Optional[int] = None,
    unique_by: Optional[Callable[[Any], Any]] = None,
    unique: bool = False,
) -> SearchStrategy:
    if unique and unique_by is None:
        unique_by = lambda x: x

    def draw(rng):
        hi = max_size if max_size is not None else min_size + 10
        n = rng.randint(min_size, hi)
        out, seen = [], set()
        attempts = 0
        while len(out) < n and attempts < n * 20:
            attempts += 1
            item = elements.example(rng)
            if unique_by is not None:
                key = unique_by(item)
                if key in seen:
                    continue
                seen.add(key)
            out.append(item)
        return out

    return SearchStrategy(draw)


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda rng: tuple(s.example(rng) for s in strategies))


def sampled_from(elements: Sequence) -> SearchStrategy:
    pool = list(elements)
    return SearchStrategy(lambda rng: pool[rng.randrange(len(pool))])


def just(value: Any) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def one_of(*strategies: SearchStrategy) -> SearchStrategy:
    pool = list(strategies)
    return SearchStrategy(lambda rng: pool[rng.randrange(len(pool))].example(rng))


_DEFAULT_MAX_EXAMPLES = 20


def given(*g_args: SearchStrategy, **g_kwargs: SearchStrategy):
    def decorate(fn):
        sig = inspect.signature(fn)
        pos_names = [
            p.name
            for p in sig.parameters.values()
            if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)
        ]
        # positional strategies bind to the RIGHTMOST positional params
        # (hypothesis semantics); anything left is a pytest fixture
        target_names = pos_names[len(pos_names) - len(g_args):] if g_args else []

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                rng = random.Random(0x5BE0 + 7919 * i)
                drawn = {name: s.example(rng) for name, s in zip(target_names, g_args)}
                drawn.update({k: s.example(rng) for k, s in g_kwargs.items()})
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception as e:
                    e.args = e.args + (
                        f"[hypothesis-stub falsifying example #{i}: {drawn!r}]",
                    )
                    raise

        # hide strategy-bound params from pytest's fixture resolution
        bound = set(target_names) | set(g_kwargs)
        wrapper.__signature__ = sig.replace(
            parameters=[p for p in sig.parameters.values() if p.name not in bound]
        )
        wrapper.is_hypothesis_test = True
        return wrapper

    return decorate


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def decorate(fn):
        fn._stub_max_examples = max_examples
        return fn

    return decorate


def _as_modules() -> tuple[ModuleType, ModuleType]:
    """Build (hypothesis, hypothesis.strategies) module objects for
    ``sys.modules`` registration."""
    st = ModuleType("hypothesis.strategies")
    for name in (
        "SearchStrategy", "integers", "booleans", "floats", "lists",
        "tuples", "sampled_from", "just", "one_of",
    ):
        setattr(st, name, getattr(sys.modules[__name__], name))
    hyp = ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.__version__ = "0.0-repro-stub"
    return hyp, st

"""Production serve launcher: continuous-batching engine over a fitted or
randomly initialized model.

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --reduced \
        --requests 6 --slots 2 --gen 8 --temperature 0.7 --top-k 40
"""
from __future__ import annotations

import argparse
import collections
import time

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import init_params
from repro.serving import ServeEngine, shrunken_draft


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--deadline", type=float, default=None,
        help="per-request deadline in seconds; expired requests are shed "
        "from the queue or cancelled mid-decode (KV blocks freed)",
    )
    ap.add_argument(
        "--max-batch", type=int, default=None,
        help="cap on concurrently decoding sequences (default: all slots)",
    )
    ap.add_argument(
        "--admit-max-wait", type=float, default=0.0,
        help="batching window in seconds: hold admissions so near-"
        "simultaneous arrivals join the decode batch together",
    )
    ap.add_argument(
        "--draft-k", type=int, default=0,
        help="speculative decoding draft depth (0 = off); the draft model "
        "is a --draft-layers-layer truncation of the target's own weights",
    )
    ap.add_argument(
        "--draft-layers", type=int, default=1,
        help="number of target layers kept in the shrunken draft model",
    )
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    draft_cfg = draft_params = None
    if args.draft_k > 0:
        draft_cfg, draft_params = shrunken_draft(
            cfg, params, n_layers=args.draft_layers
        )

    with ServeEngine(
        cfg,
        params,
        n_slots=args.slots,
        max_seq=args.max_seq,
        block_size=args.block_size,
        max_batch=args.max_batch,
        admit_max_wait=args.admit_max_wait,
        draft_cfg=draft_cfg,
        draft_params=draft_params,
        draft_k=max(args.draft_k, 1),
    ) as eng:
        t0 = time.perf_counter()
        reqs = [
            eng.submit(
                rng.integers(0, cfg.vocab, size=args.prompt_len).astype(np.int32),
                args.gen,
                temperature=args.temperature,
                top_k=args.top_k,
                seed=args.seed + i,
                deadline=args.deadline,
            )
            for i in range(args.requests)
        ]
        eng.run_until_drained()
        dt = time.perf_counter() - t0
        total_toks = sum(len(r.out_tokens) for r in reqs)
        stats = eng.stats()
        pool = stats["pool"]
        print(
            f"[serve] {args.requests} requests × {args.gen} tokens on "
            f"{args.slots} slots: {total_toks} tokens in {dt * 1e3:.0f}ms "
            f"({total_toks / dt:.0f} tok/s), {stats['steps']} engine iterations"
        )
        print(
            f"[serve] admissions: {stats['admitted']} admitted, "
            f"{stats['prefills']} prefills, {stats['restores']} restores, "
            f"{stats['preemptions']} preemptions; pool "
            f"{pool['live_blocks']}/{pool['n_blocks']} blocks live, "
            f"{pool['shared_hits']} shared hits, {pool['evictions']} evictions"
        )
        if "spec" in stats:
            sp = stats["spec"]
            print(
                f"[serve] speculation: k={sp['draft_k']}, {sp['rounds']} rounds "
                f"({sp['rollback_rounds']} rolled back, {sp['sheds']} shed), "
                f"accept rate {sp['accept_rate']:.2f}, "
                f"{sp['accepted_per_round']:.2f} tokens/round committed"
            )
        reject_reasons = collections.Counter(
            r.reject_reason for r in reqs if r.rejected
        )
        print(
            f"[serve] rejections: {sum(reject_reasons.values())} total "
            f"({reject_reasons['queue_full']} queue_full, "
            f"{reject_reasons['shed']} shed, "
            f"{reject_reasons['deadline']} deadline), "
            f"{stats['cancels']} mid-decode cancels"
        )
        assert all(r.done for r in reqs)
        return {
            "tok_per_s": total_toks / dt,
            "evictions": pool["evictions"],
            "reject_reasons": dict(reject_reasons),
            "stats": stats,
        }


if __name__ == "__main__":
    main()

"""Production serve launcher: continuous-batching engine over a fitted or
randomly initialized model.

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --reduced \
        --requests 6 --slots 2 --gen 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core import trace_metrics
from repro.models import init_params
from repro.serving import ServeEngine


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    eng = ServeEngine(cfg, params, n_slots=args.slots, max_seq=args.max_seq)
    try:
        t0 = time.perf_counter()
        reqs = [
            eng.submit(
                rng.integers(0, cfg.vocab, size=args.prompt_len).astype(np.int32),
                args.gen,
            )
            for _ in range(args.requests)
        ]
        eng.run_until_drained()
        dt = time.perf_counter() - t0
        total_toks = sum(len(r.out_tokens) for r in reqs)
        print(
            f"[serve] {args.requests} requests × {args.gen} tokens on "
            f"{args.slots} slots: {total_toks} tokens in {dt * 1e3:.0f}ms "
            f"({total_toks / dt:.0f} tok/s), {eng.pool.evictions} LRU evictions, "
            f"{eng.steps} engine iterations"
        )
        assert all(r.done for r in reqs)
        return {"tok_per_s": total_toks / dt, "evictions": eng.pool.evictions}
    finally:
        eng.close()


if __name__ == "__main__":
    main()

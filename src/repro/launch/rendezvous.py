"""Multi-process rank bootstrap for the socket transport (paper §4.4).

This is the ``launch``-side of the ROADMAP's "Multi-host ChannelHub": spin
up one OS process per rank, hand each a
:class:`~repro.core.comm.SocketTransport` dialed into a shared localhost
rendezvous (rank 0 binds the port and runs the frame router; every rank —
including rank 0 — connects to it), and drive the *same* non-blocking
comm-task protocol that the in-process :class:`~repro.core.comm.ChannelHub`
exercises — ``ring_all_reduce`` built from ``mpi_send`` / ``mpi_recv``
tasks, progressed by each process's comm thread.

Demo / measurement entry point::

    PYTHONPATH=src python -m repro.launch.rendezvous --size 2 --n 65536

spawns the ranks with :mod:`multiprocessing` (spawn context: no inherited
JAX/threading state), reduces a float32 vector over TCP, checks the result
against the NumPy reference bit-for-bit, and prints per-rank wall time —
the measured two-process result tracked in ROADMAP.md.
"""
from __future__ import annotations

import multiprocessing as mp
import queue as _queue
import time
from typing import Any

__all__ = ["bootstrap_transport", "run_ring_reduce"]


def bootstrap_transport(
    rank: int,
    size: int,
    *,
    port: int,
    host: str = "127.0.0.1",
    timeout: float = 30.0,
):
    """Create this rank's :class:`SocketTransport`: rank 0 binds ``port``
    and routes, everyone dials (retrying until rank 0 is listening)."""
    from repro.core.comm import SocketTransport

    return SocketTransport(rank, size, host=host, port=port, connect_timeout=timeout)


def _ring_worker(rank: int, size: int, port: int, n: int, steps: int, q, port_q=None) -> None:
    """One rank: build engine + graph, all-reduce ``steps`` times over TCP
    (sum first, then mean on a fresh cell), report values + transport stats.
    Rank 0 binds an OS-assigned port (``port=0``) and reports it on
    ``port_q`` — no pick-then-rebind race for the rendezvous port."""
    import numpy as np

    from repro.core import (
        SpCommGroup,
        SpComputeEngine,
        SpData,
        SpTaskGraph,
        SpWorkerTeamBuilder,
    )
    from repro.dist.collectives import ring_all_reduce

    transport = bootstrap_transport(rank, size, port=port)
    if rank == 0 and port_q is not None:
        port_q.put(transport.port)
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(2))
    try:
        group = SpCommGroup(rank, size, transport, default_timeout=60.0)
        tg = SpTaskGraph(trace=False).compute_on(eng)
        rng = np.random.default_rng(rank)
        base = rng.standard_normal(n).astype(np.float32)

        t0 = time.perf_counter()
        x = SpData(base.copy(), f"sum{rank}")
        for step in range(steps):
            if step:  # re-reduce the previous result: distinct per-step tags
                x.value = base.copy()
            ring_all_reduce(tg, group, x, op="sum", tag=step)
            tg.wait_all_tasks()
        wall_sum = time.perf_counter() - t0

        y = SpData(base.copy(), f"mean{rank}")
        ring_all_reduce(tg, group, y, op="mean", tag=steps)
        tg.wait_all_tasks()

        q.put((rank, x.value, y.value, wall_sum / steps, transport.stats()))
    finally:
        eng.stop()
        transport.close()


def run_ring_reduce(
    size: int = 2,
    n: int = 4099,
    *,
    steps: int = 1,
    timeout: float = 120.0,
) -> dict:
    """Spawn ``size`` rank processes, ring-all-reduce a ``float32[n]`` over
    the TCP transport ``steps`` times (plus one mean reduce), and return
    ``{rank: {"sum", "mean", "wall_s", "stats"}}``.  ``n`` defaults to a
    size-indivisible length so chunking is exercised."""
    ctx = mp.get_context("spawn")
    q: Any = ctx.Queue()
    port_q: Any = ctx.Queue()
    # rank 0 binds port 0 and tells us the real port before peers dial —
    # the parent never picks a port it cannot hold
    procs = [
        ctx.Process(
            target=_ring_worker, args=(0, size, 0, n, steps, q, port_q), daemon=True
        )
    ]
    procs[0].start()
    try:
        port = port_q.get(timeout=timeout)
    except _queue.Empty:
        procs[0].terminate()
        raise TimeoutError(f"rank 0 did not bind a rendezvous port within {timeout}s")
    for r in range(1, size):
        p = ctx.Process(
            target=_ring_worker, args=(r, size, port, n, steps, q), daemon=True
        )
        procs.append(p)
        p.start()
    results: dict[int, dict] = {}
    deadline = time.monotonic() + timeout
    try:
        while len(results) < size and time.monotonic() < deadline:
            try:
                rank, s, m, wall, stats = q.get(timeout=1.0)
            except _queue.Empty:
                if any(p.exitcode not in (None, 0) for p in procs):
                    raise RuntimeError(
                        "a rank process died: "
                        + str([(p.name, p.exitcode) for p in procs])
                    )
                continue
            results[rank] = {"sum": s, "mean": m, "wall_s": wall, "stats": stats}
    finally:
        for p in procs:
            p.join(timeout=10.0)
            if p.is_alive():  # pragma: no cover - hung rank
                p.terminate()
    if len(results) < size:
        raise TimeoutError(
            f"only {len(results)}/{size} ranks reported within {timeout}s"
        )
    return results


def main(argv=None) -> None:
    import argparse

    import numpy as np

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--size", type=int, default=2)
    ap.add_argument("--n", type=int, default=65536)
    ap.add_argument("--steps", type=int, default=5)
    args = ap.parse_args(argv)

    results = run_ring_reduce(args.size, args.n, steps=args.steps)
    arrays = [
        np.random.default_rng(r).standard_normal(args.n).astype(np.float32)
        for r in range(args.size)
    ]
    expected = arrays[0]
    for a in arrays[1:]:
        expected = expected + a
    for rank, res in sorted(results.items()):
        # at size 2 each element is a single float32 addition: bit-for-bit
        match = (
            bool(np.array_equal(res["sum"], expected))
            if args.size == 2
            else bool(np.allclose(res["sum"], expected, rtol=1e-5, atol=1e-6))
        )
        print(
            f"[rank {rank}] allreduce float32[{args.n}] x{args.steps}: "
            f"{res['wall_s'] * 1e3:.1f} ms/step, "
            f"{'bitexact' if args.size == 2 else 'allclose'}={match}, "
            f"transport={res['stats']}"
        )


if __name__ == "__main__":
    main()

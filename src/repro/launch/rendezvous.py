"""Multi-process rank bootstrap for the socket transport (paper §4.4).

This is the ``launch``-side of the ROADMAP's "Multi-host ChannelHub": spin
up one OS process per rank, hand each a
:class:`~repro.core.comm.SocketTransport` dialed into a shared localhost
rendezvous (rank 0 binds the port and runs the *address exchange*; every
rank — including rank 0 — registers its own data listener there, then
frames flow over lazily dialed direct peer links), and drive the *same*
non-blocking
comm-task protocol that the in-process :class:`~repro.core.comm.ChannelHub`
exercises — ``ring_all_reduce`` built from ``mpi_send`` / ``mpi_recv``
tasks, progressed by each process's comm thread.

Demo / measurement entry point::

    PYTHONPATH=src python -m repro.launch.rendezvous --size 2 --n 65536

spawns the ranks with :mod:`multiprocessing` (spawn context: no inherited
JAX/threading state), reduces a float32 vector over TCP, checks the result
against the NumPy reference bit-for-bit, and prints per-rank wall time —
the measured two-process result tracked in ROADMAP.md.

Elastic recovery (ISSUE 6): when a rank dies mid-run the survivors must
*agree* on the dead set before resharding — each may have detected the
death at a different moment.  :func:`reroll_ranks` is that agreement: a
fixed two-round, epoch-tagged all-to-all over the raw transport (view
exchange → union confirmation), returning the shrunken
:class:`~repro.core.SpCommGroup` plus every survivor's piggy-backed
payload (the drivers exchange their next step and resume from the
minimum, so no survivor waits on a step another already passed).
:func:`run_elastic_ring` is the acceptance driver: it spawns real OS
ranks, SIGKILLs one mid-``ring_all_reduce``, and returns the survivors'
per-step results plus detection/recovery timings.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import queue as _queue
import signal
import time
from typing import Any, Optional

__all__ = [
    "bootstrap_transport",
    "elastic_train_oracle",
    "reroll_ranks",
    "run_collective",
    "run_elastic_ring",
    "run_elastic_train",
    "run_ring_reduce",
]


def bootstrap_transport(
    rank: int,
    size: int,
    *,
    port: int,
    host: str = "127.0.0.1",
    timeout: float = 30.0,
    max_dial_retries: int = 100,
    heartbeat_interval: float | None = None,
    heartbeat_timeout: float | None = None,
    transport: str = "p2p",
):
    """Create this rank's transport: rank 0 binds ``port`` as the
    rendezvous, everyone dials.  ``transport`` selects the wire
    implementation — ``"p2p"`` (the direct-dial data plane,
    :class:`SocketTransport`) or ``"router"`` (the legacy star
    :class:`RouterTransport`, kept as the comm-bench baseline).  The dial
    loop is bounded: at most ``max_dial_retries`` attempts with
    exponential backoff inside ``timeout`` seconds, then a ``SpCommError``
    naming the rendezvous address."""
    from repro.core.comm import RouterTransport, SocketTransport

    try:
        cls = {"p2p": SocketTransport, "router": RouterTransport}[transport]
    except KeyError:
        raise ValueError(
            f"unknown transport {transport!r}; use 'p2p' or 'router'"
        ) from None
    return cls(
        rank,
        size,
        host=host,
        port=port,
        connect_timeout=timeout,
        max_dial_retries=max_dial_retries,
        heartbeat_interval=heartbeat_interval,
        heartbeat_timeout=heartbeat_timeout,
    )


def reroll_ranks(
    group,
    *,
    epoch: int,
    payload: Any = None,
    timeout: float = 30.0,
    poll_interval: float = 0.002,
):
    """Epoch-tagged rendezvous re-roll: survivors agree on the dead set and
    exchange payloads, then the group shrinks to the survivors.

    Fixed two-round protocol over the raw transport (no comm tasks — the
    task graph that just failed may still hold in-flight requests):

    1. every presumed survivor broadcasts its local view
       ``{dead, payload}`` to the others and collects theirs; a peer whose
       poll raises ``SpRankDeadError`` mid-round joins the dead set;
    2. every survivor broadcasts the *union* dead set it computed and
       checks the unions agree — divergence (a death landing between the
       rounds) raises ``SpCommError``, and the caller re-rolls with a
       fresh ``epoch``.

    Exactly two rounds on every rank, so no rank stalls waiting for a
    round its peers never run.  Returns ``(shrunk_group, dead, payloads)``
    with ``payloads`` keyed by surviving physical rank (self included).
    """
    from repro.core.comm import SpCommError, SpRankDeadError

    tr = group.hub
    me = group.rank

    def _exchange(round_no: int, msg: Any, peers: list[int]) -> tuple[dict, set]:
        """Send ``msg`` to ``peers``, collect their round-``round_no``
        messages; returns (views, found_dead)."""
        newly_dead: set[int] = set()
        tag = ("__reroll__", epoch, round_no)
        for r in peers:
            try:
                tr.post((me, r, tag), msg)
            except SpRankDeadError:
                newly_dead.add(r)
        views: dict[int, Any] = {me: msg}
        pending = set(peers) - newly_dead
        deadline = time.monotonic() + timeout
        while pending:
            for r in list(pending):
                try:
                    ok, m = tr.poll((r, me, tag))
                except SpRankDeadError:
                    newly_dead.add(r)
                    pending.discard(r)
                    continue
                if ok:
                    views[r] = m
                    pending.discard(r)
            if not pending:
                break
            if time.monotonic() > deadline:
                raise SpCommError(
                    f"reroll epoch {epoch} round {round_no}: ranks "
                    f"{sorted(pending)} never answered within {timeout}s"
                )
            time.sleep(poll_interval)
        return views, newly_dead

    dead = set(tr.dead_ranks)
    alive = [r for r in group.members if r not in dead and r != me]

    views, newly = _exchange(1, {"dead": sorted(dead), "payload": payload}, alive)
    dead |= newly
    for v in views.values():
        dead |= set(v["dead"])
    dead |= set(tr.dead_ranks)  # deaths detected while round 1 ran

    survivors = [r for r in alive if r not in dead]
    unions, newly2 = _exchange(2, sorted(dead), survivors)
    dead |= newly2
    for r, their_union in unions.items():
        if r != me and set(their_union) != dead - newly2:
            raise SpCommError(
                f"reroll epoch {epoch}: dead-set divergence — rank {r} "
                f"sees {their_union}, this rank sees {sorted(dead)}; "
                f"re-roll with a fresh epoch"
            )

    payloads = {
        r: v["payload"] for r, v in views.items() if r == me or r not in dead
    }
    return group.shrunk(sorted(dead)), frozenset(dead), payloads


def _ring_worker(rank: int, size: int, port: int, n: int, steps: int, q, port_q=None) -> None:
    """One rank: build engine + graph, all-reduce ``steps`` times over TCP
    (sum first, then mean on a fresh cell), report values + transport stats.
    Rank 0 binds an OS-assigned port (``port=0``) and reports it on
    ``port_q`` — no pick-then-rebind race for the rendezvous port."""
    import numpy as np

    from repro.core import (
        SpCommGroup,
        SpComputeEngine,
        SpData,
        SpTaskGraph,
        SpWorkerTeamBuilder,
    )
    from repro.dist.collectives import ring_all_reduce

    transport = bootstrap_transport(rank, size, port=port)
    if rank == 0 and port_q is not None:
        port_q.put(transport.port)
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(2))
    try:
        group = SpCommGroup(rank, size, transport, default_timeout=60.0)
        tg = SpTaskGraph(trace=False).compute_on(eng)
        rng = np.random.default_rng(rank)
        base = rng.standard_normal(n).astype(np.float32)

        t0 = time.perf_counter()
        x = SpData(base.copy(), f"sum{rank}")
        for step in range(steps):
            if step:  # re-reduce the previous result: distinct per-step tags
                x.value = base.copy()
            ring_all_reduce(tg, group, x, op="sum", tag=step)
            tg.wait_all_tasks()
        wall_sum = time.perf_counter() - t0

        y = SpData(base.copy(), f"mean{rank}")
        ring_all_reduce(tg, group, y, op="mean", tag=steps)
        tg.wait_all_tasks()

        q.put((rank, x.value, y.value, wall_sum / steps, transport.stats()))
    finally:
        eng.stop()
        transport.close()


def run_ring_reduce(
    size: int = 2,
    n: int = 4099,
    *,
    steps: int = 1,
    timeout: float = 120.0,
) -> dict:
    """Spawn ``size`` rank processes, ring-all-reduce a ``float32[n]`` over
    the TCP transport ``steps`` times (plus one mean reduce), and return
    ``{rank: {"sum", "mean", "wall_s", "stats"}}``.  ``n`` defaults to a
    size-indivisible length so chunking is exercised."""
    ctx = mp.get_context("spawn")
    q: Any = ctx.Queue()
    port_q: Any = ctx.Queue()
    # rank 0 binds port 0 and tells us the real port before peers dial —
    # the parent never picks a port it cannot hold
    procs = [
        ctx.Process(
            target=_ring_worker, args=(0, size, 0, n, steps, q, port_q), daemon=True
        )
    ]
    procs[0].start()
    try:
        port = port_q.get(timeout=timeout)
    except _queue.Empty:
        procs[0].terminate()
        raise TimeoutError(f"rank 0 did not bind a rendezvous port within {timeout}s")
    for r in range(1, size):
        p = ctx.Process(
            target=_ring_worker, args=(r, size, port, n, steps, q), daemon=True
        )
        procs.append(p)
        p.start()
    results: dict[int, dict] = {}
    deadline = time.monotonic() + timeout
    try:
        while len(results) < size and time.monotonic() < deadline:
            try:
                rank, s, m, wall, stats = q.get(timeout=1.0)
            except _queue.Empty:
                if any(p.exitcode not in (None, 0) for p in procs):
                    raise RuntimeError(
                        "a rank process died: "
                        + str([(p.name, p.exitcode) for p in procs])
                    )
                continue
            results[rank] = {"sum": s, "mean": m, "wall_s": wall, "stats": stats}
    finally:
        for p in procs:
            p.join(timeout=10.0)
            if p.is_alive():  # pragma: no cover - hung rank
                p.terminate()
    if len(results) < size:
        raise TimeoutError(
            f"only {len(results)}/{size} ranks reported within {timeout}s"
        )
    return results


def _collective_worker(rank, size, port, n, kind, kwargs, q, port_q=None) -> None:
    """One rank of :func:`run_collective`: reduce a deterministic
    integer-valued float32 vector (bit-exactness is by construction) with
    the requested collective and report the result + transport stats."""
    from repro.core import (
        SpCommGroup,
        SpComputeEngine,
        SpData,
        SpTaskGraph,
        SpWorkerTeamBuilder,
    )
    from repro.dist.collectives import hierarchical_all_reduce, ring_all_reduce

    transport = bootstrap_transport(rank, size, port=port)
    if rank == 0 and port_q is not None:
        port_q.put(transport.port)
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(2))
    try:
        group = SpCommGroup(rank, size, transport, default_timeout=120.0)
        tg = SpTaskGraph(trace=False).compute_on(eng)
        x = SpData(_det_grad(rank, 0, n), f"coll{rank}")
        if kind == "ring":
            ring_all_reduce(tg, group, x, **kwargs)
        elif kind == "hier":
            hierarchical_all_reduce(tg, group, x, **kwargs)
        else:
            raise ValueError(f"unknown collective kind {kind!r}")
        tg.wait_all_tasks()
        q.put((rank, x.value, transport.stats()))
    finally:
        eng.stop()
        transport.close()


def run_collective(
    size: int,
    n: int = 4099,
    *,
    kind: str = "ring",
    timeout: float = 240.0,
    **kwargs,
) -> dict:
    """Spawn ``size`` rank processes over the p2p transport and run one
    collective (``kind="ring"`` → :func:`ring_all_reduce` with e.g.
    ``chunk_bytes=...``; ``kind="hier"`` → :func:`hierarchical_all_reduce`
    with ``pod_size=...``).  Inputs are :func:`_det_grad` per rank —
    integer-valued float32, so results are bit-exact against any
    honest-sum oracle.  Returns ``{rank: {"value", "stats"}}``."""
    ctx = mp.get_context("spawn")
    q: Any = ctx.Queue()
    port_q: Any = ctx.Queue()
    procs = [
        ctx.Process(
            target=_collective_worker,
            args=(0, size, 0, n, kind, kwargs, q, port_q),
            daemon=True,
        )
    ]
    procs[0].start()
    try:
        port = port_q.get(timeout=timeout)
    except _queue.Empty:
        procs[0].terminate()
        raise TimeoutError(f"rank 0 did not bind a rendezvous port within {timeout}s")
    for r in range(1, size):
        p = ctx.Process(
            target=_collective_worker,
            args=(r, size, port, n, kind, kwargs, q),
            daemon=True,
        )
        procs.append(p)
        p.start()
    results: dict[int, dict] = {}
    deadline = time.monotonic() + timeout
    try:
        while len(results) < size and time.monotonic() < deadline:
            try:
                rank, value, stats = q.get(timeout=1.0)
            except _queue.Empty:
                if any(p.exitcode not in (None, 0) for p in procs):
                    raise RuntimeError(
                        "a rank process died: "
                        + str([(p.name, p.exitcode) for p in procs])
                    )
                continue
            results[rank] = {"value": value, "stats": stats}
    finally:
        for p in procs:
            p.join(timeout=10.0)
            if p.is_alive():  # pragma: no cover - hung rank
                p.terminate()
    if len(results) < size:
        raise TimeoutError(
            f"only {len(results)}/{size} ranks reported within {timeout}s"
        )
    return results


def _elastic_worker(
    rank: int,
    size: int,
    port: int,
    n: int,
    steps: int,
    q,
    progress_q,
    port_q=None,
    hb_timeout: float = 3.0,
    victim_hold: tuple[int, float] | None = None,
) -> None:
    """One elastic rank: all-reduce ``steps`` times, surviving rank death.

    Recovery lives in the *runtime* (ISSUE 8): ``SpRuntime(elastic=True)``
    gives every step a fresh task graph, catches the rank death escaping
    the step (the failed graph's lingering receives time out harmlessly on
    the comm thread), drives :func:`reroll_ranks` internally and resumes
    from the minimum exchanged step on the shrunken ring.  This worker has
    no failure handling of its own — the hand-rolled catch/re-roll/redo
    loop this function used to carry is now ``rt.elastic_loop``."""
    import numpy as np

    from repro.core import SpCommGroup, SpData, SpRuntime
    from repro.dist.collectives import ring_all_reduce

    transport = bootstrap_transport(
        rank, size, port=port, heartbeat_interval=0.2, heartbeat_timeout=hb_timeout
    )
    if rank == 0 and port_q is not None:
        port_q.put(transport.port)
    try:
        group = SpCommGroup(rank, size, transport, default_timeout=30.0)
        rng = np.random.default_rng(rank)
        base = rng.standard_normal(n).astype(np.float32)

        with SpRuntime(workers=2, elastic=True, group=group) as rt:

            def step_fn(step: int):
                x = SpData(base.copy(), f"e{rt.epoch}s{step}")
                ring_all_reduce(rt.graph, rt.group, x, op="sum", tag=(rt.epoch, step))
                # progress is reported *after* the collective is inserted —
                # its comm tasks are already in flight on the engine's
                # background threads, so a parent killing on this report
                # kills mid-collective
                progress_q.put(("step", rank, step))
                if victim_hold is not None and step == victim_hold[0]:
                    # the designated victim lingers inside the collective so
                    # the parent's SIGKILL reliably lands mid-flight
                    time.sleep(victim_hold[1])
                rt.barrier(timeout=60.0)
                return x.value

            results = rt.elastic_loop(step_fn, steps, step_timeout=60.0)
            rec = rt.recoveries[-1] if rt.recoveries else {}
            q.put(
                (
                    rank,
                    {
                        "steps": results,
                        "resume_step": rec.get("resume"),
                        "detect_at": rec.get("detect_at"),
                        "reroll_s": rec.get("reroll_s"),
                        "members": list(rt.group.members),
                        "dead": sorted(transport.dead_ranks),
                        "stats": transport.stats(),
                    },
                )
            )
    finally:
        transport.close()


def run_elastic_ring(
    size: int = 3,
    n: int = 257,
    *,
    steps: int = 4,
    fail_at: int = 2,
    timeout: float = 180.0,
    kill_delay: float = 0.02,
    victim_hold_s: float = 2.0,
    victim: int | None = None,
) -> tuple[dict, dict]:
    """Spawn ``size`` rank processes, SIGKILL ``victim`` (default: the
    highest rank) as it enters step ``fail_at``'s all-reduce, and return
    the survivors' reports.

    ``victim=0`` kills the rendezvous rank itself — legal on the p2p data
    plane, where the address book is already distributed and the survivors
    detect the death over their *direct* links (no router in the path).

    Returns ``(results, info)``: ``results[rank]`` is each survivor's
    report from :func:`_elastic_worker`; ``info`` records the victim and
    the parent's ``time.monotonic()`` at the moment of the kill, so
    detection latency is ``report["detect_at"] - info["t_kill"]``
    (CLOCK_MONOTONIC is machine-wide on Linux)."""
    if size < 3:
        raise ValueError("need >= 3 ranks: two survivors must agree on the dead set")
    if victim is None:
        victim = size - 1
    ctx = mp.get_context("spawn")
    q: Any = ctx.Queue()
    progress_q: Any = ctx.Queue()
    port_q: Any = ctx.Queue()
    hold0 = (fail_at, victim_hold_s) if victim == 0 else None
    procs = [
        ctx.Process(
            target=_elastic_worker,
            args=(0, size, 0, n, steps, q, progress_q, port_q, 3.0, hold0),
            daemon=True,
        )
    ]
    procs[0].start()
    try:
        port = port_q.get(timeout=timeout)
    except _queue.Empty:
        procs[0].terminate()
        raise TimeoutError(f"rank 0 did not bind a rendezvous port within {timeout}s")
    for r in range(1, size):
        hold = (fail_at, victim_hold_s) if r == victim else None
        p = ctx.Process(
            target=_elastic_worker,
            args=(r, size, port, n, steps, q, progress_q, None, 3.0, hold),
            daemon=True,
        )
        procs.append(p)
        p.start()

    info: dict[str, Any] = {"victim": victim, "t_kill": None}
    results: dict[int, dict] = {}
    survivors = size - 1
    deadline = time.monotonic() + timeout
    try:
        # phase 1: watch progress until the victim enters step fail_at
        while info["t_kill"] is None and time.monotonic() < deadline:
            try:
                kind, rank, step = progress_q.get(timeout=1.0)
            except _queue.Empty:
                continue
            if kind == "step" and rank == victim and step == fail_at:
                time.sleep(kill_delay)  # let its sends enter the collective
                info["t_kill"] = time.monotonic()
                os.kill(procs[victim].pid, signal.SIGKILL)
        if info["t_kill"] is None:
            raise TimeoutError(
                f"victim rank {victim} never reached step {fail_at}"
            )
        # phase 2: collect the survivors' reports
        while len(results) < survivors and time.monotonic() < deadline:
            try:
                rank, report = q.get(timeout=1.0)
                if rank == victim:  # pragma: no cover - the kill was too slow
                    raise RuntimeError("the victim survived and reported")
            except _queue.Empty:
                bad = [
                    (p.name, p.exitcode)
                    for i, p in enumerate(procs)
                    if i != victim and p.exitcode not in (None, 0)
                ]
                if bad:
                    raise RuntimeError(f"a survivor rank died: {bad}")
                continue
            results[rank] = report
    finally:
        for p in procs:
            p.join(timeout=10.0)
            if p.is_alive():  # pragma: no cover - hung rank
                p.terminate()
    if len(results) < survivors:
        raise TimeoutError(
            f"only {len(results)}/{survivors} survivors reported within {timeout}s"
        )
    return results, info


def _det_grad(rank: int, step: int, n: int):
    """Deterministic, *integer-valued* float32 pseudo-gradient.  Integer
    values below 2**24 make float32 addition exact and associative, so the
    ring reduction matches a plain NumPy sum bit-for-bit at any rank count
    and in any accumulation order — the survivors-only oracle can be exact
    even across the pre-failure full-mesh steps."""
    import numpy as np

    return (np.arange(n, dtype=np.float32) % 31.0) + np.float32(
        (rank + 1) * (step + 3)
    )


def _sgd_update(params, grad_sum, n_ranks: int, lr: float):
    """One data-parallel SGD step: ``params - lr * mean(grads)``, all in
    float32.  Shared by the elastic training worker and the test oracle so
    bit-exactness is by construction, not by matching promotions by hand."""
    import numpy as np

    mean = grad_sum / np.float32(n_ranks)
    return (params - np.float32(lr) * mean).astype(np.float32)


def _train_worker(
    rank: int,
    size: int,
    port: int,
    n: int,
    steps: int,
    lr: float,
    q,
    progress_q,
    port_q=None,
    hb_timeout: float = 3.0,
    victim_hold: tuple[int, float] | None = None,
) -> None:
    """One elastic *training* rank: a plain data-parallel SGD loop with no
    try/except and no recovery code — surviving a SIGKILLed peer is entirely
    ``SpRuntime(elastic=True)``'s job (the ISSUE 8 acceptance shape).

    Params are kept per step (``history[step]``) so a rewind to an earlier
    resume step re-executes from exactly the params that step saw — state
    indexing, not failure handling."""
    import numpy as np

    from repro.core import SpCommGroup, SpData, SpRuntime
    from repro.dist.collectives import ring_all_reduce

    transport = bootstrap_transport(
        rank, size, port=port, heartbeat_interval=0.2, heartbeat_timeout=hb_timeout
    )
    if rank == 0 and port_q is not None:
        port_q.put(transport.port)
    try:
        group = SpCommGroup(rank, size, transport, default_timeout=30.0)
        history: dict[int, Any] = {0: np.zeros(n, dtype=np.float32)}

        with SpRuntime(workers=2, elastic=True, group=group) as rt:

            def train_step(step: int):
                params = history[step]
                g = SpData(_det_grad(rank, step, n), f"g{rank}e{rt.epoch}s{step}")
                ring_all_reduce(rt.graph, rt.group, g, op="sum", tag=(rt.epoch, step))
                progress_q.put(("step", rank, step))
                if victim_hold is not None and step == victim_hold[0]:
                    time.sleep(victim_hold[1])
                rt.barrier(timeout=60.0)
                new_params = _sgd_update(params, g.value, len(rt.group.members), lr)
                history[step + 1] = new_params
                return new_params

            rt.elastic_loop(train_step, steps, step_timeout=60.0)
            rec = rt.recoveries[-1] if rt.recoveries else {}
            q.put(
                (
                    rank,
                    {
                        "params": history[steps],
                        "resume_step": rec.get("resume"),
                        "detect_at": rec.get("detect_at"),
                        "reroll_s": rec.get("reroll_s"),
                        "members": list(rt.group.members),
                        "dead": sorted(transport.dead_ranks),
                        "recoveries": len(rt.recoveries),
                    },
                )
            )
    finally:
        transport.close()


def run_elastic_train(
    size: int = 3,
    n: int = 257,
    *,
    steps: int = 5,
    fail_at: int = 2,
    lr: float = 0.01,
    timeout: float = 180.0,
    kill_delay: float = 0.02,
    victim_hold_s: float = 2.0,
) -> tuple[dict, dict]:
    """SIGKILL a real OS rank mid-*training* and let the runtime recover.

    Spawns ``size`` rank processes running :func:`_train_worker`'s plain SGD
    loop under ``SpRuntime(elastic=True)``, kills the highest rank as it
    enters step ``fail_at``'s all-reduce, and returns the survivors'
    reports (final params, recovery record).  The expected final params are
    :func:`elastic_train_oracle` with the resume step from any survivor —
    bit-exact, because the pseudo-gradients are integer-valued."""
    if size < 3:
        raise ValueError("need >= 3 ranks: the victim must not be the router")
    victim = size - 1  # never rank 0 — the router dies with it
    ctx = mp.get_context("spawn")
    q: Any = ctx.Queue()
    progress_q: Any = ctx.Queue()
    port_q: Any = ctx.Queue()
    procs = [
        ctx.Process(
            target=_train_worker,
            args=(0, size, 0, n, steps, lr, q, progress_q, port_q),
            daemon=True,
        )
    ]
    procs[0].start()
    try:
        port = port_q.get(timeout=timeout)
    except _queue.Empty:
        procs[0].terminate()
        raise TimeoutError(f"rank 0 did not bind a rendezvous port within {timeout}s")
    for r in range(1, size):
        hold = (fail_at, victim_hold_s) if r == victim else None
        p = ctx.Process(
            target=_train_worker,
            args=(r, size, port, n, steps, lr, q, progress_q, None, 3.0, hold),
            daemon=True,
        )
        procs.append(p)
        p.start()

    info: dict[str, Any] = {"victim": victim, "t_kill": None}
    results: dict[int, dict] = {}
    survivors = size - 1
    deadline = time.monotonic() + timeout
    try:
        while info["t_kill"] is None and time.monotonic() < deadline:
            try:
                kind, rank, step = progress_q.get(timeout=1.0)
            except _queue.Empty:
                continue
            if kind == "step" and rank == victim and step == fail_at:
                time.sleep(kill_delay)  # let its sends enter the collective
                info["t_kill"] = time.monotonic()
                os.kill(procs[victim].pid, signal.SIGKILL)
        if info["t_kill"] is None:
            raise TimeoutError(f"victim rank {victim} never reached step {fail_at}")
        while len(results) < survivors and time.monotonic() < deadline:
            try:
                rank, report = q.get(timeout=1.0)
                if rank == victim:  # pragma: no cover - the kill was too slow
                    raise RuntimeError("the victim survived and reported")
            except _queue.Empty:
                bad = [
                    (p.name, p.exitcode)
                    for i, p in enumerate(procs)
                    if i != victim and p.exitcode not in (None, 0)
                ]
                if bad:
                    raise RuntimeError(f"a survivor rank died: {bad}")
                continue
            results[rank] = report
    finally:
        for p in procs:
            p.join(timeout=10.0)
            if p.is_alive():  # pragma: no cover - hung rank
                p.terminate()
    if len(results) < survivors:
        raise TimeoutError(
            f"only {len(results)}/{survivors} survivors reported within {timeout}s"
        )
    return results, info


def elastic_train_oracle(
    size: int,
    n: int,
    steps: int,
    lr: float,
    *,
    resume_step: int,
    dead: tuple[int, ...] = (),
):
    """Replay the elastic SGD run in plain NumPy: full-mesh mean-reduced
    steps before ``resume_step``, survivors-only after.  Bit-exact against
    :func:`_train_worker` because both use :func:`_det_grad` /
    :func:`_sgd_update` and the gradients are integer-valued float32."""
    import numpy as np

    params = np.zeros(n, dtype=np.float32)
    for step in range(steps):
        ranks = [
            r
            for r in range(size)
            if step < resume_step or r not in set(dead)
        ]
        gsum = np.zeros(n, dtype=np.float32)
        for r in ranks:
            gsum = gsum + _det_grad(r, step, n)
        params = _sgd_update(params, gsum, len(ranks), lr)
    return params


def main(argv=None) -> None:
    import argparse

    import numpy as np

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--size", type=int, default=2)
    ap.add_argument("--n", type=int, default=65536)
    ap.add_argument("--steps", type=int, default=5)
    args = ap.parse_args(argv)

    results = run_ring_reduce(args.size, args.n, steps=args.steps)
    arrays = [
        np.random.default_rng(r).standard_normal(args.n).astype(np.float32)
        for r in range(args.size)
    ]
    expected = arrays[0]
    for a in arrays[1:]:
        expected = expected + a
    for rank, res in sorted(results.items()):
        # at size 2 each element is a single float32 addition: bit-for-bit
        match = (
            bool(np.array_equal(res["sum"], expected))
            if args.size == 2
            else bool(np.allclose(res["sum"], expected, rtol=1e-5, atol=1e-6))
        )
        print(
            f"[rank {rank}] allreduce float32[{args.n}] x{args.steps}: "
            f"{res['wall_s'] * 1e3:.1f} ms/step, "
            f"{'bitexact' if args.size == 2 else 'allclose'}={match}, "
            f"transport={res['stats']}"
        )


if __name__ == "__main__":
    main()

"""Production train launcher.

Drives the staged train step with the full substrate: host-mesh sharding,
synthetic data with background prefetch, periodic async checkpoints,
failure simulation + elastic re-mesh, resume-from-latest.

    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --reduced \
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ck --ckpt-every 20

Elastic fault tolerance (``--fail-at STEP:RANKS``): a
:class:`~repro.dist.fault.FailureSimulator` injects a rank loss at STEP
(surfaced as :class:`~repro.core.SpRankDeadError` from the step function).
The launcher itself contains **no recovery control flow** — the training
loop is a plain ``SpRuntime(elastic=True).elastic_loop``; the runtime
catches the death, and this module's ``on_reshard`` hook only does the
domain work: compute a :func:`~repro.dist.fault.remesh_plan` over the
survivors (preserving model parallelism), rebuild the mesh, and recover
state by one of two paths (``--recovery``):

* ``live`` (default) — *live reshard*: ``jax.device_put`` the surviving
  in-memory state onto the new mesh and continue from the failed step; no
  replay, no disk.  Falls back to checkpoint restore only when there is no
  in-memory state to reshard.
* ``restore`` — full checkpoint restore (replays every step since the
  last save); requires ``--ckpt-dir``/``--ckpt-every`` (or ``--resume``).

Either way the data-pipeline cursor is the step counter, so resumption is
deterministic.  Each recovery is timed; ``--bench-out PATH`` writes the
timings as JSON (the ``BENCH_recovery.json`` series).
"""
from __future__ import annotations

import argparse
import contextlib
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced_config
from repro.core import SpRankDeadError, SpRuntime
from repro.data import Prefetcher, SyntheticLMDataset
from repro.dist.fault import FailureSimulator, remesh_plan
from repro.dist.sharding import use_mesh
from repro.launch.mesh import make_host_mesh
from repro.models.config import ShapeSpec
from repro.optim import linear_warmup_cosine
from repro.runtime.train import (
    abstract_train_state,
    build_train_step,
    init_train_state,
    train_state_shardings,
)


def _parse_fail_at(spec: str) -> FailureSimulator:
    try:
        step_s, ranks_s = spec.split(":")
        step, ranks = int(step_s), int(ranks_s)
    except ValueError:
        raise argparse.ArgumentTypeError(f"expected STEP:RANKS integers, got {spec!r}")
    if step < 1:
        raise argparse.ArgumentTypeError("STEP must be >= 1 (checked after each step)")
    if ranks < 1:
        raise argparse.ArgumentTypeError("RANKS must be >= 1")
    return FailureSimulator({step: ranks})


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--reduced", action="store_true", help="smoke-scale config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--schedule-policy", default="overlap")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument(
        "--fail-at", default=None, metavar="STEP:RANKS", type=_parse_fail_at,
        help="simulate losing RANKS chips at STEP, then elastically re-mesh",
    )
    ap.add_argument(
        "--recovery", choices=("live", "restore"), default="live",
        help="after a re-mesh: live-reshard the in-memory state (default) "
        "or restore the latest checkpoint",
    )
    ap.add_argument(
        "--bench-out", default=None, metavar="PATH",
        help="write recovery timings as JSON to PATH",
    )
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    shape = ShapeSpec("train", "train", args.seq, args.batch)
    ds = SyntheticLMDataset(cfg, shape, seed=0)
    mgr = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    sim = args.fail_at

    n_devices = len(jax.devices())
    mesh = make_host_mesh() if n_devices > 1 else None
    lr = linear_warmup_cosine(args.lr, warmup=10, total_steps=args.steps)

    losses: list[float] = []  # losses[i] is the loss of step base_step + i + 1
    recoveries: list[dict] = []  # one entry per re-mesh: mode/step/seconds
    # Mutable training-segment state shared between the step function and
    # the reshard hook.  ``restorable``: only checkpoints this process saved
    # (or explicitly opted into via --resume) may be restored after a
    # failure — a stale dir from an earlier run must not hijack the step
    # counter.
    st: dict = {
        "mesh": mesh, "art": None, "state": None, "pf": None,
        "restorable": args.resume, "failed_ranks": 0,
        "seg_t0": 0.0, "seg_steps": 0,
    }

    def _mesh_ctx():
        return use_mesh(st["mesh"]) if st["mesh"] is not None else contextlib.nullcontext()

    def _bind(start: int) -> None:
        """(Re)build the jitted step artifact under the current mesh and
        point the prefetch pipeline at ``start``."""
        with _mesh_ctx():
            st["art"] = build_train_step(
                cfg,
                n_microbatches=args.microbatches,
                schedule_policy=args.schedule_policy,
                lr_schedule=lr,
                donate=False,
            )
        if st["pf"] is not None:
            st["pf"].stop()
        st["pf"] = Prefetcher(ds, start_step=start, depth=2)
        st["seg_t0"], st["seg_steps"] = time.perf_counter(), 0

    start_step = 0
    with _mesh_ctx():
        if mgr is not None and args.resume and mgr.latest_step() is not None:
            start_step, st["state"] = mgr.restore(abstract_train_state(cfg))
            print(f"[train] resumed from step {start_step}")
        else:
            st["state"] = init_train_state(jax.random.PRNGKey(0), cfg)
            if mesh is not None:
                st["state"] = jax.device_put(st["state"], train_state_shardings(cfg))
    base_step = start_step
    _bind(start_step)

    def train_step(step: int) -> float:
        """One SGD step.  No failure handling anywhere: a simulated rank
        loss raises SpRankDeadError and the elastic runtime drives the
        recovery (re-mesh + reshard via ``on_reshard``) transparently."""
        with _mesh_ctx():
            _, batch = st["pf"].get()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            st["state"], metrics = st["art"](st["state"], batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        st["seg_steps"] += 1
        s = int(st["state"].step)
        if args.log_every and s % args.log_every == 0:
            dt = (time.perf_counter() - st["seg_t0"]) / st["seg_steps"]
            print(
                f"[train] step {s:5d} loss {loss:8.4f} "
                f"gnorm {float(metrics['grad_norm']):7.3f} {dt * 1e3:7.1f} ms/step",
                flush=True,
            )
        if mgr is not None and args.ckpt_every and s % args.ckpt_every == 0:
            mgr.save(s, st["state"])  # async commit
            st["restorable"] = True
        if sim is not None:
            failed = sim.check(s)
            if failed and st["mesh"] is None:
                print("[train] failure injected but only one device; continuing")
                failed = 0
            if failed:
                st["failed_ranks"] = failed
                raise SpRankDeadError(
                    f"simulated loss of {failed} ranks after step {s}"
                )
        return loss

    def on_reshard(event) -> int:
        """Domain half of a recovery: shrink the mesh over the survivors,
        then live-reshard the in-memory state (no replay, no disk) or
        restore the latest durable checkpoint.  Returns the resume step."""
        nonlocal base_step
        t_rec = time.perf_counter()
        failed_ranks, st["failed_ranks"] = st["failed_ranks"], 0
        plan = remesh_plan(
            int(np.prod(tuple(st["mesh"].shape.values()))),
            failed_ranks,
            model_parallel=int(st["mesh"].shape["model"]),
        )
        devices = np.array(jax.devices()[: plan.n_chips]).reshape(plan.shape)
        st["mesh"] = jax.sharding.Mesh(devices, plan.axes)
        print(
            f"[train] lost {failed_ranks} ranks at step {int(st['state'].step)}; "
            f"re-meshed to {plan.shape} ({plan.dropped_chips} chips dropped)"
        )
        can_restore = (
            st["restorable"] and mgr is not None and mgr.latest_step() is not None
        )
        with _mesh_ctx():
            if args.recovery == "restore" and can_restore:
                resume, st["state"] = mgr.restore(abstract_train_state(cfg))
                jax.block_until_ready(st["state"])
                # drop losses of the steps the restore will replay
                if resume < base_step:
                    losses.clear()
                    base_step = resume
                else:
                    del losses[resume - base_step:]
                mode = "restore"
                print(f"[train] restored step {resume} onto new mesh")
            else:
                st["state"] = jax.device_put(st["state"], train_state_shardings(cfg))
                jax.block_until_ready(st["state"])
                mode = "live"
                resume = int(st["state"].step)
                prefix = "" if args.recovery == "live" else "no restorable checkpoint; "
                print(f"[train] {prefix}live-resharded step {resume} onto new mesh")
        _bind(resume)
        recoveries.append(
            {
                "mode": mode,
                "step": int(resume),
                "seconds": time.perf_counter() - t_rec,
            }
        )
        return resume

    try:
        if start_step < args.steps:
            with SpRuntime(workers=1, elastic=True, on_reshard=on_reshard) as rt:
                rt.elastic_loop(train_step, args.steps, start=start_step)
    finally:
        st["pf"].stop()
        if mgr is not None:
            mgr.wait()

    if losses:
        print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    else:
        print("[train] nothing to do: start step >= --steps")
    final_step = int(st["state"].step) if st["state"] is not None else start_step
    result = {"losses": losses, "final_step": final_step, "recoveries": recoveries}
    if args.bench_out:
        with open(args.bench_out, "w") as f:
            json.dump(
                {"recoveries": recoveries, "final_step": final_step}, f, indent=2
            )
        print(f"[train] wrote recovery timings to {args.bench_out}")
    return result


if __name__ == "__main__":
    main()

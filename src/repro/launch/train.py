"""Production train launcher.

Drives the staged train step with the full substrate: host-mesh sharding,
synthetic data with background prefetch, periodic async checkpoints,
failure simulation + elastic re-mesh, resume-from-latest.

    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --reduced \
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ck --ckpt-every 20

Elastic fault tolerance (``--fail-at STEP:RANKS``): a
:class:`~repro.dist.fault.FailureSimulator` injects a rank loss at STEP;
the launcher computes a :func:`~repro.dist.fault.remesh_plan` over the
survivors (preserving model parallelism), rebuilds the mesh, and recovers
by one of two paths (``--recovery``):

* ``live`` (default) — *live reshard*: ``jax.device_put`` the surviving
  in-memory state onto the new mesh and continue from the failed step; no
  replay, no disk.  Falls back to checkpoint restore only when there is no
  in-memory state to reshard.
* ``restore`` — full checkpoint restore (replays every step since the
  last save); requires ``--ckpt-dir``/``--ckpt-every`` (or ``--resume``).

Either way the data-pipeline cursor is the step counter, so resumption is
deterministic.  Each recovery is timed; ``--bench-out PATH`` writes the
timings as JSON (the ``BENCH_recovery.json`` series).
"""
from __future__ import annotations

import argparse
import contextlib
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced_config
from repro.data import Prefetcher, SyntheticLMDataset
from repro.dist.fault import FailureSimulator, remesh_plan
from repro.dist.sharding import use_mesh
from repro.launch.mesh import make_host_mesh
from repro.models.config import ShapeSpec
from repro.optim import linear_warmup_cosine
from repro.runtime.train import (
    abstract_train_state,
    build_train_step,
    init_train_state,
    train_state_shardings,
)


def _parse_fail_at(spec: str) -> FailureSimulator:
    try:
        step_s, ranks_s = spec.split(":")
        step, ranks = int(step_s), int(ranks_s)
    except ValueError:
        raise argparse.ArgumentTypeError(f"expected STEP:RANKS integers, got {spec!r}")
    if step < 1:
        raise argparse.ArgumentTypeError("STEP must be >= 1 (checked after each step)")
    if ranks < 1:
        raise argparse.ArgumentTypeError("RANKS must be >= 1")
    return FailureSimulator({step: ranks})


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--reduced", action="store_true", help="smoke-scale config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--schedule-policy", default="overlap")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument(
        "--fail-at", default=None, metavar="STEP:RANKS", type=_parse_fail_at,
        help="simulate losing RANKS chips at STEP, then elastically re-mesh",
    )
    ap.add_argument(
        "--recovery", choices=("live", "restore"), default="live",
        help="after a re-mesh: live-reshard the in-memory state (default) "
        "or restore the latest checkpoint",
    )
    ap.add_argument(
        "--bench-out", default=None, metavar="PATH",
        help="write recovery timings as JSON to PATH",
    )
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    shape = ShapeSpec("train", "train", args.seq, args.batch)
    ds = SyntheticLMDataset(cfg, shape, seed=0)
    mgr = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    sim = args.fail_at

    n_devices = len(jax.devices())
    mesh = make_host_mesh() if n_devices > 1 else None
    lr = linear_warmup_cosine(args.lr, warmup=10, total_steps=args.steps)

    start_step = 0
    state = None
    losses: list[float] = []  # losses[i] is the loss of step base_step + i + 1
    base_step = None
    remeshed = False
    recoveries: list[dict] = []  # one entry per re-mesh: mode/step/seconds
    # only checkpoints this process saved (or explicitly opted into via
    # --resume) may be restored after a failure — a stale dir from an
    # earlier run must not hijack the step counter
    restorable = args.resume

    while start_step < args.steps:
        failed_ranks = 0
        ctx = use_mesh(mesh) if mesh is not None else contextlib.nullcontext()
        with ctx:
            art = build_train_step(
                cfg,
                n_microbatches=args.microbatches,
                schedule_policy=args.schedule_policy,
                lr_schedule=lr,
                donate=False,
            )
            if remeshed:
                # re-entering after a re-mesh: live reshard keeps the
                # surviving in-memory state (no replay, no disk); restore
                # replays from the latest durable checkpoint
                remeshed = False
                t_rec = time.perf_counter()
                can_restore = (
                    restorable and mgr is not None and mgr.latest_step() is not None
                )
                if args.recovery == "live" and state is not None:
                    state = jax.device_put(state, train_state_shardings(cfg))
                    jax.block_until_ready(state)
                    mode = "live"
                    print(f"[train] live-resharded step {start_step} onto new mesh")
                elif can_restore:
                    start_step, state = mgr.restore(abstract_train_state(cfg))
                    jax.block_until_ready(state)
                    # drop losses of the steps the restore will replay
                    if start_step < base_step:
                        losses.clear()
                        base_step = start_step
                    else:
                        del losses[start_step - base_step:]
                    mode = "restore"
                    print(f"[train] restored step {start_step} onto new mesh")
                else:
                    state = jax.device_put(state, train_state_shardings(cfg))
                    jax.block_until_ready(state)
                    mode = "live"
                    print(
                        f"[train] no restorable checkpoint; live-resharded "
                        f"step {start_step}"
                    )
                recoveries.append(
                    {
                        "mode": mode,
                        "step": int(start_step),
                        "seconds": time.perf_counter() - t_rec,
                    }
                )
            elif mgr is not None and args.resume and mgr.latest_step() is not None:
                start_step, state = mgr.restore(abstract_train_state(cfg))
                print(f"[train] resumed from step {start_step}")
            else:
                state = init_train_state(jax.random.PRNGKey(0), cfg)
                if mesh is not None:
                    state = jax.device_put(state, train_state_shardings(cfg))
            if base_step is None:
                base_step = start_step

            pf = Prefetcher(ds, start_step=start_step, depth=2)
            seg_t0, seg_steps = time.perf_counter(), 0
            try:
                for _ in range(start_step, args.steps):
                    step_idx, batch = pf.get()
                    batch = {k: jnp.asarray(v) for k, v in batch.items()}
                    state, metrics = art(state, batch)
                    loss = float(metrics["loss"])
                    losses.append(loss)
                    seg_steps += 1
                    s = int(state.step)
                    if args.log_every and s % args.log_every == 0:
                        dt = (time.perf_counter() - seg_t0) / seg_steps
                        print(
                            f"[train] step {s:5d} loss {loss:8.4f} "
                            f"gnorm {float(metrics['grad_norm']):7.3f} {dt * 1e3:7.1f} ms/step",
                            flush=True,
                        )
                    if mgr is not None and args.ckpt_every and s % args.ckpt_every == 0:
                        mgr.save(s, state)  # async commit
                        restorable = True
                    if sim is not None:
                        failed_ranks = sim.check(s)
                        if failed_ranks and mesh is None:
                            print("[train] failure injected but only one device; continuing")
                            failed_ranks = 0
                        if failed_ranks:
                            break
            finally:
                pf.stop()
                if mgr is not None:
                    mgr.wait()
            start_step = int(state.step)

        if not failed_ranks:
            break
        plan = remesh_plan(
            int(np.prod(tuple(mesh.shape.values()))),
            failed_ranks,
            model_parallel=int(mesh.shape["model"]),
        )
        devices = np.array(jax.devices()[: plan.n_chips]).reshape(plan.shape)
        mesh = jax.sharding.Mesh(devices, plan.axes)
        remeshed = True
        print(
            f"[train] lost {failed_ranks} ranks at step {start_step}; "
            f"re-meshed to {plan.shape} ({plan.dropped_chips} chips dropped)"
        )

    if losses:
        print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    else:
        print("[train] nothing to do: start step >= --steps")
    final_step = int(state.step) if state is not None else start_step
    result = {"losses": losses, "final_step": final_step, "recoveries": recoveries}
    if args.bench_out:
        with open(args.bench_out, "w") as f:
            json.dump(
                {"recoveries": recoveries, "final_step": final_step}, f, indent=2
            )
        print(f"[train] wrote recovery timings to {args.bench_out}")
    return result


if __name__ == "__main__":
    main()

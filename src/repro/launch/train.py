"""Production train launcher.

Drives the staged train step with the full substrate: host-mesh sharding,
synthetic data with background prefetch, periodic async checkpoints,
failure simulation + elastic re-mesh, resume-from-latest.

    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --reduced \
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ck --ckpt-every 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced_config
from repro.data import Prefetcher, SyntheticLMDataset
from repro.dist.sharding import use_mesh
from repro.launch.mesh import make_host_mesh
from repro.models.config import ShapeSpec
from repro.optim import linear_warmup_cosine
from repro.runtime.train import (
    abstract_train_state,
    build_train_step,
    init_train_state,
    train_state_shardings,
)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--reduced", action="store_true", help="smoke-scale config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--schedule-policy", default="overlap")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    shape = ShapeSpec("train", "train", args.seq, args.batch)
    ds = SyntheticLMDataset(cfg, shape, seed=0)
    mesh = make_host_mesh() if len(jax.devices()) > 1 else None
    mgr = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None

    ctx = use_mesh(mesh) if mesh is not None else _null_ctx()
    with ctx:
        lr = linear_warmup_cosine(args.lr, warmup=10, total_steps=args.steps)
        art = build_train_step(
            cfg,
            n_microbatches=args.microbatches,
            schedule_policy=args.schedule_policy,
            lr_schedule=lr,
            donate=False,
        )
        start_step = 0
        if mgr is not None and args.resume and mgr.latest_step() is not None:
            template = abstract_train_state(cfg)
            start_step, state = mgr.restore(template)
            print(f"[train] resumed from step {start_step}")
        else:
            state = init_train_state(jax.random.PRNGKey(0), cfg)
            if mesh is not None:
                state = jax.device_put(state, train_state_shardings(cfg))

        pf = Prefetcher(ds, start_step=start_step, depth=2)
        losses = []
        t0 = time.perf_counter()
        try:
            for _ in range(start_step, args.steps):
                step_idx, batch = pf.get()
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                state, metrics = art(state, batch)
                loss = float(metrics["loss"])
                losses.append(loss)
                s = int(state.step)
                if args.log_every and s % args.log_every == 0:
                    dt = (time.perf_counter() - t0) / max(len(losses), 1)
                    print(
                        f"[train] step {s:5d} loss {loss:8.4f} "
                        f"gnorm {float(metrics['grad_norm']):7.3f} {dt * 1e3:7.1f} ms/step",
                        flush=True,
                    )
                if mgr is not None and args.ckpt_every and s % args.ckpt_every == 0:
                    mgr.save(s, state)  # async commit
        finally:
            pf.stop()
            if mgr is not None:
                mgr.wait()
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return {"losses": losses, "final_step": int(state.step)}


class _null_ctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell on
512 placeholder host devices; record memory_analysis / cost_analysis /
collective-bytes for EXPERIMENTS.md §Dry-run and §Roofline.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
    PYTHONPATH=src python -m repro.launch.dryrun --arch X --shape Y --set moe.dispatch=scatter

Results append to experiments/dryrun/<arch>__<shape>__<mesh>[__tag].json.
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.dist.sharding import use_mesh
from repro.launch.mesh import make_production_mesh
from repro.models import abstract_cache, abstract_inputs, applicable_shapes
from repro.models.config import SHAPES, ArchConfig, ShapeSpec

# per-arch dry-run overrides: memory-budget knobs for the ≥100B configs
DRYRUN_OVERRIDES: dict[str, dict] = {
    "qwen3-moe-235b-a22b": {"opt_state_dtype": "bfloat16"},
    "llama4-scout-17b-a16e": {"opt_state_dtype": "bfloat16"},
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]+\d+|pred)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, default: int = 1) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota form [num_groups, group_size]
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:  # explicit form {{0,1,...},{...}} — size of the first group
        return len(m.group(1).split(","))
    return default


def collective_stats(hlo_text: str) -> dict:
    """Per-device collective traffic from the post-SPMD (per-partition) HLO.

    Post-optimization HLO prints operands without types, so sizes come from
    the *result* type(s) on the LHS.  Per instance we record:

    * ``bytes``  — the full (logical) payload: result bytes, except
      reduce-scatter where the operand = result × group_size;
    * ``wire_bytes`` — estimated per-device link traffic for ring
      implementations: AG/RS move (g−1)/g × full, AR moves 2×(g−1)/g × full,
      A2A (g−1)/g, permute 1×.

    NB: ops inside a ``while`` (layer-scan) body appear once in the text;
    benchmarks/roofline.py corrects by trip count via unrolled probes.
    """
    out = {k: {"count": 0, "bytes": 0, "wire_bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.*)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        kind_m = re.match(r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+([a-z0-9\-]+)\(", rhs)
        if not kind_m:
            continue
        lhs_types, opname = kind_m.group(1), kind_m.group(2)
        base = None
        for k in _COLLECTIVES:
            if opname == k or opname == k + "-start":
                base = k
                break
        if base is None:
            continue
        sizes = [_shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(lhs_types)]
        if not sizes:
            continue
        g = _group_size(line, default=2)
        if base == "all-gather":
            full = max(sizes)  # result (gathered) size
            wire = full * (g - 1) // max(g, 1)
        elif base == "reduce-scatter":
            full = min(sizes) * g  # operand size
            wire = full * (g - 1) // max(g, 1)
        elif base == "all-reduce":
            full = max(sizes)
            wire = 2 * full * (g - 1) // max(g, 1)
        elif base == "all-to-all":
            full = max(sizes)
            wire = full * (g - 1) // max(g, 1)
        else:  # collective-permute
            full = max(sizes)
            wire = full
        out[base]["count"] += 1
        out[base]["bytes"] += full
        out[base]["wire_bytes"] += wire
    out["total_bytes"] = sum(v["bytes"] for v in out.values() if isinstance(v, dict))
    out["total_wire_bytes"] = sum(
        v["wire_bytes"] for v in out.values() if isinstance(v, dict)
    )
    out["total_count"] = sum(v["count"] for v in out.values() if isinstance(v, dict))
    return out


def _memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    keys = [
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ]
    d = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            d[k] = int(v)
    if d:
        d["total_per_device_bytes"] = (
            d.get("argument_size_in_bytes", 0)
            + d.get("output_size_in_bytes", 0)
            + d.get("temp_size_in_bytes", 0)
            - d.get("alias_size_in_bytes", 0)
        )
    else:
        d["repr"] = str(ma)
    return d


def _cost_analysis_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {str(k): float(v) for k, v in ca.items() if isinstance(v, (int, float))}


def config_for_dryrun(arch: str, overrides: dict | None = None) -> ArchConfig:
    cfg = get_config(arch)
    kw = dict(DRYRUN_OVERRIDES.get(arch, {}))
    if overrides:
        kw.update(overrides)
    # nested override support: {"moe.dispatch": "scatter"}
    flat = {k: v for k, v in kw.items() if "." not in k}
    nested = {k: v for k, v in kw.items() if "." in k}
    if flat:
        cfg = cfg.replace(**flat)
    for key, val in nested.items():
        head, field = key.split(".", 1)
        sub = getattr(cfg, head)
        cfg = cfg.replace(**{head: dataclasses.replace(sub, **{field: val})})
    return cfg


def lower_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, n_microbatches: int = 1):
    """Build and lower the step for one cell.  Returns the Lowered object."""
    with use_mesh(mesh):
        if shape.kind == "train":
            from repro.runtime.train import abstract_train_state, build_train_step

            art = build_train_step(cfg, n_microbatches=n_microbatches, donate=True)
            state_abs = abstract_train_state(cfg)
            batch_abs = abstract_inputs(cfg, shape)
            return art.step_fn.lower(state_abs, batch_abs)
        if shape.kind == "prefill":
            from repro.models import abstract_params, prefill
            from repro.models.transformer import param_shardings

            p_abs = abstract_params(cfg)
            batch_abs = abstract_inputs(cfg, shape)

            def prefill_fn(params, batch):
                logits, caches = prefill(params, batch, cfg)
                return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

            fn = jax.jit(prefill_fn, in_shardings=(param_shardings(cfg), None))
            return fn.lower(p_abs, batch_abs)
        # decode
        from repro.models import abstract_params
        from repro.runtime.serve import build_serve_step

        p_abs = abstract_params(cfg)
        tok_abs = abstract_inputs(cfg, shape)["tokens"]
        cache_abs = abstract_cache(cfg, shape.global_batch, shape.seq_len)
        pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
        fn = build_serve_step(cfg, shape, jit=True)
        return fn.lower(p_abs, tok_abs, cache_abs, pos_abs)


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    overrides: dict | None = None,
    tag: str = "",
    outdir: str = "experiments/dryrun",
) -> dict:
    overrides = dict(overrides or {})
    n_microbatches = int(overrides.pop("n_microbatches", 1))
    cfg = config_for_dryrun(arch, overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    rec: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_chips": n_chips,
        "tag": tag,
        "overrides": dict(overrides or {}, n_microbatches=n_microbatches),
    }
    t0 = time.time()
    try:
        lowered = lower_cell(cfg, shape, mesh, n_microbatches=n_microbatches)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        rec["memory"] = _memory_analysis_dict(compiled)
        rec["cost"] = _cost_analysis_dict(compiled)
        hlo = compiled.as_text()
        rec["collectives"] = collective_stats(hlo)
        rec["ok"] = True
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    os.makedirs(outdir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "") + ".json"
    with open(os.path.join(outdir, fname), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--outdir", default="experiments/dryrun")
    ap.add_argument(
        "--set",
        action="append",
        default=[],
        help="config override key=value (e.g. moe.dispatch=scatter)",
    )
    args = ap.parse_args()

    overrides: dict = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v.lower() in ("true", "false"):
            v = v.lower() == "true"
        else:
            for cast in (int, float):
                try:
                    v = cast(v)
                    break
                except ValueError:
                    continue
        overrides[k] = v

    meshes = []
    if args.multi_pod or not args.single_pod:
        pass
    if args.single_pod:
        meshes = [False]
    elif args.multi_pod:
        meshes = [True]
    else:
        meshes = [False, True]

    cells = []
    if args.all:
        for arch in ARCH_NAMES:
            cfg = get_config(arch)
            for shape in applicable_shapes(cfg):
                cells.append((arch, shape.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(arch, shape, mp, overrides or None, args.tag, args.outdir)
            status = "OK " if rec["ok"] else "FAIL"
            print(
                f"[{status}] {arch:26s} {shape:12s} {rec['mesh']:16s} "
                f"lower={rec.get('lower_s', '-'):>6}s compile={rec.get('compile_s', '-'):>6}s "
                + (
                    f"flops/dev={rec['cost'].get('flops', 0):.3e} "
                    f"coll={rec['collectives']['total_bytes']:.3e}B"
                    if rec["ok"]
                    else rec.get("error", "")
                ),
                flush=True,
            )
            if rec["ok"]:
                print(json.dumps(rec["memory"], indent=None), flush=True)


if __name__ == "__main__":
    main()

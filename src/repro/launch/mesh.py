"""Production mesh construction.

A function (not a module constant) so importing never touches jax device
state.  Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is pure
data parallelism over the slow inter-pod links (DCN/ICI-lite), which the
sharding rules use only for the batch axis and the hierarchical gradient
reduction (DESIGN.md §5).
"""
from __future__ import annotations

import jax

SINGLE_POD = (16, 16)
SINGLE_POD_AXES = ("data", "model")
MULTI_POD = (2, 16, 16)
MULTI_POD_AXES = ("pod", "data", "model")


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = MULTI_POD_AXES if multi_pod else SINGLE_POD_AXES
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int | None = None) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    mp = model_parallel or (2 if n % 2 == 0 and n > 1 else 1)
    return jax.make_mesh((n // mp, mp), ("data", "model"))

"""Sharded checkpointing with async commit — the fault-tolerance substrate.

Layout (per step)::

    <dir>/step_000042.tmp/          (written first)
        MANIFEST.json               (tree structure, shapes, dtypes, crc32s)
        leaf_00000.npy ...          (one file per pytree leaf)
    <dir>/step_000042/              (atomic rename on commit)

* **atomicity**: a crash mid-write leaves only a ``.tmp`` dir, which restore
  ignores and the next save purges — restart always finds a consistent step;
* **async commit**: device→host transfer happens on the caller thread (the
  arrays are small views once sharded), serialization+fsync on a background
  thread, so the train loop resumes immediately (Specx's "background thread
  progresses I/O" pattern, C4);
* **integrity**: per-leaf crc32 in the manifest, verified on restore;
* **retention**: keep the newest ``keep`` checkpoints;
* **multi-host posture**: each process writes ``shard-<proc>`` files for its
  addressable shards; on this single-process container that is shard-0 with
  the full array.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _tree_flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_commit: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_commit = async_commit
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None
        # purge stale tmp dirs from a previous crash
        for d in os.listdir(directory):
            if d.endswith(".tmp"):
                shutil.rmtree(os.path.join(directory, d), ignore_errors=True)

    # ------------------------------------------------------------------ save

    def save(self, step: int, state: Any, *, block: bool = False) -> None:
        self.wait()  # one in-flight commit at a time
        paths, leaves, treedef = _tree_flatten_with_paths(state)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]

        def commit():
            tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
            final = os.path.join(self.dir, f"step_{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "leaves": []}
            for i, (p, arr) in enumerate(zip(paths, host_leaves)):
                fname = f"leaf_{i:05d}.shard-0.npy"
                np.save(os.path.join(tmp, fname), arr)
                manifest["leaves"].append(
                    {
                        "path": p,
                        "file": fname,
                        "shape": list(arr.shape),
                        "dtype": str(arr.dtype),
                        "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
                    }
                )
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._retain()

        if self.async_commit and not block:
            self._pending = threading.Thread(target=commit, daemon=True)
            self._pending.start()
        else:
            commit()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _retain(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None) -> tuple[int, Any]:
        """Restore into the structure of ``template`` (a pytree of arrays or
        ShapeDtypeStructs); device placement/sharding follows the template's
        shardings when present (elastic re-mesh: pass the NEW shardings)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        paths, leaves, treedef = _tree_flatten_with_paths(template)
        by_path = {e["path"]: e for e in manifest["leaves"]}
        out_leaves = []
        for p, tmpl in zip(paths, leaves):
            e = by_path[p]
            arr = np.load(os.path.join(d, e["file"]))
            if arr.dtype.kind == "V":
                # numpy round-trips ml_dtypes (bfloat16, ...) as raw void;
                # reinterpret via the manifest dtype (registered by jax)
                import ml_dtypes  # noqa: F401

                arr = arr.view(np.dtype(e["dtype"]))
            crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
            if crc != e["crc32"]:
                raise IOError(f"checkpoint corruption in {e['file']} ({p})")
            sharding = getattr(tmpl, "sharding", None)
            if sharding is not None and not isinstance(
                sharding, jax.sharding.SingleDeviceSharding
            ):
                out_leaves.append(jax.device_put(arr, sharding))
            else:
                out_leaves.append(jax.numpy.asarray(arr))
        return step, jax.tree_util.tree_unflatten(treedef, out_leaves)

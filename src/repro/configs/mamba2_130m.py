"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060].
24L d_model=768, attn-free, vocab=50280, ssm_state=128.
d_inner = 2·768 = 1536 → 24 heads of head_dim 64.  Sub-quadratic: runs the
long_500k cell (O(1)-state decode)."""
from repro.models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=0,
    vocab=50280,
    tie_embeddings=True,
    logits_chunk=1024,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1, chunk_size=256),
)

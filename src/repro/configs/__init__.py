"""Config registry: ``get_config(name)`` for the full assigned configs,
``reduced_config(name)`` for CPU-runnable smoke variants of the same family
(small layers/width/experts/vocab — the assignment's smoke-test rule)."""
from __future__ import annotations

import dataclasses

from repro.models.config import ArchConfig, HybridConfig, MLAConfig, MoEConfig, SSMConfig

from . import (
    deepseek_7b,
    gemma_7b,
    hubert_xlarge,
    internvl2_2b,
    llama4_scout,
    mamba2_130m,
    minicpm3_4b,
    qwen15_110b,
    qwen3_moe_235b,
    recurrentgemma_9b,
)

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in [
        hubert_xlarge.CONFIG,
        gemma_7b.CONFIG,
        qwen15_110b.CONFIG,
        deepseek_7b.CONFIG,
        minicpm3_4b.CONFIG,
        qwen3_moe_235b.CONFIG,
        llama4_scout.CONFIG,
        mamba2_130m.CONFIG,
        recurrentgemma_9b.CONFIG,
        internvl2_2b.CONFIG,
    ]
}

ARCH_NAMES = list(ARCHS)


def get_config(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise ValueError(f"unknown arch {name!r}; have {ARCH_NAMES}") from None


def reduced_config(name: str) -> ArchConfig:
    """Tiny same-family config: one fwd/train step runs on CPU in seconds."""
    cfg = get_config(name)
    kw: dict = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads * 4 // max(cfg.n_heads, 1), 4)),
        head_dim=16,
        d_ff=128,
        vocab=128,
        logits_chunk=None,
        attn_blockwise_min_seq=64,
        attn_block_q=16,
        attn_block_kv=16,
        n_patches=4,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff_expert=32
        )
        kw["d_ff"] = 32
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(
            q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
            qk_rope_head_dim=8, v_head_dim=16,
        )
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=16, head_dim=8, expand=2, n_groups=1, chunk_size=8)
        kw["n_heads"] = 16
        kw["head_dim"] = 8
    if cfg.hybrid is not None:
        kw["n_layers"] = 5  # 1 scanned (rec,rec,attn) super-block + 2 tail
        kw["hybrid"] = HybridConfig(
            pattern=cfg.hybrid.pattern, lru_width=64, conv_width=4, window=16
        )
    return cfg.replace(**kw)

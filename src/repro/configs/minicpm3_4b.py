"""minicpm3-4b [dense, MLA] — multi-head latent attention
[hf:openbmb/MiniCPM3-4B].  62L d_model=2560 40H d_ff=6400 vocab=73448.
MLA: q_lora=768, kv_lora=256, nope=64, rope=32, v=64 — the decode cache
stores only (c_kv, k_rope): ~(256+32) vs 2·40·64 floats/token for GQA."""
from repro.models.config import ArchConfig, MLAConfig

CONFIG = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=64,
    d_ff=6400,
    vocab=73448,
    act="swiglu",
    logits_chunk=1024,
    mla=MLAConfig(
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
)

"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared expert
[hf:meta-llama/Llama-4-Scout-17B-16E].  48L d_model=5120 40H (kv=8)
expert d_ff=8192 vocab=202048.  The early-fusion modality frontend is out of
scope for the LM backbone cells (text path only, per assignment note)."""
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    act="swiglu",
    rope_theta=500_000.0,
    logits_chunk=1024,
    moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192, n_shared_experts=1),
)

"""internvl2-2b [vlm] — InternViT + InternLM2 [arXiv:2404.16821].
LM backbone: 24L d_model=2048 16H (kv=8) d_ff=8192 vocab=92553.
The InternViT frontend is a STUB: ``input_specs`` feeds precomputed patch
embeddings (B, n_patches, 1024) projected into the LM sequence."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=92553,
    act="swiglu",
    frontend="vision",
    logits_chunk=768,
    n_patches=256,
)

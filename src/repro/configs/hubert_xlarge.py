"""hubert-xlarge [audio] — encoder-only, wav2vec2 architecture
[arXiv:2106.07447].  48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504.

The conv feature-extractor frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings (B, L, 512); training is masked-prediction CE
over the 504 k-means units.  Encoder-only ⇒ no decode shapes.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab=504,
    act="gelu",
    frontend="audio",
    rope_theta=10000.0,
)

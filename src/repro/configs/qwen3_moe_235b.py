"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, QK-norm
[hf:Qwen/Qwen3 family].  94L d_model=4096 64H (kv=4) expert d_ff=1536
vocab=151936.  Experts shard over the model axis (EP); dispatch strategy is
the §Perf lever (einsum baseline vs scatter)."""
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab=151936,
    act="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    logits_chunk=1024,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536),
)

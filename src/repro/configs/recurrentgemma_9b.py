"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2:1 pattern
[arXiv:2402.19427].  38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000, window 2048.  Sub-quadratic: runs long_500k (ring-buffer KV of
window size + recurrent state)."""
from repro.models.config import ArchConfig, HybridConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    act="geglu",
    tie_embeddings=True,
    embed_scale=True,
    logits_chunk=1024,
    hybrid=HybridConfig(pattern=("rec", "rec", "attn"), lru_width=4096, conv_width=4, window=2048),
)

from .train import TrainStepArtifacts, build_train_step, train_state_shardings, abstract_train_state
from .serve import build_decode_fn, build_prefill_fn, build_serve_step

__all__ = [
    "TrainStepArtifacts",
    "build_train_step",
    "train_state_shardings",
    "abstract_train_state",
    "build_decode_fn",
    "build_prefill_fn",
    "build_serve_step",
]

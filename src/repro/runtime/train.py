"""Training step built from codelets and compiled through the staged backend
(DESIGN.md §2) — the paper's STF model driving a pod-scale SPMD step.

Task structure of one step (N microbatches), three codelets declared once::

    mb_0 ... mb_{N-1}   read(params), read(batch_i),
                        commutative(grads)             ← C1: order-free accum
    grad_finalize       comm task: mean + sharding constraint to the param
                        layout (the GSPMD reduce-scatter lands here)  ← C4
    optimizer           write(params/opt): clip + nonfinite check +
                        *speculative* update — computed unconditionally,
                        selected by the finite flag (branchless TPU analogue
                        of SpMaybeWrite+rollback, C6)

The step runs on ``SpRuntime(backend="staged")`` inside ``jax.jit``: the
scheduler policy decides the compiled program order — ``overlap`` hoists
the comm task between independent microbatch tasks; commutative accumulation
lets it reorder microbatches freely (both visible in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import SpData, SpRuntime, sp_task
from repro.dist.collectives import compress_tree, init_residuals
from repro.dist.sharding import current_mesh, named_sharding, shard
from repro.models import abstract_params, loss_fn, model_defs, param_shardings
from repro.models.config import ArchConfig, ShapeSpec
from repro.models.param import abstract_tree, sharding_tree
from repro.optim import TrainState, make_optimizer


# ---------------------------------------------------------------------------
# The three task shapes of a train step (codelet frontend, core/api.py).
# ---------------------------------------------------------------------------

@sp_task(read=("params", "mb"), commutative=("grads", "metrics"), name="mb", cost=10.0)
def _microbatch_codelet(params, mb, grads, metrics, *, grad_fn):
    """Forward+backward over one microbatch; order-free gradient accumulation."""
    (loss, m), g = grad_fn(params, mb)
    grads.value = jax.tree.map(
        lambda acc, gg: acc + gg.astype(acc.dtype), grads.value, g
    )
    metrics.value = {
        "loss": metrics.value["loss"] + loss.astype(jnp.float32),
        "ce_loss": metrics.value["ce_loss"] + m["ce_loss"].astype(jnp.float32),
    }
    return loss


@sp_task(write=("grads",), name="grad_allreduce", cost=3.0, comm=True)
def _grad_finalize_codelet(grads, *, n_mb, compress, p_sh):
    """Mean + (optional) int8 quantize-dequantize + reshard to the param
    layout — the GSPMD reduce-scatter lands on this comm task."""
    g = jax.tree.map(lambda t: t / n_mb, grads.value)
    if compress:
        # error-feedback residuals live across steps via state in a
        # production driver; stateless inside one compiled step we
        # quantize-dequantize only (documented in EXPERIMENTS.md)
        g, _ = compress_tree(
            g, jax.tree.map(lambda t: jnp.zeros_like(t, jnp.float32), g)
        )
    if p_sh is not None:
        g = jax.tree.map(
            lambda t, s: jax.lax.with_sharding_constraint(t, s), g, p_sh
        )
    grads.value = g


@sp_task(
    read=("grads",),
    write=("params", "opt", "new_step"),
    name="optimizer",
    cost=5.0,
)
def _optimizer_codelet(
    grads, params, opt, new_step, *, opt_update, lr_schedule, clip_norm, step
):
    """Clip + nonfinite check + branchless-speculative update (C6): the
    update is computed unconditionally; rollback = select the old state."""
    from repro.optim.optimizer import global_norm

    gnorm = global_norm(grads)
    finite = jnp.isfinite(gnorm)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    g_clipped = jax.tree.map(lambda t: t * scale, grads)
    lr = lr_schedule(step)
    cand_p, cand_o = opt_update(g_clipped, opt.value, params.value, lr, step)
    sel = lambda new, old: jnp.where(finite, new, old)
    params.value = jax.tree.map(sel, cand_p, params.value)
    opt.value = jax.tree.map(sel, cand_o, opt.value)
    new_step.value = step + 1
    return gnorm


class TrainStepArtifacts:
    """Holds the jitted step + shardings + schedule introspection."""

    def __init__(self, step_fn, in_shardings, out_shardings, schedule_names):
        self.step_fn = step_fn
        self.in_shardings = in_shardings
        self.out_shardings = out_shardings
        self.schedule_names = schedule_names

    def __call__(self, state, batch):
        return self.step_fn(state, batch)


def train_state_shardings(cfg: ArchConfig):
    """NamedSharding tree for TrainState (requires active mesh context)."""
    defs = model_defs(cfg)
    p_sh = sharding_tree(defs)
    opt_init, _ = make_optimizer(cfg.optimizer, cfg.opt_state_dtype)
    # optimizer state mirrors the param tree (adamw) — reuse param shardings
    if cfg.optimizer == "adamw":
        opt_sh = {"m": p_sh, "v": p_sh}
    else:  # adafactor states are small; replicate
        abs_p = abstract_tree(defs, cfg.dtype)
        opt_abs = opt_init(abs_p)
        opt_sh = jax.tree.map(lambda _: named_sharding((), ()), opt_abs)
    step_sh = named_sharding((), ())
    return TrainState(step=step_sh, params=p_sh, opt=opt_sh)


def abstract_train_state(cfg: ArchConfig) -> TrainState:
    """ShapeDtypeStruct TrainState for .lower() (no allocation)."""
    params = abstract_params(cfg)
    opt_init, _ = make_optimizer(cfg.optimizer, cfg.opt_state_dtype)
    opt = jax.eval_shape(opt_init, params)
    if current_mesh() is not None:
        sh = train_state_shardings(cfg)
        params = jax.tree.map(
            lambda s, d: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=d),
            params,
            sh.params,
        )
        opt = jax.tree.map(
            lambda s, d: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=d),
            opt,
            sh.opt,
        )
    step = jax.ShapeDtypeStruct((), jnp.int32)
    return TrainState(step=step, params=params, opt=opt)


def init_train_state(rng: jax.Array, cfg: ArchConfig) -> TrainState:
    from repro.models import init_params

    params = init_params(rng, cfg)
    opt_init, _ = make_optimizer(cfg.optimizer, cfg.opt_state_dtype)
    return TrainState(step=jnp.int32(0), params=params, opt=opt_init(params))


def build_train_step(
    cfg: ArchConfig,
    *,
    n_microbatches: int = 1,
    schedule_policy: str = "overlap",
    lr_schedule: Optional[Callable] = None,
    clip_norm: float = 1.0,
    grad_accum_dtype: str = "float32",
    grad_compression: bool = False,
    jit: bool = True,
    donate: bool = True,
):
    """Build the staged train step.  Returns ``TrainStepArtifacts``."""
    lr_schedule = lr_schedule or (lambda step: jnp.float32(3e-4))
    opt_init, opt_update = make_optimizer(cfg.optimizer, cfg.opt_state_dtype)
    schedule_names: list[str] = []

    def train_step(state: TrainState, batch: dict):
        params_c = SpData(state.params, "params")
        zero_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.dtype(grad_accum_dtype)), state.params
        )
        grads_c = SpData(zero_g, "grads")
        metrics_c = SpData(
            {"loss": jnp.float32(0.0), "ce_loss": jnp.float32(0.0)}, "metrics"
        )
        opt_c = SpData(state.opt, "opt")
        new_step_c = SpData(None, "new_step")

        n_mb = n_microbatches
        mb_batch = jax.tree.map(
            lambda t: t.reshape((n_mb, t.shape[0] // n_mb) + t.shape[1:]), batch
        )
        grad_fn = jax.value_and_grad(lambda p, b: loss_fn(p, b, cfg), has_aux=True)
        p_sh = param_shardings(cfg) if current_mesh() is not None else None

        with SpRuntime(backend="staged", policy=schedule_policy) as rt:
            for i in range(n_mb):
                mb_c = SpData(jax.tree.map(lambda t: t[i], mb_batch), f"mb{i}")
                _microbatch_codelet(
                    params_c, mb_c, grads_c, metrics_c,
                    grad_fn=grad_fn, name=f"mb{i}",
                )
            _grad_finalize_codelet(
                grads_c, n_mb=n_mb, compress=grad_compression, p_sh=p_sh
            )
            gnorm_view = _optimizer_codelet(
                grads_c, params_c, opt_c, new_step_c,
                opt_update=opt_update, lr_schedule=lr_schedule,
                clip_norm=clip_norm, step=state.step,
            )
            order = rt.run()
        if not schedule_names:
            schedule_names.extend(t.name for t in order)

        metrics = jax.tree.map(lambda t: t / n_mb, metrics_c.value)
        metrics["grad_norm"] = gnorm_view.result()
        new_state = TrainState(
            step=new_step_c.value, params=params_c.value, opt=opt_c.value
        )
        return new_state, metrics

    if not jit:
        return TrainStepArtifacts(train_step, None, None, schedule_names)

    in_sh = out_sh = None
    donate_argnums = (0,) if donate else ()
    if current_mesh() is not None:
        st_sh = train_state_shardings(cfg)
        in_sh = (st_sh, None)  # batch sharding inferred from input specs
        out_sh = (st_sh, None)
        step_fn = jax.jit(
            train_step,
            in_shardings=in_sh,
            out_shardings=out_sh,
            donate_argnums=donate_argnums,
        )
    else:
        step_fn = jax.jit(train_step, donate_argnums=donate_argnums)
    return TrainStepArtifacts(step_fn, in_sh, out_sh, schedule_names)

"""Training step built as an ``SpTaskGraph`` and compiled through the staged
backend (DESIGN.md §2) — the paper's STF model driving a pod-scale SPMD step.

Task structure of one step (N microbatches)::

    mb_0 ... mb_{N-1}   SpRead(params), SpRead(batch_i),
                        SpCommutativeWrite(grads)      ← C1: order-free accum
    grad_finalize       comm task: mean + sharding constraint to the param
                        layout (the GSPMD reduce-scatter lands here)  ← C4
    clip+check          SpRead(grads) → gnorm, finite flag
    optimizer           SpWrite(params/opt): *speculative* update — computed
                        unconditionally, selected by the finite flag
                        (branchless TPU analogue of SpMaybeWrite+rollback, C6)

The scheduler policy decides the compiled program order: ``overlap`` hoists
the comm task between independent microbatch tasks; commutative accumulation
lets it reorder microbatches freely (both visible in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import (
    SpCommutativeWrite,
    SpData,
    SpRead,
    SpTaskGraph,
    SpWrite,
    execute_staged,
)
from repro.dist.collectives import compress_tree, init_residuals
from repro.dist.sharding import current_mesh, named_sharding, shard
from repro.models import abstract_params, loss_fn, model_defs, param_shardings
from repro.models.config import ArchConfig, ShapeSpec
from repro.models.param import abstract_tree, sharding_tree
from repro.optim import TrainState, make_optimizer


class TrainStepArtifacts:
    """Holds the jitted step + shardings + schedule introspection."""

    def __init__(self, step_fn, in_shardings, out_shardings, schedule_names):
        self.step_fn = step_fn
        self.in_shardings = in_shardings
        self.out_shardings = out_shardings
        self.schedule_names = schedule_names

    def __call__(self, state, batch):
        return self.step_fn(state, batch)


def train_state_shardings(cfg: ArchConfig):
    """NamedSharding tree for TrainState (requires active mesh context)."""
    defs = model_defs(cfg)
    p_sh = sharding_tree(defs)
    opt_init, _ = make_optimizer(cfg.optimizer, cfg.opt_state_dtype)
    # optimizer state mirrors the param tree (adamw) — reuse param shardings
    if cfg.optimizer == "adamw":
        opt_sh = {"m": p_sh, "v": p_sh}
    else:  # adafactor states are small; replicate
        abs_p = abstract_tree(defs, cfg.dtype)
        opt_abs = opt_init(abs_p)
        opt_sh = jax.tree.map(lambda _: named_sharding((), ()), opt_abs)
    step_sh = named_sharding((), ())
    return TrainState(step=step_sh, params=p_sh, opt=opt_sh)


def abstract_train_state(cfg: ArchConfig) -> TrainState:
    """ShapeDtypeStruct TrainState for .lower() (no allocation)."""
    params = abstract_params(cfg)
    opt_init, _ = make_optimizer(cfg.optimizer, cfg.opt_state_dtype)
    opt = jax.eval_shape(opt_init, params)
    if current_mesh() is not None:
        sh = train_state_shardings(cfg)
        params = jax.tree.map(
            lambda s, d: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=d),
            params,
            sh.params,
        )
        opt = jax.tree.map(
            lambda s, d: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=d),
            opt,
            sh.opt,
        )
    step = jax.ShapeDtypeStruct((), jnp.int32)
    return TrainState(step=step, params=params, opt=opt)


def init_train_state(rng: jax.Array, cfg: ArchConfig) -> TrainState:
    from repro.models import init_params

    params = init_params(rng, cfg)
    opt_init, _ = make_optimizer(cfg.optimizer, cfg.opt_state_dtype)
    return TrainState(step=jnp.int32(0), params=params, opt=opt_init(params))


def build_train_step(
    cfg: ArchConfig,
    *,
    n_microbatches: int = 1,
    schedule_policy: str = "overlap",
    lr_schedule: Optional[Callable] = None,
    clip_norm: float = 1.0,
    grad_accum_dtype: str = "float32",
    grad_compression: bool = False,
    jit: bool = True,
    donate: bool = True,
):
    """Build the staged train step.  Returns ``TrainStepArtifacts``."""
    lr_schedule = lr_schedule or (lambda step: jnp.float32(3e-4))
    opt_init, opt_update = make_optimizer(cfg.optimizer, cfg.opt_state_dtype)
    schedule_names: list[str] = []

    def train_step(state: TrainState, batch: dict):
        tg = SpTaskGraph()
        params_c = SpData(state.params, "params")
        zero_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.dtype(grad_accum_dtype)), state.params
        )
        grads_c = SpData(zero_g, "grads")
        metrics_c = SpData(
            {"loss": jnp.float32(0.0), "ce_loss": jnp.float32(0.0)}, "metrics"
        )

        # ---- microbatch forward+backward tasks (commutative accumulation) --
        n_mb = n_microbatches
        mb_batch = jax.tree.map(
            lambda t: t.reshape((n_mb, t.shape[0] // n_mb) + t.shape[1:]), batch
        )
        grad_fn = jax.value_and_grad(lambda p, b: loss_fn(p, b, cfg), has_aux=True)

        for i in range(n_mb):
            mb = jax.tree.map(lambda t: t[i], mb_batch)
            mb_c = SpData(mb, f"mb{i}")

            def body(p, b, g_ref, m_ref, _i=i):
                (loss, metrics), g = grad_fn(p, b)
                g_ref.value = jax.tree.map(
                    lambda acc, gg: acc + gg.astype(acc.dtype), g_ref.value, g
                )
                m_ref.value = {
                    "loss": m_ref.value["loss"] + loss.astype(jnp.float32),
                    "ce_loss": m_ref.value["ce_loss"]
                    + metrics["ce_loss"].astype(jnp.float32),
                }
                return loss

            tg.task(
                SpRead(params_c),
                SpRead(mb_c),
                SpCommutativeWrite(grads_c),
                SpCommutativeWrite(metrics_c),
                body,
                name=f"mb{i}",
                cost=10.0,
            )

        # ---- gradient finalize: mean + reshard (the collective lands here) --
        p_sh = param_shardings(cfg) if current_mesh() is not None else None

        def grad_finalize(g_ref):
            g = jax.tree.map(lambda t: t / n_mb, g_ref.value)
            if grad_compression:
                res_c = getattr(grad_finalize, "_residuals", None)
                # error-feedback residuals live across steps via state in a
                # production driver; stateless inside one compiled step we
                # quantize-dequantize only (documented in EXPERIMENTS.md)
                g, _ = compress_tree(g, jax.tree.map(lambda t: jnp.zeros_like(t, jnp.float32), g))
            if p_sh is not None:
                g = jax.tree.map(
                    lambda t, s: jax.lax.with_sharding_constraint(t, s), g, p_sh
                )
            g_ref.value = g

        tg.task(SpWrite(grads_c), grad_finalize, name="grad_allreduce", comm=True, cost=3.0)

        # ---- clip + nonfinite check + speculative optimizer update ---------
        opt_c = SpData(state.opt, "opt")
        new_step_c = SpData(None, "new_step")

        def opt_task(g, p_ref, o_ref, s_ref):
            from repro.optim.optimizer import global_norm

            gnorm = global_norm(g)
            finite = jnp.isfinite(gnorm)
            scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
            g_clipped = jax.tree.map(lambda t: t * scale, g)
            lr = lr_schedule(state.step)
            cand_p, cand_o = opt_update(g_clipped, o_ref.value, p_ref.value, lr, state.step)
            # branchless speculation (C6 staged analogue): the update is
            # computed unconditionally; rollback = select the old state
            sel = lambda new, old: jnp.where(finite, new, old)
            p_ref.value = jax.tree.map(sel, cand_p, p_ref.value)
            o_ref.value = jax.tree.map(sel, cand_o, o_ref.value)
            s_ref.value = state.step + 1
            return gnorm

        gnorm_view = tg.task(
            SpRead(grads_c),
            SpWrite(params_c),
            SpWrite(opt_c),
            SpWrite(new_step_c),
            opt_task,
            name="optimizer",
            cost=5.0,
        )

        order = execute_staged(tg, schedule_policy)
        if not schedule_names:
            schedule_names.extend(t.name for t in order)

        metrics = jax.tree.map(lambda t: t / n_mb, metrics_c.value)
        metrics["grad_norm"] = gnorm_view.task.result
        new_state = TrainState(
            step=new_step_c.value, params=params_c.value, opt=opt_c.value
        )
        return new_state, metrics

    if not jit:
        return TrainStepArtifacts(train_step, None, None, schedule_names)

    in_sh = out_sh = None
    donate_argnums = (0,) if donate else ()
    if current_mesh() is not None:
        st_sh = train_state_shardings(cfg)
        in_sh = (st_sh, None)  # batch sharding inferred from input specs
        out_sh = (st_sh, None)
        step_fn = jax.jit(
            train_step,
            in_shardings=in_sh,
            out_shardings=out_sh,
            donate_argnums=donate_argnums,
        )
    else:
        step_fn = jax.jit(train_step, donate_argnums=donate_argnums)
    return TrainStepArtifacts(step_fn, in_sh, out_sh, schedule_names)

"""Serving steps: prefill and decode, jitted per (arch × shape) cell.

``serve_step`` is what the decode_* dry-run cells lower: one new token per
sequence against a sequence-sharded KV cache (flash-decoding-style combine
over the model axis, DESIGN.md §5).  Sampling is greedy (argmax) — the
serve-path compute is the model, not the sampler.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import current_mesh, named_sharding
from repro.models import (
    abstract_cache,
    abstract_inputs,
    abstract_params,
    decode_step,
    prefill,
    verify_step,
)
from repro.models.config import ArchConfig, ShapeSpec
from repro.models.param import ParamDef, sharding_tree
from repro.models.transformer import cache_defs, param_shardings


def cache_shardings(cfg: ArchConfig, batch: int, max_seq: int):
    return sharding_tree(cache_defs(cfg, batch, max_seq))


def _pad_kv(kv: jax.Array, prompt_len: int, size: int, window) -> jax.Array:
    """Place prefill K/V (B, T0, KH, Dh) into a fresh cache of ``size`` slots.

    Full cache: copy into [0:T0].  Ring cache (windowed): position p lives in
    slot p % size; only the last ``size`` positions matter."""
    B, T0 = kv.shape[:2]
    out = jnp.zeros((B, size) + kv.shape[2:], kv.dtype)
    if window is None:
        return jax.lax.dynamic_update_slice_in_dim(out, kv, 0, axis=1)
    keep = min(size, T0)
    tail = kv[:, T0 - keep :]
    slots = (jnp.arange(T0 - keep, T0)) % size
    return out.at[:, slots].set(tail)


def prime_cache(cfg: ArchConfig, prefill_caches, prompt_len: int, max_seq: int):
    """Convert ``prefill(...)``'s per-layer caches (seq dim = prompt length)
    into decode-ready caches of capacity ``max_seq`` (ring-aware)."""

    def prime_kind(cache: dict, hcfg: ArchConfig) -> dict:
        if "k" in cache:  # attention
            W = hcfg.attn_window
            size = min(max_seq, W) if W is not None else max_seq
            return {
                "k": _pad_kv(cache["k"], prompt_len, size, W),
                "v": _pad_kv(cache["v"], prompt_len, size, W),
            }
        if "c_kv" in cache:  # MLA latents (B, T0, r)
            return {
                k: _pad_kv(v[:, :, None, :], prompt_len, max_seq, None)[:, :, 0, :]
                for k, v in cache.items()
            }
        return cache  # ssm / rglru states are already decode-ready

    from repro.models.transformer import _hybrid_window_cfg, hybrid_layout

    if cfg.family == "hybrid":
        hcfg = _hybrid_window_cfg(cfg)
        pat = cfg.hybrid.pattern
        out_scan = {}
        for key_, sub in prefill_caches["scan"].items():
            # scanned caches carry a leading super-block dim; vmap the priming
            out_scan[key_] = jax.vmap(lambda c: prime_kind(c, hcfg))(sub)
        out_tail = [prime_kind(c, hcfg) for c in prefill_caches["tail"]]
        return {"scan": out_scan, "tail": out_tail}
    kind_cfg = cfg
    # scanned stack: leading layer dim
    return jax.vmap(lambda c: prime_kind(c, kind_cfg))(prefill_caches)


# ---------------------------------------------------------------------------
# Paged-cache row plumbing (serving tier).  Valid for families whose
# ``models.cache_layout(cfg)`` is non-None: stacked caches with axis 0 =
# layer, 1 = batch slot, 2 = sequence row.
# ---------------------------------------------------------------------------

def extract_cache_rows(caches, slot: int, start: int, stop: int):
    """Copy rows ``[start:stop)`` of one batch slot out of every cache leaf
    as host numpy arrays — the payload stored on a KV block at writeback."""
    import numpy as np

    return jax.tree.map(lambda leaf: np.asarray(leaf[:, slot, start:stop]), caches)


def insert_cache_rows(caches, slot: int, rows, start: int = 0):
    """Scatter payload ``rows`` (as produced by :func:`extract_cache_rows`,
    possibly concatenated along the row axis) back into one batch slot."""

    def put(full, r):
        r = jnp.asarray(r).astype(full.dtype)
        return full.at[:, slot, start : start + r.shape[1]].set(r)

    return jax.tree.map(put, caches, rows)


def concat_cache_rows(payloads):
    """Concatenate per-block payloads (ordered) along the row axis."""
    import numpy as np

    if len(payloads) == 1:
        return payloads[0]
    return jax.tree.map(lambda *xs: np.concatenate(xs, axis=1), *payloads)


def build_prefill_fn(cfg: ArchConfig, *, jit: bool = True):
    def prefill_fn(params, batch):
        logits, caches = prefill(params, batch, cfg)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, caches

    return jax.jit(prefill_fn) if jit else prefill_fn


def build_decode_fn(cfg: ArchConfig, *, jit: bool = True):
    def decode_fn(params, tokens, caches, pos):
        logits, new_caches = decode_step(params, tokens, caches, pos, cfg)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_caches

    return jax.jit(decode_fn, donate_argnums=(2,)) if jit else decode_fn


def build_verify_fn(cfg: ArchConfig, *, jit: bool = True):
    """Speculative-decoding verify forward: (params, tokens (B, T), caches,
    pos (B,), advance (B,)) → (logits (B, T, V), caches).  One jitted XLA
    call evaluates all T positions (retraced per T, which is static per
    draft depth); the per-position math is exactly
    :func:`repro.models.verify_step`'s unrolled ``decode_step``, which keeps
    greedy verification bit-exact against the plain decode path."""
    def verify_fn(params, tokens, caches, pos, advance):
        return verify_step(params, tokens, caches, pos, cfg, advance=advance)

    return jax.jit(verify_fn, donate_argnums=(2,)) if jit else verify_fn


def build_serve_step(cfg: ArchConfig, shape: ShapeSpec, *, jit: bool = True):
    """The dry-run serve_step for decode shapes: (params, tokens (B,1),
    caches, pos) → (next tokens, caches).  With jit+mesh, shardings attach."""
    decode_fn = build_decode_fn(cfg, jit=False)
    if not jit:
        return decode_fn
    if current_mesh() is None:
        return jax.jit(decode_fn, donate_argnums=(2,))
    p_sh = param_shardings(cfg)
    c_sh = cache_shardings(cfg, shape.global_batch, shape.seq_len)
    tok_sh = named_sharding((shape.global_batch, 1), ("batch", None))
    pos_sh = named_sharding((), ())
    return jax.jit(
        decode_fn,
        in_shardings=(p_sh, tok_sh, c_sh, pos_sh),
        out_shardings=(tok_sh, c_sh),
        donate_argnums=(2,),
    )

"""Pipeline parallelism as an STF task graph (the PP axis of DP/TP/PP/EP/SP).

GPipe-style microbatch pipelining is *exactly* the paper's model: stage
executions are tasks, activations are the data dependencies, gradient
accumulation across microbatches is commutative, and the schedule (GPipe
fill-drain vs 1F1B) is nothing but the scheduler's choice among ready tasks
— expressed here with per-call priorities so the standard priority
scheduler produces a 1F1B-flavoured order, while FIFO degrades to
fill-drain.  The three task shapes (forward, loss-head, backward) are
declared once as codelets and instantiated per (stage, microbatch).

Task structure for S stages × M microbatches::

    F[s,m]:  SpRead(params_s), SpRead(act[s-1,m])
             → SpWrite(act[s,m]), SpWrite(vjp[s,m])
    L[m]:    SpRead(params_head), SpRead(act[S-1,m])
             → SpWrite(dact[S-1,m]), SpCommutativeWrite(grads_head, loss)
    B[s,m]:  SpRead(vjp[s,m]), SpRead(dact[s,m])
             → SpWrite(dact[s-1,m]), SpCommutativeWrite(grads_s)

On a real pod each stage's team is a mesh slice and the act hand-offs are
collective-permutes; on this container stages map to worker threads and the
hand-off is the SpData cell itself — the schedule/bubble structure is
identical and measured by ``trace_metrics`` (bubble fraction).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import (
    SpComputeEngine,
    SpData,
    SpTaskGraph,
    graph_scope,
    sp_task,
)


# ---------------------------------------------------------------------------
# The three task shapes, declared once (codelet frontend, core/api.py).
# ---------------------------------------------------------------------------

@sp_task(read=("params", "x"), write=("act", "vjp"), name="F", cost=5.0)
def _forward(params, x, act, vjp, *, stage_fn, first):
    x_val = x["x"] if first and isinstance(x, dict) else x
    y, pull = jax.vjp(stage_fn, params, x_val)
    act.value = y
    vjp.value = pull


@sp_task(
    read=("params", "x", "mb"),
    write=("dact",),
    commutative=("grads", "loss"),
    name="L",
    cost=2.0,
)
def _loss_head(params, x, mb, dact, grads, loss, *, head_fn, inv_m):
    loss_val, pull = jax.vjp(lambda p_, x_: head_fn(p_, x_, mb), params, x)
    gp, gx = pull(jnp.float32(inv_m))
    dact.value = gx
    grads.value = jax.tree.map(lambda a, g: a + g.astype(a.dtype), grads.value, gp)
    loss.value = loss.value + loss_val * inv_m


@sp_task(read=("pull", "dy"), commutative=("grads",), write=("dact",), name="B", cost=8.0)
def _backward(pull, dy, grads, dact):
    gp, gx = pull(dy)
    grads.value = jax.tree.map(lambda a, g: a + g.astype(a.dtype), grads.value, gp)
    dact.value = gx


@sp_task(read=("pull", "dy"), commutative=("grads",), name="B0", cost=8.0)
def _backward_first(pull, dy, grads):
    gp, _ = pull(dy)
    grads.value = jax.tree.map(lambda a, g: a + g.astype(a.dtype), grads.value, gp)


def pipeline_value_and_grad(
    stage_fns: Sequence[Callable],
    head_fn: Callable,
    stage_params: Sequence[Any],
    head_params: Any,
    microbatches: Sequence[Any],
    engine: SpComputeEngine,
    *,
    schedule: str = "1f1b",
) -> tuple[jax.Array, list, Any, SpTaskGraph]:
    """Run a pipelined forward+backward over ``microbatches``.

    stage_fns[s](params_s, x) -> x';  head_fn(params_h, x, mb) -> scalar loss.
    Returns (mean loss, per-stage grads, head grads, the graph — for
    trace_metrics / exports).
    """
    S, M = len(stage_fns), len(microbatches)
    tg = SpTaskGraph().compute_on(engine)

    p_cells = [SpData(p, f"stage{s}.params") for s, p in enumerate(stage_params)]
    ph_cell = SpData(head_params, "head.params")
    act = [[SpData(None, f"act[{s}][{m}]") for m in range(M)] for s in range(S)]
    vjp = [[SpData(None, f"vjp[{s}][{m}]") for m in range(M)] for s in range(S)]
    dact = [[SpData(None, f"dact[{s}][{m}]") for m in range(M)] for s in range(S)]
    g_cells = [
        SpData(jax.tree.map(lambda t: jnp.zeros_like(t, jnp.float32), p), f"grads{s}")
        for s, p in enumerate(stage_params)
    ]
    gh_cell = SpData(
        jax.tree.map(lambda t: jnp.zeros_like(t, jnp.float32), head_params), "grads.head"
    )
    loss_cell = SpData(jnp.float32(0.0), "loss")
    mb_cells = [SpData(mb, f"mb{m}") for m, mb in enumerate(microbatches)]

    def prio(kind: str, s: int, m: int) -> int:
        if schedule == "1f1b":
            # backward beats forward; earlier microbatches beat later; deeper
            # stages first for backward (drain), shallower first for forward
            base = 10_000 if kind == "b" else 0
            return base + (M - m) * 100 + (s if kind == "b" else S - s)
        return 0  # fifo / fill-drain

    with graph_scope(tg):
        for m in range(M):
            # ---- forward tasks ------------------------------------------------
            for s in range(S):
                src = mb_cells[m] if s == 0 else act[s - 1][m]
                _forward(
                    p_cells[s], src, act[s][m], vjp[s][m],
                    stage_fn=stage_fns[s], first=(s == 0),
                    name=f"F[{s},{m}]", priority=prio("f", s, m),
                )

            # ---- loss head + seed backward ------------------------------------
            _loss_head(
                ph_cell, act[S - 1][m], mb_cells[m],
                dact[S - 1][m], gh_cell, loss_cell,
                head_fn=head_fn, inv_m=1.0 / M,
                name=f"L[{m}]", priority=prio("b", S - 1, m) + 1,
            )

            # ---- backward tasks -----------------------------------------------
            for s in range(S - 1, -1, -1):
                if s > 0:
                    _backward(
                        vjp[s][m], dact[s][m], g_cells[s], dact[s - 1][m],
                        name=f"B[{s},{m}]", priority=prio("b", s, m),
                    )
                else:
                    _backward_first(
                        vjp[0][m], dact[0][m], g_cells[0],
                        name=f"B[0,{m}]", priority=prio("b", 0, m),
                    )

    tg.wait_all_tasks()
    return loss_cell.value, [g.value for g in g_cells], gh_cell.value, tg


def split_stages(params_layers: Any, n_stages: int, n_layers: int):
    """Slice a stacked layer-param tree into ``n_stages`` contiguous chunks."""
    per = n_layers // n_stages
    assert per * n_stages == n_layers
    return [
        jax.tree.map(lambda t: t[s * per : (s + 1) * per], params_layers)
        for s in range(n_stages)
    ]

"""Pipeline parallelism as an STF task graph (the PP axis of DP/TP/PP/EP/SP).

GPipe-style microbatch pipelining is *exactly* the paper's model: stage
executions are tasks, activations are the data dependencies, gradient
accumulation across microbatches is commutative, and the schedule (GPipe
fill-drain vs 1F1B) is nothing but the scheduler's choice among ready tasks
— expressed here with ``SpPriority`` so the standard priority scheduler
produces a 1F1B-flavoured order, while FIFO degrades to fill-drain.

Task structure for S stages × M microbatches::

    F[s,m]:  SpRead(params_s), SpRead(act[s-1,m])
             → SpWrite(act[s,m]), SpWrite(vjp[s,m])
    L[m]:    SpRead(params_head), SpRead(act[S-1,m])
             → SpWrite(dact[S-1,m]), SpCommutativeWrite(grads_head, loss)
    B[s,m]:  SpRead(vjp[s,m]), SpRead(dact[s,m])
             → SpWrite(dact[s-1,m]), SpCommutativeWrite(grads_s)

On a real pod each stage's team is a mesh slice and the act hand-offs are
collective-permutes; on this container stages map to worker threads and the
hand-off is the SpData cell itself — the schedule/bubble structure is
identical and measured by ``trace_metrics`` (bubble fraction).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import (
    SpCommutativeWrite,
    SpComputeEngine,
    SpData,
    SpPriority,
    SpRead,
    SpTaskGraph,
    SpWrite,
)


def pipeline_value_and_grad(
    stage_fns: Sequence[Callable],
    head_fn: Callable,
    stage_params: Sequence[Any],
    head_params: Any,
    microbatches: Sequence[Any],
    engine: SpComputeEngine,
    *,
    schedule: str = "1f1b",
) -> tuple[jax.Array, list, Any, SpTaskGraph]:
    """Run a pipelined forward+backward over ``microbatches``.

    stage_fns[s](params_s, x) -> x';  head_fn(params_h, x, mb) -> scalar loss.
    Returns (mean loss, per-stage grads, head grads, the graph — for
    trace_metrics / exports).
    """
    S, M = len(stage_fns), len(microbatches)
    tg = SpTaskGraph().compute_on(engine)

    p_cells = [SpData(p, f"stage{s}.params") for s, p in enumerate(stage_params)]
    ph_cell = SpData(head_params, "head.params")
    act = [[SpData(None, f"act[{s}][{m}]") for m in range(M)] for s in range(S)]
    vjp = [[SpData(None, f"vjp[{s}][{m}]") for m in range(M)] for s in range(S)]
    dact = [[SpData(None, f"dact[{s}][{m}]") for m in range(M)] for s in range(S)]
    g_cells = [
        SpData(jax.tree.map(lambda t: jnp.zeros_like(t, jnp.float32), p), f"grads{s}")
        for s, p in enumerate(stage_params)
    ]
    gh_cell = SpData(
        jax.tree.map(lambda t: jnp.zeros_like(t, jnp.float32), head_params), "grads.head"
    )
    loss_cell = SpData(jnp.float32(0.0), "loss")
    mb_cells = [SpData(mb, f"mb{m}") for m, mb in enumerate(microbatches)]

    def prio(kind: str, s: int, m: int) -> int:
        if schedule == "1f1b":
            # backward beats forward; earlier microbatches beat later; deeper
            # stages first for backward (drain), shallower first for forward
            base = 10_000 if kind == "b" else 0
            return base + (M - m) * 100 + (s if kind == "b" else S - s)
        return 0  # fifo / fill-drain

    # ---- forward tasks -------------------------------------------------------
    for m in range(M):
        for s in range(S):
            src = mb_cells[m] if s == 0 else act[s - 1][m]

            def fwd(p, x_in, a_ref, v_ref, _s=s):
                x_val = x_in["x"] if _s == 0 and isinstance(x_in, dict) else x_in
                y, pull = jax.vjp(stage_fns[_s], p, x_val)
                a_ref.value = y
                v_ref.value = pull

            tg.task(
                SpPriority(prio("f", s, m)),
                SpRead(p_cells[s]),
                SpRead(src),
                SpWrite(act[s][m]),
                SpWrite(vjp[s][m]),
                fwd,
                name=f"F[{s},{m}]",
                cost=5.0,
            )

        # ---- loss head + seed backward --------------------------------------
        def head(ph, x, mb, d_ref, gh_ref, l_ref, _m=m):
            loss, pull = jax.vjp(lambda p_, x_: head_fn(p_, x_, mb), ph, x)
            gph, gx = pull(jnp.float32(1.0 / M))
            d_ref.value = gx
            gh_ref.value = jax.tree.map(
                lambda a, g: a + g.astype(a.dtype), gh_ref.value, gph
            )
            l_ref.value = l_ref.value + loss / M

        tg.task(
            SpPriority(prio("b", S - 1, m) + 1),
            SpRead(ph_cell),
            SpRead(act[S - 1][m]),
            SpRead(mb_cells[m]),
            SpWrite(dact[S - 1][m]),
            SpCommutativeWrite(gh_cell),
            SpCommutativeWrite(loss_cell),
            head,
            name=f"L[{m}]",
            cost=2.0,
        )

        # ---- backward tasks ---------------------------------------------------
        for s in range(S - 1, -1, -1):

            def bwd(pull, dy, g_ref, d_ref, _s=s):
                gp, gx = pull(dy)
                g_ref.value = jax.tree.map(
                    lambda a, g: a + g.astype(a.dtype), g_ref.value, gp
                )
                if d_ref is not None:
                    d_ref.value = gx

            if s > 0:
                tg.task(
                    SpPriority(prio("b", s, m)),
                    SpRead(vjp[s][m]),
                    SpRead(dact[s][m]),
                    SpCommutativeWrite(g_cells[s]),
                    SpWrite(dact[s - 1][m]),
                    lambda pull, dy, g_ref, d_ref, _s=s: bwd(pull, dy, g_ref, d_ref, _s),
                    name=f"B[{s},{m}]",
                    cost=8.0,
                )
            else:
                tg.task(
                    SpPriority(prio("b", s, m)),
                    SpRead(vjp[0][m]),
                    SpRead(dact[0][m]),
                    SpCommutativeWrite(g_cells[0]),
                    lambda pull, dy, g_ref, _s=0: bwd(pull, dy, g_ref, None, _s),
                    name=f"B[0,{m}]",
                    cost=8.0,
                )

    tg.wait_all_tasks()
    return loss_cell.value, [g.value for g in g_cells], gh_cell.value, tg


def split_stages(params_layers: Any, n_stages: int, n_layers: int):
    """Slice a stacked layer-param tree into ``n_stages`` contiguous chunks."""
    per = n_layers // n_stages
    assert per * n_stages == n_layers
    return [
        jax.tree.map(lambda t: t[s * per : (s + 1) * per], params_layers)
        for s in range(n_stages)
    ]

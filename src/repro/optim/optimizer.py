"""Optimizers: AdamW (opt-state dtype knob) and factored Adafactor.

Self-contained (no optax) so the dry-run controls every byte of optimizer
state: for ≥100B-param configs the ``opt_state_dtype`` knob (fp32 → bf16
m/v) is part of the memory budget in EXPERIMENTS.md §Dry-run.

Optimizer state inherits the parameter's NamedSharding (same tree shape),
so ZeRO-3-style FSDP falls out of the param sharding rules.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class TrainState(NamedTuple):
    step: jax.Array  # int32 scalar
    params: Any
    opt: Any  # optimizer state pytree


# ---------------------------------------------------------------------------
# utils
# ---------------------------------------------------------------------------

def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params, dtype: str = "float32"):
    dt = jnp.dtype(dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}


def adamw_update(
    grads,
    state,
    params,
    *,
    lr,
    step,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    t = (step + 1).astype(jnp.float32)
    c1 = 1.0 - b1**t
    c2 = 1.0 - b2**t

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        vf = v.astype(jnp.float32) * b2 + gf * gf * (1 - b2)
        mh = mf / c1
        vh = vf / c2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mf.astype(m.dtype), vf.astype(v.dtype)

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_p = jax.tree.map(lambda t3: t3[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t3: t3[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t3: t3[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"m": new_m, "v": new_v}


# ---------------------------------------------------------------------------
# Adafactor (factored second moments for ≥2-D params)
# ---------------------------------------------------------------------------

def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params):
    def init(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return jax.tree.map(init, params, is_leaf=lambda x: not isinstance(x, dict))


def adafactor_update(
    grads,
    state,
    params,
    *,
    lr,
    step,
    d: float = 1.0,
    eps: float = 1e-30,
    weight_decay: float = 0.0,
):
    t = (step + 1).astype(jnp.float32)
    beta2 = 1.0 - t ** (-0.8)

    def upd(g, s, p):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + eps
        if "vr" in s:
            vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.sqrt(
                vr[..., None] * vc[..., None, :] / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)[..., None], eps)
            )
            upd_ = gf / jnp.maximum(denom, eps)
            new_s = {"vr": vr, "vc": vc}
        else:
            v = beta2 * s["v"] + (1 - beta2) * g2
            upd_ = gf / jnp.sqrt(jnp.maximum(v, eps))
            new_s = {"v": v}
        # update clipping by RMS (Adafactor d=1)
        rms = jnp.sqrt(jnp.mean(jnp.square(upd_)) + eps)
        upd_ = upd_ / jnp.maximum(1.0, rms / d)
        new_p = (p.astype(jnp.float32) - lr * (upd_ + weight_decay * p.astype(jnp.float32))).astype(p.dtype)
        return new_p, new_s

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state)
    outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_s = treedef.unflatten([o[1] for o in outs])
    return new_p, new_s


# ---------------------------------------------------------------------------
# Factory
# ---------------------------------------------------------------------------

def make_optimizer(kind: str, opt_state_dtype: str = "float32"):
    """→ (init_fn(params), update_fn(grads, opt, params, lr, step))."""
    if kind == "adamw":
        return (
            lambda params: adamw_init(params, opt_state_dtype),
            lambda g, s, p, lr, step: adamw_update(g, s, p, lr=lr, step=step),
        )
    if kind == "adafactor":
        return (
            adafactor_init,
            lambda g, s, p, lr, step: adafactor_update(g, s, p, lr=lr, step=step),
        )
    raise ValueError(f"unknown optimizer {kind!r}")

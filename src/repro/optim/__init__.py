from .optimizer import (
    TrainState,
    adamw_init,
    adamw_update,
    adafactor_init,
    adafactor_update,
    clip_by_global_norm,
    global_norm,
    make_optimizer,
)
from .schedule import constant_schedule, cosine_schedule, linear_warmup_cosine

__all__ = [
    "TrainState", "adamw_init", "adamw_update", "adafactor_init",
    "adafactor_update", "clip_by_global_norm", "global_norm",
    "make_optimizer", "constant_schedule", "cosine_schedule",
    "linear_warmup_cosine",
]

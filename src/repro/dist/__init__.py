"""repro.dist — the distributed layer: sharding, collectives, fault tolerance.

Specx's distributed story (paper §4.4) folds communication into the task
graph: send/recv are *tasks*, dependencies order them against compute, and a
background thread progresses them "as early as possible".  This package is
that story adapted to the JAX substrate (DESIGN.md §2/§5), split in three:

* :mod:`repro.dist.sharding` — mesh context (:func:`use_mesh` /
  :func:`current_mesh`) and logical-axis sharding rules
  (:func:`default_rules`, :func:`safe_spec`, :func:`named_sharding`,
  :func:`shard`).  This is the paper's "where does each piece of data live"
  question answered declaratively: models annotate logical axes, the rules
  map them onto whatever mesh is active, and off-mesh everything is the
  identity — the same model code runs on a laptop and a pod.

* :mod:`repro.dist.collectives` — task-graph collectives (paper §4.4): ring
  :func:`all_reduce` / :func:`all_gather` built from ``mpi_send`` /
  ``mpi_recv`` communication tasks over any :class:`~repro.core.SpTransport`,
  so the reduce-scatter/all-gather pipeline is *visible to the scheduler* as
  ordinary dependencies.  Two transports ship: the in-process
  :class:`~repro.core.ChannelHub` (rank-tagged graphs inside one process,
  live-object mailboxes) and the cross-process
  :class:`~repro.core.SocketTransport` (one OS process per rank; rank 0
  binds a localhost rendezvous port and routes length-prefixed
  ``(src, dst, tag)``-keyed frames; payloads travel through the canonical
  wire codec, ``repro.core.encode_message``).  Both drive the *same*
  non-blocking start/test protocol on the comm thread — receives poll local
  mailboxes, never a socket — and both honor ``mpi_recv(timeout=...)``,
  which fails a never-matched receive with ``SpCommTimeoutError`` instead
  of spinning forever.  ``launch/rendezvous.py`` is the multi-process
  bootstrap (spawn ranks, share the port, reduce over real TCP).
  :func:`hierarchical_psum` (intra-pod reduce-scatter → inter-pod
  all-reduce → intra-pod all-gather) covers the staged backend, where
  collectives lower to ``jax.lax`` ops instead; gradient compression
  (:func:`compress_int8` / :func:`compress_tree` with error-feedback
  residuals) cuts the bytes those collectives move.

* :mod:`repro.dist.fault` — fault tolerance on top of the engine's
  cancellation hooks (paper §4.2 dynamic worker teams are the recovery
  lever): :class:`CancelToken` + :func:`run_duplicated` replicated tasks
  with first-result-wins, :class:`FailureSimulator` for injecting rank
  loss, :class:`FaultyTransport` (deterministic seeded drop / delay /
  duplicate / truncate injection) + :class:`RetryingTransport` (bounded
  exponential-backoff retry that escalates to
  :class:`~repro.core.SpRankDeadError`), and :func:`remesh_plan` for
  shrinking the mesh while preserving model parallelism (the elastic
  re-mesh driven by ``launch/train.py``; live reshard recovery is the
  ``--recovery live`` path there).
"""
from .sharding import (
    current_mesh,
    default_rules,
    named_sharding,
    safe_spec,
    shard,
    use_mesh,
)
from .collectives import (
    all_gather,
    all_reduce,
    compress_int8,
    compress_tree,
    decompress_int8,
    hierarchical_psum,
    init_residuals,
    ring_all_gather,
    ring_all_reduce,
)
from .chaos import chaos_collectives, chaos_elastic, chaos_serve
from .fault import (
    CancelToken,
    FailureSimulator,
    FaultyTransport,
    RemeshPlan,
    RetryingTransport,
    remesh_plan,
    run_duplicated,
)

__all__ = [
    "current_mesh", "default_rules", "named_sharding", "safe_spec", "shard",
    "use_mesh", "all_gather", "all_reduce", "compress_int8", "compress_tree",
    "decompress_int8", "hierarchical_psum", "init_residuals",
    "ring_all_gather", "ring_all_reduce", "CancelToken", "FailureSimulator",
    "FaultyTransport", "RetryingTransport",
    "RemeshPlan", "remesh_plan", "run_duplicated",
    # chaos soak harness (ISSUE 8)
    "chaos_collectives", "chaos_elastic", "chaos_serve",
]

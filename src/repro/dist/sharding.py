"""Mesh context + logical-axis sharding rules (DESIGN.md §5).

Models never mention mesh axes.  They annotate arrays with *logical* axes
("batch", "heads", "ff", ...) via :func:`shard`, and parameter definitions
carry logical axes per dimension (``repro.models.param.ParamDef``).  A rules
table maps logical axes onto the axes of whatever mesh is active:

* ``use_mesh(mesh)`` pushes a mesh context (a plain context manager; the
  stack lives in a :class:`contextvars.ContextVar`, so nested/overlapping
  contexts in async code stay isolated.  Helper threads — prefetch,
  checkpoint commit, engine workers — start from an *empty* context and
  deliberately see no mesh: :func:`shard` degrades to the identity there,
  which is correct because all tracing/sharding decisions happen on the
  thread that entered ``use_mesh``);
* ``safe_spec`` turns (shape, logical axes) into a ``PartitionSpec``,
  silently *replicating* any dimension the mesh cannot divide evenly — the
  invariant that makes elastic re-mesh (``repro.dist.fault.remesh_plan``)
  safe: a shrunken mesh can always load the same model, at worst with less
  parallelism;
* off-mesh (no ``use_mesh`` active) every helper degrades to the identity,
  so the same model code runs unsharded in unit tests.
"""
from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Optional, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec

_mesh_stack: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "repro_mesh_stack", default=()
)


@contextlib.contextmanager
def use_mesh(mesh):
    """Activate ``mesh`` for the dynamic extent of the ``with`` block."""
    token = _mesh_stack.set(_mesh_stack.get() + (mesh,))
    try:
        yield mesh
    finally:
        _mesh_stack.reset(token)


def current_mesh():
    """The innermost active mesh, or ``None`` outside any ``use_mesh``."""
    stack = _mesh_stack.get()
    return stack[-1] if stack else None


def default_rules() -> dict:
    """Logical axis → candidate mesh axes (major-to-minor preference).

    ``batch`` spreads over all pure-data axes (``pod`` × ``data``); tensor
    dimensions (heads, ff, experts, vocab, kv sequence) go to ``model``.
    Dimensions mapped to ``None`` are always replicated.  Each mesh axis is
    used at most once per spec; first dimension wins.
    """
    return {
        "batch": ("pod", "data"),
        "act_seq": None,       # activation sequence stays local to a shard
        "kv_seq": ("model",),  # decode KV caches are sequence-sharded
        "heads": ("model",),
        "kv_heads": ("model",),
        "ff": ("model",),
        "expert_ff": ("model",),
        "experts": ("model",),
        "vocab": ("model",),
        "embed": None,
        "head_dim": None,
        "layers": None,
    }


def _axis_product(mesh_shape: dict, axes: Sequence[str]) -> int:
    return math.prod(mesh_shape[a] for a in axes)


def safe_spec(
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    *,
    mesh=None,
    rules: Optional[dict] = None,
) -> PartitionSpec:
    """PartitionSpec for ``shape`` under the rules, dropping anything the
    mesh cannot divide.  ``mesh`` only needs a ``.shape`` mapping (so plans
    can be checked without devices); defaults to :func:`current_mesh`.
    """
    if len(shape) != len(axes):
        raise ValueError(f"shape {shape} / axes {axes} rank mismatch")
    mesh = mesh if mesh is not None else current_mesh()
    if mesh is None:
        return PartitionSpec(*(None,) * len(shape))
    rules = rules if rules is not None else default_rules()
    mesh_shape = dict(mesh.shape)
    used: set[str] = set()
    entries: list = []
    for dim, logical in zip(shape, axes):
        target = rules.get(logical) if logical is not None else None
        if target is None:
            entries.append(None)
            continue
        cand = [a for a in ((target,) if isinstance(target, str) else target)
                if a in mesh_shape and a not in used]
        # drop major axes until the shard count divides the dimension
        while cand and dim % _axis_product(mesh_shape, cand) != 0:
            cand.pop(0)
        if not cand:
            entries.append(None)
            continue
        used.update(cand)
        entries.append(cand[0] if len(cand) == 1 else tuple(cand))
    return PartitionSpec(*entries)


def named_sharding(
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    *,
    rules: Optional[dict] = None,
) -> NamedSharding:
    """NamedSharding on the active mesh (requires a ``use_mesh`` context)."""
    mesh = current_mesh()
    if mesh is None:
        raise RuntimeError(
            "named_sharding() requires an active mesh; wrap the call in "
            "`with use_mesh(mesh):`"
        )
    return NamedSharding(mesh, safe_spec(shape, axes, mesh=mesh, rules=rules))


def shard(x: jax.Array, *axes: Optional[str], rules: Optional[dict] = None) -> jax.Array:
    """Constrain ``x``'s sharding by logical axes; identity off-mesh.

    Used inside jitted model code: ``x = shard(x, "batch", "act_seq", None)``.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, safe_spec(x.shape, axes, mesh=mesh, rules=rules))
    )

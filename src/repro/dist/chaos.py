"""Chaos soak harness (ISSUE 8): seeded fault schedules over the three
recovery surfaces, with exact (or explicitly bounded) correctness checks.

Each scenario builds its whole world from one integer ``seed`` — the
fault schedule (drop/duplicate/delay/truncate draws, flaky bursts, the
kill step and victim), the workload, and the oracle — so a failing soak
is replayed bit-for-bit by rerunning the same seed:

* :func:`chaos_collectives` — ring all-reduce over a :class:`ChannelHub`
  wrapped in :class:`~repro.dist.fault.FaultyTransport` (drops, dupes,
  delays, truncations) under a :class:`~repro.dist.fault.RetryingTransport`
  budget.  Inputs are integer-valued float32 (< 2**24), so float addition
  is exact and the reduction is order-independent: every iteration must
  be **bit-exact** against the NumPy sum, faults or not.

* :func:`chaos_collectives_p2p` — the same bit-exactness soak over the
  *real* p2p data plane (ISSUE 10): one ``SocketTransport`` per rank,
  frames over direct TCP peer links, each rank's injector scoped with
  ``peers=`` to its ring neighbor's stream — drops/dupes/delays/
  truncations land on the direct links themselves, not on a legacy
  router path.

* :func:`chaos_elastic` — the in-process elastic-training story: thread
  ranks drive ``SpRuntime(elastic=True).elastic_loop``; at a seeded step
  a seeded victim rank dies mid-collective (its death is published via
  ``mark_dead``, standing in for the router's detector).  Survivors must
  recover *in-runtime* — no failure handling in the step function — and
  every step's result must be bit-exact against the full-mesh oracle
  before the resume step and the survivors-only oracle from it on.

* :func:`chaos_serve` — the serve engine under admission chaos: seeded
  bursts of requests with mixed deadlines (some already expired), seeded
  mid-decode ``cancel()`` calls, and a pool sized to force preemptions.
  The checks are invariants rather than bit-exactness (cancellation is a
  scheduling race by design): every request terminates, every rejection
  carries a valid ``reject_reason``, completed requests have exactly the
  tokens they asked for, and the drained engine holds no slots, queue
  entries, or pinned block tables.

``python -m repro.dist.chaos --seeds 3 --iters 20`` runs all scenarios
for seeds ``0..2`` — the CI ``chaos-smoke`` job's entry point.
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from repro.core import ChannelHub, SpCommGroup, SpData, SpRuntime
from repro.dist.collectives import ring_all_reduce
from repro.dist.fault import FaultyTransport, RetryingTransport


def _int_grad(rank: int, step: int, n: int) -> np.ndarray:
    """Integer-valued float32 input: sums stay < 2**24, so float32 addition
    is exact and associative — the oracle is bit-exact regardless of ring
    order, retries, or recovery replays."""
    return ((np.arange(n, dtype=np.float32) % 17.0)
            + np.float32((rank + 1) * (step + 2)))


# ---------------------------------------------------------------------------
# Scenario 1: collectives under link faults (no deaths — absorption).
# ---------------------------------------------------------------------------

def chaos_collectives(
    seed: int,
    iters: int = 20,
    *,
    size: int = 3,
    n: int = 96,
    timeout: float = 60.0,
) -> dict:
    """Soak ring all-reduce over a lossy, delaying, duplicating link layer;
    every iteration must reduce bit-exactly."""
    hub = ChannelHub()
    faulty = FaultyTransport(
        hub, seed=seed, drop=0.04, duplicate=0.04, delay=0.04,
        delay_s=0.002, truncate=0.03,
    )
    transport = RetryingTransport(faulty, max_retries=6, backoff=0.001)
    results: dict[tuple[int, int], np.ndarray] = {}
    errors: list[BaseException] = []

    def worker(rank: int) -> None:
        group = SpCommGroup(rank, size, transport, default_timeout=timeout)
        try:
            with SpRuntime(workers=2) as rt:
                for it in range(iters):
                    x = SpData(_int_grad(rank, it, n), f"cc{rank}.{it}")
                    ring_all_reduce(rt.graph, group, x, op="sum", tag=it)
                    rt.wait_all_tasks(timeout=timeout)
                    results[(rank, it)] = np.asarray(x.value)
        except BaseException as e:  # surfaced to the driver, not swallowed
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(r,)) for r in range(size)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=iters * timeout)
    if errors:
        raise errors[0]
    for it in range(iters):
        ref = np.sum([_int_grad(r, it, n) for r in range(size)], axis=0)
        for rank in range(size):
            got = results.get((rank, it))
            assert got is not None, f"rank {rank} lost iteration {it}"
            np.testing.assert_array_equal(got, ref.astype(np.float32))
    transport.close()
    stats = {"iters": iters, "size": size, "faults": dict(faulty.injected),
             "retries": transport.retries, "escalations": transport.escalations}
    assert stats["escalations"] == 0, stats  # absorbed, never escalated
    return stats


# ---------------------------------------------------------------------------
# Scenario 1b: collectives under link faults on the real p2p data plane.
# ---------------------------------------------------------------------------

def chaos_collectives_p2p(
    seed: int,
    iters: int = 20,
    *,
    size: int = 3,
    n: int = 96,
    timeout: float = 60.0,
) -> dict:
    """Soak ring all-reduce over *direct TCP peer links*: one
    :class:`~repro.core.comm.SocketTransport` per rank (in-process
    threads, real sockets), each wrapped in a :class:`FaultyTransport`
    whose injection is scoped via ``peers=`` to that rank's ring
    neighbor — the stream the collective actually uses — under a
    :class:`RetryingTransport` budget.  Every iteration must reduce
    bit-exactly; no fault may escalate to a death."""
    from repro.core.comm import SocketTransport

    base = [SocketTransport(0, size, port=0)]
    for r in range(1, size):
        base.append(SocketTransport(r, size, port=base[0].port))
    faulties, transports = [], []
    for r in range(size):
        f = FaultyTransport(
            base[r], seed=seed * size + r, drop=0.04, duplicate=0.04,
            delay=0.04, delay_s=0.002, truncate=0.03,
            peers=[(r + 1) % size],
        )
        faulties.append(f)
        transports.append(RetryingTransport(f, max_retries=6, backoff=0.001))
    results: dict[tuple[int, int], np.ndarray] = {}
    errors: list[BaseException] = []

    def worker(rank: int) -> None:
        group = SpCommGroup(rank, size, transports[rank],
                            default_timeout=timeout)
        try:
            with SpRuntime(workers=2) as rt:
                for it in range(iters):
                    x = SpData(_int_grad(rank, it, n), f"cp{rank}.{it}")
                    ring_all_reduce(rt.graph, group, x, op="sum", tag=it)
                    rt.wait_all_tasks(timeout=timeout)
                    results[(rank, it)] = np.asarray(x.value)
        except BaseException as e:
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(r,)) for r in range(size)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=iters * timeout)
    if errors:
        raise errors[0]
    for it in range(iters):
        ref = np.sum([_int_grad(r, it, n) for r in range(size)], axis=0)
        for rank in range(size):
            got = results.get((rank, it))
            assert got is not None, f"rank {rank} lost iteration {it}"
            np.testing.assert_array_equal(got, ref.astype(np.float32))
    stats = {
        "iters": iters, "size": size,
        "faults": {k: sum(f.injected[k] for f in faulties)
                   for k in faulties[0].injected},
        "retries": sum(t.retries for t in transports),
        "escalations": sum(t.escalations for t in transports),
        "links": sum(b.stats().get("links", 0) for b in base),
    }
    for tr in transports:
        tr.close()
    assert stats["escalations"] == 0, stats  # absorbed, never escalated
    assert stats["links"] >= size, stats  # frames really took direct links
    assert stats["faults"]["dropped"] + stats["faults"]["duplicated"] > 0, (
        "the seeded schedule never exercised the direct links"
    )
    return stats


# ---------------------------------------------------------------------------
# Scenario 2: elastic training surviving a seeded mid-collective death.
# ---------------------------------------------------------------------------

def chaos_elastic(
    seed: int,
    iters: int = 20,
    *,
    size: int = 3,
    n: int = 64,
    timeout: float = 30.0,
) -> dict:
    """Thread ranks all-reduce for ``iters`` steps; a seeded victim dies at
    a seeded step.  Survivors' per-step results must match the full-mesh
    oracle before the resume step and the survivors-only oracle after."""
    rng = np.random.default_rng(seed)
    kill_at = int(rng.integers(1, max(2, iters - 1)))
    victim = int(rng.integers(1, size))
    hub = ChannelHub()
    faulty = FaultyTransport(
        hub, seed=seed, drop=0.02, duplicate=0.02,
        flaky={(victim + 1) % size: 2},
    )
    transport = RetryingTransport(faulty, max_retries=6, backoff=0.001)
    out: dict[int, tuple[dict, list]] = {}
    errors: list[BaseException] = []

    def worker(rank: int) -> None:
        group = SpCommGroup(rank, size, transport, default_timeout=timeout)
        try:
            with SpRuntime(workers=2, elastic=True, group=group,
                           detect_grace=timeout) as rt:
                def step_fn(step):
                    if rank == victim and step == kill_at:
                        # die mid-collective; mark_dead stands in for the
                        # socket router's failure detector (in-process hubs
                        # have no kernel to close a dead peer's socket)
                        hub.mark_dead(rank)
                        raise SystemExit
                    x = SpData(_int_grad(rank, step, n),
                               f"ce{rank}.e{rt.epoch}.s{step}")
                    ring_all_reduce(rt.graph, rt.group, x, op="sum",
                                    tag=(rt.epoch, step))
                    rt.barrier(timeout=timeout)
                    return np.asarray(x.value)

                res = rt.elastic_loop(step_fn, iters, step_timeout=timeout)
                out[rank] = (res, rt.recoveries)
        except SystemExit:
            pass
        except BaseException as e:
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(r,)) for r in range(size)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=iters * timeout)
    if errors:
        raise errors[0]
    survivors = [r for r in range(size) if r != victim]
    assert set(out) == set(survivors), (sorted(out), survivors)
    for rank in survivors:
        res, recs = out[rank]
        assert sorted(res) == list(range(iters)), sorted(res)
        assert len(recs) == 1 and recs[0]["dead"] == [victim], recs
        resume = recs[0]["resume"]
        for step, got in res.items():
            ranks = range(size) if step < resume else survivors
            ref = np.sum([_int_grad(r, step, n) for r in ranks], axis=0)
            np.testing.assert_array_equal(got, ref.astype(np.float32))
    transport.close()
    rec = out[survivors[0]][1][0]
    return {"iters": iters, "kill_at": kill_at, "victim": victim,
            "resume": rec["resume"], "recovery_s": rec["seconds"],
            "faults": dict(faulty.injected)}


# ---------------------------------------------------------------------------
# Scenario 3: serve engine under admission chaos.
# ---------------------------------------------------------------------------

def chaos_serve(seed: int, iters: int = 20, *, max_steps: int = 4000) -> dict:
    """Seeded request bursts with expired deadlines, mid-decode cancels and
    a preemption-prone pool; asserts termination + accounting invariants."""
    import jax

    from repro.configs import reduced_config
    from repro.models import init_params
    from repro.serving import ServeEngine

    cfg = reduced_config("deepseek-7b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    all_reqs: list = []
    cancelled: list = []
    with ServeEngine(cfg, params, n_slots=2, max_seq=48, block_size=4,
                     n_blocks=20, max_queue=8, overload="shed-oldest") as eng:
        total_steps = 0
        for it in range(iters):
            burst = []
            for _ in range(int(rng.integers(2, 5))):
                prompt = rng.integers(0, cfg.vocab,
                                      int(rng.integers(4, 10))).astype(np.int32)
                gen = int(rng.integers(3, 9))
                # ~1/4 of requests arrive already past their deadline
                deadline = 0.0 if rng.random() < 0.25 else None
                burst.append(eng.submit(prompt, gen, deadline=deadline))
            all_reqs.extend(burst)
            # seeded mid-flight cancel of one live request in ~1/3 of bursts
            if rng.random() < 0.33:
                live = [r for r in burst if r.deadline is None]
                if live:
                    vic = live[int(rng.integers(len(live)))]
                    eng.step()
                    vic.cancel()
                    cancelled.append(vic)
            while eng.scheduler.queue_depth or eng.n_running:
                eng.step()
                total_steps += 1
                assert total_steps < max_steps, "serve soak failed to drain"
        stats = eng.stats()
        # invariants: everything terminated, rejections are typed, nothing
        # leaked — a violated one means a request or its KV blocks wedged
        assert all(r.done for r in all_reqs)
        for r in all_reqs:
            if r.rejected:
                assert r.reject_reason in ("queue_full", "shed", "deadline"), r
            elif not r.cancelled:
                assert len(r.out_tokens) == r.max_new_tokens, r
        assert eng.n_running == 0 and eng.scheduler.queue_depth == 0
        assert not eng.pool._tables, "leaked pinned block tables"
    return {"iters": iters, "requests": len(all_reqs),
            "completed": sum(1 for r in all_reqs
                             if r.done and not r.rejected and not r.cancelled),
            "deadline_shed": stats["deadline_shed"], "shed": stats["shed"],
            "cancels": stats["cancels"], "cancelled_q": stats["cancelled"],
            "preemptions": stats["preemptions"], "steps": stats["steps"]}


SCENARIOS = {
    "collectives": chaos_collectives,
    "collectives_p2p": chaos_collectives_p2p,
    "elastic": chaos_elastic,
    "serve": chaos_serve,
}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seeds", type=int, default=3,
                    help="run seeds 0..N-1 through every scenario")
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--scenario", choices=(*SCENARIOS, "all"), default="all")
    args = ap.parse_args(argv)

    names = list(SCENARIOS) if args.scenario == "all" else [args.scenario]
    report: dict = {}
    for name in names:
        for seed in range(args.seeds):
            t0 = time.perf_counter()
            stats = SCENARIOS[name](seed, args.iters)
            dt = time.perf_counter() - t0
            report[f"{name}/seed{seed}"] = stats
            print(f"[chaos] {name} seed={seed} iters={args.iters} "
                  f"ok in {dt:.1f}s: {stats}")
    print(f"[chaos] {len(report)} soak runs passed "
          f"({args.seeds} seeds x {args.iters} iterations each)")
    return report


if __name__ == "__main__":
    main()

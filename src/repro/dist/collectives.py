"""Task-graph collectives + gradient compression (paper §4.4, DESIGN.md §5).

Two execution substrates, one API:

* **Eager / transport** — :func:`ring_all_reduce` and :func:`ring_all_gather`
  build the textbook ring pipelines out of ``mpi_send`` / ``mpi_recv``
  *communication tasks* over whatever :class:`~repro.core.SpTransport` the
  :class:`~repro.core.SpCommGroup` carries — the in-process
  :class:`~repro.core.ChannelHub` (rank-tagged graphs inside one process)
  or the cross-process :class:`~repro.core.SocketTransport` (one OS
  process per rank over a TCP rendezvous, ``launch/rendezvous.py``).  The
  collectives are transport-agnostic: every value they put on the wire is
  an array/pytree the canonical wire codec encodes, and every chunk hop is
  an ordinary graph node, so the scheduler sees (and can overlap) the
  whole reduce-scatter/all-gather pipeline — the paper's "communications
  are incorporated into the task graph", extended from point-to-point to
  collectives the way DuctTeip layers distributed reductions over local
  task scheduling.

* **Staged** — inside ``shard_map``/``jit`` the same reductions lower to
  ``jax.lax`` collectives; :func:`hierarchical_psum` is the pod-aware
  three-stage variant (intra-pod reduce-scatter → inter-pod all-reduce on
  the scattered shards → intra-pod all-gather) that keeps the slow
  inter-pod links moving ``1/inner`` of the bytes.

Gradient compression (:func:`compress_int8`, :func:`compress_tree`) shrinks
what the collectives carry: symmetric per-tensor int8 with error-feedback
residuals (:func:`init_residuals`), so quantization error is re-injected
into the next step instead of lost.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.access import SpData
from repro.core.api import sp_task
from repro.core.comm import SpCommGroup, mpi_recv, mpi_send
from repro.core.graph import SpTaskGraph
from repro.core.task import TaskView


# ---------------------------------------------------------------------------
# Ring collectives over the ChannelHub (eager task-graph substrate).
# The chunk-level steps are codelets — declared once here, instantiated per
# rank/step with per-call names (the codelet frontend, core/api.py).
# ---------------------------------------------------------------------------

@sp_task(read=("x",), write=("chunks",), name="ring.split")
def _ring_split(x, chunks, *, n, pieces, meta):
    """Scatter ``x`` into ``n`` rank-chunks of ``pieces`` pipeline pieces
    each (``len(chunks) == n * pieces``, flat order); stash shape/dtype in
    ``meta``."""
    a = np.ascontiguousarray(np.asarray(x))
    meta["shape"], meta["dtype"] = a.shape, a.dtype
    k = 0
    # contiguous 1-D slices: the cells hold zero-copy views into x's
    # buffer, sent as-is by the scatter-gather wire path.  Nothing
    # downstream mutates them in place (accumulate allocates, concat
    # reads), and the final concat *rebinds* x.value rather than writing
    # through it, so the aliasing is safe.
    for part in np.array_split(a.reshape(-1), n):
        for piece in np.array_split(part, pieces):
            chunks[k].value = piece
            k += 1


@sp_task(read=("incoming",), write=("acc",), name="ring.acc")
def _ring_accumulate(incoming, acc):
    acc.value = acc.value + incoming


@sp_task(read=("chunks",), write=("x",), name="ring.concat")
def _ring_concat(chunks, x, *, n, op, meta):
    full = np.concatenate([np.asarray(v).reshape(-1) for v in chunks])
    if op == "mean":
        full = full / n
    x.value = full.astype(meta["dtype"]).reshape(meta["shape"])
    return x.value


@sp_task(read=("x",), write=("slot",), name="ring.seed")
def _ring_seed(x, slot):
    slot.value = x


@sp_task(read=("slots",), name="ring.collect")
def _ring_collect(slots):
    return list(slots)


@sp_task(read=("x",), name="ring.identity")
def _ring_identity(x, *, wrap=False):
    return [x] if wrap else x


def _pipeline_pieces(x, n_chunks: int, chunk_bytes, *, max_pieces: int = 32) -> int:
    """How many fixed-size pipeline pieces each rank-chunk splits into.

    Derived from the cell's value at insert time; every rank holds a
    same-shaped array, so all ranks agree.  Cells whose value is produced
    later in the graph fall back to one piece (no pipelining) — again on
    every rank, so the wire tags still line up."""
    if not chunk_bytes:
        return 1
    v = x.value if isinstance(x, SpData) else None
    if v is None:
        return 1
    per_chunk = max(1, np.asarray(v).nbytes // max(n_chunks, 1))
    return max(1, min(max_pieces, -(-per_chunk // int(chunk_bytes))))


def _ring_reduce_scatter(graph, group, cells, pieces, tag) -> int:
    """Reduce-scatter phase over ``cells`` (``S * pieces`` flat, as laid
    out by ``_ring_split``).  After S−1 steps logical rank ``r`` owns the
    fully-reduced chunk ``(r+1) % S`` (all its pieces); returns that index.

    With ``pieces > 1`` the ring is *chunk pipelined*: every piece runs
    its own independent send/recv/accumulate chain, so the comm thread
    transfers piece ``p+1`` of a step while a worker is still reducing
    piece ``p`` — transfer overlaps reduction across ring steps."""
    S, r = group.logical_size, group.logical_rank
    right, left = group.to_physical(r + 1), group.to_physical(r - 1)
    for step in range(S - 1):
        send_idx = (r - step) % S
        recv_idx = (r - step - 1) % S
        for p in range(pieces):
            mpi_send(graph, group, cells[send_idx * pieces + p], dest=right,
                     tag=("rar", tag, "rs", step, p))
            tmp = SpData(None, f"ar{tag}.r{r}.rs{step}.p{p}")
            mpi_recv(graph, group, tmp, src=left,
                     tag=("rar", tag, "rs", step, p))
            _ring_accumulate(tmp, cells[recv_idx * pieces + p],
                             graph=graph, name=f"allreduce{tag}.acc{step}.{p}")
    return (r + 1) % S


def _ring_allgather_chunks(graph, group, cells, pieces, tag) -> None:
    """All-gather phase: circulate the reduced chunks (rank ``r`` starts
    owning chunk ``(r+1) % S``, the reduce-scatter postcondition)."""
    S, r = group.logical_size, group.logical_rank
    right, left = group.to_physical(r + 1), group.to_physical(r - 1)
    for step in range(S - 1):
        send_idx = (r + 1 - step) % S
        recv_idx = (r - step) % S
        for p in range(pieces):
            mpi_send(graph, group, cells[send_idx * pieces + p], dest=right,
                     tag=("rar", tag, "ag", step, p))
            mpi_recv(graph, group, cells[recv_idx * pieces + p], src=left,
                     tag=("rar", tag, "ag", step, p))


def ring_all_reduce(
    graph: SpTaskGraph,
    group: SpCommGroup,
    x: SpData,
    *,
    op: str = "sum",
    tag: int = 0,
    chunk_bytes: Optional[int] = None,
) -> TaskView:
    """Insert a chunked ring all-reduce for ``x`` into ``graph``.

    Every rank calls this with its own (graph, group, cell); the group's
    transport wires the rings together — in-process mailboxes or TCP
    sockets, same task graph either way.  ``x.value`` is replaced by the
    reduced array; the returned view's value is the same array.  ``op`` is
    ``"sum"`` or ``"mean"``.  2·(S−1) hops per chunk — bandwidth-optimal.
    Re-issuing with a fresh ``tag`` per step is safe: drained mailboxes are
    pruned by the transport, so per-step keys do not accumulate.

    ``chunk_bytes`` turns on chunk pipelining: each of the S rank-chunks
    is further split into ~``chunk_bytes``-sized pieces that travel as
    independent frames, so successive ring steps overlap transfer with
    reduction (piece *p* of step *k+1* is in flight while piece *q* of
    step *k* is still being accumulated).  Pass the same value on every
    rank.
    """
    if op not in ("sum", "mean"):
        raise ValueError(f"unsupported op {op!r}; use 'sum' or 'mean'")
    # Logical coordinates: the ring is laid out over group.members, so a
    # group shrunk after a rank death still forms a closed ring; neighbours
    # are translated back to physical ranks for the wire.
    S, r = group.logical_size, group.logical_rank
    if S == 1:
        return _ring_identity(x, graph=graph, name=f"allreduce{tag}.id")
    P = _pipeline_pieces(x, S, chunk_bytes)
    cells = [SpData(None, f"ar{tag}.r{r}.c{i}") for i in range(S * P)]
    meta: dict = {}

    _ring_split(x, cells, n=S, pieces=P, meta=meta,
                graph=graph, name=f"allreduce{tag}.split")
    _ring_reduce_scatter(graph, group, cells, P, tag)
    _ring_allgather_chunks(graph, group, cells, P, tag)
    return _ring_concat(cells, x, n=S, op=op, meta=meta,
                        graph=graph, name=f"allreduce{tag}.concat")


def ring_all_gather(
    graph: SpTaskGraph,
    group: SpCommGroup,
    x: SpData,
    *,
    tag: int = 0,
) -> TaskView:
    """Ring all-gather: the returned view's value is the list of every
    rank's ``x.value``, ordered by logical rank — i.e. by position in
    ``group.members`` (same list on all ranks)."""
    S, r = group.logical_size, group.logical_rank
    if S == 1:
        return _ring_identity(x, wrap=True, graph=graph, name=f"allgather{tag}.id")
    right, left = group.to_physical(r + 1), group.to_physical(r - 1)
    slots = [SpData(None, f"ag{tag}.r{r}.s{i}") for i in range(S)]
    _ring_seed(x, slots[r], graph=graph, name=f"allgather{tag}.seed")
    for step in range(S - 1):
        send_idx = (r - step) % S
        recv_idx = (r - step - 1) % S
        mpi_send(graph, group, slots[send_idx], dest=right,
                 tag=("rag", tag, step))
        mpi_recv(graph, group, slots[recv_idx], src=left,
                 tag=("rag", tag, step))
    return _ring_collect(slots, graph=graph, name=f"allgather{tag}.collect")


def _ring_circulate_reduce(graph, group, cell, tag) -> None:
    """Naive ring all-reduce of a single cell over ``group``: circulate
    every rank's original value around the ring, accumulating each arrival
    into ``cell``.  (G−1)·nbytes on the wire — used only for the inter-pod
    stage of :func:`hierarchical_all_reduce`, where the payload is already
    a ``1/pod_size`` shard."""
    G, q = group.logical_size, group.logical_rank
    if G == 1:
        return
    right, left = group.to_physical(q + 1), group.to_physical(q - 1)
    orig = SpData(None, f"hc{tag}.r{q}.orig")
    _ring_seed(cell, orig, graph=graph, name=f"hier{tag}.seed")
    carry = orig
    for step in range(G - 1):
        mpi_send(graph, group, carry, dest=right, tag=("hir", tag, step))
        nxt = SpData(None, f"hc{tag}.r{q}.s{step}")
        mpi_recv(graph, group, nxt, src=left, tag=("hir", tag, step))
        _ring_accumulate(nxt, cell, graph=graph, name=f"hier{tag}.acc{step}")
        carry = nxt  # forward what we just received, keep the sum local


def hierarchical_all_reduce(
    graph: SpTaskGraph,
    group: SpCommGroup,
    x: SpData,
    *,
    pod_size: int,
    op: str = "sum",
    tag: int = 0,
) -> TaskView:
    """Eager pod-aware all-reduce over the task graph — the transport-level
    mirror of :func:`hierarchical_psum`'s three stages:

    1. intra-pod ring reduce-scatter (each pod member ends up owning one
       pod-reduced chunk),
    2. inter-pod all-reduce of that chunk across same-position members of
       every pod (``1/pod_size`` of the bytes on the slow links),
    3. intra-pod ring all-gather + concat back into ``x``.

    ``group.members`` is laid out pod-major: members ``[k*pod_size,
    (k+1)*pod_size)`` form pod ``k``.  Requires ``logical_size %
    pod_size == 0``.  Bit-exact against a flat sum whenever the values are
    exactly representable (e.g. integer-valued float32)."""
    if op not in ("sum", "mean"):
        raise ValueError(f"unsupported op {op!r}; use 'sum' or 'mean'")
    S, r = group.logical_size, group.logical_rank
    if S % pod_size != 0:
        raise ValueError(
            f"group size {S} is not divisible by pod_size {pod_size}"
        )
    if S == 1:
        return _ring_identity(x, graph=graph, name=f"hierar{tag}.id")
    pod, pos = r // pod_size, r % pod_size
    n_pods = S // pod_size
    intra = SpCommGroup(
        group.rank, group.size, group.hub,
        default_timeout=group.default_timeout,
        members=[group.to_physical(pod * pod_size + j) for j in range(pod_size)],
    )
    inter = SpCommGroup(
        group.rank, group.size, group.hub,
        default_timeout=group.default_timeout,
        members=[group.to_physical(k * pod_size + pos) for k in range(n_pods)],
    )
    cells = [SpData(None, f"har{tag}.r{r}.c{i}") for i in range(pod_size)]
    meta: dict = {}
    _ring_split(x, cells, n=pod_size, pieces=1, meta=meta,
                graph=graph, name=f"hierar{tag}.split")
    if pod_size > 1:
        owned = _ring_reduce_scatter(graph, intra, cells, 1, ("h", tag))
    else:
        owned = 0
    _ring_circulate_reduce(graph, inter, cells[owned], ("h", tag, pos))
    if pod_size > 1:
        _ring_allgather_chunks(graph, intra, cells, 1, ("h", tag))
    return _ring_concat(cells, x, n=S, op=op, meta=meta,
                        graph=graph, name=f"hierar{tag}.concat")


# ---------------------------------------------------------------------------
# Staged-substrate collectives (lower to jax.lax inside shard_map / jit).
# ---------------------------------------------------------------------------

def all_reduce(
    x,
    *,
    axis=None,
    graph: Optional[SpTaskGraph] = None,
    group: Optional[SpCommGroup] = None,
    op: str = "sum",
    tag: int = 0,
):
    """Substrate-dispatching all-reduce: with (graph, group) → hub ring;
    with ``axis`` (a mesh axis name, inside shard_map) → ``jax.lax``."""
    if graph is not None:
        if group is None:
            raise ValueError("hub all_reduce needs both graph and group")
        return ring_all_reduce(graph, group, x, op=op, tag=tag)
    if axis is None:
        raise ValueError("staged all_reduce needs axis=<mesh axis name>")
    return jax.lax.pmean(x, axis) if op == "mean" else jax.lax.psum(x, axis)


def all_gather(
    x,
    *,
    axis=None,
    graph: Optional[SpTaskGraph] = None,
    group: Optional[SpCommGroup] = None,
    tag: int = 0,
):
    """Substrate-dispatching all-gather (see :func:`all_reduce`)."""
    if graph is not None:
        if group is None:
            raise ValueError("hub all_gather needs both graph and group")
        return ring_all_gather(graph, group, x, tag=tag)
    if axis is None:
        raise ValueError("staged all_gather needs axis=<mesh axis name>")
    return jax.lax.all_gather(x, axis)


def hierarchical_psum(x, *, pod_axis: str = "pod", inner_axis: str = "data"):
    """Pod-aware psum: reduce-scatter over ``inner_axis``, all-reduce the
    scattered shards over ``pod_axis``, all-gather over ``inner_axis``.

    Numerically equal to ``jax.lax.psum(x, (pod_axis, inner_axis))`` but the
    slow inter-pod hop carries ``1/inner`` of the bytes.  Must be called
    inside ``shard_map`` with both axes bound.
    """
    inner = jax.lax.psum(1, inner_axis)  # static axis size (constant-folded)
    n = x.size
    flat = x.reshape(-1)
    pad = (-n) % inner
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    piece = jax.lax.psum_scatter(flat, inner_axis, scatter_dimension=0, tiled=True)
    piece = jax.lax.psum(piece, pod_axis)
    full = jax.lax.all_gather(piece, inner_axis, axis=0, tiled=True)
    return full[:n].reshape(x.shape)


# ---------------------------------------------------------------------------
# Gradient compression with error feedback.
# ---------------------------------------------------------------------------

def compress_int8(g, *, eps: float = 1e-8):
    """Symmetric per-tensor int8 quantization: ``(q, scale)`` with
    ``q = round(g / scale)`` and ``scale = max|g| / 127``.  The round-trip
    error of every element is bounded by ``scale / 2``."""
    g = jnp.asarray(g, jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g)), eps) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def init_residuals(grads):
    """Zero error-feedback residuals shaped like ``grads`` (float32)."""
    return jax.tree.map(lambda g: jnp.zeros(jnp.shape(g), jnp.float32), grads)


def compress_tree(grads, residuals):
    """Quantize-dequantize every leaf with error feedback.

    Returns ``(dequantized, new_residuals)``: the residual (what int8 lost
    this step) is added back before quantizing next step, so the long-run
    mean of the dequantized stream converges to the true gradient.
    """
    flat, treedef = jax.tree.flatten(grads)
    rflat = treedef.flatten_up_to(residuals)
    deq_leaves, res_leaves = [], []
    for g, r in zip(flat, rflat):
        corrected = jnp.asarray(g, jnp.float32) + r
        q, scale = compress_int8(corrected)
        deq = decompress_int8(q, scale)
        deq_leaves.append(deq)
        res_leaves.append(corrected - deq)
    return treedef.unflatten(deq_leaves), treedef.unflatten(res_leaves)

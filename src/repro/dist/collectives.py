"""Task-graph collectives + gradient compression (paper §4.4, DESIGN.md §5).

Two execution substrates, one API:

* **Eager / transport** — :func:`ring_all_reduce` and :func:`ring_all_gather`
  build the textbook ring pipelines out of ``mpi_send`` / ``mpi_recv``
  *communication tasks* over whatever :class:`~repro.core.SpTransport` the
  :class:`~repro.core.SpCommGroup` carries — the in-process
  :class:`~repro.core.ChannelHub` (rank-tagged graphs inside one process)
  or the cross-process :class:`~repro.core.SocketTransport` (one OS
  process per rank over a TCP rendezvous, ``launch/rendezvous.py``).  The
  collectives are transport-agnostic: every value they put on the wire is
  an array/pytree the canonical wire codec encodes, and every chunk hop is
  an ordinary graph node, so the scheduler sees (and can overlap) the
  whole reduce-scatter/all-gather pipeline — the paper's "communications
  are incorporated into the task graph", extended from point-to-point to
  collectives the way DuctTeip layers distributed reductions over local
  task scheduling.

* **Staged** — inside ``shard_map``/``jit`` the same reductions lower to
  ``jax.lax`` collectives; :func:`hierarchical_psum` is the pod-aware
  three-stage variant (intra-pod reduce-scatter → inter-pod all-reduce on
  the scattered shards → intra-pod all-gather) that keeps the slow
  inter-pod links moving ``1/inner`` of the bytes.

Gradient compression (:func:`compress_int8`, :func:`compress_tree`) shrinks
what the collectives carry: symmetric per-tensor int8 with error-feedback
residuals (:func:`init_residuals`), so quantization error is re-injected
into the next step instead of lost.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.access import SpData
from repro.core.api import sp_task
from repro.core.comm import SpCommGroup, mpi_recv, mpi_send
from repro.core.graph import SpTaskGraph
from repro.core.task import TaskView


# ---------------------------------------------------------------------------
# Ring collectives over the ChannelHub (eager task-graph substrate).
# The chunk-level steps are codelets — declared once here, instantiated per
# rank/step with per-call names (the codelet frontend, core/api.py).
# ---------------------------------------------------------------------------

@sp_task(read=("x",), write=("chunks",), name="ring.split")
def _ring_split(x, chunks, *, n, meta):
    """Scatter ``x`` into ``n`` flat chunks; stash shape/dtype in ``meta``."""
    a = np.asarray(x)
    meta["shape"], meta["dtype"] = a.shape, a.dtype
    for ref, piece in zip(chunks, np.array_split(a.reshape(-1), n)):
        ref.value = piece.copy()


@sp_task(read=("incoming",), write=("acc",), name="ring.acc")
def _ring_accumulate(incoming, acc):
    acc.value = acc.value + incoming


@sp_task(read=("chunks",), write=("x",), name="ring.concat")
def _ring_concat(chunks, x, *, n, op, meta):
    full = np.concatenate([np.asarray(v).reshape(-1) for v in chunks])
    if op == "mean":
        full = full / n
    x.value = full.astype(meta["dtype"]).reshape(meta["shape"])
    return x.value


@sp_task(read=("x",), write=("slot",), name="ring.seed")
def _ring_seed(x, slot):
    slot.value = x


@sp_task(read=("slots",), name="ring.collect")
def _ring_collect(slots):
    return list(slots)


@sp_task(read=("x",), name="ring.identity")
def _ring_identity(x, *, wrap=False):
    return [x] if wrap else x


def ring_all_reduce(
    graph: SpTaskGraph,
    group: SpCommGroup,
    x: SpData,
    *,
    op: str = "sum",
    tag: int = 0,
) -> TaskView:
    """Insert a chunked ring all-reduce for ``x`` into ``graph``.

    Every rank calls this with its own (graph, group, cell); the group's
    transport wires the rings together — in-process mailboxes or TCP
    sockets, same task graph either way.  ``x.value`` is replaced by the
    reduced array; the returned view's value is the same array.  ``op`` is
    ``"sum"`` or ``"mean"``.  2·(S−1) hops per chunk — bandwidth-optimal.
    Re-issuing with a fresh ``tag`` per step is safe: drained mailboxes are
    pruned by the transport, so per-step keys do not accumulate.
    """
    if op not in ("sum", "mean"):
        raise ValueError(f"unsupported op {op!r}; use 'sum' or 'mean'")
    # Logical coordinates: the ring is laid out over group.members, so a
    # group shrunk after a rank death still forms a closed ring; neighbours
    # are translated back to physical ranks for the wire.
    S, r = group.logical_size, group.logical_rank
    if S == 1:
        return _ring_identity(x, graph=graph, name=f"allreduce{tag}.id")
    right, left = group.to_physical(r + 1), group.to_physical(r - 1)
    chunks = [SpData(None, f"ar{tag}.r{r}.c{i}") for i in range(S)]
    meta: dict = {}

    _ring_split(x, chunks, n=S, meta=meta,
                graph=graph, name=f"allreduce{tag}.split")

    # reduce-scatter: after S-1 steps rank r owns the reduced chunk (r+1)%S
    for step in range(S - 1):
        send_idx = (r - step) % S
        recv_idx = (r - step - 1) % S
        mpi_send(graph, group, chunks[send_idx], dest=right,
                 tag=("rar", tag, "rs", step))
        tmp = SpData(None, f"ar{tag}.r{r}.rs{step}")
        mpi_recv(graph, group, tmp, src=left, tag=("rar", tag, "rs", step))
        _ring_accumulate(tmp, chunks[recv_idx],
                         graph=graph, name=f"allreduce{tag}.acc{step}")

    # all-gather: circulate the reduced chunks
    for step in range(S - 1):
        send_idx = (r + 1 - step) % S
        recv_idx = (r - step) % S
        mpi_send(graph, group, chunks[send_idx], dest=right,
                 tag=("rar", tag, "ag", step))
        mpi_recv(graph, group, chunks[recv_idx], src=left,
                 tag=("rar", tag, "ag", step))

    return _ring_concat(chunks, x, n=S, op=op, meta=meta,
                        graph=graph, name=f"allreduce{tag}.concat")


def ring_all_gather(
    graph: SpTaskGraph,
    group: SpCommGroup,
    x: SpData,
    *,
    tag: int = 0,
) -> TaskView:
    """Ring all-gather: the returned view's value is the list of every
    rank's ``x.value``, ordered by logical rank — i.e. by position in
    ``group.members`` (same list on all ranks)."""
    S, r = group.logical_size, group.logical_rank
    if S == 1:
        return _ring_identity(x, wrap=True, graph=graph, name=f"allgather{tag}.id")
    right, left = group.to_physical(r + 1), group.to_physical(r - 1)
    slots = [SpData(None, f"ag{tag}.r{r}.s{i}") for i in range(S)]
    _ring_seed(x, slots[r], graph=graph, name=f"allgather{tag}.seed")
    for step in range(S - 1):
        send_idx = (r - step) % S
        recv_idx = (r - step - 1) % S
        mpi_send(graph, group, slots[send_idx], dest=right,
                 tag=("rag", tag, step))
        mpi_recv(graph, group, slots[recv_idx], src=left,
                 tag=("rag", tag, step))
    return _ring_collect(slots, graph=graph, name=f"allgather{tag}.collect")


# ---------------------------------------------------------------------------
# Staged-substrate collectives (lower to jax.lax inside shard_map / jit).
# ---------------------------------------------------------------------------

def all_reduce(
    x,
    *,
    axis=None,
    graph: Optional[SpTaskGraph] = None,
    group: Optional[SpCommGroup] = None,
    op: str = "sum",
    tag: int = 0,
):
    """Substrate-dispatching all-reduce: with (graph, group) → hub ring;
    with ``axis`` (a mesh axis name, inside shard_map) → ``jax.lax``."""
    if graph is not None:
        if group is None:
            raise ValueError("hub all_reduce needs both graph and group")
        return ring_all_reduce(graph, group, x, op=op, tag=tag)
    if axis is None:
        raise ValueError("staged all_reduce needs axis=<mesh axis name>")
    return jax.lax.pmean(x, axis) if op == "mean" else jax.lax.psum(x, axis)


def all_gather(
    x,
    *,
    axis=None,
    graph: Optional[SpTaskGraph] = None,
    group: Optional[SpCommGroup] = None,
    tag: int = 0,
):
    """Substrate-dispatching all-gather (see :func:`all_reduce`)."""
    if graph is not None:
        if group is None:
            raise ValueError("hub all_gather needs both graph and group")
        return ring_all_gather(graph, group, x, tag=tag)
    if axis is None:
        raise ValueError("staged all_gather needs axis=<mesh axis name>")
    return jax.lax.all_gather(x, axis)


def hierarchical_psum(x, *, pod_axis: str = "pod", inner_axis: str = "data"):
    """Pod-aware psum: reduce-scatter over ``inner_axis``, all-reduce the
    scattered shards over ``pod_axis``, all-gather over ``inner_axis``.

    Numerically equal to ``jax.lax.psum(x, (pod_axis, inner_axis))`` but the
    slow inter-pod hop carries ``1/inner`` of the bytes.  Must be called
    inside ``shard_map`` with both axes bound.
    """
    inner = jax.lax.psum(1, inner_axis)  # static axis size (constant-folded)
    n = x.size
    flat = x.reshape(-1)
    pad = (-n) % inner
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    piece = jax.lax.psum_scatter(flat, inner_axis, scatter_dimension=0, tiled=True)
    piece = jax.lax.psum(piece, pod_axis)
    full = jax.lax.all_gather(piece, inner_axis, axis=0, tiled=True)
    return full[:n].reshape(x.shape)


# ---------------------------------------------------------------------------
# Gradient compression with error feedback.
# ---------------------------------------------------------------------------

def compress_int8(g, *, eps: float = 1e-8):
    """Symmetric per-tensor int8 quantization: ``(q, scale)`` with
    ``q = round(g / scale)`` and ``scale = max|g| / 127``.  The round-trip
    error of every element is bounded by ``scale / 2``."""
    g = jnp.asarray(g, jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g)), eps) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def init_residuals(grads):
    """Zero error-feedback residuals shaped like ``grads`` (float32)."""
    return jax.tree.map(lambda g: jnp.zeros(jnp.shape(g), jnp.float32), grads)


def compress_tree(grads, residuals):
    """Quantize-dequantize every leaf with error feedback.

    Returns ``(dequantized, new_residuals)``: the residual (what int8 lost
    this step) is added back before quantizing next step, so the long-run
    mean of the dequantized stream converges to the true gradient.
    """
    flat, treedef = jax.tree.flatten(grads)
    rflat = treedef.flatten_up_to(residuals)
    deq_leaves, res_leaves = [], []
    for g, r in zip(flat, rflat):
        corrected = jnp.asarray(g, jnp.float32) + r
        q, scale = compress_int8(corrected)
        deq = decompress_int8(q, scale)
        deq_leaves.append(deq)
        res_leaves.append(corrected - deq)
    return treedef.unflatten(deq_leaves), treedef.unflatten(res_leaves)

"""Fault tolerance: duplicated tasks, failure injection, elastic re-mesh.

Three mechanisms (DESIGN.md §5), all riding on machinery the core runtime
already has:

* :class:`CancelToken` + :func:`run_duplicated` — straggler/fault mitigation
  by replication.  ``n`` copies of a task race; the first to finish claims
  the token, and the engine's cancellation hook (``SpComputeEngine._execute``
  checks ``task.cancel_token`` before running) turns every not-yet-started
  copy into a no-op.  First-result-wins, the select is deterministic because
  all copies compute the same pure function.

* :class:`FailureSimulator` — scripted rank loss for tests and the launcher:
  a ``{step: ranks_lost}`` plan checked once per training step.

* :func:`remesh_plan` — given the surviving chip count, compute the largest
  mesh that preserves model parallelism (a param-sharding-compatible
  ``model`` axis) by shrinking the pure-data axes, idling any remainder
  chips.  Because ``repro.dist.sharding.safe_spec`` replicates anything the
  mesh cannot divide, a plan produced here can always restore a checkpoint
  taken on the bigger mesh (the elastic story exercised end-to-end in
  ``tests/test_multidevice.py``).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Callable, Optional, Sequence

from repro.core.access import SpData
from repro.core.api import sp_task
from repro.core.graph import SpTaskGraph
from repro.core.task import TaskView


class CancelToken:
    """First-result-wins latch shared by a set of duplicated tasks.

    ``set(task)`` claims the token (only the first claim sticks and records
    ``winner``); ``is_set()`` is the engine's pre-execution cancellation
    check.  A copy that *raised* must not claim the token — the engine
    records it via :meth:`record_failure` instead, so healthy replicas keep
    racing and the failure is only surfaced if every copy loses.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._event = threading.Event()
        self._claimed = False
        self.winner = None
        self.failures: list[BaseException] = []

    def set(self, task=None) -> bool:
        """Claim the token for ``task``; True iff this call won."""
        with self._lock:
            if self._claimed:
                return False
            self._claimed = True
            self.winner = task
            self._event.set()
            return True

    def record_failure(self, exc: BaseException) -> None:
        with self._lock:
            self.failures.append(exc)

    def is_set(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._event.wait(timeout)


@sp_task(read=("inputs",), commutative=("out",), name="dup.copy")
def _dup_copy(inputs, out, *, fn):
    out.value = fn(*inputs)
    return out.value


@sp_task(read=("winner",), name="dup.select")
def _dup_select(winner, *, token, n, label):
    if token.winner is None:
        raise RuntimeError(
            f"{label}: all {n} duplicated copies failed"
        ) from (token.failures[0] if token.failures else None)
    return winner


def run_duplicated(
    graph: SpTaskGraph,
    fn: Callable,
    inputs: Sequence[SpData],
    out: SpData,
    *,
    n: int = 2,
    name: str = "dup",
    cost: float = 1.0,
) -> TaskView:
    """Insert ``n`` replicated copies of ``fn(*inputs) -> out`` plus a
    select task; returns the select's view (its value is the winner's
    result).

    Copies write ``out`` commutatively (order-free, mutually exclusive), so
    the scheduler may run them concurrently on different workers; whichever
    finishes first claims the shared :class:`CancelToken` and the engine
    cancels the stragglers before they start.  ``fn`` must be pure — a
    copy that already started when the winner finished simply recomputes
    the same value.
    """
    if n < 1:
        raise ValueError("need at least one copy")
    token = CancelToken()

    for i in range(n):
        view = _dup_copy(
            list(inputs), out, fn=fn,
            graph=graph, name=f"{name}.copy{i}", cost=cost,
        )
        view.task.cancel_token = token

    return _dup_select(out, token=token, n=n, label=name,
                       graph=graph, name=f"{name}.select")


class FailureSimulator:
    """Scripted rank loss: ``plan`` maps step → number of ranks lost when
    that step is reached.  Drivers call :meth:`check` once per step."""

    def __init__(self, plan: dict[int, int]):
        self.plan = dict(plan)
        self.events: list[tuple[int, int]] = []

    def check(self, step: int) -> int:
        """Ranks lost at ``step`` (0 if none); records the event.  Each
        planned failure fires exactly once — the rank stays dead, so
        replaying the step after a restore must not kill it again."""
        lost = int(self.plan.pop(step, 0))
        if lost:
            self.events.append((step, lost))
        return lost

    @property
    def total_lost(self) -> int:
        return sum(n for _, n in self.events)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"FailureSimulator({self.plan}, lost={self.total_lost})"


@dataclass(frozen=True)
class RemeshPlan:
    """A shrunken mesh layout: build it with
    ``jax.sharding.Mesh(devices[:n_chips].reshape(shape), axes)``."""

    shape: tuple[int, ...]
    axes: tuple[str, ...]
    n_chips: int
    dropped_chips: int  # failed + idled (alive but unused) chips
    model_parallel: int


def remesh_plan(
    n_total: int,
    n_failed: int,
    *,
    model_parallel: int,
    pod_size: Optional[int] = None,
) -> RemeshPlan:
    """Largest mesh on the survivors of ``n_total`` chips that preserves a
    ``model`` axis of exactly ``model_parallel``.

    The ``model`` axis must survive intact (param shards per layer stay
    addressable); only pure-data axes shrink.  With ``pod_size``, whole
    surviving pods keep the 3-axis ``(pod, data, model)`` layout; once fewer
    than two full pods survive, the plan collapses to single-pod
    ``(data, model)`` over all remaining chips.  Raises ``RuntimeError``
    when fewer than ``model_parallel`` chips survive — at that point the
    job cannot continue and must be rescheduled, not re-meshed.
    """
    if model_parallel < 1:
        raise ValueError("model_parallel must be >= 1")
    alive = n_total - n_failed
    if alive < model_parallel:
        raise RuntimeError(
            f"{alive} chips survive of {n_total}; cannot preserve "
            f"model_parallel={model_parallel} — reschedule instead of re-mesh"
        )
    if pod_size is not None and pod_size % model_parallel:
        raise ValueError("pod_size must be a multiple of model_parallel")
    if pod_size is not None:
        pods = alive // pod_size
        if pods >= 2:
            data = pod_size // model_parallel
            n_chips = pods * pod_size
            return RemeshPlan(
                (pods, data, model_parallel),
                ("pod", "data", "model"),
                n_chips,
                n_total - n_chips,
                model_parallel,
            )
    data = alive // model_parallel
    n_chips = data * model_parallel
    return RemeshPlan(
        (data, model_parallel),
        ("data", "model"),
        n_chips,
        n_total - n_chips,
        model_parallel,
    )

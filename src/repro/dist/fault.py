"""Fault tolerance: duplicated tasks, failure injection, elastic re-mesh.

Three mechanisms (DESIGN.md §5), all riding on machinery the core runtime
already has:

* :class:`CancelToken` + :func:`run_duplicated` — straggler/fault mitigation
  by replication.  ``n`` copies of a task race; the first to finish claims
  the token, and the engine's cancellation hook (``SpComputeEngine._execute``
  checks ``task.cancel_token`` before running) turns every not-yet-started
  copy into a no-op.  First-result-wins, the select is deterministic because
  all copies compute the same pure function.

* :class:`FailureSimulator` — scripted rank loss for tests and the launcher:
  a ``{step: ranks_lost}`` plan checked once per training step.

* :func:`remesh_plan` — given the surviving chip count, compute the largest
  mesh that preserves model parallelism (a param-sharding-compatible
  ``model`` axis) by shrinking the pure-data axes, idling any remainder
  chips.  Because ``repro.dist.sharding.safe_spec`` replicates anything the
  mesh cannot divide, a plan produced here can always restore a checkpoint
  taken on the bigger mesh (the elastic story exercised end-to-end in
  ``tests/test_multidevice.py``).

Fault tolerance & recovery (ISSUE 6)
------------------------------------

The layer above scripts *pretend* failures; this section is the real
data-plane story, verified end to end against killed OS processes:

* **Detection** lives in ``repro.core.comm``: on the p2p data plane
  (ISSUE 10) every rank heartbeats its *direct* peer links (plus the
  rank-0 control link), so EOF-without-goodbye and stale heartbeats are
  **peer-observed** — whichever rank sees the death first gossips a
  ``dead`` notice over all its links and every survivor's pending *and*
  future requests addressed to that rank fail with a typed
  :class:`~repro.core.SpRankDeadError` in O(heartbeat) — dependent tasks
  cancel transitively, exactly as timeouts do.  No router sits in the
  detection path: killing rank 0 itself is detected the same way.

* **Injection** — :class:`FaultyTransport` wraps any ``SpTransport`` and
  drops, delays, duplicates, or truncates messages and kills ranks on a
  deterministic seeded schedule.  Injected send-side faults raise
  :class:`~repro.core.SpCommTransientError` (a *retryable* link fault,
  distinct from rank death); duplicates are filtered by a receive-side
  ``(src, seq)`` dedup window, which is also what makes send retry
  idempotent.  With ``peers=``, injection is scoped to the *per-peer
  streams* named — posts to other destinations pass through untouched —
  so chaos scenarios can shake exactly the direct links under test.

* **Retry** — :class:`RetryingTransport` wraps a (possibly faulty)
  transport with a bounded exponential-backoff retry budget for transient
  faults; on exhaustion it escalates, marking the peer dead and raising
  ``SpRankDeadError`` — transient faults are absorbed, real deaths are
  not masked.

* **Recovery** — on ``SpRankDeadError`` survivors agree on the dead set
  via an epoch-tagged rendezvous re-roll
  (``repro.launch.rendezvous.reroll_ranks``), shrink the communicator
  (``SpCommGroup.shrunk``; ring collectives run on *logical* coordinates
  so the shrunken ring stays closed), apply :func:`remesh_plan`, and
  rebuild sharded state live via ``jax.device_put`` of the surviving
  shards — falling back to a checkpoint restore only when live shards
  cannot reconstruct the state.  ``launch/train.py --recovery live``
  drives this; ``benchmarks/recovery_bench.py`` measures detection
  latency and live-reshard vs full-restore recovery time into
  ``BENCH_recovery.json``.

In-runtime recovery contract (ISSUE 8)
--------------------------------------

As of ISSUE 8 the recovery choreography above no longer lives in user
code: ``SpRuntime(elastic=True)`` owns it.  The contract, verified by
``tests/test_robustness.py`` against a SIGKILLed OS rank:

* **What the runtime promises.**  Inside
  :meth:`~repro.core.SpRuntime.run_step` / ``elastic_loop`` every step
  runs in a fresh graph; when a group member dies — surfaced as
  ``SpRankDeadError`` from a collective, a :meth:`barrier` call, or the
  step wait — the runtime re-rolls the group with a fresh epoch, rebinds
  ``rt.group``, invokes the ``on_reshard`` hook (domain work only:
  re-mesh, reshard/restore state), and re-executes from the **minimum**
  step any survivor still needs.  Each recovery is recorded in
  ``rt.recoveries`` (dead set, detection stamp, re-roll wall time).

* **What the step function promises.**  It must be *deterministic and
  re-runnable given its step index* — reads its inputs from step-indexed
  state, tags collectives with ``(rt.epoch, step)``, and contains **no
  failure handling**.  A step that completed on one rank may re-execute
  after a peer rewinds; idempotence comes from determinism, not from
  fencing.

* **Task-level policies** complete the story below rank death: an
  ``@sp_task(retries=, timeout=, on_failure=)`` policy retries transient
  task failures in place, the engine watchdog fails *hung* bodies with
  ``SpTaskTimeoutError`` (the body is abandoned as a zombie whose late
  writes are discarded), and ``on_failure="quarantine"`` isolates a
  poison task — dependents cancel, siblings and the graph live on, and
  ``engine.stop()`` reports the quarantined names.

* ``dist/chaos.py`` soaks all of it under seeded fault schedules
  (CI's ``chaos-smoke`` job: 3 seeds x 20 iterations).
"""
from __future__ import annotations

import collections
import random
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence

from repro.core.access import SpData
from repro.core.api import sp_task
from repro.core.comm import SpCommTransientError, SpRankDeadError, SpTransport
from repro.core.graph import SpTaskGraph
from repro.core.task import TaskView


class CancelToken:
    """First-result-wins latch shared by a set of duplicated tasks.

    ``set(task)`` claims the token (only the first claim sticks and records
    ``winner``); ``is_set()`` is the engine's pre-execution cancellation
    check.  A copy that *raised* must not claim the token — the engine
    records it via :meth:`record_failure` instead, so healthy replicas keep
    racing and the failure is only surfaced if every copy loses.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._event = threading.Event()
        self._claimed = False
        self.winner = None
        self.failures: list[BaseException] = []

    def set(self, task=None) -> bool:
        """Claim the token for ``task``; True iff this call won."""
        with self._lock:
            if self._claimed:
                return False
            self._claimed = True
            self.winner = task
            self._event.set()
            return True

    def record_failure(self, exc: BaseException) -> None:
        with self._lock:
            self.failures.append(exc)

    def is_set(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._event.wait(timeout)


@sp_task(read=("inputs",), commutative=("out",), name="dup.copy")
def _dup_copy(inputs, out, *, fn):
    out.value = fn(*inputs)
    return out.value


@sp_task(read=("winner",), name="dup.select")
def _dup_select(winner, *, token, n, label):
    if token.winner is None:
        raise RuntimeError(
            f"{label}: all {n} duplicated copies failed"
        ) from (token.failures[0] if token.failures else None)
    return winner


def run_duplicated(
    graph: SpTaskGraph,
    fn: Callable,
    inputs: Sequence[SpData],
    out: SpData,
    *,
    n: int = 2,
    name: str = "dup",
    cost: float = 1.0,
) -> TaskView:
    """Insert ``n`` replicated copies of ``fn(*inputs) -> out`` plus a
    select task; returns the select's view (its value is the winner's
    result).

    Copies write ``out`` commutatively (order-free, mutually exclusive), so
    the scheduler may run them concurrently on different workers; whichever
    finishes first claims the shared :class:`CancelToken` and the engine
    cancels the stragglers before they start.  ``fn`` must be pure — a
    copy that already started when the winner finished simply recomputes
    the same value.
    """
    if n < 1:
        raise ValueError("need at least one copy")
    token = CancelToken()

    for i in range(n):
        view = _dup_copy(
            list(inputs), out, fn=fn,
            graph=graph, name=f"{name}.copy{i}", cost=cost,
        )
        view.task.cancel_token = token

    return _dup_select(out, token=token, n=n, label=name,
                       graph=graph, name=f"{name}.select")


class FailureSimulator:
    """Scripted rank loss: ``plan`` maps step → number of ranks lost when
    that step is reached.  Drivers call :meth:`check` once per step.

    ``flaky`` scripts *transient* outages — ``{step: down_for}`` means the
    flaky ranks go dark at ``step`` and recover ``down_for`` steps later;
    drivers call :meth:`flaky_down` once per step and should treat a True
    return as "retry this step's communication", not as a death."""

    def __init__(
        self,
        plan: dict[int, int],
        *,
        flaky: Optional[dict[int, int]] = None,
    ):
        self.plan = dict(plan)
        self.events: list[tuple[int, int]] = []
        self.flaky = dict(flaky or {})
        self.flaky_events: list[tuple[int, int]] = []
        self._down_until: Optional[int] = None

    def check(self, step: int) -> int:
        """Ranks lost at ``step`` (0 if none); records the event.  Each
        planned failure fires exactly once — the rank stays dead, so
        replaying the step after a restore must not kill it again."""
        lost = int(self.plan.pop(step, 0))
        if lost:
            self.events.append((step, lost))
        return lost

    def flaky_down(self, step: int) -> bool:
        """True while a scripted transient outage covers ``step``.  An
        outage starting at step ``s`` with duration ``d`` covers steps
        ``s .. s+d-1``; at ``s+d`` the ranks have recovered.  Like
        :meth:`check`, each outage fires exactly once."""
        if step in self.flaky:
            until = step + int(self.flaky.pop(step))
            self.flaky_events.append((step, until))
            self._down_until = until
        if self._down_until is not None and step < self._down_until:
            return True
        self._down_until = None
        return False

    @property
    def total_lost(self) -> int:
        return sum(n for _, n in self.events)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"FailureSimulator({self.plan}, lost={self.total_lost})"


# ---------------------------------------------------------------------------
# Fault injection + retry: the harness the detection/retry layer is
# verified with (module docstring, "Fault tolerance & recovery").
# ---------------------------------------------------------------------------

_WRAP = "__fault__"       # wrapped payload marker: (_WRAP, src, seq, msg)
_CORRUPT = "__corrupt__"  # truncated-frame marker: (_CORRUPT, src, seq)


class FaultyTransport(SpTransport):
    """Deterministic fault injector over any :class:`SpTransport`.

    Every ``post`` consumes draws from a seeded PRNG in a fixed order
    (drop, duplicate, delay, truncate), so a given ``seed`` plus a given
    call sequence always injects the same fault schedule — tests replay
    schedules exactly.

    Fault model (probabilities in [0, 1]):

    * ``drop`` — the message is lost in flight; the sender *sees* the loss
      as :class:`SpCommTransientError` (a failed send syscall), so a retry
      wrapper can re-post it.
    * ``duplicate`` — the message is deposited twice; the receive side
      dedups via a ``(src, seq)`` window so pollers still see it once.
      The same window makes send-side *retries* idempotent.
    * ``delay`` — delivery is deferred ``delay_s`` seconds (a timer thread
      deposits late); the post itself succeeds.
    * ``truncate`` — a corrupt marker reaches the receiver (discarded and
      counted on poll) and the sender gets ``SpCommTransientError``.

    Scripted, non-random faults:

    * ``kill_plan`` — ``{post_ordinal: rank}``: when the Nth post through
      this wrapper starts, ``rank`` is marked dead on the inner transport
      (subsequent posts to it raise ``SpRankDeadError``).
    * ``flaky`` — ``{rank: n_failures}``: the next ``n`` posts to ``rank``
      raise ``SpCommTransientError``, then the rank recovers — the
      flaky-then-recovering peer a retry budget must absorb.

    ``peers`` (optional) restricts injection to posts whose *destination*
    is in the set — the per-peer-stream scoping the p2p data plane needs:
    posts to any other rank bypass the PRNG entirely (no draws consumed,
    no wrap), so the fault schedule on the named streams is independent
    of traffic elsewhere.  ``kill_plan`` ordinals likewise count only
    posts on the named streams.

    ``injected`` counts every fault by kind.  All wrapped payloads are
    ``(_WRAP, src, seq, msg)`` tuples; :meth:`poll` unwraps, so wrap and
    unwrap must happen on the same layer — wrap *both* ends of a link (or
    share one wrapper, e.g. around a ``ChannelHub``)."""

    def __init__(
        self,
        inner: SpTransport,
        *,
        seed: int = 0,
        drop: float = 0.0,
        duplicate: float = 0.0,
        delay: float = 0.0,
        delay_s: float = 0.005,
        truncate: float = 0.0,
        kill_plan: Optional[dict[int, int]] = None,
        flaky: Optional[dict[int, int]] = None,
        dedup_window: int = 4096,
        peers: Optional[Sequence[int]] = None,
    ):
        self.inner = inner
        self._peers = None if peers is None else frozenset(peers)
        self._rng = random.Random(seed)
        self._p = {"drop": drop, "duplicate": duplicate,
                   "delay": delay, "truncate": truncate}
        self._delay_s = delay_s
        self._kill_plan = dict(kill_plan or {})
        self._flaky = dict(flaky or {})
        self._dedup_window = dedup_window
        self._lock = threading.Lock()
        self._seq = 0
        self._post_ordinal = 0
        self._seen: collections.deque = collections.deque()
        self._seen_set: set = set()
        self._timers: list[threading.Timer] = []
        self.injected = {
            "dropped": 0, "duplicated": 0, "delayed": 0, "truncated": 0,
            "flaky": 0, "killed": 0, "deduped": 0, "corrupt_discarded": 0,
        }

    # -- send side -----------------------------------------------------------

    def _draw(self, kind: str) -> bool:
        # one draw per fault kind per post, in fixed order — determinism
        # does not depend on which faults are enabled
        return self._rng.random() < self._p[kind]

    def post(self, key: tuple, msg: Any) -> None:
        src, dst, _tag = key
        if self._peers is not None and dst not in self._peers:
            self.inner.post(key, msg)  # off-stream: untouched, no draws
            return
        with self._lock:
            ordinal = self._post_ordinal
            self._post_ordinal += 1
            seq = self._seq
            self._seq += 1
            victim = self._kill_plan.pop(ordinal, None)
            flaky_left = self._flaky.get(dst, 0)
            if flaky_left > 0:
                self._flaky[dst] = flaky_left - 1
            # draws happen under the lock so concurrent posters still see
            # one deterministic global schedule
            drop = self._draw("drop")
            dup = self._draw("duplicate")
            delay = self._draw("delay")
            trunc = self._draw("truncate")
        if victim is not None:
            self.injected["killed"] += 1
            self.mark_dead(victim)
        if flaky_left > 0:
            self.injected["flaky"] += 1
            raise SpCommTransientError(
                f"rank {dst} is flaky: injected send failure "
                f"({flaky_left - 1} more before recovery)"
            )
        wrapped = (_WRAP, src, seq, msg)
        if drop:
            self.injected["dropped"] += 1
            raise SpCommTransientError(
                f"injected drop of post {key!r} (seq {seq})"
            )
        if trunc:
            self.injected["truncated"] += 1
            self.inner.post(key, (_CORRUPT, src, seq))
            raise SpCommTransientError(
                f"injected truncation of post {key!r} (seq {seq})"
            )
        if delay:
            self.injected["delayed"] += 1
            t = threading.Timer(
                self._delay_s, self.inner.post, args=(key, wrapped)
            )
            t.daemon = True
            with self._lock:
                self._timers.append(t)
            t.start()
        else:
            self.inner.post(key, wrapped)
        if dup:
            self.injected["duplicated"] += 1
            self.inner.post(key, wrapped)

    # -- receive side --------------------------------------------------------

    def poll(self, key: tuple) -> tuple[bool, Any]:
        while True:
            ok, msg = self.inner.poll(key)
            if not ok:
                return False, None
            if isinstance(msg, tuple) and msg and msg[0] == _CORRUPT:
                self.injected["corrupt_discarded"] += 1
                continue
            if isinstance(msg, tuple) and msg and msg[0] == _WRAP:
                _, src, seq, payload = msg
                with self._lock:
                    if (src, seq) in self._seen_set:
                        self.injected["deduped"] += 1
                        continue
                    self._seen_set.add((src, seq))
                    self._seen.append((src, seq))
                    while len(self._seen) > self._dedup_window:
                        self._seen_set.discard(self._seen.popleft())
                return True, payload
            return True, msg  # unwrapped message from a non-faulty sender

    # -- delegation ----------------------------------------------------------

    @property
    def dead_ranks(self) -> frozenset:
        return self.inner.dead_ranks

    def mark_dead(self, rank: int) -> None:
        self.inner.mark_dead(rank)

    def death_detected_at(self, rank: int) -> Optional[float]:
        return self.inner.death_detected_at(rank)

    def recover(self, rank: int) -> None:
        """Clear any remaining scripted flakiness for ``rank`` (the peer
        'reconnected')."""
        with self._lock:
            self._flaky.pop(rank, None)

    def stats(self) -> dict:
        st = dict(self.inner.stats())
        st["faults"] = dict(self.injected)
        return st

    def reset(self) -> None:
        self.inner.reset()
        with self._lock:
            self._seen.clear()
            self._seen_set.clear()

    def close(self) -> None:
        with self._lock:
            timers = list(self._timers)
            self._timers.clear()
        for t in timers:
            t.cancel()
        self.inner.close()


class RetryingTransport(SpTransport):
    """Bounded retry-with-backoff over a (possibly fault-injecting)
    transport.

    ``post`` retries on :class:`SpCommTransientError` up to ``max_retries``
    times with exponential backoff (``backoff * factor**attempt``, capped
    at ``max_backoff``).  Retried posts are idempotent because
    :class:`FaultyTransport`'s receive side dedups on ``(src, seq)`` — a
    'drop' that actually delivered cannot double-deliver.  When the budget
    is exhausted, the wrapper *escalates*: the destination is marked dead
    on the inner transport and :class:`SpRankDeadError` is raised — a link
    that stays down is a dead peer, not an infinitely-retryable blip.

    ``poll`` passes through untouched (including ``SpRankDeadError``): the
    poll path must stay non-blocking, so there is nothing to retry."""

    def __init__(
        self,
        inner: SpTransport,
        *,
        max_retries: int = 5,
        backoff: float = 0.002,
        factor: float = 2.0,
        max_backoff: float = 0.25,
    ):
        self.inner = inner
        self.max_retries = max_retries
        self.backoff = backoff
        self.factor = factor
        self.max_backoff = max_backoff
        self.retries = 0
        self.escalations = 0

    def post(self, key: tuple, msg: Any) -> None:
        last: Optional[SpCommTransientError] = None
        for attempt in range(self.max_retries + 1):
            try:
                self.inner.post(key, msg)
                return
            except SpCommTransientError as e:
                last = e
                if attempt < self.max_retries:
                    self.retries += 1
                    time.sleep(
                        min(self.backoff * self.factor ** attempt,
                            self.max_backoff)
                    )
        dst = key[1]
        self.escalations += 1
        self.inner.mark_dead(dst)
        raise SpRankDeadError(
            f"rank {dst}: send failed {self.max_retries + 1} times "
            f"({last}); escalating transient faults to rank-dead"
        ) from last

    def poll(self, key: tuple) -> tuple[bool, Any]:
        return self.inner.poll(key)

    @property
    def dead_ranks(self) -> frozenset:
        return self.inner.dead_ranks

    def mark_dead(self, rank: int) -> None:
        self.inner.mark_dead(rank)

    def death_detected_at(self, rank: int) -> Optional[float]:
        return self.inner.death_detected_at(rank)

    def stats(self) -> dict:
        st = dict(self.inner.stats())
        st["retries"] = self.retries
        st["escalations"] = self.escalations
        return st

    def reset(self) -> None:
        self.inner.reset()

    def close(self) -> None:
        self.inner.close()


@dataclass(frozen=True)
class RemeshPlan:
    """A shrunken mesh layout: build it with
    ``jax.sharding.Mesh(devices[:n_chips].reshape(shape), axes)``."""

    shape: tuple[int, ...]
    axes: tuple[str, ...]
    n_chips: int
    dropped_chips: int  # failed + idled (alive but unused) chips
    model_parallel: int


def remesh_plan(
    n_total: int,
    n_failed: int,
    *,
    model_parallel: int,
    pod_size: Optional[int] = None,
) -> RemeshPlan:
    """Largest mesh on the survivors of ``n_total`` chips that preserves a
    ``model`` axis of exactly ``model_parallel``.

    The ``model`` axis must survive intact (param shards per layer stay
    addressable); only pure-data axes shrink.  With ``pod_size``, whole
    surviving pods keep the 3-axis ``(pod, data, model)`` layout; once fewer
    than two full pods survive, the plan collapses to single-pod
    ``(data, model)`` over all remaining chips.  Raises ``RuntimeError``
    when fewer than ``model_parallel`` chips survive — at that point the
    job cannot continue and must be rescheduled, not re-meshed.
    """
    if model_parallel < 1:
        raise ValueError("model_parallel must be >= 1")
    alive = n_total - n_failed
    if alive < model_parallel:
        raise RuntimeError(
            f"{alive} chips survive of {n_total}; cannot preserve "
            f"model_parallel={model_parallel} — reschedule instead of re-mesh"
        )
    if pod_size is not None and pod_size % model_parallel:
        raise ValueError("pod_size must be a multiple of model_parallel")
    if pod_size is not None:
        pods = alive // pod_size
        if pods >= 2:
            data = pod_size // model_parallel
            n_chips = pods * pod_size
            return RemeshPlan(
                (pods, data, model_parallel),
                ("pod", "data", "model"),
                n_chips,
                n_total - n_chips,
                model_parallel,
            )
    data = alive // model_parallel
    n_chips = data * model_parallel
    return RemeshPlan(
        (data, model_parallel),
        ("data", "model"),
        n_chips,
        n_total - n_chips,
        model_parallel,
    )

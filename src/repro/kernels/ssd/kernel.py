"""Mamba-2 SSD intra-chunk kernel — Pallas TPU.

Grid: (B·H, n_chunks).  Each program loads one chunk's (x, dt, cum, B, C)
tile into VMEM and produces the intra-chunk output and the end-of-chunk
state with three MXU matmuls:

    scores = (C Bᵀ) ⊙ Lmask,   y = scores·(x),   state = (B·w)ᵀ x

where Lmask[i,j] = exp(cum_i − cum_j)·dt_j for i ≥ j and w = exp(cum_end −
cum)·dt.  The O(n_chunks) inter-chunk recurrence (tiny: (N, P) per head)
stays in jnp — the kernel covers the quadratic-in-chunk-size hot spot.

VMEM per program (cs=256, P=64, N=128, f32):
    x 256×64, B/C 2×256×128, scores 256×256, y 256×64, state 128×64
    ≈ 0.6 MiB — comfortably resident; cs and N are multiples of 128 for
    the MXU (P=64 rides the free dimension).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None


def _ssd_kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, y_ref, state_ref, *, cs: int):
    x = x_ref[0, 0].astype(jnp.float32)    # (cs, P)
    dt = dt_ref[0].astype(jnp.float32)    # (cs, 1)
    cum = cum_ref[0].astype(jnp.float32)  # (cs, 1)
    B = b_ref[0, 0].astype(jnp.float32)    # (cs, N)
    C = c_ref[0, 0].astype(jnp.float32)    # (cs, N)

    scores = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (cs, cs)
    ii = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 1)
    decay = jnp.exp(cum - cum.T)  # cum_i - cum_j
    L = jnp.where(ii >= jj, decay, 0.0)
    w = scores * L * dt.T
    y_ref[0, 0] = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(y_ref.dtype)

    cum_end = cum[cs - 1, 0]
    wts = jnp.exp(cum_end - cum) * dt  # (cs, 1)
    state_ref[0, 0] = jax.lax.dot_general(
        B * wts, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(state_ref.dtype)


def ssd_intra_chunk_pallas(
    x: jax.Array,    # (BH, nc, cs, P)
    dt: jax.Array,   # (BH, nc, cs)
    cum: jax.Array,  # (BH, nc, cs)
    B: jax.Array,    # (BH, nc, cs, N)
    C: jax.Array,    # (BH, nc, cs, N)
    *,
    interpret: bool = False,
):
    BH, nc, cs, P = x.shape
    N = B.shape[-1]
    kernel = functools.partial(_ssd_kernel, cs=cs)
    grid = (BH, nc)

    def idx(b, c):
        return (b, c, 0, 0)

    def idx3(b, c):
        return (b, c, 0)

    y, state = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, cs, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, cs, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, cs, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, cs, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, cs, N), lambda b, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, cs, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, c: (b, c, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, nc, cs, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(
        x,
        dt.reshape(BH, nc * cs, 1),
        cum.reshape(BH, nc * cs, 1),
        B,
        C,
    )
    return y, state

from . import ops, ref
from .kernel import ssd_intra_chunk_pallas

__all__ = ["ops", "ref", "ssd_intra_chunk_pallas"]

"""Pure-jnp oracle for the SSD intra-chunk kernel.

Given one chunk's inputs (per batch·head tile), computes
* ``y_intra``  — the causal decay-weighted attention-like contribution
* ``state``    — the end-of-chunk state  Σ_j exp(cum_last − cum_j)·dt_j·B_j x_jᵀ
which the jnp inter-chunk recurrence then combines.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunk_ref(
    x: jax.Array,   # (cs, P)
    dt: jax.Array,  # (cs,)
    cum: jax.Array,  # (cs,) cumulative log-decay within the chunk
    B: jax.Array,   # (cs, N)
    C: jax.Array,   # (cs, N)
):
    cs = x.shape[0]
    xf, dtf, cumf = x.astype(jnp.float32), dt.astype(jnp.float32), cum.astype(jnp.float32)
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)
    diff = cumf[:, None] - cumf[None, :]
    ii = jnp.arange(cs)
    L = jnp.where(ii[:, None] >= ii[None, :], jnp.exp(diff), 0.0)
    scores = (Cf @ Bf.T) * L * dtf[None, :]
    y = scores @ xf  # (cs, P)
    decay_end = jnp.exp(cumf[-1] - cumf)
    state = (Bf * (decay_end * dtf)[:, None]).T @ xf  # (N, P)
    return y, state

"""Wrapper: full chunked SSD built on the intra-chunk Pallas kernel plus the
jnp inter-chunk recurrence — drop-in for models.ssm.ssd_chunked
(codelet-registered)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.api import sp_task
from repro.kernels.dispatch import interpret_mode, pallas_available

from .kernel import ssd_intra_chunk_pallas

available = pallas_available
_interpret = interpret_mode


def ssd_chunked_pallas(xh, dt, A, Bc, Cc, chunk: int, initial_state=None):
    """Same contract as repro.models.ssm.ssd_chunked (xh (B,L,H,P), dt (B,L,H),
    A (H,), Bc/Cc (B,L,H,N))."""
    B_, L, H, P = xh.shape
    N = Bc.shape[-1]
    nc = L // chunk
    lg = dt * A  # (B,L,H)
    r4 = lambda t: t.reshape(B_, nc, chunk, H, -1).transpose(0, 3, 1, 2, 4).reshape(B_ * H, nc, chunk, -1)
    r3 = lambda t: t.reshape(B_, nc, chunk, H).transpose(0, 3, 1, 2).reshape(B_ * H, nc, chunk)
    cum = jnp.cumsum(lg.reshape(B_, nc, chunk, H), axis=2).reshape(B_, L, H)

    y_intra, states = ssd_intra_chunk_pallas(
        r4(xh), r3(dt), r3(cum), r4(Bc), r4(Cc), interpret=_interpret()
    )  # (BH, nc, cs, P), (BH, nc, N, P)

    # inter-chunk recurrence (jnp): S_c = exp(cum_end_c)·S_{c-1} + state_c
    cum_end = r3(cum)[:, :, -1]  # (BH, nc)
    s0 = (
        initial_state.reshape(B_ * H, N, P).astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((B_ * H, N, P), jnp.float32)
    )

    def body(s_prev, inp):
        dec, st = inp
        return s_prev * jnp.exp(dec)[:, None, None] + st, s_prev

    s_final, s_prevs = jax.lax.scan(
        body, s0, (cum_end.swapaxes(0, 1), states.swapaxes(0, 1))
    )
    s_prevs = s_prevs.swapaxes(0, 1)  # (BH, nc, N, P)

    y_inter = jnp.einsum(
        "xcin,xci,xcnp->xcip",
        r4(Cc).astype(jnp.float32),
        jnp.exp(r3(cum)),
        s_prevs,
    )
    y = (y_intra + y_inter).reshape(B_, H, nc, chunk, P).transpose(0, 2, 3, 1, 4)
    y = y.reshape(B_, L, H, P)
    return y, s_final.reshape(B_, H, N, P)


# -- codelet registration (SpCpu/SpCuda selection, paper §4.3) ---------------

@sp_task(read=("xh", "dt", "A", "Bc", "Cc"), write=("out",), name="ssd_chunked")
def ssd_codelet(xh, dt, A, Bc, Cc, out, *, chunk: int, initial_state=None):
    from repro.models.ssm import ssd_chunked

    out.value = ssd_chunked(xh, dt, A, Bc, Cc, chunk, initial_state)


@ssd_codelet.impl("pallas", available=pallas_available)
def _ssd_pallas_impl(xh, dt, A, Bc, Cc, out, *, chunk: int, initial_state=None):
    out.value = ssd_chunked_pallas(xh, dt, A, Bc, Cc, chunk, initial_state)

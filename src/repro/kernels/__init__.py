"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §6).

Specx itself is runtime infrastructure — its "kernels" are whatever the
tasks run.  In this adaptation the perf-critical task bodies are the
attention/SSD/norm inner loops, so each gets a TPU kernel:

* ``flash_attention``  — causal/windowed GQA attention, online softmax,
  (bq × bk) VMEM tiles, scratch-carried stats across the KV grid dim.
* ``decode_attention`` — one-token attention against a long KV cache,
  block-accumulated with masked slots (flash-decoding structure).
* ``ssd``              — Mamba-2 intra-chunk SSD matmuls per (batch, head,
  chunk) tile; the short inter-chunk recurrence stays in jnp.
* ``rmsnorm``          — fused RMS-normalize + scale epilogue.

Every kernel ships ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd wrapper + platform dispatch) and ``ref.py`` (pure-jnp oracle);
tests sweep shapes/dtypes in interpret mode against the oracle.
"""

"""Pure-jnp oracle for decode attention (one token vs KV cache)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(
    q: jax.Array,        # (B, H, Dh)
    k_cache: jax.Array,  # (B, KH, S, Dh)
    v_cache: jax.Array,  # (B, KH, S, Dv)
    pos: jax.Array,      # scalar int32: slots <= pos are valid
) -> jax.Array:
    B, H, Dh = q.shape
    KH, S = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    qg = q.reshape(B, KH, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhsd->bhgs", qg, k_cache.astype(jnp.float32)) / math.sqrt(Dh)
    valid = jnp.arange(S) <= pos
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", w, v_cache.astype(jnp.float32))
    return out.reshape(B, H, v_cache.shape[-1]).astype(q.dtype)

"""Wrapper + dispatch for the decode-attention kernel."""
from __future__ import annotations

import jax

from . import ref
from .kernel import decode_attention_pallas


def available() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def decode_attention(q, k_cache, v_cache, pos, *, block_s: int = 512):
    """q (B, 1, H, Dh) model layout; caches (B, S, KH, D·) model layout."""
    out = decode_attention_pallas(
        q[:, 0],
        k_cache.transpose(0, 2, 1, 3),
        v_cache.transpose(0, 2, 1, 3),
        pos,
        block_s=block_s,
        interpret=_interpret(),
    )
    return out[:, None]


def decode_attention_ref(q, k_cache, v_cache, pos):
    return ref.decode_attention_ref(
        q[:, 0], k_cache.transpose(0, 2, 1, 3), v_cache.transpose(0, 2, 1, 3), pos
    )[:, None]

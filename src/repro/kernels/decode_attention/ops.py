"""Wrapper + dispatch for the decode-attention kernel (codelet-registered)."""
from __future__ import annotations

from repro.core.api import sp_task
from repro.kernels.dispatch import interpret_mode, pallas_available

from . import ref
from .kernel import decode_attention_pallas

available = pallas_available
_interpret = interpret_mode


def decode_attention(q, k_cache, v_cache, pos, *, block_s: int = 512):
    """q (B, 1, H, Dh) model layout; caches (B, S, KH, D·) model layout."""
    out = decode_attention_pallas(
        q[:, 0],
        k_cache.transpose(0, 2, 1, 3),
        v_cache.transpose(0, 2, 1, 3),
        pos,
        block_s=block_s,
        interpret=_interpret(),
    )
    return out[:, None]


def decode_attention_ref(q, k_cache, v_cache, pos):
    return ref.decode_attention_ref(
        q[:, 0], k_cache.transpose(0, 2, 1, 3), v_cache.transpose(0, 2, 1, 3), pos
    )[:, None]


# -- codelet registration (SpCpu/SpCuda selection, paper §4.3) ---------------

@sp_task(read=("q", "k_cache", "v_cache", "pos"), write=("out",), name="decode_attention")
def decode_attention_codelet(q, k_cache, v_cache, pos, out, *, block_s: int = 512):
    del block_s  # tiling hint is meaningful only to the Pallas variant
    out.value = decode_attention_ref(q, k_cache, v_cache, pos)


@decode_attention_codelet.impl("pallas", available=pallas_available)
def _decode_attention_pallas_impl(q, k_cache, v_cache, pos, out, *, block_s: int = 512):
    out.value = decode_attention(q, k_cache, v_cache, pos, block_s=block_s)

"""Decode attention (flash-decoding) — Pallas TPU kernel.

One new token per sequence attends to a long KV cache.  Grid:
(B·KH, n_splits) — the cache is split along the sequence into ``bs``-slot
blocks; each iteration accumulates masked partial (m, l, acc) into VMEM
scratch (the split-K structure of FlashDecoding; on the sequential TPU grid
the combine is the same online-softmax update, and fully-invalid blocks
beyond ``pos`` are skipped with ``pl.when``).

The current position arrives via scalar prefetch (SMEM) so block validity
is known before the tile is touched.

VMEM per program (bs=512, Dh=128, G≤8): k/v tiles 2×512×128×2 = 256 KiB,
scores G×512×4 ≤ 16 KiB, acc G×128×4 = 4 KiB — trivially resident.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None

NEG_INF = -1e30


def _decode_kernel(
    pos_ref,  # scalar prefetch (SMEM): (1,) int32
    q_ref, k_ref, v_ref,
    o_ref,
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    bs: int,
    ns: int,
):
    si = pl.program_id(1)
    pos = pos_ref[0]
    s_start = si * bs

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(s_start <= pos)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (G, Dh)
        k = k_ref[0].astype(jnp.float32)  # (bs, Dh)
        v = v_ref[0]  # (bs, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (G, bs)
        slot = s_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(slot <= pos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * corr + pv

    @pl.when(si == ns - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,        # (B, H, Dh)
    k_cache: jax.Array,  # (B, KH, S, Dh)
    v_cache: jax.Array,  # (B, KH, S, Dv)
    pos: jax.Array,      # scalar int32
    *,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, Dh = q.shape
    KH, S = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    G = H // KH
    bs = min(block_s, S)
    assert S % bs == 0
    ns = S // bs
    scale = 1.0 / math.sqrt(Dh)

    qr = q.reshape(B * KH, G, Dh)
    kr = k_cache.reshape(B * KH, S, Dh)
    vr = v_cache.reshape(B * KH, S, Dv)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    kernel = functools.partial(_decode_kernel, scale=scale, bs=bs, ns=ns)
    scratch_shapes = [
        pltpu.VMEM((G, 1), jnp.float32) if pltpu else jax.ShapeDtypeStruct((G, 1), jnp.float32),
        pltpu.VMEM((G, 1), jnp.float32) if pltpu else jax.ShapeDtypeStruct((G, 1), jnp.float32),
        pltpu.VMEM((G, Dv), jnp.float32) if pltpu else jax.ShapeDtypeStruct((G, Dv), jnp.float32),
    ]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * KH, ns),
        in_specs=[
            pl.BlockSpec((1, G, Dh), lambda bh, si, pos_ref: (bh, 0, 0)),
            pl.BlockSpec((1, bs, Dh), lambda bh, si, pos_ref: (bh, si, 0)),
            pl.BlockSpec((1, bs, Dv), lambda bh, si, pos_ref: (bh, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, Dv), lambda bh, si, pos_ref: (bh, 0, 0)),
        scratch_shapes=scratch_shapes,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * KH, G, Dv), q.dtype),
        interpret=interpret,
    )(pos_arr, qr, kr, vr)
    return out.reshape(B, H, Dv)

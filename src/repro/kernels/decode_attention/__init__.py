from . import ops, ref
from .kernel import decode_attention_pallas

__all__ = ["ops", "ref", "decode_attention_pallas"]

"""Fused RMSNorm — Pallas TPU kernel.

Bandwidth-bound epilogue: one HBM read + one write per element (the
unfused jnp version reads x three times: square-mean, normalize, scale).
Grid: (n_row_blocks,); each program normalizes a (rows_blk, D) tile in VMEM
with fp32 statistics.

VMEM per program (rows=256, D=8192, bf16): 256×8192×2 ×2 (in+out) = 8 MiB.
For D > 8192 use rows=128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * (1.0 + s_ref[...].astype(jnp.float32))
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_pallas(
    x: jax.Array,      # (T, D) rows to normalize
    scale: jax.Array,  # (D,)
    eps: float = 1e-6,
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    T, D = x.shape
    br = min(block_rows, T)
    while T % br:
        br //= 2
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(T // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda r: (r, 0)),
            pl.BlockSpec((D,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((T, D), x.dtype),
        interpret=interpret,
    )(x, scale)

"""Wrapper + dispatch for the fused RMSNorm kernel."""
from __future__ import annotations

import jax

from . import ref
from .kernel import rmsnorm_pallas


def available() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def rmsnorm(x, scale, eps: float = 1e-6):
    """x (..., D) → normalized, any leading dims."""
    lead = x.shape[:-1]
    D = x.shape[-1]
    flat = x.reshape(-1, D)
    out = rmsnorm_pallas(flat, scale, eps, interpret=_interpret())
    return out.reshape(*lead, D)


rmsnorm_ref = ref.rmsnorm_ref

"""Wrapper + dispatch for the fused RMSNorm kernel (codelet-registered)."""
from __future__ import annotations

from repro.core.api import sp_task
from repro.kernels.dispatch import interpret_mode, pallas_available

from . import ref
from .kernel import rmsnorm_pallas

available = pallas_available
_interpret = interpret_mode


def rmsnorm(x, scale, eps: float = 1e-6):
    """x (..., D) → normalized, any leading dims."""
    lead = x.shape[:-1]
    D = x.shape[-1]
    flat = x.reshape(-1, D)
    out = rmsnorm_pallas(flat, scale, eps, interpret=_interpret())
    return out.reshape(*lead, D)


rmsnorm_ref = ref.rmsnorm_ref


# -- codelet registration (SpCpu/SpCuda selection, paper §4.3) ---------------

@sp_task(read=("x", "scale"), write=("out",), name="rmsnorm")
def rmsnorm_codelet(x, scale, out, *, eps: float = 1e-6):
    out.value = rmsnorm_ref(x, scale, eps)


@rmsnorm_codelet.impl("pallas", available=pallas_available)
def _rmsnorm_pallas_impl(x, scale, out, *, eps: float = 1e-6):
    out.value = rmsnorm(x, scale, eps)

from . import ops, ref
from .kernel import rmsnorm_pallas

__all__ = ["ops", "ref", "rmsnorm_pallas"]

from . import ops, ref
from .kernel import flash_attention_pallas

__all__ = ["ops", "ref", "flash_attention_pallas"]

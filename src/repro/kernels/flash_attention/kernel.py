"""Flash attention — Pallas TPU kernel.

Grid: (B·H, nq, nk) with the KV dimension innermost (sequential on TPU);
online-softmax statistics (m, l) and the output accumulator live in VMEM
scratch and persist across the nk iterations of one (head, q-block).

VMEM working set per program (bq=512, bk=512, Dh=128, bf16 in / f32 acc):
    q tile  512×128×2   =  128 KiB
    k tile  512×128×2   =  128 KiB
    v tile  512×128×2   =  128 KiB
    scores  512×512×4   = 1024 KiB
    acc     512×128×4   =  256 KiB
    m, l    2×512×4     =    4 KiB        → ≈ 1.7 MiB  (≪ 16 MiB VMEM)

MXU alignment: all matmul dims are multiples of 128 (bq, bk, Dh).
Fully-masked (q-block, kv-block) pairs are skipped with ``pl.when`` —
the causal structural skip the pure-jnp ``tri`` mode approximates.

GQA: query head h reads KV head h // (H // KH) via the k/v index_maps.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific memory spaces; interpret mode works without them
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,  # VMEM tiles
    o_ref,  # output tile
    m_scr, l_scr, acc_scr,  # scratch
    *,
    causal: bool,
    window: Optional[int],
    scale: float,
    bq: int,
    bk: int,
    nk: int,
    q_offset: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    q_start = q_offset + qi * bq
    k_start = ki * bk

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # structural skip: block fully above the diagonal / outside the window
    live = True
    if causal:
        live = jnp.asarray(k_start <= q_start + bq - 1)
    if window is not None:
        live = jnp.logical_and(
            live, jnp.asarray(k_start + bk - 1 > q_start - window)
        ) if causal else jnp.asarray(True)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, Dh)
        k = k_ref[0].astype(jnp.float32)  # (bk, Dh)
        v = v_ref[0]  # (bk, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * corr + pv

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (B, H, Lq, Dh)
    k: jax.Array,  # (B, KH, Lk, Dh)
    v: jax.Array,  # (B, KH, Lk, Dv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, Lq, Dh = q.shape
    KH, Lk = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // KH
    bq = min(block_q, Lq)
    bk = min(block_kv, Lk)
    assert Lq % bq == 0 and Lk % bk == 0
    nq, nk = Lq // bq, Lk // bk
    scale = 1.0 / math.sqrt(Dh)

    # fold (B, H) into one grid dim; kv head = (bh % H) // G
    qr = q.reshape(B * H, Lq, Dh)
    kr = k.reshape(B * KH, Lk, Dh)
    vr = v.reshape(B * KH, Lk, Dv)

    def q_index(bh, qi, ki):
        return (bh, qi, 0)

    def kv_index(bh, qi, ki):
        return ((bh // H) * KH + (bh % H) // G, ki, 0)

    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        window=window,
        scale=scale,
        bq=bq,
        bk=bk,
        nk=nk,
        q_offset=q_offset,
    )
    scratch = [
        jax.ShapeDtypeStruct((bq, 1), jnp.float32),
        jax.ShapeDtypeStruct((bq, 1), jnp.float32),
        jax.ShapeDtypeStruct((bq, Dv), jnp.float32),
    ]
    if _VMEM is not None and not interpret:
        scratch_shapes = [
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ]
    else:
        scratch_shapes = [
            pltpu.VMEM((bq, 1), jnp.float32) if pltpu else jax.ShapeDtypeStruct((bq, 1), jnp.float32)
            for _ in range(2)
        ] + [
            pltpu.VMEM((bq, Dv), jnp.float32) if pltpu else jax.ShapeDtypeStruct((bq, Dv), jnp.float32)
        ]

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, Dh), q_index),
            pl.BlockSpec((1, bk, Dh), kv_index),
            pl.BlockSpec((1, bk, Dv), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, Dv), q_index),
        out_shape=jax.ShapeDtypeStruct((B * H, Lq, Dv), q.dtype),
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, Lq, Dv)

"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, H, Lq, Dh)
    k: jax.Array,  # (B, KH, Lk, Dh)
    v: jax.Array,  # (B, KH, Lk, Dv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jax.Array:
    B, H, Lq, Dh = q.shape
    KH, Lk = k.shape[1], k.shape[2]
    G = H // KH
    qg = q.reshape(B, KH, G, Lq, Dh).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32)) / math.sqrt(Dh)
    qpos = q_offset + jnp.arange(Lq)
    kpos = jnp.arange(Lk)
    mask = jnp.ones((Lq, Lk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", w, v.astype(jnp.float32))
    return out.reshape(B, H, Lq, v.shape[-1]).astype(q.dtype)

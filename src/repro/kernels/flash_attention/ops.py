"""Jit'd wrapper + platform dispatch for the flash-attention kernel.

Models call :func:`flash_attention` with (B, L, H, Dh)-layout tensors (the
framework layout); this adapter transposes to the kernel's (B, H, L, Dh)
layout, dispatches to Pallas on TPU (interpret mode elsewhere when forced),
and falls back to the pure-jnp reference otherwise.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .kernel import flash_attention_pallas


def available() -> bool:
    if os.environ.get("REPRO_FORCE_PALLAS_INTERPRET"):
        return True
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(
    q: jax.Array,  # (B, L, H, Dh) — model layout
    k: jax.Array,  # (B, L, KH, Dh)
    v: jax.Array,  # (B, L, KH, Dv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jax.Array:
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    out = flash_attention_pallas(
        qt, kt, vt, causal=causal, window=window, q_offset=q_offset,
        interpret=_interpret(),
    )
    return out.swapaxes(1, 2)


def flash_attention_ref(q, k, v, *, causal=True, window=None, q_offset=0):
    return ref.attention_ref(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
        causal=causal, window=window, q_offset=q_offset,
    ).swapaxes(1, 2)

"""Jit'd wrapper + platform dispatch for the flash-attention kernel.

Models call :func:`flash_attention` with (B, L, H, Dh)-layout tensors (the
framework layout); this adapter transposes to the kernel's (B, H, L, Dh)
layout, dispatches to Pallas on TPU (interpret mode elsewhere when forced),
and falls back to the pure-jnp reference otherwise.  The same pair is
registered as :data:`flash_attention_codelet` for task-graph use.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.api import sp_task
from repro.kernels.dispatch import interpret_mode, pallas_available

from . import ref
from .kernel import flash_attention_pallas

available = pallas_available
_interpret = interpret_mode


def flash_attention(
    q: jax.Array,  # (B, L, H, Dh) — model layout
    k: jax.Array,  # (B, L, KH, Dh)
    v: jax.Array,  # (B, L, KH, Dv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jax.Array:
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    out = flash_attention_pallas(
        qt, kt, vt, causal=causal, window=window, q_offset=q_offset,
        interpret=_interpret(),
    )
    return out.swapaxes(1, 2)


def flash_attention_ref(q, k, v, *, causal=True, window=None, q_offset=0):
    return ref.attention_ref(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
        causal=causal, window=window, q_offset=q_offset,
    ).swapaxes(1, 2)


# -- codelet registration (SpCpu/SpCuda selection, paper §4.3) ---------------

@sp_task(read=("q", "k", "v"), write=("out",), name="flash_attention", cost=10.0)
def flash_attention_codelet(q, k, v, out, *, causal=True, window=None, q_offset=0):
    out.value = flash_attention_ref(
        q, k, v, causal=causal, window=window, q_offset=q_offset
    )


@flash_attention_codelet.impl("pallas", available=pallas_available)
def _flash_attention_pallas_impl(q, k, v, out, *, causal=True, window=None, q_offset=0):
    out.value = flash_attention(q, k, v, causal=causal, window=window, q_offset=q_offset)

"""Shared capability probes for the Pallas kernels (one copy, four users).

Every ``kernels/*/ops.py`` used to carry its own ``available()`` /
``_interpret()`` pair — and only flash-attention's honored
``REPRO_FORCE_PALLAS_INTERPRET``.  This module is the single source of
truth; the env var now forces interpret-mode Pallas availability for every
kernel (useful for exercising the Pallas code path on CPU CI).

These are also the ``available=`` predicates the kernel codelets register
with the capability-dispatch frontend (``repro.core.api``).
"""
from __future__ import annotations

import os

import jax


def force_interpret() -> bool:
    """True when REPRO_FORCE_PALLAS_INTERPRET requests interpret-mode Pallas."""
    return bool(os.environ.get("REPRO_FORCE_PALLAS_INTERPRET"))


def pallas_available() -> bool:
    """Can the Pallas implementation run here?  On TPU, natively; elsewhere
    only when interpret mode is forced."""
    return force_interpret() or jax.default_backend() == "tpu"


def interpret_mode() -> bool:
    """Should ``pl.pallas_call`` run in interpret mode (any non-TPU backend)?"""
    return jax.default_backend() != "tpu"

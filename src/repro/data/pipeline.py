"""Deterministic synthetic data pipeline.

Restart-safety is the point: ``batch_for_step(step)`` is a pure function of
``(seed, step)``, so resuming from a checkpoint at step k replays the exact
stream — no data-state checkpointing needed (the data "cursor" *is* the
step counter).  In a multi-host deployment each host computes only its batch
slice (``host_index / host_count``); on this container that collapses to the
full batch.

A background :class:`Prefetcher` thread keeps ``depth`` batches ahead —
the host-side analogue of Specx's communication thread overlapping the
workers (DESIGN.md §2): data production is a task off the critical path.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np

from repro.models.config import ArchConfig, ShapeSpec


def batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """numpy dtypes/shapes of one global batch (mirrors models.input_defs)."""
    B, L = shape.global_batch, shape.seq_len
    if cfg.frontend == "audio":
        return {
            "embeds": ((B, L, 512), np.float32),
            "mask": ((B, L), np.bool_),
            "labels": ((B, L), np.int32),
        }
    if cfg.frontend == "vision":
        lt = L - cfg.n_patches
        return {
            "tokens": ((B, lt), np.int32),
            "patch_embeds": ((B, cfg.n_patches, 1024), np.float32),
            "labels": ((B, lt), np.int32),
        }
    return {"tokens": ((B, L), np.int32), "labels": ((B, L), np.int32)}


class SyntheticLMDataset:
    """Markov-ish synthetic token stream with learnable structure (so a ~100M
    model's loss visibly decreases within a few hundred steps)."""

    def __init__(
        self,
        cfg: ArchConfig,
        shape: ShapeSpec,
        seed: int = 0,
        host_index: int = 0,
        host_count: int = 1,
    ):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.host_index = host_index
        self.host_count = host_count
        assert shape.global_batch % host_count == 0
        self.local_batch = shape.global_batch // host_count

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_index])
        )

    def batch_for_step(self, step: int) -> dict:
        cfg, shape = self.cfg, self.shape
        rng = self._rng(step)
        B, L = self.local_batch, shape.seq_len
        if cfg.frontend == "audio":
            emb = rng.standard_normal((B, L, 512), dtype=np.float32)
            mask = rng.random((B, L)) < 0.08
            labels = rng.integers(0, cfg.vocab, (B, L), dtype=np.int32)
            return {"embeds": emb, "mask": mask, "labels": labels}
        lt = L - cfg.n_patches if cfg.frontend == "vision" else L
        # structured stream: x_{t+1} = (a·x_t + b) mod V.  The rule (a, b) is
        # fixed per dataset seed (a learnable "language"); only x0 varies per
        # step, so a ~100M model's loss drops fast (examples/train_lm.py).
        V = cfg.vocab
        rule = np.random.default_rng(np.random.SeedSequence([self.seed, 0xA11CE]))
        a = rule.integers(1, 8, (1, 1)).repeat(B, 0)
        b = rule.integers(0, V, (1, 1)).repeat(B, 0)
        x0 = rng.integers(0, V, (B, 1))
        toks = np.empty((B, lt + 1), dtype=np.int64)
        toks[:, :1] = x0
        for t in range(lt):
            toks[:, t + 1] = (a[:, 0] * toks[:, t] + b[:, 0]) % V
        batch = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        if cfg.frontend == "vision":
            batch["patch_embeds"] = rng.standard_normal(
                (B, cfg.n_patches, 1024), dtype=np.float32
            )
        return batch

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_for_step(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of ``dataset.batch_for_step`` results."""

    def __init__(self, dataset: SyntheticLMDataset, start_step: int = 0, depth: int = 2):
        self.dataset = dataset
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._next = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        step = self._next
        while not self._stop.is_set():
            batch = self.dataset.batch_for_step(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self) -> tuple[int, dict]:
        return self._q.get()

    def stop(self) -> None:
        self._stop.set()
        self._thread.join(timeout=2.0)

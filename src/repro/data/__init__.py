from .pipeline import Prefetcher, SyntheticLMDataset, batch_specs

__all__ = ["Prefetcher", "SyntheticLMDataset", "batch_specs"]

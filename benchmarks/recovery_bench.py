"""Failure detection + recovery benchmark → ``BENCH_recovery.json``.

Two measurements (ISSUE 6 acceptance):

* **detection** — :func:`repro.launch.rendezvous.run_elastic_ring` spawns
  real OS rank processes, SIGKILLs one mid-``ring_all_reduce``, and each
  survivor reports ``transport.death_detected_at(victim)``; detection
  latency is that stamp minus the parent's kill time (CLOCK_MONOTONIC is
  machine-wide on Linux).  The re-roll wall time (dead-set agreement +
  group shrink) rides along as ``reroll_s``.

* **recovery** — ``launch/train.py --fail-at`` run twice in a subprocess
  with 8 virtual host devices (``--xla_force_host_platform_device_count``),
  once per ``--recovery`` mode: ``live`` (``jax.device_put`` the surviving
  in-memory state onto the shrunken mesh — no replay, no disk) vs
  ``restore`` (full checkpoint restore + replay).  The per-recovery wall
  times come from the launcher's own ``--bench-out`` JSON.

Numbers land in ROADMAP.md's "Live elasticity" item.  Run:

    PYTHONPATH=src python benchmarks/recovery_bench.py
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import textwrap

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_recovery.json")

TRAIN_SCRIPT = textwrap.dedent(
    """
    import os, sys, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    from repro.launch.train import main
    out = main([
        "--arch", "deepseek-7b", "--reduced", "--steps", "6",
        "--batch", "4", "--seq", "32", "--microbatches", "2",
        "--fail-at", "3:4", "--ckpt-dir", sys.argv[1], "--ckpt-every", "1",
        "--recovery", sys.argv[2], "--bench-out", sys.argv[3],
        "--log-every", "0",
    ])
    assert out["final_step"] == 6, out
    assert out["recoveries"], "no recovery happened"
    """
)


def measure_detection(reps: int = 3) -> dict:
    from repro.launch.rendezvous import run_elastic_ring

    detect, reroll = [], []
    for _ in range(reps):
        results, info = run_elastic_ring(size=3, n=257, steps=4, fail_at=2)
        for rank, rep in results.items():
            detect.append(rep["detect_at"] - info["t_kill"])
            reroll.append(rep["reroll_s"])
    return {
        "ranks": 3,
        "reps": reps,
        "detect_latency_s": {"min": min(detect), "max": max(detect)},
        "reroll_s": {"min": min(reroll), "max": max(reroll)},
    }


def measure_recovery() -> dict:
    root = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out: dict = {}
    for mode in ("live", "restore"):
        with tempfile.TemporaryDirectory() as ckdir:
            bench = os.path.join(ckdir, "bench.json")
            r = subprocess.run(
                [sys.executable, "-c", TRAIN_SCRIPT, ckdir, mode, bench],
                env=env, capture_output=True, text=True, timeout=900, cwd=root,
            )
            if r.returncode != 0:
                raise RuntimeError(
                    f"{mode} run failed:\n{r.stdout[-2000:]}\n{r.stderr[-2000:]}"
                )
            with open(bench) as f:
                rec = json.load(f)["recoveries"]
            out[mode] = rec[0]
    return out


def main() -> None:
    report = {
        "detection": measure_detection(),
        "recovery": measure_recovery(),
    }
    with open(OUT, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(json.dumps(report, indent=2))
    print(f"wrote {os.path.abspath(OUT)}")


if __name__ == "__main__":
    main()

"""Failure detection + recovery benchmark → ``BENCH_recovery.json``.

Four measurements (ISSUE 6 + ISSUE 8 acceptance):

* **detection** — :func:`repro.launch.rendezvous.run_elastic_ring` spawns
  real OS rank processes, SIGKILLs one mid-``ring_all_reduce``, and each
  survivor reports ``transport.death_detected_at(victim)``; detection
  latency is that stamp minus the parent's kill time (CLOCK_MONOTONIC is
  machine-wide on Linux).  The re-roll wall time (dead-set agreement +
  group shrink) rides along as ``reroll_s``.

* **recovery** — ``launch/train.py --fail-at`` run twice in a subprocess
  with 8 virtual host devices (``--xla_force_host_platform_device_count``),
  once per ``--recovery`` mode: ``live`` (``jax.device_put`` the surviving
  in-memory state onto the shrunken mesh — no replay, no disk) vs
  ``restore`` (full checkpoint restore + replay).  The per-recovery wall
  times come from the launcher's own ``--bench-out`` JSON.

* **big_state** (ISSUE 8) — the same live-reshard vs save+restore
  comparison at serious state size: a ≥64 MiB sharded param pytree is
  moved onto a shrunken mesh by ``jax.device_put`` (live) and by a full
  checkpoint round-trip (durable write + restore onto the new
  shardings), in a subprocess with 8 virtual host devices.

* **watchdog** (ISSUE 8) — task-hang detection latency: a task with an
  ``sp_task(timeout=...)`` policy blocks forever; the engine watchdog
  must fail it with ``SpTaskTimeoutError``.  Reported as the overshoot
  past the configured timeout (the watchdog sweeps every ≤50 ms).

Numbers land in ROADMAP.md's "Live elasticity" item.  Run:

    PYTHONPATH=src python benchmarks/recovery_bench.py
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import textwrap
import threading
import time

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_recovery.json")

TRAIN_SCRIPT = textwrap.dedent(
    """
    import os, sys, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    from repro.launch.train import main
    out = main([
        "--arch", "deepseek-7b", "--reduced", "--steps", "6",
        "--batch", "4", "--seq", "32", "--microbatches", "2",
        "--fail-at", "3:4", "--ckpt-dir", sys.argv[1], "--ckpt-every", "1",
        "--recovery", sys.argv[2], "--bench-out", sys.argv[3],
        "--log-every", "0",
    ])
    assert out["final_step"] == 6, out
    assert out["recoveries"], "no recovery happened"
    """
)


def measure_detection(reps: int = 3) -> dict:
    from repro.launch.rendezvous import run_elastic_ring

    detect, reroll = [], []
    for _ in range(reps):
        results, info = run_elastic_ring(size=3, n=257, steps=4, fail_at=2)
        for rank, rep in results.items():
            detect.append(rep["detect_at"] - info["t_kill"])
            reroll.append(rep["reroll_s"])
    return {
        "ranks": 3,
        "reps": reps,
        "detect_latency_s": {"min": min(detect), "max": max(detect)},
        "reroll_s": {"min": min(reroll), "max": max(reroll)},
    }


def measure_recovery() -> dict:
    root = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out: dict = {}
    for mode in ("live", "restore"):
        with tempfile.TemporaryDirectory() as ckdir:
            bench = os.path.join(ckdir, "bench.json")
            r = subprocess.run(
                [sys.executable, "-c", TRAIN_SCRIPT, ckdir, mode, bench],
                env=env, capture_output=True, text=True, timeout=900, cwd=root,
            )
            if r.returncode != 0:
                raise RuntimeError(
                    f"{mode} run failed:\n{r.stdout[-2000:]}\n{r.stderr[-2000:]}"
                )
            with open(bench) as f:
                rec = json.load(f)["recoveries"]
            out[mode] = rec[0]
    return out


BIG_STATE_SCRIPT = textwrap.dedent(
    """
    import os, sys, json, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.checkpoint import CheckpointManager
    from repro.dist.fault import remesh_plan

    mib = int(sys.argv[2])
    # a pytree of float32 shards totalling >= mib MiB, sharded over 'data'
    n_arrays = 8
    rows = (mib * (1 << 20)) // (4 * 1024 * n_arrays)
    def mesh_for(plan):
        devs = np.array(jax.devices()[: plan.n_chips]).reshape(plan.shape)
        return jax.sharding.Mesh(devs, plan.axes)
    def shardings(mesh):
        spec = jax.sharding.PartitionSpec("data", None)
        return {f"w{i}": jax.sharding.NamedSharding(mesh, spec)
                for i in range(n_arrays)}
    full = mesh_for(remesh_plan(8, 0, model_parallel=2))
    keys = jax.random.split(jax.random.PRNGKey(0), n_arrays)
    state = {
        f"w{i}": jax.device_put(
            jax.random.normal(keys[i], (rows, 1024), jnp.float32),
            shardings(full)[f"w{i}"],
        )
        for i in range(n_arrays)
    }
    jax.block_until_ready(state)
    nbytes = sum(x.nbytes for x in state.values())

    # half the chips die; live-reshard onto the shrunken mesh
    shrunk = mesh_for(remesh_plan(8, 4, model_parallel=2))
    t0 = time.perf_counter()
    live = jax.device_put(state, shardings(shrunk))
    jax.block_until_ready(live)
    live_s = time.perf_counter() - t0

    # the checkpoint path: durable write (blocking), restore onto the
    # NEW shardings (template carries them), replay excluded
    mgr = CheckpointManager(sys.argv[1], keep=1)
    t0 = time.perf_counter()
    mgr.save(1, state, block=True)
    save_s = time.perf_counter() - t0
    template = {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=shardings(shrunk)[k])
        for k, v in state.items()
    }
    t0 = time.perf_counter()
    _, restored = mgr.restore(template)
    jax.block_until_ready(restored)
    restore_s = time.perf_counter() - t0
    print(json.dumps({
        "state_mib": nbytes / (1 << 20),
        "live_reshard_s": live_s,
        "ckpt_save_s": save_s,
        "ckpt_restore_s": restore_s,
    }))
    """
)


def measure_big_state(mib: int = 64) -> dict:
    root = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    with tempfile.TemporaryDirectory() as ckdir:
        r = subprocess.run(
            [sys.executable, "-c", BIG_STATE_SCRIPT, ckdir, str(mib)],
            env=env, capture_output=True, text=True, timeout=900, cwd=root,
        )
    if r.returncode != 0:
        raise RuntimeError(
            f"big-state run failed:\n{r.stdout[-2000:]}\n{r.stderr[-2000:]}"
        )
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["state_mib"] >= mib, out
    return out


def measure_watchdog(reps: int = 5, timeout_s: float = 0.2) -> dict:
    """Hang a policied task; measure how far past its configured timeout
    the watchdog's SpTaskTimeoutError lands."""
    from repro.core import SpData, SpRuntime, SpTaskTimeoutError, sp_task

    @sp_task(read=("x",), timeout=timeout_s, on_failure="quarantine",
             name="bench.hang")
    def hang(x, *, release):
        release.wait(30.0)

    overshoot = []
    with SpRuntime(workers=2) as rt:
        for i in range(reps):
            release = threading.Event()
            t0 = time.perf_counter()
            view = hang(SpData(i, f"hang{i}"), release=release)
            try:
                view.result(timeout=10.0)
            except SpTaskTimeoutError:
                pass
            overshoot.append((time.perf_counter() - t0) - timeout_s)
            release.set()  # unblock the zombie body
    return {
        "reps": reps,
        "configured_timeout_s": timeout_s,
        "detect_overshoot_s": {"min": min(overshoot), "max": max(overshoot)},
    }


def main() -> None:
    report = {
        "detection": measure_detection(),
        "recovery": measure_recovery(),
        "big_state": measure_big_state(),
        "watchdog": measure_watchdog(),
    }
    with open(OUT, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(json.dumps(report, indent=2))
    print(f"wrote {os.path.abspath(OUT)}")


if __name__ == "__main__":
    main()

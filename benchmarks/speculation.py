"""Speculative-execution benchmark (paper §3.2 / Bramas'19 Monte-Carlo).

A rejection-heavy MC chain: each iteration is an uncertain *update* task
(``SpMaybeWrite`` on the state — it only writes when the proposal is
accepted) followed by a heavy *evaluation* task reading the state.  With
speculation the evaluation overlaps the update and is rolled back only on
acceptance, so wall time approaches max(D_u, D_e) per step instead of
D_u + D_e.  Reported: wall time and speedup vs the NO_SPEC graph across
acceptance probabilities.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (
    SpComputeEngine,
    SpData,
    SpMaybeWrite,
    SpRead,
    SpSpeculativeModel,
    SpTaskGraph,
    SpWorkerTeamBuilder,
    SpWrite,
)


def _busy(d: float) -> None:
    # paper protocol: the body waits; sleep so worker threads overlap on 1 core
    time.sleep(d)


def run_chain(
    spec: bool, accept_p: float, steps: int = 20, d_update: float = 4e-3,
    d_eval: float = 4e-3, seed: int = 0,
) -> dict:
    rng = np.random.default_rng(seed)
    accepts = rng.random(steps) < accept_p
    model = SpSpeculativeModel.SP_MODEL_1 if spec else SpSpeculativeModel.SP_NO_SPEC
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(4))
    try:
        tg = SpTaskGraph(model)
        state = SpData(0.0, "state")
        energy = SpData(0.0, "energy")
        t0 = time.perf_counter()
        for i in range(steps):
            def update(s_ref, _i=i):
                _busy(d_update)
                if accepts[_i]:
                    s_ref.value = s_ref.value + 1.0  # accepted → writes

            def evaluate(s_val, e_ref):
                _busy(d_eval)
                e_ref.value = s_val * 2.0

            tg.task(SpMaybeWrite(state), update, name=f"mc{i}")
            tg.task(SpRead(state), SpWrite(energy), evaluate, name=f"eval{i}")
        tg.compute_on(eng)
        tg.wait_all_tasks()
        wall = time.perf_counter() - t0
        return {
            "spec": spec,
            "accept_p": accept_p,
            "steps": steps,
            "wall_s": wall,
            "state": state.value,
            "energy": energy.value,
            "stats": dict(tg.spec_stats),
        }
    finally:
        eng.stop()


def main() -> list[dict]:
    rows = []
    print("accept_p,nospec_s,spec_s,speedup,commits,rollbacks,state_ok")
    for p in (0.0, 0.25, 0.5, 1.0):
        base = run_chain(False, p)
        sp = run_chain(True, p)
        ok = base["state"] == sp["state"] and base["energy"] == sp["energy"]
        rows.append({"accept_p": p, "base": base, "spec": sp, "ok": ok})
        print(
            f"{p},{base['wall_s']:.3f},{sp['wall_s']:.3f},"
            f"{base['wall_s'] / sp['wall_s']:.2f},"
            f"{sp['stats']['commits']},{sp['stats']['rollbacks']},{ok}"
        )
        assert ok, "speculative result must equal sequential result"
    return rows


if __name__ == "__main__":
    main()

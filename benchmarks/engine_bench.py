"""Engine hot-path benchmark → ``BENCH_engine.json`` (perf trajectory).

Two workloads, three scheduling policies (FIFO, plain work stealing,
locality-aware work stealing), several team sizes:

* **dispatch** — chains of empty-body tasks.  Nothing to compute, so the
  wall clock *is* the runtime: ``us_per_task`` here is the per-task
  dispatch overhead (insert → ready → pop → execute → release).  This is
  the number the CI smoke job gates on (>2× regression fails).  Measured
  through **three frontends**: the positional ``tg.task(...)`` spelling
  (``frontend="task"``), the codelet ``@sp_task`` spelling
  (``frontend="codelet"``) which additionally allocates the hidden result
  cell + WRITE access behind ``TaskView.then``, and the fire-and-forget
  codelet call (``frontend="codelet_noresult"``, ``result=False``) which
  skips that cell — the ROADMAP's "codelet-path dispatch cost" is the
  task↔codelet delta, and the noresult row shows how much of it the
  ISSUE 10 opt-out claws back.
* **scaling** — the ``engine_scaling.py`` protocol with data dependencies:
  ``n_chains = 2 × n_workers`` independent chains whose task bodies sleep a
  fixed duration (sleeps release the GIL, so worker threads genuinely
  overlap on small containers).  Chained writes give the locality push its
  signal: each task's input was produced by the worker that ran its
  predecessor.

Results are best-of-``reps`` per configuration — the engine runs on shared
noisy containers and we track the achievable envelope, not the draw of the
load average.  Work-stealing rows also record the scheduler's push/pop/steal
counters (``WorkStealingScheduler.stats()``), so hit rates are part of the
trajectory.
"""
from __future__ import annotations

import json
import os
import time

from repro.core import (
    FifoScheduler,
    SpComputeEngine,
    SpData,
    SpTaskGraph,
    SpWorkerTeamBuilder,
    SpWrite,
    WorkStealingScheduler,
    sp_task,
)

SCHEDULER_FACTORIES = {
    "fifo": lambda: FifoScheduler(),
    "work_stealing": lambda: WorkStealingScheduler(locality=False),
    "locality_work_stealing": lambda: WorkStealingScheduler(locality=True),
}


@sp_task(write=("cell",), name="bench.codelet")
def _codelet_step(cell, *, duration=0.0):
    if duration > 0:
        time.sleep(duration)


def run_chains(
    scheduler_name: str,
    n_workers: int,
    n_chains: int,
    chain_len: int,
    duration: float = 0.0,
    frontend: str = "task",
) -> dict:
    """One measured run: ``n_chains`` independent write-chains of
    ``chain_len`` tasks each, bodies sleeping ``duration`` seconds
    (0 = empty body, pure dispatch).  ``frontend`` selects the insertion
    spelling: positional ``tg.task(...)`` or the ``@sp_task`` codelet.
    Production settings: ``trace=False`` so the run allocates no per-task
    trace events."""
    sched = SCHEDULER_FACTORIES[scheduler_name]()
    eng = SpComputeEngine(
        SpWorkerTeamBuilder.team_of_cpu_workers(n_workers), scheduler=sched
    )
    try:
        tg = SpTaskGraph(trace=False)
        cells = [SpData(0, f"c{i}") for i in range(n_chains)]
        tg.compute_on(eng)
        t0 = time.perf_counter()
        if frontend == "codelet":
            # duration=0 calls omit the static kwarg so the dispatch row
            # measures the bare codelet path (no functools.partial layer)
            if duration > 0:
                for _step in range(chain_len):
                    for c in range(n_chains):
                        _codelet_step(cells[c], duration=duration, graph=tg)
            else:
                for _step in range(chain_len):
                    for c in range(n_chains):
                        _codelet_step(cells[c], graph=tg)
        elif frontend == "codelet_noresult":
            for _step in range(chain_len):
                for c in range(n_chains):
                    _codelet_step(cells[c], graph=tg, result=False)
        else:
            body = (lambda ref: time.sleep(duration)) if duration > 0 else (lambda ref: None)
            for _step in range(chain_len):
                for c in range(n_chains):
                    tg.task(SpWrite(cells[c]), body)
        tg.wait_all_tasks()
        wall = time.perf_counter() - t0
        n_tasks = n_chains * chain_len
        row = {
            "scheduler": scheduler_name,
            "n_workers": n_workers,
            "frontend": frontend,
            "n_tasks": n_tasks,
            "task_duration_s": duration,
            "wall_s": wall,
            "tasks_per_s": n_tasks / wall,
            "us_per_task": wall / n_tasks * 1e6,
        }
        stats = getattr(sched, "stats", None)
        if stats is not None:
            s = stats()
            row["stats"] = {
                k: round(v, 4) if isinstance(v, float) else v for k, v in s.items()
            }
        return row
    finally:
        eng.stop()


def _measure_interleaved(configs: list[tuple], reps: int) -> list[dict]:
    """Best-of-``reps`` per config, with configs *interleaved* across reps:
    shared-container load drifts on the scale of seconds, so measuring all
    of scheduler A then all of scheduler B would bias the comparison —
    round-robin keeps every config exposed to the same drift."""
    best: dict[int, dict] = {}
    for _rep in range(reps):
        for i, args in enumerate(configs):
            r = run_chains(*args)
            if i not in best or r["tasks_per_s"] > best[i]["tasks_per_s"]:
                best[i] = r
    return [best[i] for i in range(len(configs))]


def run_suite(smoke: bool = False) -> dict:
    reps = 2 if smoke else 5
    chain_len = 100 if smoke else 400
    scale_len = 40 if smoke else 120
    scale_workers = (2, 4) if smoke else (2, 4, 8)
    dispatch = _measure_interleaved(
        [
            (name, w, 2 * w, chain_len, 0.0, fe)
            for fe in ("task", "codelet", "codelet_noresult")
            for name in SCHEDULER_FACTORIES
            for w in (1, 4)
        ],
        reps,
    )
    scaling = _measure_interleaved(
        [
            (name, w, 2 * w, scale_len, 2e-4)
            for name in SCHEDULER_FACTORIES
            for w in scale_workers
        ],
        reps,
    )
    return {
        "meta": {
            "smoke": smoke,
            "cpus": os.cpu_count(),
            "reps": reps,
            "schedulers": list(SCHEDULER_FACTORIES),
            "workload": "independent write-chains (2x workers), empty-body for "
            "dispatch overhead (tg.task and @sp_task frontends), 0.2 ms sleep "
            "bodies for scaling",
        },
        "dispatch": dispatch,
        "scaling": scaling,
    }


def compare_against_baseline(current: dict, baseline: dict, factor: float = 2.0) -> list[str]:
    """Regression check for CI: per-task dispatch overhead must stay within
    ``factor`` × the checked-in baseline for every matching configuration.
    Returns a list of human-readable failures (empty = pass)."""
    base_by_key = {
        (r["scheduler"], r["n_workers"], r.get("frontend", "task")): r
        for r in baseline.get("dispatch", ())
    }
    failures = []
    for row in current.get("dispatch", ()):
        key = (row["scheduler"], row["n_workers"], row.get("frontend", "task"))
        base = base_by_key.get(key)
        if base is None:
            continue
        if row["us_per_task"] > factor * base["us_per_task"]:
            failures.append(
                f"dispatch overhead regression: {row['scheduler']} "
                f"@{row['n_workers']}w ({key[2]} frontend) "
                f"{row['us_per_task']:.1f} us/task vs baseline "
                f"{base['us_per_task']:.1f} us/task (>{factor:.1f}x)"
            )
    return failures


def main(out: str = "BENCH_engine.json", smoke: bool = False) -> dict:
    payload = run_suite(smoke=smoke)
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print("workload,scheduler,n_workers,frontend,tasks_per_s,us_per_task")
    for section in ("dispatch", "scaling"):
        for r in payload[section]:
            print(
                f"{section},{r['scheduler']},{r['n_workers']},"
                f"{r.get('frontend', 'task')},"
                f"{r['tasks_per_s']:.0f},{r['us_per_task']:.2f}"
            )
    return payload


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv)

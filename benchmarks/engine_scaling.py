"""Worker-team scaling: N independent tasks of duration D over 1..8 workers
(paper §4.2 teams; also exercises dynamic worker moves mid-run)."""
from __future__ import annotations

import time

from repro.core import SpComputeEngine, SpData, SpRead, SpTaskGraph, SpWorkerTeamBuilder


def _busy(d: float) -> None:
    # paper protocol: the body waits; sleep so worker threads overlap on 1 core
    time.sleep(d)


def run(n_workers: int, n_tasks: int = 64, d: float = 2e-3) -> float:
    eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(n_workers))
    try:
        tg = SpTaskGraph()
        x = SpData(1.0, "x")
        t0 = time.perf_counter()
        for i in range(n_tasks):
            tg.task(SpRead(x), lambda v: _busy(d), name=f"t{i}")
        tg.compute_on(eng)
        tg.wait_all_tasks()
        return time.perf_counter() - t0
    finally:
        eng.stop()


def main() -> list[dict]:
    rows = []
    base = None
    print("n_workers,wall_s,speedup,efficiency")
    for w in (1, 2, 4, 8):
        wall = run(w)
        base = base or wall
        rows.append({"n_workers": w, "wall_s": wall, "speedup": base / wall})
        print(f"{w},{wall:.3f},{base / wall:.2f},{base / wall / w:.2f}")
    return rows


if __name__ == "__main__":
    main()

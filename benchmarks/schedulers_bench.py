"""Scheduler-impact benchmarks — the paper's §4.5 value proposition
measured twice:

1. **Staged backend**: linearization policy changes the compiled program
   order — the ``overlap`` policy hoists the gradient-reduction comm task
   ahead of independent microbatch tasks (earlier issue → more overlap room
   for XLA's async scheduler).  Metric: normalized schedule position of the
   comm task.

2. **Eager backend**: 1F1B-priority pipeline schedule vs FIFO fill-drain on
   the same task graph, 2 workers × (4 stages × 6 microbatches).  Metric:
   worker utilization from ``trace_metrics`` (bubble fraction).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import SpComputeEngine, SpWorkerTeamBuilder, trace_metrics
from repro.configs import reduced_config
from repro.data import SyntheticLMDataset
from repro.models.config import ShapeSpec
from repro.runtime.pipeline import pipeline_value_and_grad
from repro.runtime.train import build_train_step, init_train_state


def staged_overlap() -> dict:
    """Per-microbatch reduction graph: mb_i's grads reduce as soon as mb_i
    finishes (independent of mb_j) — the structure where linearization
    policy matters.  NB: the production train step accumulates into ONE
    commutative cell, which structurally serializes its single reduction
    behind all microbatches (measured: comm position identical across
    policies) — that finding motivated this per-microbatch variant.
    """
    from repro.core import SpData, SpRead, SpTaskGraph, SpWrite, linearize

    out = {}
    for policy in ("fifo", "overlap"):
        tg = SpTaskGraph()
        # naive program order: all compute first, then all reductions —
        # exactly what a straightforward trainer emits
        gs = [SpData(None, f"g{i}") for i in range(4)]
        rs = [SpData(None, f"r{i}") for i in range(4)]
        for i in range(4):
            tg.task(SpWrite(gs[i]), lambda ref: None, name=f"mb{i}", cost=10.0)
        for i in range(4):
            tg.task(SpRead(gs[i]), SpWrite(rs[i]), lambda v, ref: None,
                    name=f"reduce{i}", comm=True, cost=3.0)
        tg.task(*[SpRead(r) for r in rs], lambda *v: None, name="optimizer")
        order = [t.name for t in linearize(tg, policy)]
        pos = [i for i, n in enumerate(order) if n.startswith("reduce")]
        out[policy] = {
            "schedule": order,
            "mean_comm_pos": sum(pos) / len(pos) / (len(order) - 1),
        }
    return out


def pipeline_schedules() -> dict:
    import numpy as np

    depth, M, B, width = 4, 6, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(0), depth + 2)
    stage_params = [{"w": jax.random.normal(ks[i], (width, width)) * 0.3} for i in range(depth)]
    head_params = {"w": jax.random.normal(ks[-2], (width, 1)) * 0.3}
    xs = jax.random.normal(ks[-1], (M, B, width))
    mbs = [{"x": xs[m], "y": jnp.sin(xs[m].sum(-1, keepdims=True))} for m in range(M)]

    import time

    def stage_fn(p, x):
        # fixed-duration stage work (sleep releases the GIL → the 2 worker
        # threads genuinely overlap on this 1-core container; the math
        # keeps gradients meaningful)
        time.sleep(0.004)
        return jnp.tanh(x @ p["w"])

    def head_fn(p, x, mb):
        return jnp.mean((x @ p["w"] - mb["y"]) ** 2)

    # warm the jit caches so the first-measured schedule pays no compiles
    _ = pipeline_value_and_grad(
        [stage_fn] * depth, head_fn, stage_params, head_params, mbs,
        SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(2)), schedule="fifo",
    )
    out = {}
    for schedule in ("fifo", "1f1b"):
        eng = SpComputeEngine(SpWorkerTeamBuilder.team_of_cpu_workers(2))
        try:
            loss, _, _, tg = pipeline_value_and_grad(
                [stage_fn] * depth, head_fn, stage_params, head_params, mbs, eng,
                schedule=schedule,
            )
            m = trace_metrics(tg)
            # activation residency: F[s,m]'s output lives until B[s,m] runs.
            # 1F1B's raison d'être is bounding in-flight microbatches — the
            # wall-clock integral of live activations measures exactly that.
            ev = {e["task"]: e for e in tg.trace_events}
            residency = 0.0
            for s_i in range(depth):
                for m_i in range(M):
                    f = ev.get(f"F[{s_i},{m_i}]")
                    b = ev.get(f"B[{s_i},{m_i}]")
                    if f and b:
                        residency += max(b["t1"] - f["t0"], 0.0)
            out[schedule] = {
                "loss": float(loss),
                "utilization": m["utilization"],
                "span_ms": m["span_s"] * 1e3,
                "residency_ms": residency * 1e3,
            }
        finally:
            eng.stop()
    assert abs(out["fifo"]["loss"] - out["1f1b"]["loss"]) < 1e-5
    return out


def main() -> None:
    so = staged_overlap()
    print("staged mean comm position (0=first): "
          f"fifo={so['fifo']['mean_comm_pos']:.2f} overlap={so['overlap']['mean_comm_pos']:.2f}")
    ps = pipeline_schedules()
    print(
        "pipeline 2 workers: "
        f"fifo util={ps['fifo']['utilization']:.2f} ({ps['fifo']['span_ms']:.0f}ms, "
        f"act-residency {ps['fifo']['residency_ms']:.0f}ms)  "
        f"1f1b util={ps['1f1b']['utilization']:.2f} ({ps['1f1b']['span_ms']:.0f}ms, "
        f"act-residency {ps['1f1b']['residency_ms']:.0f}ms)"
    )


if __name__ == "__main__":
    main()

"""Serving benchmark → ``BENCH_serving.json`` (continuous batching vs the
drain-barrier baseline).

One seeded Poisson workload (``repro.serving.loadgen``) is replayed through
two fresh, identically-built engines:

* ``continuous`` — requests join the decode batch the moment they arrive
  (the persistent-task-graph scheduler this PR introduces);
* ``drain`` — the removed policy (static batching): up to ``n_slots``
  arrived requests form a generation once the engine is idle, and that
  batch runs to completion before the next is admitted.

Reported per mode: offered-load-normalized throughput (tokens/s), p50/p99
time-to-first-token, p50/p99 inter-token latency.  The CI smoke gate
(:func:`compare_against_baseline`) fails on a >``factor``× tokens/s drop of
the *continuous* row vs the checked-in ``BENCH_serving.json``; the
continuous-beats-drain comparison is recorded in the payload so the
trajectory is auditable, but is not gated in smoke (container noise).

Engine geometry uses ``block_size=4`` with prompt lengths ≡ 1 (mod 4) so a
duplicated prompt's first ``len-1`` tokens are block-aligned — the paged
pool can serve repeat prompts from saved KV rows (restore) instead of
re-running prefill, which is part of what the benchmark measures.

A second section (``spec_decode``, ISSUE 9) measures speculative decoding:
a small dense target and a separately *fitted* 1-layer draft (truncations
of random weights accept ~nothing; a trained draft is what the technique
assumes) serve the same decode-heavy workload twice — plain vs speculative
— through identically-built engines.  Reported: accept rate, committed
(accepted) tokens per engine round, tokens/s both ways, and their ratio as
``decode_speedup``.  The gate compares the speedup *ratio* against the
checked-in baseline rather than raw tokens/s, so it is robust to container
speed differences; committed output is asserted bit-identical between the
two runs on every benchmark execution.
"""
from __future__ import annotations

import json

PROMPT_LENS = (5, 9, 13, 17)
SPEC_PROMPT_LENS = (13, 17)


def _build_engine():
    from repro.configs import reduced_config
    from repro.models import init_params
    from repro.serving import ServeEngine

    import jax

    cfg = reduced_config("deepseek-7b").replace(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return ServeEngine(
        cfg,
        params,
        n_slots=6,
        max_seq=112,
        block_size=4,
        max_queue=64,
    )


def _fit(cfg, steps: int, seed: int = 0):
    """Quick-fit ``cfg`` on the synthetic affine rule; returns params."""
    import jax
    import jax.numpy as jnp

    from repro.data import SyntheticLMDataset
    from repro.models.config import ShapeSpec
    from repro.runtime.train import build_train_step, init_train_state

    ds = SyntheticLMDataset(cfg, ShapeSpec("t", "train", 48, 8), seed=seed)
    state = init_train_state(jax.random.PRNGKey(seed), cfg)
    step = build_train_step(
        cfg, lr_schedule=lambda s: jnp.float32(3e-3), donate=False
    )
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_for_step(i).items()}
        state, _ = step(state, batch)
    return state.params


def run_spec_suite(smoke: bool = False) -> dict:
    """Speculative-vs-plain decode on a fitted target + fitted 1-layer draft.

    Measures the decode steady state: each engine first drains a full-length
    warmup wave (compiles + draft cache priming), then a second wave of
    slot-count requests is timed end-to-end.  Admission/latency behavior is
    the *other* section's job (``run_suite``); this row isolates tokens/s of
    the decode loop itself, which is what speculation changes.
    """
    import hashlib
    import time

    import numpy as np

    from repro.models.config import ArchConfig
    from repro.serving import ServeEngine

    cfg = ArchConfig(
        name="spec-bench", family="dense", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=512, vocab=256,
        act="swiglu", attn_blockwise_min_seq=512,
    )
    # the draft shares only the vocab: 1 layer at a quarter of the target's
    # width, fitted separately — cheap enough that k drafts + one batched
    # verify beat k+1 sequential engine rounds once acceptance is high
    draft_cfg = cfg.replace(
        name="spec-bench-draft", n_layers=1, d_model=64,
        n_heads=2, n_kv_heads=1, d_ff=256,
    )
    # the draft must actually learn the rule or acceptance (and the whole
    # measurement) collapses, so the fit is NOT shortened in smoke mode —
    # only the served workload is
    fit_steps = 40
    params = _fit(cfg, fit_steps)
    draft_params = _fit(draft_cfg, fit_steps)

    # prompts follow the affine rule both models were fitted on
    # (x_{t+1} = (a·x_t + b) mod V, rule fixed by the dataset seed): with
    # rule-following prompts the draft's greedy continuations agree with
    # the target's, which is the regime speculative decoding assumes —
    # random-token prompts would measure ~0 acceptance by construction
    rule = np.random.default_rng(np.random.SeedSequence([0, 0xA11CE]))
    a = int(rule.integers(1, 8))
    b = int(rule.integers(0, cfg.vocab))

    def rule_prompt(x0: int, length: int) -> np.ndarray:
        seq = [x0 % cfg.vocab]
        for _ in range(length - 1):
            seq.append((a * seq[-1] + b) % cfg.vocab)
        return np.asarray(seq, np.int32)

    n_slots = 5
    gen = 32 if smoke else 64
    draft_k = 6
    warm_prompts = [
        rule_prompt(17 * i + 3, SPEC_PROMPT_LENS[i % len(SPEC_PROMPT_LENS)])
        for i in range(n_slots)
    ]
    meas_prompts = [
        rule_prompt(31 * i + 5, SPEC_PROMPT_LENS[i % len(SPEC_PROMPT_LENS)])
        for i in range(n_slots)
    ]

    def build(with_draft: bool):
        kw = dict(
            draft_cfg=draft_cfg, draft_params=draft_params, draft_k=draft_k
        ) if with_draft else {}
        return ServeEngine(
            cfg, params, n_slots=n_slots, max_seq=96, block_size=4,
            max_queue=64, **kw,
        )

    rows = {}
    for mode, with_draft in (("plain", False), ("spec", True)):
        with build(with_draft) as eng:
            for p in warm_prompts:
                eng.submit(p, gen, speculative=with_draft)
            eng.run_until_drained()
            warm_rounds = eng.stats()["spec"]["rounds"] if with_draft else 0
            reqs = [
                eng.submit(p, gen, speculative=with_draft)
                for p in meas_prompts
            ]
            t0 = time.perf_counter()
            eng.run_until_drained()
            elapsed = time.perf_counter() - t0
            n_tokens = sum(len(r.out_tokens) for r in reqs)
            res = {
                "output_checksum": hashlib.sha256(
                    repr([list(r.out_tokens) for r in reqs]).encode()
                ).hexdigest()[:16],
                "tokens": n_tokens,
                "elapsed_s": elapsed,
                "tokens_per_s": n_tokens / elapsed if elapsed > 0 else 0.0,
            }
            if with_draft:
                sp = eng.stats()["spec"]
                res.update(
                    accept_rate=sp["accept_rate"],
                    accepted_tokens_per_step=sp["accepted_per_round"],
                    rounds=sp["rounds"] - warm_rounds,
                    rollback_rounds=sp["rollback_rounds"],
                    sheds=sp["sheds"],
                    graph=sp["graph"],
                )
            rows[mode] = res

    assert rows["plain"]["output_checksum"] == rows["spec"]["output_checksum"], (
        "speculative decode diverged from plain greedy decode: "
        f"{rows['plain']['output_checksum']} != {rows['spec']['output_checksum']}"
    )
    speedup = (
        rows["spec"]["tokens_per_s"] / rows["plain"]["tokens_per_s"]
        if rows["plain"]["tokens_per_s"]
        else 0.0
    )
    return {
        "draft_k": draft_k,
        "fit_steps": fit_steps,
        "plain": rows["plain"],
        "spec": rows["spec"],
        "decode_speedup": speedup,
    }


def run_suite(smoke: bool = False) -> dict:
    from repro.serving import LoadSpec, build_workload
    from repro.serving.loadgen import run_load

    # offered load is deliberately above the drain-mode service rate, with
    # high-variance output lengths: the barrier then holds freed slots idle
    # until each generation's longest sequence finishes (tokens/s loss) and
    # queues late arrivals behind whole generations (TTFT loss) — exactly
    # the utilization continuous batching recovers
    spec = LoadSpec(
        seed=7,
        n_requests=12 if smoke else 32,
        rate_rps=400.0,
        prompt_lens=PROMPT_LENS,
        out_lens=(8, 16, 80),
        vocab=64,
        dup_frac=0.3,
    )
    workload = build_workload(spec)
    modes = []
    for mode in ("continuous", "drain"):
        with _build_engine() as eng:
            modes.append(run_load(eng, workload, mode=mode, spec=spec))
    cont, drain = modes
    return {
        "spec_decode": run_spec_suite(smoke=smoke),
        "spec": {
            "seed": spec.seed,
            "n_requests": spec.n_requests,
            "rate_rps": spec.rate_rps,
            "prompt_lens": list(spec.prompt_lens),
            "out_lens": list(spec.out_lens),
            "dup_frac": spec.dup_frac,
            "smoke": smoke,
        },
        "modes": modes,
        "comparison": {
            "throughput_ratio": (
                cont["tokens_per_s"] / drain["tokens_per_s"]
                if drain["tokens_per_s"]
                else 0.0
            ),
            "ttft_p99_ratio": (
                cont["ttft_p99_ms"] / drain["ttft_p99_ms"]
                if drain["ttft_p99_ms"]
                else 0.0
            ),
            "continuous_wins": (
                cont["tokens_per_s"] > drain["tokens_per_s"]
                and cont["ttft_p99_ms"] < drain["ttft_p99_ms"]
            ),
        },
    }


def compare_against_baseline(
    current: dict, baseline: dict, factor: float = 2.0
) -> list[str]:
    """CI gate: continuous-mode throughput must stay within ``factor``× of
    the checked-in baseline.  Returns human-readable failures (empty = pass)."""
    base_by_mode = {r["mode"]: r for r in baseline.get("modes", ())}
    failures = []
    for row in current.get("modes", ()):
        if row["mode"] != "continuous":
            continue
        base = base_by_mode.get(row["mode"])
        if base is None or not base.get("tokens_per_s"):
            continue
        if row["tokens_per_s"] < base["tokens_per_s"] / factor:
            failures.append(
                f"serving throughput regression ({row['mode']}): "
                f"{row['tokens_per_s']:.1f} tok/s vs baseline "
                f"{base['tokens_per_s']:.1f} tok/s (<1/{factor:.1f}x)"
            )
    # spec-decode gate: the speculative/plain speedup *ratio* must not
    # collapse vs baseline (the ratio cancels out container speed, so this
    # catches acceptance/commit-path regressions rather than slow hardware)
    cur_sd = current.get("spec_decode", {})
    base_sd = baseline.get("spec_decode", {})
    if cur_sd.get("decode_speedup") and base_sd.get("decode_speedup"):
        if cur_sd["decode_speedup"] < base_sd["decode_speedup"] / factor:
            failures.append(
                "spec-decode speedup regression: "
                f"{cur_sd['decode_speedup']:.2f}x vs baseline "
                f"{base_sd['decode_speedup']:.2f}x (<1/{factor:.1f}x)"
            )
    return failures


def main(out: str = "BENCH_serving.json", smoke: bool = False) -> dict:
    payload = run_suite(smoke=smoke)
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print("mode,tokens_per_s,ttft_p50_ms,ttft_p99_ms,itl_p50_ms,itl_p99_ms")
    for r in payload["modes"]:
        print(
            f"{r['mode']},{r['tokens_per_s']:.1f},{r['ttft_p50_ms']:.1f},"
            f"{r['ttft_p99_ms']:.1f},{r['itl_p50_ms']:.1f},{r['itl_p99_ms']:.1f}"
        )
    sd = payload["spec_decode"]
    print(
        f"spec_decode,k={sd['draft_k']},accept_rate={sd['spec']['accept_rate']:.2f},"
        f"accepted_tokens_per_step={sd['spec']['accepted_tokens_per_step']:.2f},"
        f"tokens_per_s={sd['spec']['tokens_per_s']:.1f} (plain "
        f"{sd['plain']['tokens_per_s']:.1f}),decode_speedup={sd['decode_speedup']:.2f}x"
    )
    return payload


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv)
